"""Empirical autotuner for the kernel layer (ERT-style).

Measures real per-device ceilings (:mod:`repro.tune.microbench`), sweeps
kernel block sizes against them (:mod:`repro.tune.sweep`), and persists
the winners in a JSON table (:mod:`repro.tune.table`) that the kernel ops
layers load at trace time — with a clean fallback to the hand-tuned
128x128-class defaults whenever no table (or no matching device kind /
shape bucket) is available. ``repro.launch.tune`` is the CLI front end.
"""

from repro.tune.microbench import (
    measure_ceilings,
    measure_mem_bandwidth,
    measure_peak_flops,
)
from repro.tune.sweep import build_tuning_table, sweep_op, tuned_vs_default_ratio
from repro.tune.table import (
    ENV_VAR,
    TuningTable,
    active_table,
    device_kind,
    load_table,
    lookup_blocks,
    measured_ceilings,
    reset,
    set_active_table,
    shape_bucket,
)

__all__ = [
    "ENV_VAR",
    "TuningTable",
    "active_table",
    "build_tuning_table",
    "device_kind",
    "load_table",
    "lookup_blocks",
    "measure_ceilings",
    "measure_mem_bandwidth",
    "measure_peak_flops",
    "measured_ceilings",
    "reset",
    "set_active_table",
    "shape_bucket",
    "sweep_op",
    "tuned_vs_default_ratio",
]
