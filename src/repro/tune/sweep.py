"""Block-size sweeps for the Pallas kernel layer.

For each op a representative workload per shape bucket is timed under a
small grid of candidate block configs (the hand-tuned default always
included). The winner is persisted to the tuning table — but only when it
beats the default by a margin (:data:`WIN_MARGIN`): measurement noise must
never displace a known-good default, which is what keeps the bench-CI
``tuned >= 0.95 x default`` floor structurally safe.

Every candidate is passed as *explicit* block arguments, so an already-
active table cannot steer the sweep that is about to replace it. Results
are bit-identity-checked against the default config before a candidate
may win — tuning may change speed, never results (the property the
``tests/test_tune.py`` suite pins independently).

Banded variants fix ``block_r`` at 128: the OMS host-side tile budget
(``plan_candidates``) prices windows in 128-row tiles and the serve layer
aligns shard bases to it (``_OMS_ALIGN``); sweeping it would silently
change scanned fractions. All other parameters are fair game.
"""

from __future__ import annotations

import itertools
import time

from repro.kernels.block_utils import DEFAULTS
from repro.tune.microbench import measure_ceilings
from repro.tune.table import TuningTable, device_kind

WIN_MARGIN = 0.03  # a candidate must be >=3% faster to displace the default

OPS = ("topk_hamming", "topk_hamming_banded", "encode_search",
       "encode_search_banded", "hd_encode", "imc_mvm")

# candidate grids: name -> values (the default is always added as a
# candidate even when absent from the grid)
_GRIDS_QUICK: dict[str, dict[str, tuple[int, ...]]] = {
    "topk_hamming": {"block_q": (32, 128), "block_r": (128, 256),
                     "word_chunk": (32,)},
    "topk_hamming_banded": {"block_q": (8, 32), "block_r": (128,),
                            "word_chunk": (32,)},
    "encode_search": {"block_q": (8, 32), "block_r": (128, 256),
                      "block_f": (128,), "word_chunk": (32,)},
    "encode_search_banded": {"block_q": (8, 32), "block_r": (128,),
                             "block_f": (128,), "word_chunk": (32,)},
    "hd_encode": {"block_b": (8, 32), "block_d": (128, 256),
                  "block_f": (128,)},
    "imc_mvm": {"block_q": (32, 128), "block_r": (128,),
                "tile_cols": (128,)},
}

_GRIDS_FULL: dict[str, dict[str, tuple[int, ...]]] = {
    "topk_hamming": {"block_q": (8, 32, 128), "block_r": (128, 256, 512),
                     "word_chunk": (8, 16, 32)},
    "topk_hamming_banded": {"block_q": (8, 16, 32), "block_r": (128,),
                            "word_chunk": (8, 16, 32)},
    "encode_search": {"block_q": (8, 16, 32), "block_r": (128, 256),
                      "block_f": (32, 128), "word_chunk": (16, 32)},
    "encode_search_banded": {"block_q": (8, 16, 32), "block_r": (128,),
                             "block_f": (32, 128), "word_chunk": (16, 32)},
    "hd_encode": {"block_b": (8, 16, 32), "block_d": (128, 256, 512),
                  "block_f": (32, 128)},
    "imc_mvm": {"block_q": (8, 32, 128), "block_r": (128, 256),
                "tile_cols": (128,)},
}


def _candidates(op: str, quick: bool) -> list[dict[str, int]]:
    grid = (_GRIDS_QUICK if quick else _GRIDS_FULL)[op]
    names = list(grid)
    cands = [dict(zip(names, vals))
             for vals in itertools.product(*(grid[n] for n in names))]
    default = dict(DEFAULTS[op])
    if default not in cands:
        cands.insert(0, default)
    return cands


def _median_us(call, iters: int, warmup: int = 1) -> float:
    import jax
    for _ in range(warmup):
        jax.block_until_ready(call())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(call())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def _workload(op: str, quick: bool):
    """(shape, run) for one op: ``shape`` is the table's bucketing tuple,
    ``run(blocks)`` executes the op under explicit block overrides and
    returns the result arrays (for the bit-identity check)."""
    import jax.numpy as jnp
    import numpy as np

    rng = np.random.default_rng(12)
    if quick:
        q_n, r_n, dim, k = 32, 1024, 1024, 8
        feats, levels_n = 64, 16
    else:
        q_n, r_n, dim, k = 128, 8192, 2048, 16
        feats, levels_n = 256, 32

    def bip(shape):
        return rng.choice([-1, 1], size=shape).astype(np.int8)

    if op in ("topk_hamming", "topk_hamming_banded"):
        from repro.core.hd.similarity import bitpack_bipolar
        from repro.kernels.topk_hamming import (
            topk_hamming_banded_pallas,
            topk_hamming_pallas,
        )
        q = bitpack_bipolar(jnp.asarray(bip((q_n, dim))))
        r = bitpack_bipolar(jnp.asarray(bip((r_n, dim))))
        if op == "topk_hamming":
            def run(blocks):
                return topk_hamming_pallas(q, r, dim=dim, k=k, **blocks)
            return (q_n, r_n, dim // 32), run
        width = max(r_n // 4, k)
        starts = jnp.asarray(
            rng.integers(0, r_n - width, size=q_n).astype(np.int32))
        lens = jnp.full((q_n,), width, jnp.int32)
        nt = -(-width // 128) + 1

        def run(blocks):
            return topk_hamming_banded_pallas(
                q, r, starts, lens, dim=dim, k=k, num_tiles=nt, **blocks)
        return (q_n, r_n, dim // 32), run

    if op in ("encode_search", "encode_search_banded"):
        from repro.core.hd.similarity import bitpack_bipolar
        from repro.kernels.encode_search import (
            encode_search_banded_pallas,
            encode_search_pallas,
        )
        lv = jnp.asarray(
            rng.integers(0, levels_n, size=(q_n, feats)).astype(np.int32))
        id_hvs = jnp.asarray(bip((feats, dim)))
        level_hvs = jnp.asarray(bip((levels_n, dim)))
        bank = bitpack_bipolar(jnp.asarray(bip((r_n, dim))))
        if op == "encode_search":
            def run(blocks):
                return encode_search_pallas(lv, id_hvs, level_hvs, bank,
                                            dim=dim, k=k, **blocks)
            return (q_n, r_n, feats), run
        width = max(r_n // 4, k)
        starts = jnp.asarray(
            rng.integers(0, r_n - width, size=q_n).astype(np.int32))
        lens = jnp.full((q_n,), width, jnp.int32)
        nt = -(-width // 128) + 1

        def run(blocks):
            return encode_search_banded_pallas(
                lv, id_hvs, level_hvs, bank, starts, lens, dim=dim, k=k,
                num_tiles=nt, **blocks)
        return (q_n, r_n, feats), run

    if op == "hd_encode":
        from repro.kernels.hd_encode import hd_encode_pallas
        lv = jnp.asarray(
            rng.integers(0, levels_n, size=(q_n, feats)).astype(np.int32))
        id_hvs = jnp.asarray(bip((feats, dim)))
        level_hvs = jnp.asarray(bip((levels_n, dim)))

        def run(blocks):
            return hd_encode_pallas(lv, id_hvs, level_hvs, **blocks)
        return (q_n, dim, feats), run

    if op == "imc_mvm":
        from repro.kernels.imc_mvm import imc_mvm_pallas
        dp = 128 if quick else 512
        qf = jnp.asarray(rng.standard_normal((q_n, dp)).astype(np.float32))
        wf = jnp.asarray(
            rng.standard_normal((min(r_n, 512), dp)).astype(np.float32))

        def run(blocks):
            return imc_mvm_pallas(qf, wf, full_scale=float(dp), **blocks)
        return (q_n, int(wf.shape[0]), dp), run

    raise ValueError(f"unknown op {op!r}")


def _same_result(a, b) -> bool:
    import jax
    import numpy as np
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


def sweep_op(op: str, *, quick: bool = True, iters: int = 3) -> dict:
    """Time every candidate config for ``op``'s representative workload.

    Returns ``{"shape", "blocks", "us", "default_us", "candidates"}`` —
    ``blocks`` is the default config unless a candidate was both
    bit-identical to it and at least :data:`WIN_MARGIN` faster.
    """
    shape, run = _workload(op, quick)
    default = dict(DEFAULTS[op])
    oracle = run(default)
    default_us = _median_us(lambda: run(default), iters)
    best, best_us = default, default_us
    report = []
    for cand in _candidates(op, quick):
        if cand == default:
            report.append({"blocks": cand, "us": default_us})
            continue
        out = run(cand)
        if not _same_result(oracle, out):  # pragma: no cover — safety net
            report.append({"blocks": cand, "us": None,
                           "rejected": "result mismatch vs default config"})
            continue
        us = _median_us(lambda: run(cand), iters)
        report.append({"blocks": cand, "us": us})
        if us < best_us and us < default_us * (1.0 - WIN_MARGIN):
            best, best_us = cand, us
    return {"shape": shape, "blocks": best, "us": best_us,
            "default_us": default_us, "candidates": report}


def build_tuning_table(out_path=None, *, quick: bool = True,
                       ops=None, iters: int = 3,
                       skip_ceilings: bool = False) -> TuningTable:
    """Measure ceilings, sweep every op, persist the winning configs.

    The returned table's entries carry the measured ``us``/``default_us``
    pair (bench-CI derives its tuned-vs-default floor from them) and each
    op's achieved fraction of the measured bandwidth ceiling.
    """
    ceilings = {} if skip_ceilings else measure_ceilings(quick=quick)
    table = TuningTable(device_kind=device_kind(), ceilings=ceilings,
                        meta={"quick": bool(quick),
                              "win_margin": WIN_MARGIN})
    for op in (ops or OPS):
        res = sweep_op(op, quick=quick, iters=iters)
        table.set_entry(op, res["shape"], res["blocks"],
                        us=res["us"], default_us=res["default_us"])
    if out_path is not None:
        table.save(out_path)
    return table


def tuned_vs_default_ratio(table: TuningTable) -> float:
    """min over table entries of (default qps / tuned qps)^-1 — i.e. the
    worst tuned-vs-default throughput ratio, >= 1.0 when every winner is
    at least as fast as the default it displaced (entries missing timing
    info are skipped)."""
    ratios = []
    for buckets in table.ops.values():
        for entry in buckets.values():
            us, dus = entry.get("us"), entry.get("default_us")
            if us and dus:
                ratios.append(dus / us)
    return min(ratios) if ratios else 1.0
