"""ERT-style device-ceiling microbenchmarks.

The Empirical Roofline Toolkit measures a machine's *achievable* ceilings
by sweeping working sets: peak compute from matmuls of growing size (small
ones are launch-bound, large ones saturate the FMA units), and memory
bandwidth from streaming copies of growing size (small ones live in cache,
large ones stream from DRAM/HBM). We take the max achieved rate across the
sweep as the ceiling — the same harness shape as the Berkeley ERT and the
Intel-Advisor roofline checks referenced in ROADMAP.

These numbers replace the hardcoded TPU-v5e constants in
``repro.launch.roofline`` whenever a tuning table measured on the local
device kind is active, so roofline verdicts (compute- vs memory- vs
ICI-bound) are priced for the machine actually running, not a v5e that
may not exist here.
"""

from __future__ import annotations

import time


def _median_time(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall seconds of ``fn(*args)`` with device sync."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def measure_peak_flops(sizes: tuple[int, ...] | None = None,
                       iters: int = 5) -> dict:
    """Peak achieved FLOP/s from a growing-matmul sweep.

    Square float32 matmuls of side n cost ``2 n^3`` FLOPs; the max rate
    across the sweep is the empirical compute ceiling. Returns
    ``{"peak_flops", "by_size": {n: flops_per_s}}``.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    sizes = sizes or (256, 512, 1024, 2048)
    f = jax.jit(lambda a, b: a @ b)
    rng = np.random.default_rng(0)
    by_size = {}
    for n in sizes:
        a = jnp.asarray(rng.standard_normal((n, n)).astype(np.float32))
        b = jnp.asarray(rng.standard_normal((n, n)).astype(np.float32))
        t = _median_time(f, a, b, iters=iters)
        by_size[int(n)] = 2.0 * n ** 3 / t
    return {"peak_flops": max(by_size.values()), "by_size": by_size}


def measure_mem_bandwidth(sizes_mb: tuple[float, ...] | None = None,
                          iters: int = 5) -> dict:
    """Peak achieved memory bandwidth from a growing-copy sweep.

    ``x + 1`` streams one read + one write per element (a pure copy can
    be aliased away by XLA); bytes moved per call = 2 x array bytes. The
    max GB/s across the sweep is the empirical bandwidth ceiling.
    Returns ``{"hbm_bw", "by_size_mb": {mb: bytes_per_s}}``.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    sizes_mb = sizes_mb or (4, 16, 64, 256)
    f = jax.jit(lambda x: x + 1.0)
    rng = np.random.default_rng(1)
    by_size = {}
    for mb in sizes_mb:
        n = int(mb * (1 << 20) // 4)
        x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
        t = _median_time(f, x, iters=iters)
        by_size[float(mb)] = 2.0 * n * 4 / t
    return {"hbm_bw": max(by_size.values()), "by_size_mb": by_size}


def measure_ceilings(quick: bool = False) -> dict:
    """Both sweeps -> the ``ceilings`` dict a :class:`~repro.tune.table.
    TuningTable` persists (``peak_flops`` / ``hbm_bw`` in SI units, plus
    the per-size curves for inspection). ``quick`` shrinks the sweep for
    CI — ceilings are then lower bounds, which is the safe direction for
    a roofline (terms look *more* expensive, never cheaper than real)."""
    sizes = (256, 512, 1024) if quick else (256, 512, 1024, 2048)
    mbs = (4.0, 16.0, 64.0) if quick else (4.0, 16.0, 64.0, 256.0)
    iters = 3 if quick else 5
    flops = measure_peak_flops(sizes, iters=iters)
    bw = measure_mem_bandwidth(mbs, iters=iters)
    return {
        "peak_flops": flops["peak_flops"],
        "hbm_bw": bw["hbm_bw"],
        "flops_by_size": {str(k): v for k, v in flops["by_size"].items()},
        "bw_by_size_mb": {str(k): v for k, v in bw["by_size_mb"].items()},
        "quick": bool(quick),
    }
