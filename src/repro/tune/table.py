"""Persisted tuning tables: measured ceilings + winning block configs.

A table is one JSON file produced by ``repro.launch.tune`` (the ERT-style
sweep in :mod:`repro.tune.sweep`):

.. code-block:: json

    {
      "schema": 1,
      "device_kind": "cpu",
      "ceilings": {"peak_flops": 1.1e11, "hbm_bw": 2.3e10, ...},
      "ops": {
        "topk_hamming": {
          "q128_r8192_w32": {
            "blocks": {"block_q": 32, "block_r": 256, "word_chunk": 32},
            "us": 412.0, "default_us": 508.0
          }
        }
      }
    }

Lookups are keyed by (device kind, op, shape bucket):

* **device kind** — the table records the ``jax.devices()[0].device_kind``
  it was measured on; a table written on one device kind is *never*
  silently applied on another (one-time log line, then defaults);
* **shape bucket** — each shape dimension rounds up to a power of two
  (:func:`shape_bucket`), so nearby problem sizes share one entry;
* a corrupt / partial / schema-mismatched JSON file degrades to "no
  table" with a one-time log line — it can never raise into the serving
  path — and individual entries whose blocks violate the kernel's tile
  alignment are dropped at load (``block_utils.block_aligned``).

The active table is chosen by the ``REPRO_TUNING_TABLE`` env var (a file
path) or programmatically via :func:`set_active_table`, and cached for
the process; :func:`reset` clears the cache (tests, table rewrites).
"""

from __future__ import annotations

import dataclasses
import json
import logging
import os
from pathlib import Path

SCHEMA = 1
ENV_VAR = "REPRO_TUNING_TABLE"

log = logging.getLogger("repro.tune")

# one-time-log bookkeeping: messages keyed by reason so each distinct
# fallback cause is reported exactly once per process
_logged: set[str] = set()


def _log_once(key: str, msg: str) -> None:
    if key not in _logged:
        _logged.add(key)
        log.warning(msg)


def device_kind() -> str:
    """The local accelerator kind the table is keyed by (e.g. ``cpu``,
    ``TPU v5e``)."""
    import jax
    return str(jax.devices()[0].device_kind)


def _pow2_ceil(n: int) -> int:
    n = max(int(n), 1)
    return 1 << (n - 1).bit_length()


def shape_bucket(shape: tuple[int, ...]) -> str:
    """Power-of-two bucket key for an op shape tuple, e.g. ``(100, 8000,
    32)`` -> ``"128x8192x32"`` — nearby problem sizes share one tuned
    entry, and the sweep only has to measure one representative per
    bucket."""
    return "x".join(str(_pow2_ceil(d)) for d in shape)


@dataclasses.dataclass
class TuningTable:
    """In-memory form of one persisted table (see module docstring)."""

    device_kind: str
    ceilings: dict = dataclasses.field(default_factory=dict)
    ops: dict = dataclasses.field(default_factory=dict)
    meta: dict = dataclasses.field(default_factory=dict)

    def lookup(self, op: str, shape: tuple[int, ...]) -> dict | None:
        """Winning blocks for (op, bucket of shape), or None."""
        entry = self.ops.get(op, {}).get(shape_bucket(shape))
        if not entry:
            return None
        return dict(entry.get("blocks") or {}) or None

    def set_entry(self, op: str, shape: tuple[int, ...], blocks: dict,
                  **extra) -> None:
        self.ops.setdefault(op, {})[shape_bucket(shape)] = {
            "blocks": dict(blocks), **extra}

    def to_json(self) -> dict:
        return {"schema": SCHEMA, "device_kind": self.device_kind,
                "ceilings": self.ceilings, "ops": self.ops,
                "meta": self.meta}

    def save(self, path: str | Path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_suffix(path.suffix + ".tmp")
        tmp.write_text(json.dumps(self.to_json(), indent=2) + "\n")
        tmp.replace(path)  # atomic: readers never see a partial table
        return path


def _sanitize_ops(ops: dict, source: str) -> dict:
    """Drop table entries whose blocks violate the kernel's tile
    alignment (or whose op is unknown) — a hand-edited or stale table
    degrades entry-by-entry instead of poisoning a trace."""
    from repro.kernels.block_utils import ALIGN, block_aligned
    clean: dict = {}
    for op, buckets in ops.items():
        if op not in ALIGN or not isinstance(buckets, dict):
            _log_once(f"op:{op}", f"tuning table {source}: unknown op "
                                  f"{op!r} ignored")
            continue
        for bucket, entry in buckets.items():
            blocks = (entry or {}).get("blocks")
            if not isinstance(blocks, dict) or not block_aligned(op, blocks):
                _log_once(
                    f"entry:{op}/{bucket}",
                    f"tuning table {source}: entry {op}/{bucket} has "
                    f"misaligned blocks {blocks!r}; entry dropped")
                continue
            clean.setdefault(op, {})[bucket] = entry
    return clean


def load_table(path: str | Path) -> TuningTable | None:
    """Parse a table file; corrupt/partial/unreadable -> None (one-time
    log line), never an exception."""
    path = Path(path)
    try:
        raw = json.loads(path.read_text())
        if not isinstance(raw, dict) or raw.get("schema") != SCHEMA \
                or not isinstance(raw.get("device_kind"), str):
            raise ValueError(f"not a schema-{SCHEMA} tuning table")
        return TuningTable(
            device_kind=raw["device_kind"],
            ceilings=dict(raw.get("ceilings") or {}),
            ops=_sanitize_ops(dict(raw.get("ops") or {}), path.name),
            meta=dict(raw.get("meta") or {}),
        )
    except (OSError, ValueError, TypeError, AttributeError) as e:
        _log_once(f"load:{path}", f"tuning table {path}: unreadable "
                                  f"({e}); falling back to default blocks")
        return None


# process-wide active-table cache: (resolved-or-None, cache key). The key
# records which env-var value the cache was built from so an env change
# between calls is picked up without an explicit reset().
_active: TuningTable | None = None
_active_key: object = None
_OVERRIDE = object()  # sentinel key marking a set_active_table() override


def set_active_table(table: TuningTable | str | Path | None) -> None:
    """Programmatically install (or clear, with None) the active table —
    used by tests and by the tune CLI right after writing a table."""
    global _active, _active_key
    if isinstance(table, (str, Path)):
        table = load_table(table)
    _active = table
    _active_key = _OVERRIDE if table is not None else None


def reset() -> None:
    """Drop the active-table cache and the one-time-log memory (tests)."""
    global _active, _active_key
    _active = None
    _active_key = None
    _logged.clear()


def active_table() -> TuningTable | None:
    """The table the ops layer consults, or None.

    Resolution order: a :func:`set_active_table` override, else the
    ``REPRO_TUNING_TABLE`` env var. A table recorded on a different
    device kind than the local one is rejected here (one-time log) — a
    config swept on a TPU must not steer CPU traces or vice versa.
    """
    global _active, _active_key
    if _active_key is _OVERRIDE:
        table = _active
    else:
        env = os.environ.get(ENV_VAR) or None
        if env != _active_key:
            _active = load_table(env) if env else None
            _active_key = env
        table = _active
    if table is None:
        return None
    local = device_kind()
    if table.device_kind != local:
        _log_once(
            f"kind:{table.device_kind}->{local}",
            f"tuning table was measured on device kind "
            f"{table.device_kind!r} but this process runs on {local!r}; "
            f"ignoring it (default blocks apply)")
        return None
    return table


def lookup_blocks(op: str, shape: tuple[int, ...]) -> dict | None:
    """Tuned blocks for (active table, op, shape bucket), or None."""
    table = active_table()
    if table is None:
        return None
    return table.lookup(op, shape)


def measured_ceilings() -> dict | None:
    """The active table's measured device ceilings (for the roofline
    profile), or None when no matching table is active."""
    table = active_table()
    if table is None or not table.ceilings:
        return None
    return dict(table.ceilings)
