"""Gemma-7B [arXiv:2403.08295; hf google/gemma-7b].

28 layers, d_model 3072, 16 heads with head_dim 256 (attention width 4096 >
d_model), full MHA (kv=16), GeGLU FFN with hidden 24576, vocab 256000,
tied embeddings."""

from repro.configs.base import ArchConfig, register


@register("gemma_7b")
def config() -> ArchConfig:
    return ArchConfig(
        name="gemma_7b",
        family="dense",
        num_layers=28,
        d_model=3072,
        num_heads=16,
        num_kv_heads=16,
        head_dim=256,
        d_ff=24_576,
        vocab_size=256_000,
        activation="geglu",
        norm="rmsnorm",
        tie_embeddings=True,
    )
