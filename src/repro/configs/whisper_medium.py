"""Whisper-medium [arXiv:2212.04356; unverified].

Encoder-decoder, d_model 1024, 16 heads (full MHA), d_ff 4096, vocab 51865.
The assignment's 24L maps to whisper-medium's 24 encoder + 24 decoder
layers. The conv audio frontend is a STUB: input_specs() provides
precomputed frame embeddings (post-conv). seq_len splits 50/50 between
encoder frames and decoder tokens (DESIGN.md §4)."""

from repro.configs.base import ArchConfig, register


@register("whisper_medium")
def config() -> ArchConfig:
    return ArchConfig(
        name="whisper_medium",
        family="audio",
        num_layers=24,            # decoder layers
        num_encoder_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=4096,
        vocab_size=51_865,
        is_encoder_decoder=True,
        activation="gelu",
        norm="layernorm",
    )
