"""Llama-4-Scout-17B-16E [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48 layers, d_model 5120, 40 heads (GQA kv=8), MoE 16 experts top-1 with a
shared expert, expert FFN width 8192."""

from repro.configs.base import ArchConfig, register


@register("llama4_scout_17b_a16e")
def config() -> ArchConfig:
    return ArchConfig(
        name="llama4_scout_17b_a16e",
        family="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        d_ff=8192,
        expert_d_ff=8192,
        vocab_size=202_048,
        num_experts=16,
        num_shared_experts=1,
        top_k=1,
        activation="swiglu",
        norm="rmsnorm",
        rope_theta=500_000.0,
    )
