from repro.configs.base import ARCH_IDS, ArchConfig, get_config, list_archs
from repro.configs.shapes import SHAPES, ShapeSpec, applicable

__all__ = ["ArchConfig", "get_config", "list_archs", "ARCH_IDS",
           "SHAPES", "ShapeSpec", "applicable"]
