"""Granite-20B-Code [arXiv:2405.04324; hf ibm-granite/granite-20b-code-base].

52 layers, d_model 6144, 48 heads with MQA (kv=1), d_ff 24576, vocab 49152,
llama-style blocks (gpt-bigcode lineage -> gelu MLP, layernorm)."""

from repro.configs.base import ArchConfig, register


@register("granite_20b")
def config() -> ArchConfig:
    return ArchConfig(
        name="granite_20b",
        family="dense",
        num_layers=52,
        d_model=6144,
        num_heads=48,
        num_kv_heads=1,
        d_ff=24_576,
        vocab_size=49_152,
        activation="gelu",
        norm="layernorm",
    )
