"""xLSTM-125M [arXiv:2405.04517; unverified].

12 layers, d_model 768, 4 heads, vocab 50304 (GPT-NeoX tokenizer padding).
d_ff=0: blocks are mLSTM (matrix-memory) with one sLSTM (scalar-memory)
block every 4 layers — the paper's xLSTM[7:1]-style mix. Recurrent state
makes decode O(1) per token (long_500k eligible)."""

from repro.configs.base import ArchConfig, register


@register("xlstm_125m")
def config() -> ArchConfig:
    return ArchConfig(
        name="xlstm_125m",
        family="ssm",
        num_layers=12,
        d_model=768,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,               # no separate FFN: mLSTM blocks have an
                              # up/down projection (factor 2) built in
        vocab_size=50_304,
        ssm_ratio=4,          # every 4th block is sLSTM
        activation="swiglu",
        norm="rmsnorm",
    )
