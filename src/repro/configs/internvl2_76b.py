"""InternVL2-76B [arXiv:2404.16821; unverified].

LM backbone (Llama-3-70B-style): 80 layers, d_model 8192, 64 heads (GQA
kv=8), d_ff 28672, vocab 128256. The InternViT-6B vision frontend is a STUB
per the assignment: input_specs() provides precomputed patch embeddings for
1/8 of the sequence; the backbone trains with loss on text positions."""

from repro.configs.base import ArchConfig, register


@register("internvl2_76b")
def config() -> ArchConfig:
    return ArchConfig(
        name="internvl2_76b",
        family="vlm",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=28_672,
        vocab_size=128_256,
        vision_frontend=True,
        vision_fraction=8,
        activation="swiglu",
        norm="rmsnorm",
        rope_theta=500_000.0,
    )
