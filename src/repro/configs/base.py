"""Architecture configuration system.

One ``ArchConfig`` describes any model family the framework supports
(dense / MoE / SSM / hybrid / enc-dec / VLM backbone). Every assigned
architecture gets a module in this package registering its exact published
config plus a ``reduced()`` smoke-test variant.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Callable

_REGISTRY: dict[str, Callable[[], "ArchConfig"]] = {}

ARCH_IDS = [
    "deepseek_moe_16b",
    "llama4_scout_17b_a16e",
    "xlstm_125m",
    "internvl2_76b",
    "gemma_7b",
    "granite_20b",
    "qwen2_7b",
    "granite_34b",
    "whisper_medium",
    "hymba_1_5b",
]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | vlm | audio | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 1024
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_ratio: int = 0           # xlstm: one sLSTM block every `ssm_ratio` layers
    # --- attention details ---
    qkv_bias: bool = False       # qwen2
    sliding_window: int = 0      # 0 = full attention
    rope_theta: float = 10000.0
    # --- activation / norm ---
    activation: str = "swiglu"   # swiglu | geglu | gelu
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    # --- structure ---
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    vision_frontend: bool = False
    vision_fraction: int = 8     # 1/8 of seq are patch embeddings (vlm)
    tie_embeddings: bool = False
    # --- numerics ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    kv_quant_int8: bool = False  # int8 KV store (SpecPCM MLC insight)
    # --- paper technique hook ---
    imc_linear: bool = False     # route FFN down-proj through the IMC-MVM model
    imc_mlc_bits: int = 3
    imc_adc_bits: int = 6

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_recurrent(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def supports_long_decode(self) -> bool:
        """Sub-quadratic / bounded-state decode (long_500k eligibility)."""
        return self.family in ("ssm", "hybrid")

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the vocab axis shards over
        any mesh axis up to 256 (whisper's 51865 -> 52224 etc.)."""
        return -(-self.vocab_size // 256) * 256

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "_reduced",
            num_layers=2,
            num_encoder_layers=2 if self.is_encoder_decoder else 0,
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            num_experts=min(self.num_experts, 8),
            num_shared_experts=min(self.num_shared_experts, 1),
            top_k=min(self.top_k, 2),
            expert_d_ff=64 if self.num_experts else 0,
            moe_group_size=32,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            dtype="float32",
        )


def register(arch_id: str):
    def deco(fn: Callable[[], ArchConfig]):
        _REGISTRY[arch_id] = fn
        return fn
    return deco


def get_config(arch_id: str) -> ArchConfig:
    arch_id = arch_id.replace("-", "_")
    if arch_id not in _REGISTRY:
        # lazy import of the arch module
        importlib.import_module(f"repro.configs.{arch_id}")
    return _REGISTRY[arch_id]()


def list_archs() -> list[str]:
    for a in ARCH_IDS:
        if a not in _REGISTRY:
            importlib.import_module(f"repro.configs.{a}")
    return sorted(_REGISTRY)
