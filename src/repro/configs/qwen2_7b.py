"""Qwen2-7B [arXiv:2407.10671; hf Qwen/Qwen2-7B].

28 layers, d_model 3584, 28 heads (GQA kv=4), d_ff 18944, vocab 152064,
QKV bias (the Qwen signature), SwiGLU + RMSNorm."""

from repro.configs.base import ArchConfig, register


@register("qwen2_7b")
def config() -> ArchConfig:
    return ArchConfig(
        name="qwen2_7b",
        family="dense",
        num_layers=28,
        d_model=3584,
        num_heads=28,
        num_kv_heads=4,
        d_ff=18_944,
        vocab_size=152_064,
        qkv_bias=True,
        activation="swiglu",
        norm="rmsnorm",
        rope_theta=1_000_000.0,
    )
