"""DeepSeekMoE-16B [arXiv:2401.06066; hf deepseek-ai/deepseek-moe-16b-base].

Fine-grained MoE: 64 routed experts (top-6) + 2 shared experts, expert FFN
width 1408 (= d_ff). 28 layers, d_model 2048, 16 heads (full MHA: kv=16)."""

from repro.configs.base import ArchConfig, register


@register("deepseek_moe_16b")
def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek_moe_16b",
        family="moe",
        num_layers=28,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1408,            # per-expert FFN width (fine-grained)
        expert_d_ff=1408,
        vocab_size=102_400,
        num_experts=64,
        num_shared_experts=2,
        top_k=6,
        activation="swiglu",
        norm="rmsnorm",
    )
