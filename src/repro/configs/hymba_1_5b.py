"""Hymba-1.5B [arXiv:2411.13676; hf nvidia/Hymba-1.5B-Base].

32 layers, d_model 1600, 25 heads with head_dim 64 (GQA kv=5), d_ff 5504,
vocab 32001, ssm_state 16. Hybrid-head blocks: attention heads and Mamba
(selective-SSM) heads run in PARALLEL on the same input and their outputs
are combined with learned per-path scales. Most attention is sliding-window
(2048) which, plus the SSM state, bounds decode memory (long_500k eligible)."""

from repro.configs.base import ArchConfig, register


@register("hymba_1_5b")
def config() -> ArchConfig:
    return ArchConfig(
        name="hymba_1_5b",
        family="hybrid",
        num_layers=32,
        d_model=1600,
        num_heads=25,
        num_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab_size=32_001,
        ssm_state=16,
        sliding_window=2048,
        activation="swiglu",
        norm="rmsnorm",
    )
