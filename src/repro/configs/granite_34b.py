"""Granite-34B-Code [arXiv:2405.04324; hf ibm-granite/granite-34b-code-base].

88 layers, d_model 6144, 48 heads MQA (kv=1), d_ff 24576, vocab 49152
(depth-upscaled granite-20b)."""

from repro.configs.base import ArchConfig, register


@register("granite_34b")
def config() -> ArchConfig:
    return ArchConfig(
        name="granite_34b",
        family="dense",
        num_layers=88,
        d_model=6144,
        num_heads=48,
        num_kv_heads=1,
        d_ff=24_576,
        vocab_size=49_152,
        activation="gelu",
        norm="layernorm",
    )
