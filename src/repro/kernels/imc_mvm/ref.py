"""Pure-jnp oracle for the IMC MVM kernel.

Models the SpecPCM analog chain exactly as `repro.core.imc.array`:
DAC-clamped query x noisy packed weights, per-128-column-tile partial sums,
flash-ADC clamp+quantize of each partial, digital accumulation of quantized
partials. The Pallas kernel must match this bit-for-bit in fp32.
"""

from __future__ import annotations

import jax.numpy as jnp


def imc_mvm_ref(
    queries: jnp.ndarray,   # (Q, Dp) float32 (already packed levels)
    weights: jnp.ndarray,   # (R, Dp) float32 (noisy conductance domain)
    *,
    tile_cols: int = 128,
    dac_limit: int = 3,
    adc_levels: int = 31,
    full_scale: float,
) -> jnp.ndarray:
    q = jnp.clip(jnp.round(queries.astype(jnp.float32)), -dac_limit, dac_limit)
    w = weights.astype(jnp.float32)
    Q, Dp = q.shape
    R = w.shape[0]
    pad = (-Dp) % tile_cols
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad)))
        w = jnp.pad(w, ((0, 0), (0, pad)))
        Dp += pad
    t = Dp // tile_cols
    qt = q.reshape(Q, t, tile_cols)
    wt = w.reshape(R, t, tile_cols)
    part = jnp.einsum("qtc,rtc->qrt", qt, wt, preferred_element_type=jnp.float32)
    lsb = full_scale / adc_levels
    code = jnp.clip(jnp.round(part / lsb), -adc_levels, adc_levels)
    return (code * lsb).sum(axis=-1)
