"""Public jit'd wrapper for the IMC MVM Pallas kernel.

Handles padding to MXU-aligned blocks, backend selection (interpret mode on
CPU), and defaulting the ADC full scale from the array config formula."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.block_utils import resolve_blocks
from repro.kernels.imc_mvm.imc_mvm import imc_mvm_pallas_call


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def imc_mvm_pallas(
    queries: jax.Array,
    weights: jax.Array,
    *,
    full_scale: float,
    block_q: int | None = None,
    block_r: int | None = None,
    tile_cols: int | None = None,
    dac_limit: int = 3,
    adc_levels: int = 31,
    interpret: bool | None = None,
) -> jax.Array:
    """(Q, Dp) x (R, Dp) -> (Q, R) through the modeled analog IMC chain.

    Arbitrary Q/R/Dp are zero-padded to block multiples; zero tiles quantize
    to zero codes so padding does not perturb results. Blocks resolve
    explicit -> tuning table -> defaults
    (:mod:`repro.kernels.block_utils`).
    """
    cfg = resolve_blocks(
        "imc_mvm", (queries.shape[0], weights.shape[0], queries.shape[1]),
        {"block_q": block_q, "block_r": block_r, "tile_cols": tile_cols})
    return _imc_mvm_jit(
        queries, weights, full_scale=full_scale, block_q=cfg["block_q"],
        block_r=cfg["block_r"], tile_cols=cfg["tile_cols"],
        dac_limit=dac_limit, adc_levels=adc_levels, interpret=interpret)


@partial(
    jax.jit,
    static_argnames=(
        "block_q", "block_r", "tile_cols", "dac_limit", "adc_levels",
        "full_scale", "interpret",
    ),
)
def _imc_mvm_jit(
    queries: jax.Array,
    weights: jax.Array,
    *,
    full_scale: float,
    block_q: int,
    block_r: int,
    tile_cols: int,
    dac_limit: int,
    adc_levels: int,
    interpret: bool | None,
) -> jax.Array:
    if interpret is None:
        interpret = _default_interpret()
    q = queries.astype(jnp.float32)
    w = weights.astype(jnp.float32)
    Q, Dp = q.shape
    R = w.shape[0]
    pq, pr, pd = (-Q) % block_q, (-R) % block_r, (-Dp) % tile_cols
    if pq or pd:
        q = jnp.pad(q, ((0, pq), (0, pd)))
    if pr or pd:
        w = jnp.pad(w, ((0, pr), (0, pd)))
    out = imc_mvm_pallas_call(
        q, w,
        block_q=block_q, block_r=block_r, tile_cols=tile_cols,
        dac_limit=dac_limit, adc_levels=adc_levels, full_scale=full_scale,
        interpret=interpret,
    )
    return out[:Q, :R]
