"""Public jit'd wrapper for the IMC MVM Pallas kernel.

Handles padding to MXU-aligned blocks, backend selection (interpret mode on
CPU), and defaulting the ADC full scale from the array config formula."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.imc_mvm.imc_mvm import imc_mvm_pallas_call


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(
    jax.jit,
    static_argnames=(
        "block_q", "block_r", "tile_cols", "dac_limit", "adc_levels",
        "full_scale", "interpret",
    ),
)
def imc_mvm_pallas(
    queries: jax.Array,
    weights: jax.Array,
    *,
    full_scale: float,
    block_q: int = 128,
    block_r: int = 128,
    tile_cols: int = 128,
    dac_limit: int = 3,
    adc_levels: int = 31,
    interpret: bool | None = None,
) -> jax.Array:
    """(Q, Dp) x (R, Dp) -> (Q, R) through the modeled analog IMC chain.

    Arbitrary Q/R/Dp are zero-padded to block multiples; zero tiles quantize
    to zero codes so padding does not perturb results.
    """
    if interpret is None:
        interpret = _default_interpret()
    q = queries.astype(jnp.float32)
    w = weights.astype(jnp.float32)
    Q, Dp = q.shape
    R = w.shape[0]
    pq, pr, pd = (-Q) % block_q, (-R) % block_r, (-Dp) % tile_cols
    if pq or pd:
        q = jnp.pad(q, ((0, pq), (0, pd)))
    if pr or pd:
        w = jnp.pad(w, ((0, pr), (0, pd)))
    out = imc_mvm_pallas_call(
        q, w,
        block_q=block_q, block_r=block_r, tile_cols=tile_cols,
        dac_limit=dac_limit, adc_levels=adc_levels, full_scale=full_scale,
        interpret=interpret,
    )
    return out[:Q, :R]
