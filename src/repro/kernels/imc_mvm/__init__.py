from repro.kernels.imc_mvm.ops import imc_mvm_pallas

__all__ = ["imc_mvm_pallas"]
