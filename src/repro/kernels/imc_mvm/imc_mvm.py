"""Pallas TPU kernel for the SpecPCM analog IMC MVM.

Hardware mapping (DESIGN.md §2): one 128x128 PCM array == one 128x128 MXU
tile. The kernel streams K in 128-wide tiles (one "array stripe" per tile),
computes the tile partial sum on the MXU, applies the flash-ADC transfer
function (clamp + uniform quantization) to the *partial* sum — the defining
non-ideality of the paper's dataflow — and accumulates quantized partials in
an fp32 VMEM scratch accumulator.

Grid: (Q/bq, R/br). Each program instance owns a (bq, br) output block and
loops over all K tiles, so weight blocks are read once per (q-block) pass —
the same reuse the physical array gets by keeping references resident.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _imc_mvm_kernel(
    q_ref, w_ref, o_ref, *,
    n_tiles: int,
    tile_cols: int,
    dac_limit: float,
    adc_levels: int,
    full_scale: float,
):
    bq = q_ref.shape[0]
    br = w_ref.shape[0]
    lsb = full_scale / adc_levels

    def tile_body(t, acc):
        qt = q_ref[:, pl.dslice(t * tile_cols, tile_cols)]
        wt = w_ref[:, pl.dslice(t * tile_cols, tile_cols)]
        # DAC: clamp+round the packed query levels
        qt = jnp.clip(jnp.round(qt), -dac_limit, dac_limit)
        # analog tile partial sum (MXU)
        part = jax.lax.dot_general(
            qt, wt,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        # flash ADC on the partial sum
        code = jnp.clip(jnp.round(part / lsb), -adc_levels, adc_levels)
        return acc + code * lsb

    acc = jnp.zeros((bq, br), jnp.float32)
    acc = jax.lax.fori_loop(0, n_tiles, tile_body, acc)
    o_ref[...] = acc


def imc_mvm_pallas_call(
    queries: jax.Array,   # (Q, Dp) float32, Dp % tile_cols == 0
    weights: jax.Array,   # (R, Dp) float32
    *,
    block_q: int = 128,
    block_r: int = 128,
    tile_cols: int = 128,
    dac_limit: int = 3,
    adc_levels: int = 31,
    full_scale: float,
    interpret: bool = False,
) -> jax.Array:
    Q, Dp = queries.shape
    R = weights.shape[0]
    assert Q % block_q == 0 and R % block_r == 0 and Dp % tile_cols == 0
    n_tiles = Dp // tile_cols

    kernel = functools.partial(
        _imc_mvm_kernel,
        n_tiles=n_tiles,
        tile_cols=tile_cols,
        dac_limit=float(dac_limit),
        adc_levels=adc_levels,
        full_scale=full_scale,
    )
    return pl.pallas_call(
        kernel,
        grid=(Q // block_q, R // block_r),
        in_specs=[
            pl.BlockSpec((block_q, Dp), lambda i, j: (i, 0)),
            pl.BlockSpec((block_r, Dp), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_r), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Q, R), jnp.float32),
        interpret=interpret,
    )(queries, weights)
