"""Shared block-size validation + trace-time tuned-config resolution.

Every kernel ops layer (``topk_hamming``, ``encode_search``, ``hd_encode``,
``imc_mvm``) resolves its block sizes through :func:`resolve_blocks`:

  1. an **explicit** caller argument wins — validated against the kernel's
     TPU tile-alignment constraints so a bad value raises a clear
     ``ValueError`` here instead of an opaque Mosaic lowering error three
     layers down;
  2. else the **active tuning table** (``repro.tune.table``, written by the
     ``repro.launch.tune`` sweep and selected via the ``REPRO_TUNING_TABLE``
     env var) is consulted for this (device kind, op, shape bucket);
  3. else the hand-tuned :data:`DEFAULTS` — today's 128x128-class tiles —
     apply unchanged.

Resolution happens at trace time (plain Python, before the jitted inner
call), so the chosen blocks become ordinary static arguments: a table swap
re-resolves on the next call and jit caches key on the concrete values.

Alignment rationale (see the Pallas guide's tiling table): the last block
dimension maps to the 128-wide lane axis and the second-to-last to 8
sublanes (float32/int32 tiles), so Q-like / sublane-side blocks must be
multiples of 8. R-like / lane-side blocks allow half-register 64s (the
ops layers pad the array up to the block, and the established API accepts
``block_r=64``); the full-tile dims (``block_d``, ``tile_cols``) that
feed MXU-shaped loads stay multiples of 128. ``word_chunk`` slices the
packed uint32 word axis inside the popcount loop and only needs to keep
whole 4-word groups (a 128-bit load) per step.
"""

from __future__ import annotations

# per-op alignment constraints: block name -> required multiple
ALIGN: dict[str, dict[str, int]] = {
    "topk_hamming": {"block_q": 8, "block_r": 64, "word_chunk": 4},
    "topk_hamming_banded": {"block_q": 8, "block_r": 64, "word_chunk": 4},
    "encode_search": {"block_q": 8, "block_r": 64, "block_f": 8,
                      "word_chunk": 4},
    "encode_search_banded": {"block_q": 8, "block_r": 64, "block_f": 8,
                             "word_chunk": 4},
    "hd_encode": {"block_b": 8, "block_d": 128, "block_f": 8},
    "imc_mvm": {"block_q": 8, "block_r": 64, "tile_cols": 128},
}

# the pre-autotuner hand-picked blocks — the fallback when no table entry
# exists, and the baseline every sweep candidate must beat to displace
DEFAULTS: dict[str, dict[str, int]] = {
    "topk_hamming": {"block_q": 128, "block_r": 128, "word_chunk": 32},
    "topk_hamming_banded": {"block_q": 128, "block_r": 128, "word_chunk": 32},
    "encode_search": {"block_q": 8, "block_r": 128, "block_f": 128,
                      "word_chunk": 32},
    "encode_search_banded": {"block_q": 8, "block_r": 128, "block_f": 128,
                             "word_chunk": 32},
    "hd_encode": {"block_b": 8, "block_d": 256, "block_f": 128},
    "imc_mvm": {"block_q": 128, "block_r": 128, "tile_cols": 128},
}


def validate_block(op: str, name: str, value) -> int:
    """Return ``value`` if it satisfies ``op``'s alignment for ``name``,
    else raise a ``ValueError`` naming the constraint."""
    mult = ALIGN[op][name]
    if not isinstance(value, int) or isinstance(value, bool) \
            or value < mult or value % mult:
        raise ValueError(
            f"{op}: {name}={value!r} must be a positive multiple of {mult} "
            f"(TPU tile alignment — Mosaic cannot lower misaligned blocks)")
    return value


def block_aligned(op: str, cfg: dict) -> bool:
    """True when every entry of ``cfg`` is a valid block for ``op`` —
    the tuning-table sanitizer (invalid persisted entries are *dropped*,
    never raised, so a stale table degrades to defaults)."""
    try:
        for name, value in cfg.items():
            if name not in ALIGN[op]:
                return False
            validate_block(op, name, value)
    except (ValueError, KeyError):
        return False
    return True


def resolve_blocks(op: str, shape: tuple[int, ...],
                   overrides: dict) -> dict[str, int]:
    """Final block config for one kernel call.

    shape: the op's bucketing shape (e.g. ``(Q, R, W)``) — only used to
      pick the tuning-table bucket.
    overrides: caller kwargs, ``None`` meaning "not specified". Explicit
      values are validated here (clear error at the API boundary); table
      values were sanitized at load, and defaults are aligned by
      construction.
    """
    cfg = dict(DEFAULTS[op])
    # deferred so the kernel packages stay importable without repro.tune
    # (and without forcing a table load on cold import)
    from repro.tune.table import lookup_blocks
    tuned = lookup_blocks(op, shape)
    if tuned:
        for name, value in tuned.items():
            if name in cfg:
                cfg[name] = value
    for name, value in overrides.items():
        if value is not None:
            cfg[name] = validate_block(op, name, value)
    return cfg
