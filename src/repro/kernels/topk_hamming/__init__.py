from repro.kernels.topk_hamming.ops import topk_hamming_pallas

__all__ = ["topk_hamming_pallas"]
