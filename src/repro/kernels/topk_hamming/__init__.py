from repro.kernels.topk_hamming.ops import (
    canonicalize_overflow_slots,
    topk_hamming_banded_pallas,
    topk_hamming_pallas,
)

__all__ = [
    "canonicalize_overflow_slots",
    "topk_hamming_banded_pallas",
    "topk_hamming_pallas",
]
