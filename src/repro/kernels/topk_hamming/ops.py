"""Public jit'd wrapper for the fused streaming top-k Hamming kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.topk_hamming.topk_hamming import topk_hamming_pallas_call


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


@partial(jax.jit, static_argnames=("dim", "k", "block_q", "block_r",
                                   "word_chunk", "interpret"))
def topk_hamming_pallas(
    q: jax.Array,
    r: jax.Array,
    *,
    dim: int,
    k: int,
    num_valid: jax.Array | int | None = None,
    block_q: int = 128,
    block_r: int = 128,
    word_chunk: int = 32,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused top-k search: (Q, W|D) x (R, W|D) -> (idx (Q, k), vals (Q, k)).

    uint32 inputs are bit-packed HVs scored by XOR+popcount on the bipolar
    dot-product scale (``dim - 2 * popcount``); int8 inputs score by a
    plain integer dot (the ``D % 32 != 0`` fallback). Bit-identical to
    ``lax.top_k`` over the full score matrix — tie order included — but
    the (Q, R) matrix stays in VMEM tiles and only (Q, k) reaches HBM.

    num_valid: reference rows at or past this count score as a sentinel
      below any real score (the shard-padding mask of
      ``repro.serve.db_search._local_topk``); may be a traced scalar.
      Defaults to all R rows.

    Zero row/word padding is harmless: padded reference rows fall outside
    ``num_valid`` and padded words XOR to zero on both sides.
    """
    if interpret is None:
        interpret = _default_interpret()
    if q.ndim != 2 or r.ndim != 2 or q.shape[1] != r.shape[1]:
        raise ValueError(f"bad operand shapes {q.shape} x {r.shape}")
    if q.dtype != r.dtype:
        raise ValueError(f"dtype mismatch {q.dtype} vs {r.dtype}")
    packed = q.dtype == jnp.uint32
    if not packed and q.dtype != jnp.int8:
        raise ValueError(f"expected uint32 (packed) or int8, got {q.dtype}")
    Q, W = q.shape
    R = r.shape[0]
    if not 1 <= k <= R:
        raise ValueError(f"k={k} must be in [1, {R}]")

    # shrink blocks to the (aligned) problem so tiny searches don't pay
    # full 128x128 tiles in interpret mode
    bq = min(block_q, _round_up(Q, 8))
    br = min(block_r, _round_up(R, 128))
    lane = word_chunk if packed else 128
    pq, pr, pw = (-Q) % bq, (-R) % br, (-W) % lane
    if pq or pw:
        q = jnp.pad(q, ((0, pq), (0, pw)))
    if pr or pw:
        r = jnp.pad(r, ((0, pr), (0, pw)))

    nv = R if num_valid is None else num_valid
    nv = jnp.minimum(jnp.asarray(nv, jnp.int32).reshape(1), R)
    vals, idx = topk_hamming_pallas_call(
        q, r, nv, dim=dim, k=k, block_q=bq, block_r=br,
        word_chunk=word_chunk, interpret=interpret)
    return idx[:Q], vals[:Q]
