"""Public jit'd wrappers for the fused streaming top-k Hamming kernels."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.block_utils import resolve_blocks
from repro.kernels.topk_hamming.topk_hamming import (
    topk_hamming_banded_pallas_call,
    topk_hamming_pallas_call,
)

_SENTINEL = jnp.iinfo(jnp.int32).min


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def topk_hamming_pallas(
    q: jax.Array,
    r: jax.Array,
    *,
    dim: int,
    k: int,
    num_valid: jax.Array | int | None = None,
    block_q: int | None = None,
    block_r: int | None = None,
    word_chunk: int | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused top-k search: (Q, W|D) x (R, W|D) -> (idx (Q, k), vals (Q, k)).

    uint32 inputs are bit-packed HVs scored by XOR+popcount on the bipolar
    dot-product scale (``dim - 2 * popcount``); int8 inputs score by a
    plain integer dot (the ``D % 32 != 0`` fallback). Bit-identical to
    ``lax.top_k`` over the full score matrix — tie order included — but
    the (Q, R) matrix stays in VMEM tiles and only (Q, k) reaches HBM.

    num_valid: reference rows at or past this count score as a sentinel
      below any real score (the shard-padding mask of
      ``repro.serve.db_search._local_topk``); may be a traced scalar.
      Defaults to all R rows.

    block_q/block_r/word_chunk: explicit tile sizes (validated for TPU
      alignment); ``None`` resolves through the active tuning table for
      this (device kind, shape bucket), else the 128x128 defaults — see
      :mod:`repro.kernels.block_utils`.

    Zero row/word padding is harmless: padded reference rows fall outside
    ``num_valid`` and padded words XOR to zero on both sides.
    """
    cfg = resolve_blocks(
        "topk_hamming", (q.shape[0], r.shape[0], q.shape[1]),
        {"block_q": block_q, "block_r": block_r, "word_chunk": word_chunk})
    return _topk_hamming_jit(
        q, r, dim=dim, k=k, num_valid=num_valid, block_q=cfg["block_q"],
        block_r=cfg["block_r"], word_chunk=cfg["word_chunk"],
        interpret=interpret)


@partial(jax.jit, static_argnames=("dim", "k", "block_q", "block_r",
                                   "word_chunk", "interpret"))
def _topk_hamming_jit(
    q: jax.Array,
    r: jax.Array,
    *,
    dim: int,
    k: int,
    num_valid: jax.Array | int | None,
    block_q: int,
    block_r: int,
    word_chunk: int,
    interpret: bool | None,
) -> tuple[jax.Array, jax.Array]:
    if interpret is None:
        interpret = _default_interpret()
    if q.ndim != 2 or r.ndim != 2 or q.shape[1] != r.shape[1]:
        raise ValueError(f"bad operand shapes {q.shape} x {r.shape}")
    if q.dtype != r.dtype:
        raise ValueError(f"dtype mismatch {q.dtype} vs {r.dtype}")
    packed = q.dtype == jnp.uint32
    if not packed and q.dtype != jnp.int8:
        raise ValueError(f"expected uint32 (packed) or int8, got {q.dtype}")
    Q, W = q.shape
    R = r.shape[0]
    if not 1 <= k <= R:
        raise ValueError(f"k={k} must be in [1, {R}]")

    # shrink blocks to the (aligned) problem so tiny searches don't pay
    # full 128x128 tiles in interpret mode
    bq = min(block_q, _round_up(Q, 8))
    br = min(block_r, _round_up(R, 128))
    lane = word_chunk if packed else 128
    pq, pr, pw = (-Q) % bq, (-R) % br, (-W) % lane
    if pq or pw:
        q = jnp.pad(q, ((0, pq), (0, pw)))
    if pr or pw:
        r = jnp.pad(r, ((0, pr), (0, pw)))

    nv = R if num_valid is None else num_valid
    nv = jnp.minimum(jnp.asarray(nv, jnp.int32).reshape(1), R)
    vals, idx = topk_hamming_pallas_call(
        q, r, nv, dim=dim, k=k, block_q=bq, block_r=br,
        word_chunk=word_chunk, interpret=interpret)
    return idx[:Q], vals[:Q]


def canonicalize_overflow_slots(idx: jax.Array, vals: jax.Array,
                                starts: jax.Array, ends: jax.Array,
                                num_rows: int | jax.Array) -> jax.Array:
    """Rewrite sentinel-valued top-k slots to the oracle's overflow indices.

    ``lax.top_k`` over a banded-masked score matrix fills slots past the
    band's width with the lowest-index *masked* columns (ties at the
    sentinel break by ascending index). The banded kernel never visits most
    masked columns, so its overflow slots carry arbitrary filler indices;
    this rewrites them to the m-th smallest row outside the bands — making
    banded results bit-identical to the masked full matrix, overflow slots
    included.

    starts/ends: (B, Q) ascending disjoint bands per query (clipped to
    ``num_rows``). Returns idx with sentinel slots canonicalized.
    """
    if starts.ndim == 1:
        starts = starts[None, :]
        ends = ends[None, :]
    sentinel = vals == _SENTINEL
    n_real = jnp.sum(~sentinel, axis=1, keepdims=True)
    k = idx.shape[1]
    m = jnp.arange(k, dtype=jnp.int32)[None, :] - n_real  # rank among masked
    # masked rows form B+1 runs: [0, s_0), [e_0, s_1), ..., [e_{B-1}, rows)
    num_bands = starts.shape[0]
    run_start = [jnp.zeros_like(starts[0])]
    run_len = []
    for b in range(num_bands):
        run_len.append(starts[b] - run_start[-1])
        run_start.append(ends[b])
    rows = jnp.asarray(num_rows, jnp.int32)
    run_len.append(rows - run_start[-1])
    col = jnp.zeros_like(m)
    cum = jnp.zeros_like(starts[0])
    done = jnp.zeros(m.shape, bool)
    for rs, rl in zip(run_start, run_len):
        in_run = ~done & (m < (cum + rl)[:, None])
        col = jnp.where(in_run, rs[:, None] + (m - cum[:, None]), col)
        done = done | in_run
        cum = cum + rl
    return jnp.where(sentinel, col, idx)


def topk_hamming_banded_pallas(
    q: jax.Array,
    r: jax.Array,
    starts: jax.Array,
    lens: jax.Array,
    *,
    dim: int,
    k: int,
    num_valid: jax.Array | int | None = None,
    num_tiles: int | None = None,
    block_q: int | None = None,
    block_r: int | None = None,
    word_chunk: int | None = None,
    interpret: bool | None = None,
    canonicalize: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Banded fused top-k: each query scores only reference rows in its own
    ``[starts[q], starts[q] + lens[q])`` band (an OMS precursor window over
    a precursor-sorted bank).

    Blocks resolve like :func:`topk_hamming_pallas` (explicit -> tuning
    table -> defaults), under the op key ``topk_hamming_banded``.

    Bit-identical to sentinel-masking the full (Q, R) score matrix outside
    the band (and at or past ``num_valid``) and running ``lax.top_k`` — tie
    order and, with ``canonicalize=True``, overflow slots included — but
    only ``num_tiles`` R tiles per Q block are fetched and scored.

    num_tiles: static per-Q-block tile budget. Every query's (clipped) band
      in a Q block must fit in ``num_tiles * block_r`` rows starting at the
      block's lowest band start — callers compute it host-side from the
      batch's windows (``repro.serve.oms.plan_candidates``). ``None`` scans
      the full bank (always correct, no work saved).
    canonicalize: rewrite sentinel overflow slots (band narrower than k) to
      the oracle's ascending masked indices. Per-shard callers that merge
      and canonicalize globally switch this off.
    """
    cfg = resolve_blocks(
        "topk_hamming_banded", (q.shape[0], r.shape[0], q.shape[1]),
        {"block_q": block_q, "block_r": block_r, "word_chunk": word_chunk})
    return _topk_hamming_banded_jit(
        q, r, starts, lens, dim=dim, k=k, num_valid=num_valid,
        num_tiles=num_tiles, block_q=cfg["block_q"], block_r=cfg["block_r"],
        word_chunk=cfg["word_chunk"], interpret=interpret,
        canonicalize=canonicalize)


@partial(jax.jit, static_argnames=("dim", "k", "num_tiles", "block_q",
                                   "block_r", "word_chunk", "interpret",
                                   "canonicalize"))
def _topk_hamming_banded_jit(
    q: jax.Array,
    r: jax.Array,
    starts: jax.Array,
    lens: jax.Array,
    *,
    dim: int,
    k: int,
    num_valid: jax.Array | int | None,
    num_tiles: int | None,
    block_q: int,
    block_r: int,
    word_chunk: int,
    interpret: bool | None,
    canonicalize: bool,
) -> tuple[jax.Array, jax.Array]:
    if interpret is None:
        interpret = _default_interpret()
    if q.ndim != 2 or r.ndim != 2 or q.shape[1] != r.shape[1]:
        raise ValueError(f"bad operand shapes {q.shape} x {r.shape}")
    if q.dtype != r.dtype:
        raise ValueError(f"dtype mismatch {q.dtype} vs {r.dtype}")
    packed = q.dtype == jnp.uint32
    if not packed and q.dtype != jnp.int8:
        raise ValueError(f"expected uint32 (packed) or int8, got {q.dtype}")
    Q, W = q.shape
    R = r.shape[0]
    if not 1 <= k <= R:
        raise ValueError(f"k={k} must be in [1, {R}]")
    if starts.shape != (Q,) or lens.shape != (Q,):
        raise ValueError(
            f"starts/lens must be ({Q},), got {starts.shape}/{lens.shape}")

    bq = min(block_q, _round_up(Q, 8))
    br = min(block_r, _round_up(R, 128))
    lane = word_chunk if packed else 128
    pq, pr, pw = (-Q) % bq, (-R) % br, (-W) % lane
    if pq or pw:
        q = jnp.pad(q, ((0, pq), (0, pw)))
    if pr or pw:
        r = jnp.pad(r, ((0, pr), (0, pw)))

    nv = R if num_valid is None else num_valid
    nv = jnp.minimum(jnp.asarray(nv, jnp.int32), R)
    s = jnp.clip(starts.astype(jnp.int32), 0, nv)
    e = jnp.clip(starts.astype(jnp.int32) + lens.astype(jnp.int32), s, nv)
    # edge-pad so padded queries inherit a real band and don't widen the
    # per-block tile span
    if pq:
        s = jnp.pad(s, (0, pq), mode="edge")
        e = jnp.pad(e, (0, pq), mode="edge")

    total_tiles = (R + pr) // br
    nt = total_tiles if num_tiles is None else min(num_tiles, total_tiles)
    tb = jnp.min(s.reshape(-1, bq) // br, axis=1)
    tb = jnp.clip(tb, 0, total_tiles - nt).astype(jnp.int32)

    vals, idx = topk_hamming_banded_pallas_call(
        q, r, tb, s[:, None], e[:, None], dim=dim, k=k, num_tiles=nt,
        block_q=bq, block_r=br, word_chunk=word_chunk, interpret=interpret)
    idx, vals = idx[:Q], vals[:Q]
    if canonicalize:
        idx = canonicalize_overflow_slots(idx, vals, s[:Q], e[:Q], R)
    return idx, vals
