"""Pure-jnp oracle for the fused streaming top-k Hamming search.

Materializes the full (Q, R) score matrix and runs ``lax.top_k`` over it —
exactly what the fused kernel avoids, which is what makes it the
bit-identity oracle (indices, scores, and tie order included).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_SENTINEL = jnp.iinfo(jnp.int32).min


def topk_hamming_ref(q: jnp.ndarray, r: jnp.ndarray, dim: int, k: int,
                     num_valid: int | jnp.ndarray | None = None
                     ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(Q, W|D) x (R, W|D) -> (idx (Q, k), vals (Q, k)) int32.

    uint32 inputs score as ``dim - 2 * popcount(q ^ r)`` (the bipolar
    dot-product scale); int8 inputs as a plain integer dot. Rows at or
    past ``num_valid`` are masked below any real score before the top-k.
    """
    if q.dtype == jnp.uint32:
        x = q[:, None, :] ^ r[None, :, :]
        dist = jax.lax.population_count(x).astype(jnp.int32).sum(axis=-1)
        scores = dim - 2 * dist
    else:
        scores = jnp.einsum("qd,rd->qr", q.astype(jnp.int32),
                            r.astype(jnp.int32),
                            preferred_element_type=jnp.int32)
    if num_valid is not None:
        col = jnp.arange(r.shape[0], dtype=jnp.int32)
        scores = jnp.where(col[None, :] < num_valid, scores, _SENTINEL)
    vals, idx = jax.lax.top_k(scores, k)
    return idx.astype(jnp.int32), vals


def topk_hamming_banded_ref(q: jnp.ndarray, r: jnp.ndarray,
                            starts: jnp.ndarray, lens: jnp.ndarray,
                            dim: int, k: int,
                            num_valid: int | jnp.ndarray | None = None
                            ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Masked-full-matrix oracle for the banded kernel: columns outside each
    query's ``[starts[q], starts[q] + lens[q])`` band (or at/past
    ``num_valid``) mask to the sentinel before ``lax.top_k``."""
    if q.dtype == jnp.uint32:
        x = q[:, None, :] ^ r[None, :, :]
        dist = jax.lax.population_count(x).astype(jnp.int32).sum(axis=-1)
        scores = dim - 2 * dist
    else:
        scores = jnp.einsum("qd,rd->qr", q.astype(jnp.int32),
                            r.astype(jnp.int32),
                            preferred_element_type=jnp.int32)
    col = jnp.arange(r.shape[0], dtype=jnp.int32)[None, :]
    band = (col >= starts[:, None]) & (col < (starts + lens)[:, None])
    if num_valid is not None:
        band = band & (col < num_valid)
    scores = jnp.where(band, scores, _SENTINEL)
    vals, idx = jax.lax.top_k(scores, k)
    return idx.astype(jnp.int32), vals
