"""Pallas TPU kernel: fused streaming top-k Hamming search (SpecPCM §III.C).

SpecPCM's DB search never materializes a full score matrix: the PCM array
emits per-row similarities and a near-memory unit keeps only the running
best matches. This kernel is the TPU equivalent of that dataflow. The
bit-packed reference bank is tiled over a ``(Q-block, R-block)`` grid with
the R dimension innermost; each tile computes XOR+popcount similarities in
VMEM (the ``hamming_pop`` inner loop) and folds them into a running
per-query top-k (values + row indices) held in VMEM scratch across the R
steps. Only the ``(Q, k)`` result ever reaches HBM — per-query traffic is
O(k) instead of the O(R) score row the unfused path writes and re-reads.

**Tie-breaking.** ``lax.top_k`` orders ties by ascending index. The merge
selects one output slot at a time as (max value, then min row index) over
the union of the scratch and the current tile. Candidate row indices are
distinct by construction — scratch holds rows from earlier (lower-index)
tiles plus out-of-range initials ``>= R_padded`` — so the selection is
well-defined and reproduces the oracle bit-exactly, sentinel-masked
padding rows included.

Two score variants share the merge: uint32 inputs take the packed
XOR+popcount path (scores on the bipolar dot-product scale,
``dim - 2 * popcount``); int8 inputs take a plain integer dot — the
fallback when ``D % 32 != 0`` and bit-packing is unavailable.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_SENTINEL = jnp.iinfo(jnp.int32).min
_BIG = jnp.iinfo(jnp.int32).max


def _select_topk(vals: jax.Array, idx: jax.Array, k: int
                 ) -> tuple[jax.Array, jax.Array]:
    """Top-k of (vals, idx) candidates, ordered (value desc, index asc).

    One slot per step: the max value, ties broken toward the minimum row
    index. Requires all candidate indices in a row to be distinct (true
    for scratch ∪ tile, see module docstring), so the selected entry is
    unique and can be retired from ``avail`` by its index.
    """
    avail = jnp.ones(vals.shape, dtype=jnp.bool_)
    out_v, out_i = [], []
    for _ in range(k):
        m = jnp.max(jnp.where(avail, vals, _SENTINEL), axis=1, keepdims=True)
        cand = avail & (vals == m)
        sel = jnp.min(jnp.where(cand, idx, _BIG), axis=1, keepdims=True)
        avail = avail & ~(cand & (idx == sel))
        out_v.append(m)
        out_i.append(sel)
    return jnp.concatenate(out_v, axis=1), jnp.concatenate(out_i, axis=1)


def _tile_scores(q_ref, r_ref, *, dim: int, word_chunk: int, packed: bool
                 ) -> jax.Array:
    """(bq, br) int32 similarity tile: XOR+popcount on the bipolar dot scale
    for packed uint32 inputs, a plain integer dot for int8."""
    bq = q_ref.shape[0]
    br = r_ref.shape[0]
    if packed:
        n_words = q_ref.shape[1]

        def body(c, acc):
            w0 = c * word_chunk
            qc = q_ref[:, pl.dslice(w0, word_chunk)]   # (bq, wc) uint32
            rc = r_ref[:, pl.dslice(w0, word_chunk)]   # (br, wc)
            x = qc[:, None, :] ^ rc[None, :, :]        # (bq, br, wc)
            return acc + jax.lax.population_count(x).astype(jnp.int32).sum(-1)

        acc = jax.lax.fori_loop(0, n_words // word_chunk, body,
                                jnp.zeros((bq, br), jnp.int32))
        return dim - 2 * acc  # <q, r> for bipolar HVs, exactly
    return jax.lax.dot_general(
        q_ref[...], r_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)


def _topk_kernel(nv_ref, q_ref, r_ref, ovals_ref, oidx_ref,
                 svals_ref, sidx_ref, *, dim: int, k: int, block_r: int,
                 word_chunk: int, packed: bool, r_padded: int):
    j = pl.program_id(1)
    bq = q_ref.shape[0]
    br = r_ref.shape[0]

    # first R step of this Q block: reset the running top-k. Initial
    # entries sit at SENTINEL with distinct indices past every real or
    # padded row, so any tile column (masked ones included) beats them.
    @pl.when(j == 0)
    def _():
        svals_ref[...] = jnp.full((bq, k), _SENTINEL, jnp.int32)
        sidx_ref[...] = r_padded + jax.lax.broadcasted_iota(
            jnp.int32, (bq, k), 1)

    scores = _tile_scores(q_ref, r_ref, dim=dim, word_chunk=word_chunk,
                          packed=packed)

    col = j * block_r + jax.lax.broadcasted_iota(jnp.int32, (bq, br), 1)
    scores = jnp.where(col < nv_ref[0], scores, _SENTINEL)
    svals, sidx = _select_topk(
        jnp.concatenate([svals_ref[...], scores], axis=1),
        jnp.concatenate([sidx_ref[...], col], axis=1), k)
    svals_ref[...] = svals
    sidx_ref[...] = sidx

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        ovals_ref[...] = svals
        oidx_ref[...] = sidx


def topk_hamming_pallas_call(
    q: jax.Array,          # (Q, W) uint32 packed, or (Q, D) int8
    r: jax.Array,          # (R, W) uint32 packed, or (R, D) int8
    num_valid: jax.Array,  # (1,) int32: rows >= num_valid mask to SENTINEL
    *,
    dim: int,
    k: int,
    block_q: int = 128,
    block_r: int = 128,
    word_chunk: int = 32,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (vals (Q, k), idx (Q, k)) — the streaming top-k, never
    materializing the (Q, R) score matrix."""
    Q, W = q.shape
    R = r.shape[0]
    packed = q.dtype == jnp.uint32
    assert Q % block_q == 0 and R % block_r == 0
    assert not packed or W % word_chunk == 0

    kernel = functools.partial(
        _topk_kernel, dim=dim, k=k, block_r=block_r, word_chunk=word_chunk,
        packed=packed, r_padded=R)
    return pl.pallas_call(
        kernel,
        grid=(Q // block_q, R // block_r),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,), memory_space=pltpu.SMEM),
            pl.BlockSpec((block_q, W), lambda i, j: (i, 0)),
            pl.BlockSpec((block_r, W), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, k), jnp.int32),
            pltpu.VMEM((block_q, k), jnp.int32),
        ],
        interpret=interpret,
    )(num_valid, q, r)


def _topk_banded_kernel(tb_ref, q_ref, r_ref, starts_ref, ends_ref,
                        ovals_ref, oidx_ref, svals_ref, sidx_ref, *,
                        dim: int, k: int, block_r: int, word_chunk: int,
                        packed: bool, r_padded: int):
    """Banded variant: only ``num_tiles`` R tiles per Q block are visited,
    starting at the scalar-prefetched ``tb_ref[i]`` (OMS precursor windows).

    ``tb_ref`` generalizes the full kernel's traced ``num_valid`` scalar:
    instead of one mask bound for the whole grid, each Q block gets a tile
    base from SMEM (it steers the R BlockSpec index_map, so out-of-window
    tiles are never even fetched) and each query row gets its own
    ``[start, end)`` bounds. Columns outside the band mask to the sentinel
    exactly like ``num_valid`` padding — the merge is unchanged, so the
    result is bit-identical to masking the full score matrix.
    """
    i = pl.program_id(0)
    j = pl.program_id(1)
    bq = q_ref.shape[0]
    br = r_ref.shape[0]

    @pl.when(j == 0)
    def _():
        svals_ref[...] = jnp.full((bq, k), _SENTINEL, jnp.int32)
        sidx_ref[...] = r_padded + jax.lax.broadcasted_iota(
            jnp.int32, (bq, k), 1)

    scores = _tile_scores(q_ref, r_ref, dim=dim, word_chunk=word_chunk,
                          packed=packed)

    tile = tb_ref[i] + j
    col = tile * block_r + jax.lax.broadcasted_iota(jnp.int32, (bq, br), 1)
    in_band = (col >= starts_ref[...]) & (col < ends_ref[...])
    scores = jnp.where(in_band, scores, _SENTINEL)
    svals, sidx = _select_topk(
        jnp.concatenate([svals_ref[...], scores], axis=1),
        jnp.concatenate([sidx_ref[...], col], axis=1), k)
    svals_ref[...] = svals
    sidx_ref[...] = sidx

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        ovals_ref[...] = svals
        oidx_ref[...] = sidx


def topk_hamming_banded_pallas_call(
    q: jax.Array,          # (Q, W) uint32 packed, or (Q, D) int8
    r: jax.Array,          # (R, W) uint32 packed, or (R, D) int8
    tile_base: jax.Array,  # (Q // block_q,) int32 first R tile per Q block
    starts: jax.Array,     # (Q, 1) int32 per-query band start row
    ends: jax.Array,       # (Q, 1) int32 per-query band end row (exclusive)
    *,
    dim: int,
    k: int,
    num_tiles: int,
    block_q: int = 128,
    block_r: int = 128,
    word_chunk: int = 32,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Banded streaming top-k: grid (Q blocks, num_tiles), scanning only
    tiles ``[tile_base[i], tile_base[i] + num_tiles)`` per Q block.

    Caller contract: for every Q block i, every query's ``[start, end)``
    must lie inside the scanned rows
    ``[tile_base[i] * block_r, (tile_base[i] + num_tiles) * block_r)``
    and ``tile_base[i] + num_tiles <= R // block_r`` — band rows outside
    the scanned window would be silently skipped.
    """
    Q, W = q.shape
    R = r.shape[0]
    packed = q.dtype == jnp.uint32
    assert Q % block_q == 0 and R % block_r == 0
    assert not packed or W % word_chunk == 0
    assert 1 <= num_tiles <= R // block_r

    kernel = functools.partial(
        _topk_banded_kernel, dim=dim, k=k, block_r=block_r,
        word_chunk=word_chunk, packed=packed, r_padded=R)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Q // block_q, num_tiles),
        in_specs=[
            pl.BlockSpec((block_q, W), lambda i, j, tb: (i, 0)),
            pl.BlockSpec((block_r, W), lambda i, j, tb: (tb[i] + j, 0)),
            pl.BlockSpec((block_q, 1), lambda i, j, tb: (i, 0)),
            pl.BlockSpec((block_q, 1), lambda i, j, tb: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda i, j, tb: (i, 0)),
            pl.BlockSpec((block_q, k), lambda i, j, tb: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, k), jnp.int32),
            pltpu.VMEM((block_q, k), jnp.int32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        interpret=interpret,
    )(tile_base, q, r, starts, ends)
