"""Pure-jnp oracle for the ID-level HD encoding kernel (Eq. 1).

Level 0 is the 'absent peak' sentinel and contributes nothing; sign ties
(acc == 0) resolve to -1, matching the paper's sign convention."""

from __future__ import annotations

import jax.numpy as jnp


def hd_encode_ref(
    levels: jnp.ndarray,     # (B, F) int32 quantized intensity levels
    id_hvs: jnp.ndarray,     # (F, D) int8 bipolar
    level_hvs: jnp.ndarray,  # (m, D) int8 bipolar
) -> jnp.ndarray:
    lv = level_hvs[levels]                       # (B, F, D)
    present = (levels > 0).astype(jnp.int32)     # (B, F)
    acc = jnp.einsum(
        "bf,bfd,fd->bd",
        present, lv.astype(jnp.int32), id_hvs.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    return jnp.where(acc > 0, jnp.int8(1), jnp.int8(-1))
