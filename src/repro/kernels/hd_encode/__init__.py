from repro.kernels.hd_encode.ops import hd_encode_pallas

__all__ = ["hd_encode_pallas"]
