"""Pallas TPU kernel for ID-level HD encoding (SpecPCM Eq. 1).

For a (bb, bd) output block the kernel holds in VMEM:
  * the level codebook slice   (m, bd)   — small, m <= 64
  * the ID codebook slice      (F, bd)   — streamed rows in the F-loop
  * the level indices          (bb, F)

and accumulates  acc[b, d] += present[b,f] * LV[level[b,f], d] * ID[f, d]
over features f, then binarizes with the paper's sign convention. The gather
over the level codebook is a (bb, m) one-hot matmul against the codebook
slice — MXU-friendly, no scatter/gather unit needed.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def encode_acc(levels_ref, id_ref, lv_ref, *, num_features: int,
               num_levels: int, block_f: int) -> jax.Array:
    """In-kernel Eq. 1 accumulator: (bb, bd) float32 sums over features.

    The shared inner loop of the standalone encode kernel and the fused
    encode->search kernel (``repro.kernels.encode_search``): accumulates
    ``acc[b, d] += present[b,f] * LV[level[b,f], d] * ID[f, d]`` over
    feature blocks. float32 accumulation of +-1 terms is exact up to
    2**24 summands, far beyond any feature count, so ``sign(acc)`` is
    bit-identical to the int32 einsum oracle.
    """
    bb = levels_ref.shape[0]
    bd = id_ref.shape[1]
    lvs = lv_ref[...].astype(jnp.float32)         # (m, bd)

    def f_body(fb, acc):
        f0 = fb * block_f
        lvl = levels_ref[:, pl.dslice(f0, block_f)]            # (bb, bf) int32
        ids = id_ref[pl.dslice(f0, block_f), :].astype(jnp.float32)  # (bf, bd)
        # one-hot gather of level HVs: (bb, bf, m) @ (m, bd) via reshape
        onehot = jax.nn.one_hot(lvl, num_levels, dtype=jnp.float32)  # (bb,bf,m)
        present = (lvl > 0).astype(jnp.float32)                      # (bb,bf)
        lv_rows = jax.lax.dot_general(
            onehot.reshape(bb * block_f, num_levels), lvs,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).reshape(bb, block_f, bd)                                   # (bb,bf,bd)
        contrib = jnp.einsum(
            "bf,bfd,fd->bd", present, lv_rows, ids,
        )
        return acc + contrib

    nfb = num_features // block_f
    acc = jnp.zeros((bb, bd), jnp.float32)
    return jax.lax.fori_loop(0, nfb, f_body, acc)


def _hd_encode_kernel(levels_ref, id_ref, lv_ref, o_ref, *, num_features: int,
                      num_levels: int, block_f: int):
    acc = encode_acc(levels_ref, id_ref, lv_ref, num_features=num_features,
                     num_levels=num_levels, block_f=block_f)
    o_ref[...] = jnp.where(acc > 0, jnp.int8(1), jnp.int8(-1))


def hd_encode_pallas_call(
    levels: jax.Array,     # (B, F) int32
    id_hvs: jax.Array,     # (F, D) int8
    level_hvs: jax.Array,  # (m, D) int8
    *,
    block_b: int = 8,
    block_d: int = 256,
    block_f: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, F = levels.shape
    m, D = level_hvs.shape
    assert B % block_b == 0 and D % block_d == 0 and F % block_f == 0

    kernel = functools.partial(
        _hd_encode_kernel, num_features=F, num_levels=m, block_f=block_f,
    )
    return pl.pallas_call(
        kernel,
        grid=(B // block_b, D // block_d),
        in_specs=[
            pl.BlockSpec((block_b, F), lambda i, j: (i, 0)),
            pl.BlockSpec((F, block_d), lambda i, j: (0, j)),
            pl.BlockSpec((m, block_d), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_d), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.int8),
        interpret=interpret,
    )(levels, id_hvs, level_hvs)
