"""Public jit'd wrapper for the HD encoding Pallas kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.block_utils import resolve_blocks
from repro.kernels.hd_encode.hd_encode import hd_encode_pallas_call


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def hd_encode_pallas(
    levels: jax.Array,
    id_hvs: jax.Array,
    level_hvs: jax.Array,
    *,
    block_b: int | None = None,
    block_d: int | None = None,
    block_f: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """(B, F) levels + codebooks -> (B, D) bipolar int8 HVs.

    Pads B/F/D to block multiples. F-padding uses level 0 (absent) so padded
    features are inert; D-padding is sliced off; B-padding is sliced off.
    Blocks resolve explicit -> tuning table -> defaults
    (:mod:`repro.kernels.block_utils`).
    """
    cfg = resolve_blocks(
        "hd_encode",
        (levels.shape[0], level_hvs.shape[1], levels.shape[1]),
        {"block_b": block_b, "block_d": block_d, "block_f": block_f})
    return _hd_encode_jit(
        levels, id_hvs, level_hvs, block_b=cfg["block_b"],
        block_d=cfg["block_d"], block_f=cfg["block_f"], interpret=interpret)


@partial(jax.jit, static_argnames=("block_b", "block_d", "block_f", "interpret"))
def _hd_encode_jit(
    levels: jax.Array,
    id_hvs: jax.Array,
    level_hvs: jax.Array,
    *,
    block_b: int,
    block_d: int,
    block_f: int,
    interpret: bool | None,
) -> jax.Array:
    if interpret is None:
        interpret = _default_interpret()
    B, F = levels.shape
    m, D = level_hvs.shape
    pb, pf, pd = (-B) % block_b, (-F) % block_f, (-D) % block_d
    if pb or pf:
        levels = jnp.pad(levels, ((0, pb), (0, pf)))
    if pf or pd:
        id_hvs = jnp.pad(id_hvs, ((0, pf), (0, pd)))
    if pd:
        level_hvs = jnp.pad(level_hvs, ((0, 0), (0, pd)))
    out = hd_encode_pallas_call(
        levels.astype(jnp.int32), id_hvs, level_hvs,
        block_b=block_b, block_d=block_d, block_f=block_f,
        interpret=interpret,
    )
    return out[:B, :D]
