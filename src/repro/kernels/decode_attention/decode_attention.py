"""Pallas TPU kernel: one-token GQA decode attention over an int8 KV store.

This is the fused kernel that EXPERIMENTS.md §Perf cell 3 identifies: the
XLA graph version of int8-KV decode materializes the dequantized fp32 cache
in HBM (quadrupling traffic vs bf16); here dequantization happens in VMEM
registers between the int8 loads and the MXU dot, so HBM traffic is the
int8 codes + scales only — the paper's MLC-read dataflow (§III.C) on TPU.

Grid: (B, KV). Each program owns one (batch, kv-head) pair: q (G, hd) stays
resident; K8/V8 stream through VMEM in S-chunks with online softmax
(m, denom, acc) carried across chunks in fp32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _decode_attn_kernel(q_ref, k_ref, v_ref, ks_ref, vs_ref, len_ref, o_ref,
                        *, seq_len: int, chunk: int):
    g, hd = q_ref.shape[2], q_ref.shape[3]
    q = q_ref[0, 0].astype(jnp.float32)                    # (G, hd)
    valid = len_ref[0, 0]

    def body(c, carry):
        m, denom, acc = carry
        s0 = c * chunk
        k8 = k_ref[0, pl.dslice(s0, chunk), 0, :].astype(jnp.float32)  # (C,hd)
        ks = ks_ref[0, pl.dslice(s0, chunk), 0].astype(jnp.float32)    # (C,)
        logits = jax.lax.dot_general(
            q, k8, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)            # (G, C)
        logits = logits * ks[None, :]
        pos = s0 + jax.lax.iota(jnp.int32, chunk)
        logits = jnp.where((pos < valid)[None, :], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(-1))
        p = jnp.exp(logits - m_new[:, None])
        corr = jnp.exp(m - m_new)
        denom = denom * corr + p.sum(-1)
        v8 = v_ref[0, pl.dslice(s0, chunk), 0, :].astype(jnp.float32)
        vs = vs_ref[0, pl.dslice(s0, chunk), 0].astype(jnp.float32)
        acc = acc * corr[:, None] + jax.lax.dot_general(
            p * vs[None, :], v8, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, denom, acc

    m0 = jnp.full((g,), -jnp.inf, jnp.float32)
    d0 = jnp.zeros((g,), jnp.float32)
    a0 = jnp.zeros((g, hd), jnp.float32)
    m, denom, acc = jax.lax.fori_loop(0, seq_len // chunk, body, (m0, d0, a0))
    o_ref[0, 0] = acc / jnp.maximum(denom[:, None], 1e-30)


def decode_attention_pallas_call(
    q: jax.Array,        # (B, KV, G, hd) f32
    k8: jax.Array,       # (B, S, KV, hd) int8
    v8: jax.Array,       # (B, S, KV, hd) int8
    k_scale: jax.Array,  # (B, S, KV) f32
    v_scale: jax.Array,  # (B, S, KV) f32
    valid_len: jax.Array,  # (1, 1) int32
    *,
    chunk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, kv, g, hd = q.shape
    s = k8.shape[1]
    assert s % chunk == 0, (s, chunk)
    kernel = functools.partial(_decode_attn_kernel, seq_len=s, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(b, kv),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, s, 1, hd), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, s, 1, hd), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, s, 1), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, s, 1), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), jnp.float32),
        interpret=interpret,
    )(q, k8, v8, k_scale, v_scale, valid_len)
