"""Pure-jnp oracle for fused int8-KV decode attention.

Dequantization algebra (exact): logits_s = (q . k8_s) * kscale_s;
out = sum_s softmax(logits)_s * vscale_s * v8_s.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(
    q: jnp.ndarray,        # (B, KV, G, hd) f32 (already rope'd + scaled)
    k8: jnp.ndarray,       # (B, S, KV, hd) int8
    v8: jnp.ndarray,       # (B, S, KV, hd) int8
    k_scale: jnp.ndarray,  # (B, S, KV) f32
    v_scale: jnp.ndarray,  # (B, S, KV) f32
    valid_len: jnp.ndarray,  # () int32 — positions < valid_len attend
) -> jnp.ndarray:
    logits = jnp.einsum("bngk,bsnk->bngs", q.astype(jnp.float32),
                        k8.astype(jnp.float32))
    logits = logits * k_scale.transpose(0, 2, 1)[:, :, None, :]
    s = k8.shape[1]
    mask = jnp.arange(s) < valid_len
    logits = jnp.where(mask[None, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    w = w * v_scale.transpose(0, 2, 1)[:, :, None, :]
    return jnp.einsum("bngs,bsnk->bngk", w, v8.astype(jnp.float32))
