"""Public jit'd wrapper for fused int8-KV decode attention."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import decode_attention_pallas_call


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def decode_attention_pallas(
    q: jax.Array,        # (B, KV, G, hd)
    k8: jax.Array,       # (B, S, KV, hd) int8
    v8: jax.Array,
    k_scale: jax.Array,  # (B, S, KV) f32
    v_scale: jax.Array,
    valid_len: jax.Array,  # () int32
    *,
    chunk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = _default_interpret()
    s = k8.shape[1]
    chunk = min(chunk, s)
    if s % chunk:
        pad = chunk - s % chunk
        k8 = jnp.pad(k8, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v8 = jnp.pad(v8, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_scale = jnp.pad(k_scale, ((0, 0), (0, pad), (0, 0)))
        v_scale = jnp.pad(v_scale, ((0, 0), (0, pad), (0, 0)))
    vl = jnp.reshape(valid_len.astype(jnp.int32), (1, 1))
    return decode_attention_pallas_call(
        q.astype(jnp.float32), k8, v8,
        k_scale.astype(jnp.float32), v_scale.astype(jnp.float32), vl,
        chunk=chunk, interpret=interpret)
