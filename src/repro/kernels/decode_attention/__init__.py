from repro.kernels.decode_attention.ops import decode_attention_pallas

__all__ = ["decode_attention_pallas"]
