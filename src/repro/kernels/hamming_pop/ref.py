"""Pure-jnp oracle for bit-packed Hamming similarity."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def hamming_pop_ref(q_packed: jnp.ndarray, r_packed: jnp.ndarray, dim: int
                    ) -> jnp.ndarray:
    """(Q, W) uint32 x (R, W) uint32 -> (Q, R) int32 similarity =
    dim - popcount(q ^ r) (number of agreeing bipolar positions)."""
    x = q_packed[:, None, :] ^ r_packed[None, :, :]
    dist = jax.lax.population_count(x).astype(jnp.int32).sum(axis=-1)
    return dim - dist
