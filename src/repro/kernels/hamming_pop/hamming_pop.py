"""Pallas TPU kernel: bit-packed SLC Hamming similarity (beyond-paper).

The paper's SLC mode stores one bipolar dim per cell; on TPU the natural
equivalent packs 32 dims per uint32 lane and computes
``dim - popcount(q XOR r)`` with the vector unit — a 32x reduction in memory
traffic vs int8 HVs. Each program instance owns a (bq, br) output block and
loops over word-chunks so the (bq, br, wchunk) XOR intermediate stays inside
VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hamming_kernel(q_ref, r_ref, o_ref, *, dim: int, n_words: int,
                    word_chunk: int):
    bq = q_ref.shape[0]
    br = r_ref.shape[0]

    def body(c, acc):
        w0 = c * word_chunk
        qc = q_ref[:, pl.dslice(w0, word_chunk)]   # (bq, wc) uint32
        rc = r_ref[:, pl.dslice(w0, word_chunk)]   # (br, wc)
        x = qc[:, None, :] ^ rc[None, :, :]        # (bq, br, wc)
        pc = jax.lax.population_count(x).astype(jnp.int32)
        return acc + pc.sum(axis=-1)

    nchunks = n_words // word_chunk
    acc = jnp.zeros((bq, br), jnp.int32)
    acc = jax.lax.fori_loop(0, nchunks, body, acc)
    o_ref[...] = dim - acc


def hamming_pop_pallas_call(
    q_packed: jax.Array,   # (Q, W) uint32
    r_packed: jax.Array,   # (R, W) uint32
    *,
    dim: int,
    block_q: int = 128,
    block_r: int = 128,
    word_chunk: int = 32,
    interpret: bool = False,
) -> jax.Array:
    Q, W = q_packed.shape
    R = r_packed.shape[0]
    assert Q % block_q == 0 and R % block_r == 0 and W % word_chunk == 0

    kernel = functools.partial(
        _hamming_kernel, dim=dim, n_words=W, word_chunk=word_chunk,
    )
    return pl.pallas_call(
        kernel,
        grid=(Q // block_q, R // block_r),
        in_specs=[
            pl.BlockSpec((block_q, W), lambda i, j: (i, 0)),
            pl.BlockSpec((block_r, W), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_r), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Q, R), jnp.int32),
        interpret=interpret,
    )(q_packed, r_packed)
