"""Public jit'd wrapper for the bit-packed Hamming similarity kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.hamming_pop.hamming_pop import hamming_pop_pallas_call


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("dim", "block_q", "block_r", "word_chunk",
                                   "interpret"))
def hamming_pop_pallas(
    q_packed: jax.Array,
    r_packed: jax.Array,
    *,
    dim: int,
    block_q: int = 128,
    block_r: int = 128,
    word_chunk: int = 32,
    interpret: bool | None = None,
) -> jax.Array:
    """(Q, W) x (R, W) packed uint32 -> (Q, R) int32 hamming similarity.

    Zero-padded queries/refs XOR to zero against zero words only in the
    padded region, which is sliced off; word padding pads both sides with
    zeros (XOR -> 0 -> popcount 0) so similarities are unaffected.
    """
    if interpret is None:
        interpret = _default_interpret()
    Q, W = q_packed.shape
    R = r_packed.shape[0]
    pq, pr, pw = (-Q) % block_q, (-R) % block_r, (-W) % word_chunk
    if pq or pw:
        q_packed = jnp.pad(q_packed, ((0, pq), (0, pw)))
    if pr or pw:
        r_packed = jnp.pad(r_packed, ((0, pr), (0, pw)))
    out = hamming_pop_pallas_call(
        q_packed, r_packed, dim=dim,
        block_q=block_q, block_r=block_r, word_chunk=word_chunk,
        interpret=interpret,
    )
    return out[:Q, :R]
