from repro.kernels.hamming_pop.ops import hamming_pop_pallas

__all__ = ["hamming_pop_pallas"]
