"""Public jit'd wrappers for the fused encode -> pack -> top-k kernels.

Padding conventions (all inert by construction, proven by
``tests/test_encode_search_fused.py``):

  * **features** pad to a ``block_f`` multiple with level 0 (absent peak)
    and zero ID rows — zero contribution to the accumulator;
  * **HD dims** pad to the bank's storage width (a ``word_chunk``-word
    multiple when packed, a 128-lane multiple for int8) with zero
    codebook columns: the accumulator is 0 there, so queries encode the
    pad dims to sign(0) = -1 -> packed bit 0, while padded reference
    words/columns are zero — XOR popcount and int8 dot cross terms both
    vanish, leaving scores on the true ``dim`` scale;
  * **query rows** pad with all-zero spectra and are sliced off;
  * **reference rows** pad with zeros and mask to the sentinel via
    ``num_valid``.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.block_utils import resolve_blocks
from repro.kernels.encode_search.encode_search import (
    encode_search_banded_pallas_call,
    encode_search_pallas_call,
)
from repro.kernels.topk_hamming.ops import canonicalize_overflow_slots


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _check_operands(levels, id_hvs, level_hvs, r, k):
    if levels.ndim != 2 or id_hvs.ndim != 2 or level_hvs.ndim != 2:
        raise ValueError(
            f"bad operand ranks {levels.shape} / {id_hvs.shape} / "
            f"{level_hvs.shape}")
    F, D = id_hvs.shape
    if levels.shape[1] != F or level_hvs.shape[1] != D:
        raise ValueError(
            f"codebook shapes disagree: levels {levels.shape}, id "
            f"{id_hvs.shape}, level {level_hvs.shape}")
    packed = r.dtype == jnp.uint32
    if packed:
        if D % 32 != 0 or r.shape[1] != D // 32:
            raise ValueError(
                f"packed bank width {r.shape[1]} != D/32 for D={D}")
    elif r.dtype == jnp.int8:
        if r.shape[1] != D:
            raise ValueError(f"bank width {r.shape[1]} != D={D}")
    else:
        raise ValueError(f"expected uint32 (packed) or int8 bank, "
                         f"got {r.dtype}")
    if not 1 <= k <= r.shape[0]:
        raise ValueError(f"k={k} must be in [1, {r.shape[0]}]")
    return packed


def _pad_operands(levels, id_hvs, level_hvs, r, *, packed: bool, bq: int,
                  br: int, block_f: int, word_chunk: int):
    """Apply the module-docstring padding; returns the padded operands."""
    Q, F = levels.shape
    D = id_hvs.shape[1]
    R, W = r.shape
    pq, pf, pr = (-Q) % bq, (-F) % block_f, (-R) % br
    pw = ((-W) % word_chunk) if packed else ((-D) % 128)
    pd = 32 * pw if packed else pw
    if pq or pf:
        levels = jnp.pad(levels, ((0, pq), (0, pf)))
    if pf or pd:
        id_hvs = jnp.pad(id_hvs, ((0, pf), (0, pd)))
    if pd:
        level_hvs = jnp.pad(level_hvs, ((0, 0), (0, pd)))
    if pr or pw:
        r = jnp.pad(r, ((0, pr), (0, pw)))
    return levels, id_hvs, level_hvs, r


def encode_search_pallas(
    levels: jax.Array,     # (Q, F) int quantized intensity levels
    id_hvs: jax.Array,     # (F, D) int8 bipolar ID codebook
    level_hvs: jax.Array,  # (m, D) int8 bipolar level codebook
    r: jax.Array,          # (R, D/32) uint32 packed or (R, D) int8 bank
    *,
    dim: int,
    k: int,
    num_valid: jax.Array | int | None = None,
    block_q: int | None = None,
    block_r: int | None = None,
    block_f: int | None = None,
    word_chunk: int | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused query pipeline: raw (Q, F) spectra -> (idx (Q, k), vals (Q, k)).

    Bit-identical — tie order and ``num_valid`` sentinel masking included
    — to the staged oracle
    ``encode_levels_batch -> encode_queries -> topk_hamming_pallas``
    (equivalently ``topk_search`` over the encoded HVs), but the encoded
    hypervector and the (Q, R) score matrix never leave VMEM: only the
    (Q, k) winners reach HBM. ``dim`` must be the true HD dimensionality
    (``id_hvs.shape[1]``); the bank's dtype selects the packed
    XOR+popcount or int8-dot score path. Blocks resolve explicit ->
    tuning table -> defaults (:mod:`repro.kernels.block_utils`).
    """
    cfg = resolve_blocks(
        "encode_search", (levels.shape[0], r.shape[0], levels.shape[1]),
        {"block_q": block_q, "block_r": block_r, "block_f": block_f,
         "word_chunk": word_chunk})
    return _encode_search_jit(
        levels, id_hvs, level_hvs, r, dim=dim, k=k, num_valid=num_valid,
        block_q=cfg["block_q"], block_r=cfg["block_r"],
        block_f=cfg["block_f"], word_chunk=cfg["word_chunk"],
        interpret=interpret)


@partial(jax.jit, static_argnames=("dim", "k", "block_q", "block_r",
                                   "block_f", "word_chunk", "interpret"))
def _encode_search_jit(
    levels: jax.Array,
    id_hvs: jax.Array,
    level_hvs: jax.Array,
    r: jax.Array,
    *,
    dim: int,
    k: int,
    num_valid: jax.Array | int | None,
    block_q: int,
    block_r: int,
    block_f: int,
    word_chunk: int,
    interpret: bool | None,
) -> tuple[jax.Array, jax.Array]:
    if interpret is None:
        interpret = _default_interpret()
    packed = _check_operands(levels, id_hvs, level_hvs, r, k)
    Q, _ = levels.shape
    R = r.shape[0]
    bq = min(block_q, _round_up(Q, 8))
    br = min(block_r, _round_up(R, 128))
    bf = min(block_f, _round_up(levels.shape[1], 8))
    levels, id_hvs, level_hvs, r = _pad_operands(
        levels.astype(jnp.int32), id_hvs, level_hvs, r, packed=packed,
        bq=bq, br=br, block_f=bf, word_chunk=word_chunk)

    nv = R if num_valid is None else num_valid
    nv = jnp.minimum(jnp.asarray(nv, jnp.int32).reshape(1), R)
    vals, idx = encode_search_pallas_call(
        levels, id_hvs, level_hvs, r, nv, dim=dim, k=k, block_q=bq,
        block_r=br, block_f=bf, word_chunk=word_chunk, interpret=interpret)
    return idx[:Q], vals[:Q]


def encode_search_banded_pallas(
    levels: jax.Array,
    id_hvs: jax.Array,
    level_hvs: jax.Array,
    r: jax.Array,
    starts: jax.Array,     # (Q,) per-query band start row
    lens: jax.Array,       # (Q,) per-query band length
    *,
    dim: int,
    k: int,
    num_valid: jax.Array | int | None = None,
    num_tiles: int | None = None,
    block_q: int | None = None,
    block_r: int | None = None,
    block_f: int | None = None,
    word_chunk: int | None = None,
    interpret: bool | None = None,
    canonicalize: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Banded fused encode->search: each raw spectrum scores only bank
    rows in its own ``[starts[q], starts[q] + lens[q])`` band (an OMS
    precursor window over a precursor-sorted bank), scanning only
    ``num_tiles`` R tiles per Q block. Same contract — tile budget,
    clipping, overflow canonicalization — as
    ``topk_hamming_banded_pallas``, with the encode fused in. Blocks
    resolve under the op key ``encode_search_banded``.
    """
    cfg = resolve_blocks(
        "encode_search_banded",
        (levels.shape[0], r.shape[0], levels.shape[1]),
        {"block_q": block_q, "block_r": block_r, "block_f": block_f,
         "word_chunk": word_chunk})
    return _encode_search_banded_jit(
        levels, id_hvs, level_hvs, r, starts, lens, dim=dim, k=k,
        num_valid=num_valid, num_tiles=num_tiles, block_q=cfg["block_q"],
        block_r=cfg["block_r"], block_f=cfg["block_f"],
        word_chunk=cfg["word_chunk"], interpret=interpret,
        canonicalize=canonicalize)


@partial(jax.jit, static_argnames=("dim", "k", "num_tiles", "block_q",
                                   "block_r", "block_f", "word_chunk",
                                   "interpret", "canonicalize"))
def _encode_search_banded_jit(
    levels: jax.Array,
    id_hvs: jax.Array,
    level_hvs: jax.Array,
    r: jax.Array,
    starts: jax.Array,
    lens: jax.Array,
    *,
    dim: int,
    k: int,
    num_valid: jax.Array | int | None,
    num_tiles: int | None,
    block_q: int,
    block_r: int,
    block_f: int,
    word_chunk: int,
    interpret: bool | None,
    canonicalize: bool,
) -> tuple[jax.Array, jax.Array]:
    if interpret is None:
        interpret = _default_interpret()
    packed = _check_operands(levels, id_hvs, level_hvs, r, k)
    Q, _ = levels.shape
    R = r.shape[0]
    if starts.shape != (Q,) or lens.shape != (Q,):
        raise ValueError(
            f"starts/lens must be ({Q},), got {starts.shape}/{lens.shape}")
    bq = min(block_q, _round_up(Q, 8))
    br = min(block_r, _round_up(R, 128))
    bf = min(block_f, _round_up(levels.shape[1], 8))
    pq, pr = (-Q) % bq, (-R) % br
    levels, id_hvs, level_hvs, r = _pad_operands(
        levels.astype(jnp.int32), id_hvs, level_hvs, r, packed=packed,
        bq=bq, br=br, block_f=bf, word_chunk=word_chunk)

    nv = R if num_valid is None else num_valid
    nv = jnp.minimum(jnp.asarray(nv, jnp.int32), R)
    s = jnp.clip(starts.astype(jnp.int32), 0, nv)
    e = jnp.clip(starts.astype(jnp.int32) + lens.astype(jnp.int32), s, nv)
    # edge-pad so padded queries inherit a real band and don't widen the
    # per-block tile span
    if pq:
        s = jnp.pad(s, (0, pq), mode="edge")
        e = jnp.pad(e, (0, pq), mode="edge")

    total_tiles = (R + pr) // br
    nt = total_tiles if num_tiles is None else min(num_tiles, total_tiles)
    tb = jnp.min(s.reshape(-1, bq) // br, axis=1)
    tb = jnp.clip(tb, 0, total_tiles - nt).astype(jnp.int32)

    vals, idx = encode_search_banded_pallas_call(
        levels, id_hvs, level_hvs, r, tb, s[:, None], e[:, None], dim=dim,
        k=k, num_tiles=nt, block_q=bq, block_r=br, block_f=bf,
        word_chunk=word_chunk, interpret=interpret)
    idx, vals = idx[:Q], vals[:Q]
    if canonicalize:
        idx = canonicalize_overflow_slots(idx, vals, s[:Q], e[:Q], R)
    return idx, vals
