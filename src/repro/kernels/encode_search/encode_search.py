"""Pallas TPU kernel: fused HD encode -> bit-pack -> streaming top-k search.

SpecPCM's end-to-end pipeline keeps a spectrum on-accelerator from
encoding (Eq. 1) through DB search (§III.C): the encoded hypervector is
written straight into the near-memory search unit, never round-tripping
main memory. This kernel is the TPU equivalent for the serving query hot
path. Per ``(Q-block, R-tile)`` grid step (R innermost):

  * on the **first** R tile of a Q block, the raw quantized spectra
    (``levels``) are encoded in VMEM with the shared Eq. 1 accumulator
    (:func:`repro.kernels.hd_encode.hd_encode.encode_acc`), signed, and —
    for packed banks — bit-packed to uint32 words, all inside the kernel;
    the encoded block persists in VMEM scratch across the R tiles, so the
    query hypervector **never reaches HBM** in any form;
  * every R tile then scores against the resident encoded block with the
    fused search's tile scorer (XOR+popcount or int8 dot) and folds into
    the same running VMEM top-k
    (:func:`repro.kernels.topk_hamming.topk_hamming._select_topk`).

Only the ``(Q, k)`` result is ever written to HBM — the staged path's
intermediate ``(Q, D)`` encoded batch, its packed ``(Q, W)`` form, *and*
the ``(Q, R)`` score matrix all stay on-chip.

**Bit-identity.** The encode accumulates +-1 terms in float32 (exact far
beyond any feature count), signs with the paper's tie -> -1 convention,
and packs with the ``bitpack_bipolar`` bit order (+1 -> bit 1), so the
resident encoded block is bit-identical to
``encode_queries(db, encode_levels_batch(levels, ...))``; the scoring and
merge are the verbatim ``topk_hamming`` inner loops. Hence the whole
fusion matches the staged oracle bit-for-bit, tie order and sentinel
masking included. Padding is inert by construction: padded feature
columns carry level 0 (absent) with zero ID rows, padded HD dims
accumulate to 0 -> sign -1 -> packed bit 0, and padded reference
words/columns are zero, so cross terms vanish (see ops.py).

The banded variant mirrors ``_topk_banded_kernel``: a scalar-prefetched
per-Q-block tile base steers the R BlockSpec so only the tiles covering
each query's OMS precursor window are fetched, with per-query
``[start, end)`` bounds masking in-tile rows.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.hd_encode.hd_encode import encode_acc
from repro.kernels.topk_hamming.topk_hamming import (
    _SENTINEL,
    _select_topk,
    _tile_scores,
)


def _encode_block(levels_ref, id_ref, lv_ref, *, num_features: int,
                  num_levels: int, block_f: int, packed: bool) -> jax.Array:
    """Encode one Q block in VMEM: (bq, W) packed uint32 or (bq, D) int8.

    Shares the Eq. 1 accumulator with the standalone encode kernel, then
    signs (tie -> -1) and, for packed banks, bit-packs with the
    ``bitpack_bipolar`` convention (+1 -> bit 1, word w holds dims
    [32w, 32w+32) with dim 32w at bit 0).
    """
    acc = encode_acc(levels_ref, id_ref, lv_ref, num_features=num_features,
                     num_levels=num_levels, block_f=block_f)
    if not packed:
        return jnp.where(acc > 0, jnp.int8(1), jnp.int8(-1))
    bq, d = acc.shape
    bits = (acc > 0).astype(jnp.uint32).reshape(bq, d // 32, 32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2)
    return (bits << shifts).sum(axis=-1, dtype=jnp.uint32)


def _encode_search_kernel(nv_ref, levels_ref, id_ref, lv_ref, r_ref,
                          ovals_ref, oidx_ref, qenc_ref, svals_ref, sidx_ref,
                          *, dim: int, k: int, block_r: int, word_chunk: int,
                          packed: bool, r_padded: int, num_features: int,
                          num_levels: int, block_f: int):
    j = pl.program_id(1)
    bq = levels_ref.shape[0]
    br = r_ref.shape[0]

    # first R step of this Q block: encode (+ pack) the raw spectra into
    # scratch and reset the running top-k — the encoded block then stays
    # resident in VMEM for every R tile of this Q block.
    @pl.when(j == 0)
    def _():
        qenc_ref[...] = _encode_block(
            levels_ref, id_ref, lv_ref, num_features=num_features,
            num_levels=num_levels, block_f=block_f, packed=packed)
        svals_ref[...] = jnp.full((bq, k), _SENTINEL, jnp.int32)
        sidx_ref[...] = r_padded + jax.lax.broadcasted_iota(
            jnp.int32, (bq, k), 1)

    scores = _tile_scores(qenc_ref, r_ref, dim=dim, word_chunk=word_chunk,
                          packed=packed)

    col = j * block_r + jax.lax.broadcasted_iota(jnp.int32, (bq, br), 1)
    scores = jnp.where(col < nv_ref[0], scores, _SENTINEL)
    svals, sidx = _select_topk(
        jnp.concatenate([svals_ref[...], scores], axis=1),
        jnp.concatenate([sidx_ref[...], col], axis=1), k)
    svals_ref[...] = svals
    sidx_ref[...] = sidx

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        ovals_ref[...] = svals
        oidx_ref[...] = sidx


def encode_search_pallas_call(
    levels: jax.Array,     # (Q, F) int32 quantized intensity levels
    id_hvs: jax.Array,     # (F, D) int8 bipolar (D padded to the ref width)
    level_hvs: jax.Array,  # (m, D) int8 bipolar
    r: jax.Array,          # (R, W) uint32 packed, or (R, D) int8
    num_valid: jax.Array,  # (1,) int32: rows >= num_valid mask to SENTINEL
    *,
    dim: int,
    k: int,
    block_q: int = 8,
    block_r: int = 128,
    block_f: int = 128,
    word_chunk: int = 32,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (vals (Q, k), idx (Q, k)): fused encode -> pack -> top-k.

    ``dim`` is the *true* HD dimensionality used on the score scale;
    ``id_hvs``/``level_hvs`` columns and ``r`` words/columns may be
    zero-padded past it (inert, see module docstring).
    """
    Q, F = levels.shape
    m, D = level_hvs.shape
    R, W = r.shape
    packed = r.dtype == jnp.uint32
    assert Q % block_q == 0 and R % block_r == 0 and F % block_f == 0
    assert (D == 32 * W) if packed else (D == W)
    assert not packed or W % word_chunk == 0

    kernel = functools.partial(
        _encode_search_kernel, dim=dim, k=k, block_r=block_r,
        word_chunk=word_chunk, packed=packed, r_padded=R, num_features=F,
        num_levels=m, block_f=block_f)
    return pl.pallas_call(
        kernel,
        grid=(Q // block_q, R // block_r),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,), memory_space=pltpu.SMEM),
            pl.BlockSpec((block_q, F), lambda i, j: (i, 0)),
            pl.BlockSpec((F, D), lambda i, j: (0, 0)),
            pl.BlockSpec((m, D), lambda i, j: (0, 0)),
            pl.BlockSpec((block_r, W), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, W), jnp.uint32 if packed else jnp.int8),
            pltpu.VMEM((block_q, k), jnp.int32),
            pltpu.VMEM((block_q, k), jnp.int32),
        ],
        interpret=interpret,
    )(num_valid, levels, id_hvs, level_hvs, r)


def _encode_search_banded_kernel(tb_ref, levels_ref, id_ref, lv_ref, r_ref,
                                 starts_ref, ends_ref, ovals_ref, oidx_ref,
                                 qenc_ref, svals_ref, sidx_ref, *, dim: int,
                                 k: int, block_r: int, word_chunk: int,
                                 packed: bool, r_padded: int,
                                 num_features: int, num_levels: int,
                                 block_f: int):
    """Banded twin: only ``num_tiles`` R tiles per Q block are visited,
    starting at the scalar-prefetched ``tb_ref[i]`` (OMS precursor
    windows), with per-query ``[start, end)`` row bounds — the same
    contract as ``topk_hamming._topk_banded_kernel``, with the encode
    fused in at j == 0."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    bq = levels_ref.shape[0]
    br = r_ref.shape[0]

    @pl.when(j == 0)
    def _():
        qenc_ref[...] = _encode_block(
            levels_ref, id_ref, lv_ref, num_features=num_features,
            num_levels=num_levels, block_f=block_f, packed=packed)
        svals_ref[...] = jnp.full((bq, k), _SENTINEL, jnp.int32)
        sidx_ref[...] = r_padded + jax.lax.broadcasted_iota(
            jnp.int32, (bq, k), 1)

    scores = _tile_scores(qenc_ref, r_ref, dim=dim, word_chunk=word_chunk,
                          packed=packed)

    tile = tb_ref[i] + j
    col = tile * block_r + jax.lax.broadcasted_iota(jnp.int32, (bq, br), 1)
    in_band = (col >= starts_ref[...]) & (col < ends_ref[...])
    scores = jnp.where(in_band, scores, _SENTINEL)
    svals, sidx = _select_topk(
        jnp.concatenate([svals_ref[...], scores], axis=1),
        jnp.concatenate([sidx_ref[...], col], axis=1), k)
    svals_ref[...] = svals
    sidx_ref[...] = sidx

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        ovals_ref[...] = svals
        oidx_ref[...] = sidx


def encode_search_banded_pallas_call(
    levels: jax.Array,     # (Q, F) int32 quantized intensity levels
    id_hvs: jax.Array,     # (F, D) int8 bipolar
    level_hvs: jax.Array,  # (m, D) int8 bipolar
    r: jax.Array,          # (R, W) uint32 packed, or (R, D) int8
    tile_base: jax.Array,  # (Q // block_q,) int32 first R tile per Q block
    starts: jax.Array,     # (Q, 1) int32 per-query band start row
    ends: jax.Array,       # (Q, 1) int32 per-query band end row (exclusive)
    *,
    dim: int,
    k: int,
    num_tiles: int,
    block_q: int = 8,
    block_r: int = 128,
    block_f: int = 128,
    word_chunk: int = 32,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Banded fused encode->search: grid (Q blocks, num_tiles), scanning
    only tiles ``[tile_base[i], tile_base[i] + num_tiles)`` per Q block.
    Caller contract matches ``topk_hamming_banded_pallas_call``."""
    Q, F = levels.shape
    m, D = level_hvs.shape
    R, W = r.shape
    packed = r.dtype == jnp.uint32
    assert Q % block_q == 0 and R % block_r == 0 and F % block_f == 0
    assert (D == 32 * W) if packed else (D == W)
    assert not packed or W % word_chunk == 0
    assert 1 <= num_tiles <= R // block_r

    kernel = functools.partial(
        _encode_search_banded_kernel, dim=dim, k=k, block_r=block_r,
        word_chunk=word_chunk, packed=packed, r_padded=R, num_features=F,
        num_levels=m, block_f=block_f)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Q // block_q, num_tiles),
        in_specs=[
            pl.BlockSpec((block_q, F), lambda i, j, tb: (i, 0)),
            pl.BlockSpec((F, D), lambda i, j, tb: (0, 0)),
            pl.BlockSpec((m, D), lambda i, j, tb: (0, 0)),
            pl.BlockSpec((block_r, W), lambda i, j, tb: (tb[i] + j, 0)),
            pl.BlockSpec((block_q, 1), lambda i, j, tb: (i, 0)),
            pl.BlockSpec((block_q, 1), lambda i, j, tb: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda i, j, tb: (i, 0)),
            pl.BlockSpec((block_q, k), lambda i, j, tb: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, W), jnp.uint32 if packed else jnp.int8),
            pltpu.VMEM((block_q, k), jnp.int32),
            pltpu.VMEM((block_q, k), jnp.int32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        interpret=interpret,
    )(tile_base, levels, id_hvs, level_hvs, r, starts, ends)
