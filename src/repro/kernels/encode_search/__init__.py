from repro.kernels.encode_search.ops import (
    encode_search_banded_pallas,
    encode_search_pallas,
)
from repro.kernels.encode_search.ref import (
    encode_search_banded_ref,
    encode_search_ref,
)

__all__ = ["encode_search_pallas", "encode_search_banded_pallas",
           "encode_search_ref", "encode_search_banded_ref"]
