"""Staged oracle for the fused encode -> pack -> top-k search kernel.

Runs the exact pipeline the kernel fuses, stage at a time through HBM:
Eq. 1 encode (``encode_levels_batch``), deterministic bank-form encoding
(bit-pack or int8 cast — ``repro.serve.db_search.encode_queries``'s
math), then the full-matrix top-k oracle of ``repro.kernels.
topk_hamming.ref``. Bit-identity against this — indices, scores, tie
order, overflow slots — is the kernel's correctness contract.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.hd.encoding import encode_levels_batch
from repro.core.hd.similarity import bitpack_bipolar
from repro.kernels.topk_hamming.ref import (
    topk_hamming_banded_ref,
    topk_hamming_ref,
)


def encode_queries_ref(levels, id_hvs, level_hvs, *, packed: bool):
    """Staged query encoding: levels -> bipolar HVs -> bank storage form."""
    hv = encode_levels_batch(jnp.asarray(levels, jnp.int32), id_hvs,
                             level_hvs)
    return bitpack_bipolar(hv) if packed else hv.astype(jnp.int8)


def encode_search_ref(levels, id_hvs, level_hvs, r, *, k: int,
                      num_valid=None):
    """(Q, F) levels x (R, W|D) bank -> (idx (Q, k), vals (Q, k)) int32."""
    q = encode_queries_ref(levels, id_hvs, level_hvs,
                           packed=r.dtype == jnp.uint32)
    return topk_hamming_ref(q, r, int(id_hvs.shape[1]), k,
                            num_valid=num_valid)


def encode_search_banded_ref(levels, id_hvs, level_hvs, r, starts, lens, *,
                             k: int, num_valid=None):
    """Banded staged oracle: encode, then sentinel-mask columns outside
    each query's ``[start, start + len)`` band before ``lax.top_k``."""
    q = encode_queries_ref(levels, id_hvs, level_hvs,
                           packed=r.dtype == jnp.uint32)
    return topk_hamming_banded_ref(q, r, jnp.asarray(starts, jnp.int32),
                                   jnp.asarray(lens, jnp.int32),
                                   int(id_hvs.shape[1]), k,
                                   num_valid=num_valid)
