"""Pallas TPU kernels for SpecPCM compute hot-spots.

Each kernel directory holds:
  <name>.py — the pl.pallas_call kernel with explicit BlockSpec VMEM tiling
  ops.py    — the jit'd public wrapper (padding, dtype plumbing)
  ref.py    — the pure-jnp oracle used by tests and as the CPU fallback

Kernels target TPU (MXU-aligned 128 tiles); on CPU they run with
``interpret=True`` which executes the kernel body in Python for correctness.
"""

from repro.kernels.decode_attention.ops import decode_attention_pallas
from repro.kernels.encode_search.ops import encode_search_pallas
from repro.kernels.hamming_pop.ops import hamming_pop_pallas
from repro.kernels.hd_encode.ops import hd_encode_pallas
from repro.kernels.imc_mvm.ops import imc_mvm_pallas
from repro.kernels.topk_hamming.ops import topk_hamming_pallas

__all__ = ["imc_mvm_pallas", "hd_encode_pallas", "hamming_pop_pallas",
           "decode_attention_pallas", "topk_hamming_pallas",
           "encode_search_pallas"]
