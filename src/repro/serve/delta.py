"""Append-only delta banks: streaming ingestion for the serving stack.

A tenant's base bank is the heavy artifact — bit-packed, precursor-sorted,
row-sharded over the mesh, behind a jit cache keyed on its geometry.
Rebuilding it per append would make ingestion O(bank) per spectrum. Instead
new refs (and decoys) land in a small **unpacked single-shard delta bank**
(:class:`DeltaBank`) that is cheap to rebuild per append, and search runs an
exact merged top-k over base + delta:

  * each side runs its own local-top-k/merge pipeline unchanged (the PR 2
    shard machinery — the delta is effectively one extra, unpacked shard);
  * every candidate's index is translated into the row numbering the bank
    *would* have after a from-scratch rebuild over the concatenated arrays
    (``[base decoys; delta decoys; base targets; delta targets]``, each
    block re-sorted by precursor for OMS banks);
  * the two candidate blocks merge by ``(score desc, rebuilt row asc)`` —
    a two-key :func:`jax.lax.sort`, because rebuilt rows *interleave*
    across the sides (a delta decoy sits between base decoys and base
    targets), so the positional tie-break of the shard-merge
    (``_merge_topk``) does not apply across sides.

Both translations are strictly increasing (appended rows keep their
relative order inside each block, and a stable blockwise sort of the
concatenated precursors keeps base rows ahead of delta rows on mass ties),
so each side's top-k — re-keyed by rebuilt rows — is exactly the rebuilt
bank's top-k restricted to that side. Any rebuilt winner therefore appears
among the ``2k`` merged candidates, and the two-key merge reproduces the
rebuilt result **bit-identically**, tie order and (for OMS) sentinel
overflow slots included: the OMS path merges *sorted-layout* rows, then
runs the very same ``canonicalize_overflow_slots`` + permutation translate
a rebuilt bank's ``_oms_finish`` would, against the merged precursor index
and the merged window ranges.

Score scale is shared by construction: the unpacked delta scores int8 dot
products and the packed base scores ``2*hamming - D`` — equal integers for
bipolar HVs — so cross-side comparisons are exact.

:meth:`repro.serve.cache.BankRegistry.compact` folds the delta back into
the bit-packed base past a size threshold; by the identity above, results
are unchanged across the swap.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hd.similarity import dot_similarity
from repro.serve.oms import OMSConfig, OMSPlan, PrecursorIndex, \
    build_precursor_index


@dataclasses.dataclass(frozen=True)
class MergedLayout:
    """Index maps from per-side storage rows into the rebuilt bank's rows.

    ``b_map``/``d_map`` take a base/delta *storage* row (original row for
    plain banks, sorted-layout row for OMS banks) to the storage row the
    same HV would occupy after a from-scratch rebuild over the
    concatenated arrays. Both maps are strictly increasing — the property
    that lets each side's own ascending-index tie-break stand in for the
    rebuilt bank's.
    """

    num_rows: int
    num_decoys: int
    b_map: np.ndarray              # (base.num_rows,) int32
    d_map: np.ndarray              # (delta.num_rows,) int32
    index: PrecursorIndex | None   # merged OMS index (None for plain banks)


class DeltaBank:
    """Append-only unpacked delta rows for one tenant.

    Appended refs/decoys accumulate host-side; after every append the
    small single-shard, never-packed :class:`ShardedDatabase` (``self.db``)
    is rebuilt — O(delta), not O(bank). For OMS tenants the delta carries
    its own precursor-sorted index, and :meth:`layout` caches the maps
    into the merged (rebuilt-equivalent) row space.
    """

    def __init__(self, dim: int, *, oms: bool):
        self.dim = int(dim)
        self.oms = bool(oms)
        self.refs = np.zeros((0, self.dim), np.int8)
        self.decoys = np.zeros((0, self.dim), np.int8)
        self.precursor = np.zeros((0,), np.float32)
        self.decoy_precursor = np.zeros((0,), np.float32)
        self.version = 0
        self.db = None
        self.storage = np.zeros((0, self.dim), np.int8)
        self._layout: MergedLayout | None = None
        self._layout_key = None

    @property
    def num_targets(self) -> int:
        return int(self.refs.shape[0])

    @property
    def num_decoys(self) -> int:
        return int(self.decoys.shape[0])

    @property
    def num_rows(self) -> int:
        return self.num_targets + self.num_decoys

    def append(self, refs, decoys=None, *, precursor=None,
               decoy_precursor=None) -> int:
        """Land one batch of refs (+ optional decoys) in the delta; returns
        the delta's total row count. OMS deltas require per-ref precursor
        masses (``decoy_precursor`` defaults to ``precursor`` when the
        decoy count matches, mirroring ``shard_database``)."""
        r = np.asarray(refs, np.int8)
        if r.size == 0:
            r = np.zeros((0, self.dim), np.int8)
        if r.ndim != 2 or r.shape[1] != self.dim:
            raise ValueError(f"appended refs shape {r.shape} != "
                             f"(n, {self.dim})")
        d = None
        if decoys is not None:
            d = np.asarray(decoys, np.int8)
            if d.ndim != 2 or d.shape[1] != self.dim:
                raise ValueError(f"appended decoys shape {d.shape} != "
                                 f"(n, {self.dim})")
        n_new = r.shape[0] + (0 if d is None else d.shape[0])
        if n_new == 0:
            raise ValueError("append needs at least one ref or decoy row")
        if self.oms:
            if precursor is None:
                raise ValueError("this tenant's bank is precursor-sorted "
                                 "(OMS); append requires precursor=")
            prec = np.asarray(precursor, np.float32).reshape(-1)
            if prec.shape[0] != r.shape[0]:
                raise ValueError(f"precursor has {prec.shape[0]} entries "
                                 f"for {r.shape[0]} appended refs")
            dprec = None
            if d is not None:
                dprec = (prec if decoy_precursor is None
                         else np.asarray(decoy_precursor,
                                         np.float32).reshape(-1))
                if dprec.shape[0] != d.shape[0]:
                    raise ValueError(
                        f"decoy_precursor has {dprec.shape[0]} entries for "
                        f"{d.shape[0]} appended decoys")
        else:
            if precursor is not None or decoy_precursor is not None:
                raise ValueError("this tenant's bank has no precursor "
                                 "index; append must not pass precursor=")
            prec = dprec = None

        self.refs = np.concatenate([self.refs, r])
        if d is not None:
            self.decoys = np.concatenate([self.decoys, d])
        if self.oms:
            self.precursor = np.concatenate([self.precursor, prec])
            if dprec is not None:
                self.decoy_precursor = np.concatenate(
                    [self.decoy_precursor, dprec])
        self.version += 1
        self._rebuild()
        return self.num_rows

    def _rebuild(self) -> None:
        from repro.serve.db_search import shard_database
        decoys = self.decoys if self.num_decoys else None
        self.db = shard_database(
            self.refs, decoys=decoys, pack=False,
            precursor=self.precursor if self.oms else None,
            decoy_precursor=(self.decoy_precursor
                             if self.oms and decoys is not None else None))
        # storage-order rows for the fused merged-search tail: the bank
        # layout is decoys-then-targets, precursor-sorted for OMS banks
        # (``oms.perm`` maps sorted row -> original row)
        rows = (np.concatenate([self.decoys, self.refs])
                if self.num_decoys else self.refs)
        self.storage = rows[self.db.oms.perm] if self.oms else rows

    def layout(self, base) -> MergedLayout:
        """The (cached) rebuilt-row maps for this delta against ``base``.

        Keyed on the delta version and base geometry only: an evicted-and-
        rebuilt base is content-identical, so the maps survive it.
        """
        key = (self.version, base.num_rows, base.num_decoys)
        if self._layout is None or self._layout_key != key:
            self._layout = merged_layout(base, self)
            self._layout_key = key
        return self._layout


def merged_layout(base, delta: DeltaBank) -> MergedLayout:
    """Compute the rebuilt-row maps (see :class:`MergedLayout`)."""
    nd0, ndd = base.num_decoys, delta.num_decoys
    nt0 = base.num_targets
    n_m = base.num_rows + delta.num_rows
    b_orig = np.arange(base.num_rows, dtype=np.int32)
    b_trans = np.where(b_orig < nd0, b_orig, b_orig + ndd).astype(np.int32)
    d_orig = np.arange(delta.num_rows, dtype=np.int32)
    d_trans = np.where(d_orig < ndd, d_orig + nd0,
                       d_orig + nd0 + nt0).astype(np.int32)
    if base.oms is None:
        return MergedLayout(num_rows=n_m, num_decoys=nd0 + ndd,
                            b_map=b_trans, d_map=d_trans, index=None)
    # original-order base precursors, recovered exactly from the sorted
    # index (float32 round-trips, so this matches whatever register()
    # passed — including the decoy default)
    base_prec = np.empty(base.num_rows, np.float32)
    base_prec[base.oms.perm] = base.oms.prec_sorted
    tgt = np.concatenate([base_prec[nd0:], delta.precursor])
    dec = np.concatenate([base_prec[:nd0], delta.decoy_precursor])
    index = build_precursor_index(tgt, dec if dec.shape[0] else None)
    pos = np.empty(n_m, np.int32)
    pos[index.perm] = np.arange(n_m, dtype=np.int32)
    return MergedLayout(
        num_rows=n_m, num_decoys=nd0 + ndd,
        b_map=pos[b_trans[base.oms.perm]].astype(np.int32),
        d_map=pos[d_trans[delta.db.oms.perm]].astype(np.int32),
        index=index)


def _merge_by_row(cand_vals, cand_rows, k: int):
    """Top-k over candidate blocks by ``(score desc, rebuilt row asc)``.

    The cross-side twin of ``_merge_topk``: rebuilt rows interleave across
    the base/delta blocks, so the tie-break must sort on the translated
    row itself, not block position. Scores are int32 bounded by ±D, so the
    negated-float32 primary key is exact; sentinel slots map to +inf and
    sort behind every real candidate (their payload value stays sentinel
    for the caller's overflow canonicalization).
    """
    from repro.serve.db_search import _SENTINEL
    key = jnp.where(cand_vals == _SENTINEL, jnp.float32(jnp.inf),
                    -cand_vals.astype(jnp.float32))
    _, rows, vals = jax.lax.sort(
        (key, cand_rows.astype(jnp.int32), cand_vals), num_keys=2)
    return rows[..., :k], vals[..., :k]


@functools.partial(jax.jit, static_argnames=("k", "kd"))
def _merged_tail(delta_rows, q_raw, bi, bv, b_map, d_map, *, k: int,
                 kd: int):
    """Everything after the base search, fused into ONE jitted dispatch.

    The delta is small by construction, so the dominant cost of searching
    it through the generic per-shard pipeline is fixed eager-op dispatch
    overhead, not math — enough to drag the merged path well below the
    pure-base qps the bench floor guards. Here the delta scores
    (``dot_similarity``, the exact int32 scale ``_local_scores`` uses on
    unpacked banks), its ``lax.top_k`` (ties break to the lowest storage
    row, and ``d_map`` is strictly increasing, so rebuilt-row order is
    preserved — the same argument as the staged pipeline's), both row
    translations, and the cross-side merge compile into a single call.
    ``delta_rows`` holds exactly the delta's storage rows (no shard
    padding), so no sentinel masking is needed on that side; base
    overflow slots clip into ``b_map``'s range with their sentinel
    values intact, exactly as before.
    """
    scores = dot_similarity(q_raw, delta_rows)
    # top-kd by iterative masked argmax rather than lax.top_k: the CPU
    # top-k custom call sorts entire rows (~ms for a few hundred columns),
    # while kd is tiny. argmax ties to the lowest index and each winner is
    # masked below any real score (bounded by ±D), so the (value desc,
    # row asc) order is bit-identical to lax.top_k's.
    s = scores
    cols = jnp.arange(s.shape[1], dtype=jnp.int32)[None, :]
    dvs, dis = [], []
    for _ in range(kd):
        i = jnp.argmax(s, axis=1).astype(jnp.int32)
        dvs.append(jnp.take_along_axis(s, i[:, None], axis=1))
        dis.append(i[:, None])
        s = jnp.where(cols == i[:, None], jnp.iinfo(jnp.int32).min, s)
    dv = jnp.concatenate(dvs, axis=1)
    di = jnp.concatenate(dis, axis=1)
    b_rows = jnp.take(b_map, jnp.clip(bi, 0, b_map.shape[0] - 1), axis=0)
    d_rows = jnp.take(d_map, di, axis=0)
    return _merge_by_row(jnp.concatenate([bv, dv], axis=1),
                         jnp.concatenate([b_rows, d_rows], axis=1), k)


def merged_search_encoded(base, delta: DeltaBank, q_enc, q_raw, k: int
                          ) -> tuple[jax.Array, jax.Array]:
    """Exact top-k over base + delta, bit-identical to a from-scratch
    rebuild over the concatenated arrays.

    ``q_enc`` is the batch in the *base* bank's storage form (packed or
    int8); ``q_raw`` the same batch as raw bipolar int8 rows for the
    unpacked delta. Returned indices are rebuilt-bank storage rows
    (original rows for plain banks; the sorted layout for OMS banks,
    matching what exact search over a rebuilt OMS bank returns).
    """
    from repro.serve.db_search import search_database_encoded
    layout = delta.layout(base)
    bi, bv = search_database_encoded(base, q_enc, k)
    kd = min(k, delta.num_rows)
    return _merged_tail(jnp.asarray(delta.storage), q_raw, bi, bv,
                        jnp.asarray(layout.b_map),
                        jnp.asarray(layout.d_map), k=k, kd=kd)


@dataclasses.dataclass(frozen=True)
class MergedOMSPlan:
    """Per-batch OMS plan for a base + delta pair.

    Carries each side's own :class:`~repro.serve.oms.OMSPlan` (the delta
    plan runs on the small unpacked bank, full masked path) plus the
    *merged* candidate ranges — identical to the ranges a rebuilt bank's
    plan would hold, since they depend only on the merged precursor index.
    """

    base: OMSPlan
    delta: OMSPlan
    starts: np.ndarray       # (B, Q) int32, merged sorted-layout rows
    lens: np.ndarray         # (B, Q) int32
    candidate_fraction: float
    scanned_fraction: float

    @property
    def has_candidate(self) -> np.ndarray:
        return self.lens.sum(axis=0) > 0


def merged_oms_plan(base, delta: DeltaBank, query_prec: np.ndarray,
                    cfg: OMSConfig | None = None) -> MergedOMSPlan:
    """Host-side plan for one precursor-sorted query batch against
    base + delta. ``scanned_fraction`` counts the delta as a full scan
    (it is searched unbanded — it's small by construction)."""
    from repro.serve.db_search import oms_plan
    cfg = cfg or OMSConfig()
    layout = delta.layout(base)
    bplan = oms_plan(base, query_prec, cfg)
    dplan = oms_plan(delta.db, query_prec, cfg)
    starts, lens = layout.index.candidate_ranges(
        np.asarray(query_prec), cfg)
    q = max(starts.shape[1], 1)
    cand = float(lens.sum()) / max(q * max(layout.num_rows, 1), 1)
    base_padded = base.num_shards * base.shard_rows
    total = max(base_padded + delta.db.num_rows, 1)
    scanned = min(1.0, (bplan.scanned_fraction * base_padded
                        + delta.db.num_rows) / total)
    return MergedOMSPlan(base=bplan, delta=dplan, starts=starts, lens=lens,
                         candidate_fraction=cand, scanned_fraction=scanned)


def merged_oms_search_encoded(base, delta: DeltaBank, q_enc, q_raw,
                              mplan: MergedOMSPlan, k: int
                              ) -> tuple[jax.Array, jax.Array]:
    """OMS top-k over base + delta, bit-identical to a rebuilt bank.

    Each side runs its inner (pre-canonicalization) OMS route against its
    own index; candidates merge in the *merged sorted layout*, then the
    shared overflow-canonicalize + perm-translate tail runs against the
    merged index and window ranges — the same two steps a rebuilt bank's
    ``_oms_finish`` applies. Returned indices are original merged-bank
    rows (delta decoys land after base decoys, delta targets after base
    targets).
    """
    from repro.kernels.topk_hamming import canonicalize_overflow_slots
    from repro.serve.db_search import _oms_search_inner
    layout = delta.layout(base)
    bi, bv = _oms_search_inner(base, q_enc, mplan.base, k)
    kd = min(k, delta.db.num_rows)
    di, dv = _oms_search_inner(delta.db, q_raw, mplan.delta, kd)
    # kernel overflow fillers may point past the (padded) bank; clip before
    # the map gather — their values are sentinel, so the merge ranks them
    # behind every real candidate and canonicalization rewrites them
    b_rows = jnp.take(jnp.asarray(layout.b_map),
                      jnp.clip(bi, 0, base.num_rows - 1), axis=0)
    d_rows = jnp.take(jnp.asarray(layout.d_map),
                      jnp.clip(di, 0, delta.db.num_rows - 1), axis=0)
    rows, vals = _merge_by_row(jnp.concatenate([bv, dv], axis=1),
                               jnp.concatenate([b_rows, d_rows], axis=1), k)
    starts = jnp.asarray(mplan.starts, jnp.int32)
    ends = starts + jnp.asarray(mplan.lens, jnp.int32)
    s_c = jnp.clip(starts, 0, layout.num_rows)
    e_c = jnp.clip(ends, s_c, layout.num_rows)
    rows = canonicalize_overflow_slots(rows, vals, s_c, e_c, layout.num_rows)
    idx = jnp.take(jnp.asarray(layout.index.perm), rows, axis=0)
    return idx, vals
