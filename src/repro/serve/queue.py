"""Tenant-aware FIFO micro-batching queue with per-request latency accounting.

Serving throughput comes from batching queries over the 'data' mesh axis,
but requests arrive one at a time — and, multi-tenant, against different
reference banks, so a flush must be tenant-homogeneous. The queue keeps
one FIFO lane per tenant and flushes a batch when either

  * some tenant has ``max_batch_size`` requests pending (throughput
    bound), or
  * the oldest pending request (across all tenants) has waited
    ``flush_timeout_s`` (latency bound — a lone request is never
    stranded).

``take_batch`` picks the tenant with a full lane first (oldest such
lane), else the tenant owning the globally-oldest request. With a
``fairness_cap``, a flush is additionally capped at that many requests
while other tenants wait, and the tenant just served is skipped on the
next pick — so one hot tenant can neither fill every flush nor take
consecutive flushes while others are pending.

Lanes are actually keyed by ``(tenant, kind)``: a server that exposes
several request types (DB search and the clustering endpoint) gets
kind-homogeneous batches from the same flush/fairness machinery — a
tenant's search lane and cluster lane rotate against each other exactly
like two tenants would.

The clock is injectable so flush-on-timeout is deterministic to test:

>>> now = [0.0]
>>> q = MicroBatchQueue(max_batch_size=2, flush_timeout_s=1.0,
...                     clock=lambda: now[0])
>>> _ = q.submit([0.5]); q.ready()       # one pending, not timed out yet
False
>>> now[0] = 1.25
>>> q.ready()                            # oldest has waited >= 1.0s
True
>>> [r.rid for r in q.take_batch()]
[0]
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable

import numpy as np


@dataclasses.dataclass
class Request:
    """One in-flight query and its timing record.

    ``t_submit`` is stamped at *enqueue* (never at flush), so
    ``latency_s`` always includes the time spent waiting in the queue;
    ``t_dispatch`` is stamped when the request leaves the queue for the
    device (flush-sync flush, or continuous-batching slot admission),
    splitting the total into ``queue_wait_s`` + ``service_s``.
    """

    rid: int
    query: Any
    t_submit: float
    tenant: str = "default"
    t_done: float | None = None
    result: Any = None
    precursor: float | None = None  # query precursor mass (OMS serving mode)
    t_dispatch: float | None = None  # left the queue for the device
    cancelled: bool = False          # dropped by the scheduler's cancel()
    kind: str = "search"             # request type: "search" | "cluster"

    @property
    def latency_s(self) -> float:
        if self.t_done is None:
            raise ValueError(f"request {self.rid} not completed yet")
        return self.t_done - self.t_submit

    @property
    def queue_wait_s(self) -> float:
        if self.t_dispatch is None:
            raise ValueError(f"request {self.rid} not dispatched yet")
        return self.t_dispatch - self.t_submit

    @property
    def service_s(self) -> float:
        if self.t_done is None or self.t_dispatch is None:
            raise ValueError(f"request {self.rid} not completed yet")
        return self.t_done - self.t_dispatch


class MicroBatchQueue:
    """Per-tenant FIFO queues that group requests into micro-batches.

    ``submit`` never blocks; the serving loop calls ``ready`` /
    ``take_batch`` (see :class:`repro.serve.db_search.DBSearchServer`).
    Every batch returned by ``take_batch`` holds requests of a single
    tenant, in FIFO order.
    """

    def __init__(self, max_batch_size: int = 32, flush_timeout_s: float = 0.01,
                 clock: Callable[[], float] = time.monotonic,
                 fairness_cap: int | None = None):
        if max_batch_size < 1:
            raise ValueError(f"max_batch_size must be >= 1, got {max_batch_size}")
        if flush_timeout_s < 0:
            raise ValueError(f"flush_timeout_s must be >= 0, got {flush_timeout_s}")
        if fairness_cap is not None and fairness_cap < 1:
            raise ValueError(f"fairness_cap must be >= 1, got {fairness_cap}")
        self.max_batch_size = int(max_batch_size)
        self.flush_timeout_s = float(flush_timeout_s)
        self.fairness_cap = fairness_cap
        self._clock = clock
        # lane key: (tenant, kind) — see module docstring
        self._pending: dict[tuple[str, str],
                            collections.deque[Request]] = {}
        self._next_rid = 0
        self._last_served: tuple[str, str] | None = None

    def __len__(self) -> int:
        return sum(len(d) for d in self._pending.values())

    def pending_tenants(self) -> list[str]:
        """Tenants with at least one pending request (insertion order)."""
        return list(dict.fromkeys(t for t, _ in self._pending))

    def submit(self, query, tenant: str = "default",
               precursor: float | None = None,
               kind: str = "search") -> int:
        """Enqueue one query; returns its request id (FIFO-ordered)."""
        req = Request(rid=self._next_rid, query=query, tenant=tenant,
                      t_submit=self._clock(), precursor=precursor,
                      kind=kind)
        self._next_rid += 1
        self._pending.setdefault((tenant, kind),
                                 collections.deque()).append(req)
        return req.rid

    def cancel(self, rid: int) -> bool:
        """Remove a still-pending request from its lane. Returns False when
        ``rid`` is not pending (already taken by a flush, or unknown) —
        in-flight cancellation is the scheduler's job."""
        for key, lane in self._pending.items():
            for r in lane:
                if r.rid == rid:
                    lane.remove(r)
                    if not lane:
                        del self._pending[key]
                    return True
        return False

    def _oldest(self) -> Request | None:
        heads = [d[0] for d in self._pending.values() if d]
        return min(heads, key=lambda r: r.rid) if heads else None

    def oldest_age_s(self) -> float | None:
        oldest = self._oldest()
        if oldest is None:
            return None
        return self._clock() - oldest.t_submit

    def ready(self) -> bool:
        """True when a batch should flush: some tenant's lane is full, or
        the globally-oldest request timed out."""
        if any(len(d) >= self.max_batch_size for d in self._pending.values()):
            return True
        age = self.oldest_age_s()
        return age is not None and age >= self.flush_timeout_s

    def time_until_flush(self) -> float | None:
        """Seconds until the timeout would flush; None when the queue is
        empty, 0.0 when already flushable. Lets a serving loop sleep
        precisely."""
        if not len(self):
            return None
        if any(len(d) >= self.max_batch_size for d in self._pending.values()):
            return 0.0
        return max(0.0, self.flush_timeout_s - self.oldest_age_s())

    def _next_lane(self) -> tuple[str, str] | None:
        """The lane the next ``take_batch`` would serve: the oldest full
        lane, else the lane of the globally-oldest request — except that,
        under a ``fairness_cap``, the lane served by the previous flush
        is skipped while other lanes are waiting."""
        lanes = self._pending
        if (self.fairness_cap is not None and len(lanes) > 1
                and self._last_served in lanes):
            lanes = {t: d for t, d in lanes.items() if t != self._last_served}
        full = [d[0] for d in lanes.values()
                if len(d) >= self.max_batch_size]
        if full:
            head = min(full, key=lambda r: r.rid)
        else:
            heads = [d[0] for d in lanes.values() if d]
            if not heads:
                return None
            head = min(heads, key=lambda r: r.rid)
        return (head.tenant, head.kind)

    def next_tenant(self) -> str | None:
        """The tenant the next ``take_batch`` would serve (see
        ``_next_lane`` — lane selection is per (tenant, kind))."""
        lane = self._next_lane()
        return None if lane is None else lane[0]

    def take_batch(self) -> list[Request]:
        """Pop up to ``max_batch_size`` requests of one lane (single
        tenant, single kind) in FIFO order (may be called
        unconditionally, e.g. to drain on shutdown). With other lanes
        waiting, the flush is additionally capped at ``fairness_cap``
        requests."""
        key = self._next_lane()
        if key is None:
            return []
        lane = self._pending[key]
        n = min(len(lane), self.max_batch_size)
        if self.fairness_cap is not None and len(self._pending) > 1:
            n = min(n, self.fairness_cap)
        batch = [lane.popleft() for _ in range(n)]
        if not lane:
            del self._pending[key]
        self._last_served = key
        return batch


class LatencyStats:
    """Streaming per-request latency + batch-size accounting.

    Counts and timestamps are exact running values; percentiles/means are
    computed over a bounded sliding window of the most recent ``window``
    requests, so a long-lived server's memory and ``summary`` cost stay
    O(window) under sustained traffic.
    """

    def __init__(self, window: int = 8192):
        self._latencies: collections.deque[float] = collections.deque(
            maxlen=window)
        self._queue_waits: collections.deque[float] = collections.deque(
            maxlen=window)
        self._batch_sizes: collections.deque[int] = collections.deque(
            maxlen=window)
        self._count = 0
        self._batches = 0
        self._t_first: float | None = None
        self._t_last: float | None = None

    def record_batch(self, requests: list[Request]) -> None:
        """Record a completed batch (each request must have ``t_done``)."""
        if not requests:
            return
        self._batches += 1
        self._batch_sizes.append(len(requests))
        for r in requests:
            self._count += 1
            self._latencies.append(r.latency_s)
            if r.t_dispatch is not None:
                self._queue_waits.append(r.queue_wait_s)
            if self._t_first is None or r.t_submit < self._t_first:
                self._t_first = r.t_submit
            if self._t_last is None or r.t_done > self._t_last:
                self._t_last = r.t_done

    @property
    def count(self) -> int:
        return self._count

    def summary(self) -> dict:
        """{count, batches, mean_batch, qps, p50_ms, p95_ms, mean_ms,
        queue_wait_p50_ms, queue_wait_p95_ms} — count/batches/qps over the
        full history, the rest over the latest ``window`` requests. The
        ``queue_wait_*`` split (time before dispatch, part of every
        latency number) is 0.0 when no request carried ``t_dispatch``."""
        if not self._count:
            return {"count": 0, "batches": 0, "mean_batch": 0.0, "qps": 0.0,
                    "p50_ms": 0.0, "p95_ms": 0.0, "mean_ms": 0.0,
                    "queue_wait_p50_ms": 0.0, "queue_wait_p95_ms": 0.0}
        lat = np.asarray(self._latencies)
        span = max(self._t_last - self._t_first, 1e-9)
        qw = np.asarray(self._queue_waits) if self._queue_waits else None
        return {
            "count": self._count,
            "batches": self._batches,
            "mean_batch": float(np.mean(self._batch_sizes)),
            "qps": float(self._count / span),
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p95_ms": float(np.percentile(lat, 95) * 1e3),
            "mean_ms": float(lat.mean() * 1e3),
            "queue_wait_p50_ms": (0.0 if qw is None
                                  else float(np.percentile(qw, 50) * 1e3)),
            "queue_wait_p95_ms": (0.0 if qw is None
                                  else float(np.percentile(qw, 95) * 1e3)),
        }
