"""Sharded HD database search: local top-k per shard, global top-k merge.

The reference bank (targets + decoys, bipolar HVs) is sharded row-wise
over the ``model`` mesh axis; queries are batched over ``data``. Each
shard scores its ``R/n`` rows — via the bit-packed XOR+popcount path when
``D % 32 == 0`` (:func:`repro.core.hd.similarity.topk_search_packed`'s
kernel), else the int matmul — keeps its local ``lax.top_k``, and only
the ``Q x k`` candidate (index, score) pairs per shard cross the
interconnect (``all_gather`` over ``model``), never the full ``Q x R``
score matrix. A second ``lax.top_k`` over the ``Q x (n*k)`` gathered
candidates produces the global result.

**Fused per-shard search.** With ``shard_database(..., fused=True)`` the
per-shard score-then-top-k pair is replaced by the streaming Pallas
kernel (:mod:`repro.kernels.topk_hamming`): score tiles stay in VMEM and
the running top-k is carried across reference tiles in scratch, so even
*per shard* the ``Q x R/n`` score matrix never reaches HBM — candidate
traffic is O(Q·k) end to end. The kernel reproduces ``lax.top_k``
tie-breaking exactly, so every bit-identity invariant below holds
unchanged on the fused path (the global k-merge is shared code).

**Bit-identity with the unsharded oracle.** ``lax.top_k`` breaks ties
toward the lower position. Each shard's local top-k orders tied scores by
ascending local (hence global) index, and the gather concatenates shard
blocks in ascending shard-offset order, so within any tied score the
gathered candidates appear in ascending *global* index order — the merge
therefore selects exactly the rows the unsharded
:func:`repro.core.hd.similarity.topk_search` would. A row pruned by its
shard's local top-k is beaten by k rows of the same shard and so can
never appear in the global top-k. Ragged banks are padded to equal shard
sizes and padding columns are masked to ``INT32_MIN`` (strictly below any
real score, which is bounded by ``-D``).

**Degradation.** With no mesh (or a size-1 ``model`` axis) everything
falls back to the single-device ``topk_search`` path; a query batch not
divisible by the ``data`` axis is replicated instead of batch-sharded —
same contract as ``repro.dist.sharding``.

**FDR routing.** The bank stores decoys *before* targets so that on a
target/decoy score tie the decoy (lower row) wins the merged top-1 —
exactly the conservative ``best_target > best_decoy`` competition of
``repro.core.pipeline.run_db_search`` — and the rank-0 candidate alone
determines the competition outcome fed to ``repro.spectra.fdr``.

**Serving layer.** :class:`DBSearchServer` runs the host-side loop:
tenant-homogeneous micro-batches out of
:class:`~repro.serve.queue.MicroBatchQueue`, per-tenant banks out of a
:class:`~repro.serve.cache.BankRegistry` (lazy shard-on-first-use, LRU),
query encodes memoized in a :class:`~repro.serve.cache.QueryHVCache`,
and batch shapes padded to a bounded bucket ladder so tenant switches
reuse the jit cache instead of recompiling. Device work runs behind the
:class:`SearchExecutor` dispatch/poll/finalize seam, shared by the
synchronous flush loop and the continuous-batching scheduler
(:mod:`repro.serve.scheduler`); with a :class:`QueryEncoder` the server
additionally accepts *raw quantized spectra* and encodes on the device —
staged, or as one fused encode->pack->search kernel dispatch per shard
(:mod:`repro.kernels.encode_search`, ``fused_e2e=True``).
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.hd.encoding import (
    HDEncoderConfig,
    encode_levels_batch,
    make_codebooks,
)
from repro.core.hd.similarity import (
    bitpack_bipolar,
    dot_similarity,
    hamming_similarity_packed,
    topk_search,
)
from repro.kernels.block_utils import validate_block
from repro.serve.cache import BankRegistry, QueryHVCache
from repro.serve.clustering import ClusteringConfig, StreamingClusterer
from repro.serve.oms import (
    OMSConfig,
    OMSPlan,
    PrecursorIndex,
    build_precursor_index,
    plan_candidates,
)
from repro.serve.queue import LatencyStats, MicroBatchQueue, Request
from repro.serve.scheduler import ContinuousScheduler
from repro.spectra.fdr import fdr_filter

_SENTINEL = jnp.iinfo(jnp.int32).min
_OMS_ALIGN = 128  # shard_rows alignment for OMS banks (= kernel block_r), so
                  # shard bases stay tile-aligned and per-shard band spans
                  # never exceed the host-side plan's tile budget
_OMS_BLOCK_Q = 8  # banded-kernel Q-block: the tile budget is per Q block, so
                  # narrow blocks of precursor-adjacent queries (the server
                  # sorts each batch) keep the scanned span near the window
                  # width instead of the batch's full mass spread


# --------------------------------------------------------------------------
# per-shard compute + merge (pure; shared by shard_map and the emulated path)
# --------------------------------------------------------------------------

def _local_scores(queries, refs_local, *, dim: int, packed: bool) -> jax.Array:
    """(Q, *) x (Rl, *) -> (Q, Rl) int32 dot-product-scale scores."""
    if packed:
        # 2 * hamming_sim - dim == <q, r> for bipolar HVs, exactly
        return 2 * hamming_similarity_packed(queries, refs_local, dim) - dim
    return dot_similarity(queries, refs_local)


def _local_topk(scores, base, k: int, num_rows: int):
    """Per-shard top-k with padding mask and global index translation.

    base: this shard's first global row (int). Padding columns (global row
    >= num_rows) are masked to a sentinel below any real score.
    Returns (vals (Q, k), global_idx (Q, k)).
    """
    shard_rows = scores.shape[-1]
    col = base + jnp.arange(shard_rows, dtype=jnp.int32)
    scores = jnp.where(col[None, :] < num_rows, scores, _SENTINEL)
    vals, local_idx = jax.lax.top_k(scores, k)
    return vals, local_idx.astype(jnp.int32) + base


def _local_topk_fused(queries, refs_local, base, k: int, num_rows: int,
                      dim: int, block_q: int | None = None,
                      block_r: int | None = None,
                      word_chunk: int | None = None):
    """Fused twin of ``_local_scores`` + ``_local_topk``: the streaming
    Pallas kernel computes tile scores and keeps the running top-k in
    VMEM, so this shard's (Q, Rl) score matrix never reaches HBM.

    base may be a python int (emulated shards) or a traced scalar (the
    shard_map path); the kernel masks rows past ``num_rows - base`` to
    the same sentinel ``_local_topk`` uses, and returns local indices
    that translate to global rows by adding ``base`` — bit-identical to
    the unfused pair, tie order included. Block overrides (the bank's
    ``shard_database(..., block_q=...)`` settings) pass straight to the
    kernel; None defers to the tuning table / defaults.
    """
    # deferred like similarity.topk_search_packed: the kernel package is
    # only pulled in when a fused bank is actually searched
    from repro.kernels.topk_hamming import topk_hamming_pallas
    shard_rows = refs_local.shape[0]
    num_valid = jnp.clip(jnp.asarray(num_rows - base, jnp.int32),
                         0, shard_rows)
    idx, vals = topk_hamming_pallas(queries, refs_local, dim=dim, k=k,
                                    num_valid=num_valid, block_q=block_q,
                                    block_r=block_r, word_chunk=word_chunk)
    return vals, idx + jnp.asarray(base, jnp.int32)


def _merge_topk(cand_vals, cand_idx, k: int):
    """Global top-k over gathered per-shard candidates (Q, n*k).

    Candidate blocks must be concatenated in ascending shard order so the
    positional tie-break reproduces the global ascending-index tie-break.
    Returns (idx (Q, k), vals (Q, k)) — the ``topk_search`` contract.
    """
    vals, pos = jax.lax.top_k(cand_vals, k)
    idx = jnp.take_along_axis(cand_idx, pos, axis=-1)
    return idx, vals


def _local_oms_topk(q_enc, refs_local, base, k: int, num_rows: int, dim: int,
                    packed: bool, starts, ends):
    """Unfused per-shard OMS top-k: full local scores, sentinel-masked
    outside every query's per-block band (global sorted-layout rows in
    ``starts``/``ends``, each (B, Q)) and past ``num_rows``.

    This *is* the masked-full-matrix oracle restricted to one shard — the
    banded kernel below must match it bit-exactly.
    """
    scores = _local_scores(q_enc, refs_local, dim=dim, packed=packed)
    shard_rows = refs_local.shape[0]
    col = (jnp.asarray(base, jnp.int32)
           + jnp.arange(shard_rows, dtype=jnp.int32))[None, :]
    band = jnp.zeros(scores.shape, bool)
    for b in range(starts.shape[0]):  # static B (1 or 2) bands per query
        band = band | ((col >= starts[b][:, None]) & (col < ends[b][:, None]))
    scores = jnp.where(band & (col < num_rows), scores, _SENTINEL)
    vals, local_idx = jax.lax.top_k(scores, k)
    return vals, local_idx.astype(jnp.int32) + jnp.asarray(base, jnp.int32)


def _local_oms_topk_fused(q_enc, refs_local, base, k: int, num_rows: int,
                          dim: int, starts, ends, num_tiles: int):
    """Banded-kernel twin of ``_local_oms_topk``: one kernel launch per
    band (decoy block, target block), each scanning only ``num_tiles`` R
    tiles around that band, then a local merge over the 2k candidates.

    Band blocks concatenate in ascending global-row order (decoy rows
    precede target rows in the sorted layout) so the merge's positional
    tie-break keeps the global ascending-index tie-break. Overflow slots
    keep their kernel fillers — sentinel-valued, overwritten by the
    caller's global canonicalization — hence ``canonicalize=False``.
    """
    from repro.kernels.topk_hamming import topk_hamming_banded_pallas
    shard_rows = refs_local.shape[0]
    nv = jnp.clip(jnp.asarray(num_rows - base, jnp.int32), 0, shard_rows)
    vals_blocks, idx_blocks = [], []
    for b in range(starts.shape[0]):
        s_l = jnp.clip(starts[b] - base, 0, shard_rows).astype(jnp.int32)
        e_l = jnp.clip(ends[b] - base, s_l, shard_rows).astype(jnp.int32)
        idx, vals = topk_hamming_banded_pallas(
            q_enc, refs_local, s_l, e_l - s_l, dim=dim, k=k, num_valid=nv,
            num_tiles=num_tiles, block_q=_OMS_BLOCK_Q, canonicalize=False)
        vals_blocks.append(vals)
        idx_blocks.append(idx + jnp.asarray(base, jnp.int32))
    if len(vals_blocks) == 1:
        return vals_blocks[0], idx_blocks[0]
    idx, vals = _merge_topk(jnp.concatenate(vals_blocks, axis=1),
                            jnp.concatenate(idx_blocks, axis=1), k)
    return vals, idx


def _local_oms(q_enc, refs_local, base, k: int, num_rows: int, dim: int,
               packed: bool, fused: bool, starts, ends, num_tiles: int):
    """Per-shard OMS top-k, fused or unfused. Returns (vals, global_idx)."""
    if fused:
        return _local_oms_topk_fused(q_enc, refs_local, base, k, num_rows,
                                     dim, starts, ends, num_tiles)
    return _local_oms_topk(q_enc, refs_local, base, k, num_rows, dim,
                           packed, starts, ends)


# --------------------------------------------------------------------------
# sharded database
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedDatabase:
    """A reference bank prepared for sharded search.

    data holds ``num_shards * shard_rows`` rows (zero-padded past
    ``num_rows``), bit-packed to uint32 words when ``packed``; rows
    ``[0, num_decoys)`` are decoys, ``[num_decoys, num_rows)`` targets.

    With ``oms`` set (the bank was built with ``precursor=``), each block
    is stored sorted by precursor mass and ``oms.perm`` maps sorted rows
    back to original block rows — search results from the OMS routes are
    translated before they leave :func:`oms_search_encoded`, so callers
    always see original row numbering.
    """

    data: jax.Array
    num_rows: int
    num_decoys: int
    dim: int
    shard_rows: int
    packed: bool
    mesh: Mesh | None
    axis: str
    emulated_shards: int = 1
    fused: bool = False
    oms: PrecursorIndex | None = None
    # explicit per-bank kernel tile overrides for the fused paths; None
    # defers to the active tuning table / defaults at trace time
    block_q: int | None = None
    block_r: int | None = None
    word_chunk: int | None = None

    @property
    def num_targets(self) -> int:
        return self.num_rows - self.num_decoys

    @property
    def num_shards(self) -> int:
        if self.mesh is None or self.axis not in self.mesh.shape:
            return self.emulated_shards
        return self.mesh.shape[self.axis]


def shard_database(refs: jax.Array, *, decoys: jax.Array | None = None,
                   mesh: Mesh | None = None, axis: str = "model",
                   pack: bool | str = "auto",
                   emulate_shards: int | None = None,
                   fused: bool = False,
                   precursor: np.ndarray | None = None,
                   decoy_precursor: np.ndarray | None = None,
                   block_q: int | None = None,
                   block_r: int | None = None,
                   word_chunk: int | None = None
                   ) -> ShardedDatabase:
    """Build a :class:`ShardedDatabase` from bipolar (R, D) reference HVs.

    decoys: optional (Rd, D) decoy HVs, stored *before* the targets (see
      module docstring for why the order matters).
    pack: True / False / "auto" (bit-pack whenever D % 32 == 0).
    emulate_shards: with no mesh, pad/slice the bank as if it were split
      into this many shards and run the identical local-top-k/merge
      pipeline shard-by-shard on one device — the tier-1 stand-in for the
      shard_map path (mutually exclusive with a >1 ``axis`` mesh).
    fused: route per-shard search through the streaming top-k Pallas
      kernel (``repro.kernels.topk_hamming``) instead of materializing
      each shard's (Q, R/n) score matrix — bit-identical results; packed
      banks take the XOR+popcount tile path, unpacked banks the int8-dot
      variant.
    precursor: optional (R,) per-target precursor masses — enables the OMS
      routes: each block is stored precursor-sorted (decoys still before
      targets; blocks sort independently so the decoy-wins-ties order
      survives) with the permutation kept for index translation.
    decoy_precursor: per-decoy masses; defaults to ``precursor`` (decoys
      from ``make_decoys`` reverse the m/z axis but keep the mass).
    block_q/block_r/word_chunk: explicit kernel tile sizes for this bank's
      fused search paths (validated here against the TPU tile alignment);
      ``None`` defers to the active tuning table / kernel defaults at
      trace time (:mod:`repro.kernels.block_utils`). The OMS banded
      routes keep their fixed ``block_q``/``block_r`` (the host-side tile
      budget is priced in those units) regardless of these overrides.
    The padded bank is device_put row-sharded over ``axis`` when a mesh
    with that axis (size > 1) is supplied; otherwise it stays local.
    """
    for _name, _val in (("block_q", block_q), ("block_r", block_r),
                        ("word_chunk", word_chunk)):
        if _val is not None:
            validate_block("topk_hamming", _name, _val)
    dim = int(refs.shape[-1])
    num_decoys = 0
    bank = refs
    if decoys is not None:
        if decoys.shape[-1] != dim:
            raise ValueError(f"decoy dim {decoys.shape[-1]} != ref dim {dim}")
        num_decoys = int(decoys.shape[0])
        bank = jnp.concatenate([decoys, refs], axis=0)
    num_rows = int(bank.shape[0])

    oms_index = None
    if precursor is not None:
        prec = np.asarray(precursor, np.float32).reshape(-1)
        if prec.shape[0] != int(refs.shape[0]):
            raise ValueError(
                f"precursor has {prec.shape[0]} entries for "
                f"{int(refs.shape[0])} refs")
        dprec = None
        if decoys is not None:
            dprec = prec if decoy_precursor is None else np.asarray(
                decoy_precursor, np.float32).reshape(-1)
            if dprec.shape[0] != num_decoys:
                raise ValueError(
                    f"decoy_precursor has {dprec.shape[0]} entries for "
                    f"{num_decoys} decoys")
        oms_index = build_precursor_index(prec, dprec)
        bank = bank[jnp.asarray(oms_index.perm)]

    if pack == "auto":
        packed = dim % 32 == 0
    else:
        packed = bool(pack)
        if packed and dim % 32 != 0:
            raise ValueError(f"pack=True requires D % 32 == 0, got D={dim}")
    store = bitpack_bipolar(bank) if packed else bank.astype(jnp.int8)

    mesh_n = mesh.shape[axis] if (mesh is not None and axis in mesh.shape) else 1
    emu = int(emulate_shards or 1)
    if emu > 1 and mesh_n > 1:
        raise ValueError("emulate_shards requires no (or size-1) mesh axis")
    n = mesh_n if mesh_n > 1 else emu
    shard_rows = -(-num_rows // n)  # ceil
    if oms_index is not None and n > 1:
        # tile-align shard bases: every shard's clipped band then spans at
        # most as many kernel tiles as the global band does, so one static
        # host-side tile budget covers all shards
        shard_rows = -(-shard_rows // _OMS_ALIGN) * _OMS_ALIGN
    pad_rows = n * shard_rows - num_rows
    if pad_rows:
        store = jnp.pad(store, ((0, pad_rows), (0, 0)))
    if mesh_n > 1:
        store = jax.device_put(store, NamedSharding(mesh, P(axis, None)))
    return ShardedDatabase(data=store, num_rows=num_rows, num_decoys=num_decoys,
                           dim=dim, shard_rows=shard_rows, packed=packed,
                           mesh=mesh if mesh_n > 1 else None, axis=axis,
                           emulated_shards=emu if mesh_n == 1 else 1,
                           fused=bool(fused), oms=oms_index,
                           block_q=block_q, block_r=block_r,
                           word_chunk=word_chunk)


@functools.lru_cache(maxsize=None)
def _sharded_search_fn(mesh: Mesh, axis: str, shard_rows: int, num_rows: int,
                       dim: int, packed: bool, k: int, batch_sharded: bool,
                       fused: bool = False,
                       blocks: tuple[int | None, ...] = (None, None, None)):
    """Compile the shard_map search for one (db geometry, k, batch,
    block-override) signature — ``blocks`` is (block_q, block_r,
    word_chunk) and joins the cache key so banks with different explicit
    tiles never share a stale compile."""
    q_spec = P("data", None) if batch_sharded else P(None, None)
    block_q, block_r, word_chunk = blocks

    def body(q, refs_local):
        base = jax.lax.axis_index(axis).astype(jnp.int32) * shard_rows
        if fused:
            vals, gidx = _local_topk_fused(q, refs_local, base, k, num_rows,
                                           dim, block_q=block_q,
                                           block_r=block_r,
                                           word_chunk=word_chunk)
        else:
            scores = _local_scores(q, refs_local, dim=dim, packed=packed)
            vals, gidx = _local_topk(scores, base, k, num_rows)
        # Q x k per shard on the wire — all_gather concatenates the shard
        # blocks in ascending axis order (the tie-break invariant).
        vals_all = jax.lax.all_gather(vals, axis, axis=1, tiled=True)
        idx_all = jax.lax.all_gather(gidx, axis, axis=1, tiled=True)
        return _merge_topk(vals_all, idx_all, k)

    return jax.jit(shard_map(
        body, mesh=mesh, in_specs=(q_spec, P(axis, None)),
        out_specs=(q_spec, q_spec), check_rep=False))


@functools.lru_cache(maxsize=None)
def _sharded_oms_fn(mesh: Mesh, axis: str, shard_rows: int, num_rows: int,
                    dim: int, packed: bool, k: int, batch_sharded: bool,
                    fused: bool, num_bands: int, num_tiles: int):
    """Compile the shard_map OMS search for one (geometry, k, batch, tile
    budget) signature. ``num_tiles`` is bucketed host-side (power of two)
    so repeated batches with similar window spans share a compile."""
    q_spec = P("data", None) if batch_sharded else P(None, None)
    band_spec = P(None, "data") if batch_sharded else P(None, None)

    def body(q, starts, ends, refs_local):
        base = jax.lax.axis_index(axis).astype(jnp.int32) * shard_rows
        vals, gidx = _local_oms(q, refs_local, base, k, num_rows, dim,
                                packed, fused, starts, ends, num_tiles)
        vals_all = jax.lax.all_gather(vals, axis, axis=1, tiled=True)
        idx_all = jax.lax.all_gather(gidx, axis, axis=1, tiled=True)
        return _merge_topk(vals_all, idx_all, k)

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(q_spec, band_spec, band_spec, P(axis, None)),
        out_specs=(q_spec, q_spec), check_rep=False))


def encode_queries(db: ShardedDatabase, queries: jax.Array) -> jax.Array:
    """Encode (Q, D) bipolar queries into the bank's storage form.

    Deterministic (bit-pack to uint32 words when the bank is packed, else
    an int8 cast) — which is what makes memoizing the result in
    :class:`~repro.serve.cache.QueryHVCache` safe: cached and cold
    encodes are bit-identical by construction.
    """
    return bitpack_bipolar(queries) if db.packed else queries.astype(jnp.int8)


def _check_k(db: ShardedDatabase, k: int) -> None:
    if k > db.num_rows:
        raise ValueError(f"k={k} > bank rows {db.num_rows}")
    if k > db.shard_rows:
        raise ValueError(
            f"k={k} exceeds shard_rows={db.shard_rows}; use fewer shards or "
            f"a smaller k (local top-k needs k candidates per shard)")


def search_database_encoded(db: ShardedDatabase, q_enc: jax.Array, k: int
                            ) -> tuple[jax.Array, jax.Array]:
    """Top-k search over *already encoded* queries (see
    :func:`encode_queries`) — the serving hot path, where encodes come
    out of the query-HV cache."""
    _check_k(db, k)

    if db.mesh is None:
        if db.emulated_shards > 1:
            vals_blocks, idx_blocks = [], []
            for s in range(db.emulated_shards):
                r_local = db.data[s * db.shard_rows:(s + 1) * db.shard_rows]
                if db.fused:
                    vals, gidx = _local_topk_fused(
                        q_enc, r_local, s * db.shard_rows, k, db.num_rows,
                        db.dim, block_q=db.block_q, block_r=db.block_r,
                        word_chunk=db.word_chunk)
                else:
                    scores = _local_scores(q_enc, r_local, dim=db.dim,
                                           packed=db.packed)
                    vals, gidx = _local_topk(scores, s * db.shard_rows, k,
                                             db.num_rows)
                vals_blocks.append(vals)
                idx_blocks.append(gidx)
            return _merge_topk(jnp.concatenate(vals_blocks, axis=1),
                               jnp.concatenate(idx_blocks, axis=1), k)
        if db.fused:
            vals, gidx = _local_topk_fused(q_enc, db.data, 0, k, db.num_rows,
                                           db.dim, block_q=db.block_q,
                                           block_r=db.block_r,
                                           word_chunk=db.word_chunk)
            return gidx, vals
        scores = _local_scores(q_enc, db.data, dim=db.dim, packed=db.packed)
        vals, gidx = _local_topk(scores, 0, k, db.num_rows)
        return gidx, vals

    data_n = db.mesh.shape.get("data", 1)
    batch_sharded = data_n > 1 and q_enc.shape[0] % data_n == 0
    fn = _sharded_search_fn(db.mesh, db.axis, db.shard_rows, db.num_rows,
                            db.dim, db.packed, k, batch_sharded, db.fused,
                            (db.block_q, db.block_r, db.word_chunk))
    return fn(q_enc, db.data)


def search_database(db: ShardedDatabase, queries: jax.Array, k: int
                    ) -> tuple[jax.Array, jax.Array]:
    """Top-k search of (Q, D) bipolar queries against a sharded bank.

    Returns (indices (Q, k), scores (Q, k)) over global bank rows,
    bit-identical to ``topk_search(queries, bank)`` on one device.
    """
    return search_database_encoded(db, encode_queries(db, queries), k)


# --------------------------------------------------------------------------
# open-modification search (OMS) routes
# --------------------------------------------------------------------------

def oms_plan(db: ShardedDatabase, query_prec: np.ndarray,
             cfg: OMSConfig | None = None) -> OMSPlan:
    """Host-side candidate plan for one query batch against an OMS bank:
    per-query per-block ``[start, len)`` ranges in the sorted layout, plus
    the static tile budget the banded kernel needs."""
    if db.oms is None:
        raise ValueError("bank was built without precursor=; OMS search "
                         "needs shard_database(..., precursor=...)")
    return plan_candidates(db.oms, np.asarray(query_prec),
                           cfg or OMSConfig(),
                           num_rows_padded=db.num_shards * db.shard_rows,
                           block_q=_OMS_BLOCK_Q)


def oms_search_encoded(db: ShardedDatabase, q_enc: jax.Array, plan: OMSPlan,
                       k: int) -> tuple[jax.Array, jax.Array]:
    """OMS top-k over already-encoded queries: every query scores only the
    bank rows inside its precursor window.

    Bit-identical — tie order and overflow slots included — to sentinel-
    masking the full score matrix over the sorted bank outside the plan's
    bands, running ``lax.top_k``, and translating the winners through
    ``db.oms.perm``: the per-shard/banded decomposition preserves the
    ascending-global-index tie-break exactly like the exact-search routes,
    and sentinel overflow slots (window narrower than k) are rewritten to
    the oracle's ascending masked rows before translation. Returned
    indices are *original* bank rows (decoys still ``< db.num_decoys``).
    """
    starts = jnp.asarray(plan.starts, jnp.int32)     # (B, Q)
    ends = starts + jnp.asarray(plan.lens, jnp.int32)
    idx, vals = _oms_search_inner(db, q_enc, plan, k)
    return _oms_finish(db, idx, vals, starts, ends)


def _oms_search_inner(db: ShardedDatabase, q_enc: jax.Array, plan: OMSPlan,
                      k: int) -> tuple[jax.Array, jax.Array]:
    """The routed banded search *before* the shared tail: returns top-k
    (sorted-layout idx, vals) with kernel overflow fillers still in place
    (sentinel-valued). Callers — :func:`oms_search_encoded` and the
    base+delta merge in :mod:`repro.serve.delta` — run overflow
    canonicalization + perm translation against *their* index."""
    if db.oms is None:
        raise ValueError("bank was built without precursor=")
    _check_k(db, k)
    starts = jnp.asarray(plan.starts, jnp.int32)     # (B, Q)
    ends = starts + jnp.asarray(plan.lens, jnp.int32)
    nt = int(plan.num_tiles)

    if db.mesh is None:
        if db.emulated_shards > 1:
            vals_blocks, idx_blocks = [], []
            for s in range(db.emulated_shards):
                r_local = db.data[s * db.shard_rows:(s + 1) * db.shard_rows]
                vals, gidx = _local_oms(
                    q_enc, r_local, s * db.shard_rows, k, db.num_rows,
                    db.dim, db.packed, db.fused, starts, ends, nt)
                vals_blocks.append(vals)
                idx_blocks.append(gidx)
            idx, vals = _merge_topk(jnp.concatenate(vals_blocks, axis=1),
                                    jnp.concatenate(idx_blocks, axis=1), k)
        else:
            vals, idx = _local_oms(q_enc, db.data, 0, k, db.num_rows,
                                   db.dim, db.packed, db.fused, starts, ends,
                                   nt)
    else:
        data_n = db.mesh.shape.get("data", 1)
        batch_sharded = data_n > 1 and q_enc.shape[0] % data_n == 0
        fn = _sharded_oms_fn(db.mesh, db.axis, db.shard_rows, db.num_rows,
                             db.dim, db.packed, k, batch_sharded, db.fused,
                             int(starts.shape[0]), nt)
        idx, vals = fn(q_enc, starts, ends, db.data)
    return idx, vals


def _oms_finish(db: ShardedDatabase, idx, vals, starts, ends):
    """Shared OMS tail: overflow slots -> the oracle's ascending masked
    rows, then translate every (now in-range) sorted row back to its
    original bank row."""
    from repro.kernels.topk_hamming import canonicalize_overflow_slots
    s_c = jnp.clip(starts, 0, db.num_rows)
    e_c = jnp.clip(ends, s_c, db.num_rows)
    idx = canonicalize_overflow_slots(idx, vals, s_c, e_c, db.num_rows)
    idx = jnp.take(jnp.asarray(db.oms.perm), idx, axis=0)
    return idx, vals


def oms_search(db: ShardedDatabase, queries: jax.Array,
               query_prec: np.ndarray, k: int,
               cfg: OMSConfig | None = None
               ) -> tuple[jax.Array, jax.Array, OMSPlan]:
    """Open-modification top-k search of (Q, D) bipolar queries.

    Returns (indices, scores, plan) — indices over original bank rows;
    the plan carries candidate/scanned fractions for accounting.
    """
    plan = oms_plan(db, query_prec, cfg)
    idx, vals = oms_search_encoded(db, encode_queries(db, queries), plan, k)
    return idx, vals, plan


def oms_search_with_fdr(db: ShardedDatabase, queries: jax.Array,
                        query_prec: np.ndarray, k: int, fdr: float = 0.01,
                        cfg: OMSConfig | None = None) -> "FDRSearchResult":
    """OMS search + target-decoy FDR in one call. Queries whose window is
    empty are excluded from the FDR estimate (never counted as decoy
    wins) and rejected."""
    idx, vals, plan = oms_search(db, queries, query_prec, k, cfg)
    return fdr_route(db, idx, vals, fdr=fdr,
                     valid=jnp.asarray(plan.has_candidate))


# --------------------------------------------------------------------------
# end-to-end routes: raw quantized spectra in, top-k out
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QueryEncoder:
    """The query-side HD codebooks (Eq. 1) bundled for the e2e routes.

    Built from the *same* :class:`~repro.core.hd.encoding.HDEncoderConfig`
    the reference bank was encoded with (dim/num_features/num_levels/seed),
    so query and reference HVs live in one space. Holding the codebooks —
    rather than re-deriving them per batch — is what lets the serving loop
    accept raw (F,) quantized level vectors and encode on the device,
    staged or fused.
    """

    id_hvs: jax.Array     # (F, D) int8 bipolar ID codebook
    level_hvs: jax.Array  # (m, D) int8 bipolar level codebook

    @property
    def num_features(self) -> int:
        return int(self.id_hvs.shape[0])

    @property
    def dim(self) -> int:
        return int(self.id_hvs.shape[1])

    @property
    def num_levels(self) -> int:
        return int(self.level_hvs.shape[0])

    @classmethod
    def from_config(cls, *, dim: int, num_features: int, num_levels: int,
                    seed: int = 0) -> "QueryEncoder":
        id_hvs, level_hvs = make_codebooks(HDEncoderConfig(
            dim=dim, num_features=num_features, num_levels=num_levels,
            seed=seed))
        return cls(id_hvs=id_hvs, level_hvs=level_hvs)


def _check_levels(db: ShardedDatabase, enc: QueryEncoder, levels) -> None:
    if enc.dim != db.dim:
        raise ValueError(f"encoder dim {enc.dim} != bank dim {db.dim}")
    if levels.ndim != 2 or levels.shape[1] != enc.num_features:
        raise ValueError(
            f"levels shape {levels.shape} != (Q, {enc.num_features})")


def _local_topk_e2e(levels, enc: QueryEncoder, refs_local, base, k: int,
                    num_rows: int, dim: int, block_q: int | None = None,
                    block_r: int | None = None,
                    word_chunk: int | None = None):
    """Fully-fused per-shard twin of encode + ``_local_topk_fused``: one
    Pallas dispatch encodes the raw levels (Eq. 1), packs, and streams the
    shard's reference tiles — the query hypervector never reaches HBM.
    Same sentinel masking and base translation as the staged pair. The
    bank's block overrides apply where the parameter names coincide
    (``block_f`` always defers to the table / default)."""
    from repro.kernels.encode_search import encode_search_pallas
    shard_rows = refs_local.shape[0]
    nv = jnp.clip(jnp.asarray(num_rows - base, jnp.int32), 0, shard_rows)
    idx, vals = encode_search_pallas(levels, enc.id_hvs, enc.level_hvs,
                                     refs_local, dim=dim, k=k, num_valid=nv,
                                     block_q=block_q, block_r=block_r,
                                     word_chunk=word_chunk)
    return vals, idx + jnp.asarray(base, jnp.int32)


def _local_oms_e2e(levels, enc: QueryEncoder, refs_local, base, k: int,
                   num_rows: int, dim: int, starts, ends, num_tiles: int):
    """Fused-e2e twin of ``_local_oms_topk_fused``: one banded
    encode->search dispatch per band, then the same ascending-block local
    merge. Overflow slots keep their kernel fillers (``canonicalize=
    False``) for the caller's global canonicalization, exactly like the
    encoded-query path."""
    from repro.kernels.encode_search import encode_search_banded_pallas
    shard_rows = refs_local.shape[0]
    nv = jnp.clip(jnp.asarray(num_rows - base, jnp.int32), 0, shard_rows)
    vals_blocks, idx_blocks = [], []
    for b in range(starts.shape[0]):
        s_l = jnp.clip(starts[b] - base, 0, shard_rows).astype(jnp.int32)
        e_l = jnp.clip(ends[b] - base, s_l, shard_rows).astype(jnp.int32)
        idx, vals = encode_search_banded_pallas(
            levels, enc.id_hvs, enc.level_hvs, refs_local, s_l, e_l - s_l,
            dim=dim, k=k, num_valid=nv, num_tiles=num_tiles,
            block_q=_OMS_BLOCK_Q, canonicalize=False)
        vals_blocks.append(vals)
        idx_blocks.append(idx + jnp.asarray(base, jnp.int32))
    if len(vals_blocks) == 1:
        return vals_blocks[0], idx_blocks[0]
    idx, vals = _merge_topk(jnp.concatenate(vals_blocks, axis=1),
                            jnp.concatenate(idx_blocks, axis=1), k)
    return vals, idx


@functools.lru_cache(maxsize=None)
def _sharded_e2e_fn(mesh: Mesh, axis: str, shard_rows: int, num_rows: int,
                    dim: int, k: int, batch_sharded: bool,
                    blocks: tuple[int | None, ...] = (None, None, None)):
    """Compile the shard_map fused-e2e search for one (geometry, k, batch,
    block-override) signature. Codebooks are replicated; only the bank is
    row-sharded."""
    q_spec = P("data", None) if batch_sharded else P(None, None)
    rep = P(None, None)
    block_q, block_r, word_chunk = blocks

    def body(levels, id_hvs, level_hvs, refs_local):
        from repro.kernels.encode_search import encode_search_pallas
        base = jax.lax.axis_index(axis).astype(jnp.int32) * shard_rows
        nv = jnp.clip(num_rows - base, 0, shard_rows)
        idx, vals = encode_search_pallas(levels, id_hvs, level_hvs,
                                         refs_local, dim=dim, k=k,
                                         num_valid=nv, block_q=block_q,
                                         block_r=block_r,
                                         word_chunk=word_chunk)
        vals_all = jax.lax.all_gather(vals, axis, axis=1, tiled=True)
        idx_all = jax.lax.all_gather(idx + base, axis, axis=1, tiled=True)
        return _merge_topk(vals_all, idx_all, k)

    return jax.jit(shard_map(
        body, mesh=mesh, in_specs=(q_spec, rep, rep, P(axis, None)),
        out_specs=(q_spec, q_spec), check_rep=False))


@functools.lru_cache(maxsize=None)
def _sharded_oms_e2e_fn(mesh: Mesh, axis: str, shard_rows: int,
                        num_rows: int, dim: int, k: int,
                        batch_sharded: bool, num_bands: int, num_tiles: int):
    """Compile the shard_map fused-e2e OMS search (banded twin of
    ``_sharded_e2e_fn``; same tile-budget bucketing as ``_sharded_oms_fn``)."""
    q_spec = P("data", None) if batch_sharded else P(None, None)
    band_spec = P(None, "data") if batch_sharded else P(None, None)
    rep = P(None, None)

    def body(levels, id_hvs, level_hvs, starts, ends, refs_local):
        base = jax.lax.axis_index(axis).astype(jnp.int32) * shard_rows
        enc = QueryEncoder(id_hvs=id_hvs, level_hvs=level_hvs)
        vals, gidx = _local_oms_e2e(levels, enc, refs_local, base, k,
                                    num_rows, dim, starts, ends, num_tiles)
        vals_all = jax.lax.all_gather(vals, axis, axis=1, tiled=True)
        idx_all = jax.lax.all_gather(gidx, axis, axis=1, tiled=True)
        return _merge_topk(vals_all, idx_all, k)

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(q_spec, rep, rep, band_spec, band_spec, P(axis, None)),
        out_specs=(q_spec, q_spec), check_rep=False))


def search_database_levels(db: ShardedDatabase, enc: QueryEncoder,
                           levels: jax.Array, k: int, *,
                           fused_e2e: bool = False
                           ) -> tuple[jax.Array, jax.Array]:
    """Top-k search straight from raw (Q, F) quantized level vectors.

    Staged (default): Eq. 1 encode (``encode_levels_batch``) -> bank-form
    encode (``encode_queries``) -> ``search_database_encoded`` — each
    stage round-trips HBM, and the encoded rows are cacheable.

    Fused (``fused_e2e=True``): one Pallas dispatch per shard runs encode
    -> bit-pack -> streaming top-k with the query HV and score tiles held
    in VMEM throughout; only the (Q, k) winners reach HBM.

    Both paths are bit-identical — indices, scores, tie order — in every
    routed configuration (single device, emulated shards, mesh).
    """
    levels = jnp.asarray(levels, jnp.int32)
    _check_levels(db, enc, levels)
    if not fused_e2e:
        hv = encode_levels_batch(levels, enc.id_hvs, enc.level_hvs)
        return search_database_encoded(db, encode_queries(db, hv), k)
    _check_k(db, k)

    if db.mesh is None:
        if db.emulated_shards > 1:
            vals_blocks, idx_blocks = [], []
            for s in range(db.emulated_shards):
                r_local = db.data[s * db.shard_rows:(s + 1) * db.shard_rows]
                vals, gidx = _local_topk_e2e(levels, enc, r_local,
                                             s * db.shard_rows, k,
                                             db.num_rows, db.dim,
                                             block_q=db.block_q,
                                             block_r=db.block_r,
                                             word_chunk=db.word_chunk)
                vals_blocks.append(vals)
                idx_blocks.append(gidx)
            return _merge_topk(jnp.concatenate(vals_blocks, axis=1),
                               jnp.concatenate(idx_blocks, axis=1), k)
        vals, gidx = _local_topk_e2e(levels, enc, db.data, 0, k,
                                     db.num_rows, db.dim,
                                     block_q=db.block_q, block_r=db.block_r,
                                     word_chunk=db.word_chunk)
        return gidx, vals

    data_n = db.mesh.shape.get("data", 1)
    batch_sharded = data_n > 1 and levels.shape[0] % data_n == 0
    fn = _sharded_e2e_fn(db.mesh, db.axis, db.shard_rows, db.num_rows,
                         db.dim, k, batch_sharded,
                         (db.block_q, db.block_r, db.word_chunk))
    return fn(levels, enc.id_hvs, enc.level_hvs, db.data)


def oms_search_levels(db: ShardedDatabase, enc: QueryEncoder,
                      levels: jax.Array, plan: OMSPlan, k: int, *,
                      fused_e2e: bool = False
                      ) -> tuple[jax.Array, jax.Array]:
    """OMS top-k straight from raw (Q, F) level vectors (queries must be
    ordered to match ``plan`` — i.e. precursor-sorted like the bank).
    Staged vs fused exactly as :func:`search_database_levels`; both end in
    the shared overflow-canonicalize + perm-translate tail, so results are
    bit-identical to ``oms_search_encoded`` over the staged encodes."""
    levels = jnp.asarray(levels, jnp.int32)
    _check_levels(db, enc, levels)
    if db.oms is None:
        raise ValueError("bank was built without precursor=")
    if not fused_e2e:
        hv = encode_levels_batch(levels, enc.id_hvs, enc.level_hvs)
        return oms_search_encoded(db, encode_queries(db, hv), plan, k)
    _check_k(db, k)
    starts = jnp.asarray(plan.starts, jnp.int32)
    ends = starts + jnp.asarray(plan.lens, jnp.int32)
    nt = int(plan.num_tiles)

    if db.mesh is None:
        if db.emulated_shards > 1:
            vals_blocks, idx_blocks = [], []
            for s in range(db.emulated_shards):
                r_local = db.data[s * db.shard_rows:(s + 1) * db.shard_rows]
                vals, gidx = _local_oms_e2e(levels, enc, r_local,
                                            s * db.shard_rows, k,
                                            db.num_rows, db.dim, starts,
                                            ends, nt)
                vals_blocks.append(vals)
                idx_blocks.append(gidx)
            idx, vals = _merge_topk(jnp.concatenate(vals_blocks, axis=1),
                                    jnp.concatenate(idx_blocks, axis=1), k)
        else:
            vals, idx = _local_oms_e2e(levels, enc, db.data, 0, k,
                                       db.num_rows, db.dim, starts, ends, nt)
    else:
        data_n = db.mesh.shape.get("data", 1)
        batch_sharded = data_n > 1 and levels.shape[0] % data_n == 0
        fn = _sharded_oms_e2e_fn(db.mesh, db.axis, db.shard_rows,
                                 db.num_rows, db.dim, k, batch_sharded,
                                 int(starts.shape[0]), nt)
        idx, vals = fn(levels, enc.id_hvs, enc.level_hvs, starts, ends,
                       db.data)
    return _oms_finish(db, idx, vals, starts, ends)


def sharded_topk_search(queries: jax.Array, refs: jax.Array, k: int, *,
                        mesh: Mesh | None = None, axis: str = "model",
                        num_shards: int | None = None,
                        pack: bool | str = "auto",
                        fused: bool = False
                        ) -> tuple[jax.Array, jax.Array]:
    """One-shot sharded top-k (the oracle-comparable entry point).

    With ``mesh``: shard over ``axis`` via shard_map (the serving path).
    With ``num_shards`` (and no mesh): run the identical local-topk/merge
    pipeline shard-by-shard on one device — used by tier-1 tests to prove
    shard-merge correctness without a multi-device runtime.
    With neither: plain ``topk_search`` (or the fused kernel over the
    whole bank when ``fused``).
    """
    if mesh is not None:
        db = shard_database(refs, mesh=mesh, axis=axis, pack=pack,
                            fused=fused)
        return search_database(db, queries, k)
    if num_shards is None or num_shards <= 1:
        if fused:
            db = shard_database(refs, mesh=None, pack=pack, fused=True)
            return search_database(db, queries, k)
        return topk_search(queries, refs, k)
    db = shard_database(refs, mesh=None, pack=pack, emulate_shards=num_shards,
                        fused=fused)
    return search_database(db, queries, k)


# --------------------------------------------------------------------------
# FDR routing over merged results
# --------------------------------------------------------------------------

@dataclasses.dataclass
class FDRSearchResult:
    """Batch search output after target-decoy FDR filtering.

    match holds the *target-library* row (bank row minus num_decoys) for
    accepted queries, -1 otherwise.
    """

    indices: np.ndarray   # (Q, k) global bank rows
    scores: np.ndarray    # (Q, k)
    is_target: np.ndarray  # (Q,) rank-0 candidate is a target (and valid)
    accept: np.ndarray    # (Q,) passed FDR
    match: np.ndarray     # (Q,) accepted target row or -1
    valid: np.ndarray | None = None  # (Q,) had >= 1 candidate (OMS batches)


def fdr_route(db: ShardedDatabase, indices: jax.Array, scores: jax.Array,
              fdr: float = 0.01, valid: jax.Array | None = None,
              num_decoys: int | None = None) -> FDRSearchResult:
    """Target-decoy competition + FDR filter over merged top-k results.

    Only rank 0 decides the competition: because decoys precede targets in
    the bank, a score tie resolves to the decoy — the conservative
    ``best_target > best_decoy`` convention of ``run_db_search``. The FDR
    estimate is computed over the queries in this batch (the serving
    analogue of per-run filtering; callers wanting run-level FDR can
    re-filter accumulated (score, is_target) pairs).

    valid: (Q,) bool for OMS batches — False marks queries with an empty
    candidate window; they are excluded from the target/decoy counts
    (mirroring ``run_db_search``: an unmatchable query is not a decoy
    win), never accepted, and reported with ``is_target=False``.

    num_decoys: override of ``db.num_decoys`` for results whose row space
    is wider than ``db`` — the base+delta merged search
    (:mod:`repro.serve.delta`), where the decoy block spans both sides.
    """
    nd = db.num_decoys if num_decoys is None else int(num_decoys)
    top_idx = indices[:, 0]
    top_val = scores[:, 0]
    is_target = top_idx >= nd
    accept = fdr_filter(top_val.astype(jnp.float32), is_target, fdr=fdr,
                        valid=valid)
    if valid is not None:
        is_target = is_target & valid
    match = jnp.where(accept & is_target, top_idx - nd, -1)
    return FDRSearchResult(
        indices=np.asarray(indices), scores=np.asarray(scores),
        is_target=np.asarray(is_target), accept=np.asarray(accept),
        match=np.asarray(match),
        valid=None if valid is None else np.asarray(valid))


def search_with_fdr(db: ShardedDatabase, queries: jax.Array, k: int,
                    fdr: float = 0.01) -> FDRSearchResult:
    """Sharded top-k search + FDR post-filtering in one call."""
    idx, vals = search_database(db, queries, k)
    return fdr_route(db, idx, vals, fdr=fdr)


# --------------------------------------------------------------------------
# shape-bucketed dispatch
# --------------------------------------------------------------------------

def make_buckets(max_batch_size: int, num_buckets: int = 4) -> tuple[int, ...]:
    """Geometric batch-size ladder ending at ``max_batch_size``.

    E.g. ``make_buckets(32, 4) == (4, 8, 16, 32)``. Padding ragged
    flushes up to the nearest bucket keeps the set of jit signatures
    small (at most ``num_buckets`` batch shapes per bank geometry) while
    wasting at most ~2x compute on the padded rows — instead of either
    recompiling per ragged size or always padding to the maximum.
    """
    if max_batch_size < 1:
        raise ValueError(f"max_batch_size must be >= 1, got {max_batch_size}")
    if num_buckets < 1:
        raise ValueError(f"num_buckets must be >= 1, got {num_buckets}")
    bs = [int(max_batch_size)]
    while len(bs) < num_buckets and bs[-1] > 1:
        bs.append(bs[-1] // 2)
    return tuple(sorted(set(bs)))


def bucket_for(n: int, buckets: Sequence[int]) -> int:
    """The smallest bucket >= n (buckets must be sorted ascending)."""
    for b in buckets:
        if b >= n:
            return b
    raise ValueError(f"batch of {n} exceeds largest bucket {buckets[-1]}")


# --------------------------------------------------------------------------
# serving loop
# --------------------------------------------------------------------------

@dataclasses.dataclass
class QueryResult:
    """Per-request result attached by the server."""

    indices: np.ndarray  # (k,) global bank rows
    scores: np.ndarray   # (k,)
    is_target: bool
    accept: bool
    match: int           # accepted target-library row or -1
    has_candidate: bool = True  # precursor window non-empty (OMS mode)


@dataclasses.dataclass
class BatchHandle:
    """One dispatched batch's in-flight device work (executor-internal).

    ``idx``/``vals`` are *unrealized* device arrays until ``finalize``:
    JAX's async dispatch returns immediately, so the host goes back to
    assembling the next batch while the device searches this one.
    """

    reqs: list[Request]
    tenant: str
    db: ShardedDatabase
    n: int                # real rows (the rest is bucket padding)
    idx: jax.Array
    vals: jax.Array
    valid: np.ndarray | None = None  # OMS has_candidate, submit order
    inv: np.ndarray | None = None    # OMS unsort permutation
    oms: bool = False
    num_decoys: int | None = None    # merged-row-space override (delta path)


@dataclasses.dataclass
class ClusterBatchHandle:
    """In-flight clustering batch (the second handle type behind the
    scheduler seam — the scheduler treats handles opaquely, so the
    clustering endpoint needed no scheduler change). ``dists`` is the
    unrealized snapshot-distance launch; the sequential assign-or-spawn
    decision runs host-side at finalize."""

    reqs: list[Request]
    tenant: str
    n: int                       # real rows (the rest is bucket padding)
    hvs: np.ndarray              # (bucket, D) int8 batch
    dists: jax.Array | None     # (bucket, >=c0) device distances, or None
    c0: int                      # clusters covered by the snapshot
    struct_version: int          # clusterer structure at dispatch


class SearchExecutor:
    """The production device executor behind the scheduler seam.

    Implements the three-method protocol of
    :class:`~repro.serve.scheduler.ContinuousScheduler` (``dispatch`` /
    ``poll`` / ``finalize``) on top of a :class:`DBSearchServer`'s banks,
    caches, and stats — and is the *only* place serving work touches the
    device, so flush-sync and continuous modes share one code path:

      * ``dispatch`` stamps ``t_dispatch``, assembles the bucket-padded
        host batch (through the query-HV cache on the encoded/staged
        routes), ships it with ``jax.device_put`` (async H2D), and
        launches the jitted search without blocking — with two scheduler
        slots this is classic double-buffering: slot B's host-side prep
        and transfer overlap slot A's device search;
      * ``poll`` asks the result arrays whether the computation finished
        (``Array.is_ready``; conservatively True on runtimes without it);
      * ``finalize`` blocks on the device values, unsorts OMS batches,
        routes FDR, fills per-request results, stamps ``t_done``, records
        latency stats, and drops cancelled requests.

    Tests replace this class with fake executors to make scheduling
    decisions deterministic — see ``tests/test_scheduler.py``.
    """

    def __init__(self, server: "DBSearchServer"):
        self.server = server

    def dispatch(self, reqs: list[Request]) -> BatchHandle:
        srv = self.server
        t = srv._clock()
        for r in reqs:
            r.t_dispatch = t
        tenant = reqs[0].tenant
        if reqs[0].kind == "cluster":
            return self._dispatch_cluster(reqs, tenant)
        db, delta = srv.banks.get_with_delta(tenant)  # lazy shard-on-use
        n = len(reqs)
        bucket = bucket_for(n, srv.buckets)
        srv._bucket_counts[bucket] += 1
        if srv.oms is not None:
            return self._dispatch_oms(reqs, db, delta, n, bucket, tenant)
        if delta is not None:
            # merged base+delta search (bit-identical to a rebuilt bank).
            # The fused-e2e route has no encoded intermediate to hand the
            # delta, so delta batches take the staged pipeline — which is
            # bit-identical to fused by the PR 7 invariant.
            from repro.serve.delta import merged_search_encoded
            q_enc = jax.device_put(
                srv._encode_batch(reqs, db, bucket, tenant))
            q_raw = jax.device_put(srv._raw_batch(reqs, bucket))
            idx, vals = merged_search_encoded(db, delta, q_enc, q_raw,
                                              srv.k)
            return BatchHandle(
                reqs=reqs, tenant=tenant, db=db, n=n, idx=idx, vals=vals,
                num_decoys=db.num_decoys + delta.num_decoys)
        if srv.encoder is not None and srv.fused_e2e:
            batch = jax.device_put(srv._levels_batch(reqs, bucket))
            idx, vals = search_database_levels(db, srv.encoder, batch,
                                               srv.k, fused_e2e=True)
        else:
            batch = jax.device_put(
                srv._encode_batch(reqs, db, bucket, tenant))
            idx, vals = search_database_encoded(db, batch, srv.k)
        return BatchHandle(reqs=reqs, tenant=tenant, db=db, n=n, idx=idx,
                           vals=vals)

    def _dispatch_oms(self, reqs: list[Request], db: ShardedDatabase,
                      delta, n: int, bucket: int, tenant: str
                      ) -> BatchHandle:
        """OMS dispatch: precursor-sort the batch (nearby masses share
        kernel tiles, keeping the static tile budget small — pad rows
        inherit the highest real precursor), plan host-side, launch the
        banded search. Results unsort at finalize; FDR routing is
        order-independent. With a non-empty delta the plan and search run
        merged over base + delta (see :mod:`repro.serve.delta`) — the
        fused-e2e shortcut falls back to the staged pipeline for those
        batches, which is bit-identical."""
        srv = self.server
        prec = np.asarray([r.precursor for r in reqs], np.float32)
        order = np.argsort(prec, kind="stable")
        inv = np.argsort(order, kind="stable")
        prec_padded = np.concatenate(
            [prec[order], np.full(bucket - n, prec[order][-1], np.float32)])
        num_decoys = None
        if delta is not None:
            from repro.serve.delta import merged_oms_plan, \
                merged_oms_search_encoded
            mplan = merged_oms_plan(db, delta, prec_padded, srv.oms)
            batch = srv._encode_batch(reqs, db, bucket, tenant)
            q_enc = jax.device_put(
                np.concatenate([batch[:n][order], batch[n:]]))
            raw = srv._raw_batch(reqs, bucket)
            q_raw = jax.device_put(
                np.concatenate([raw[:n][order], raw[n:]]))
            idx, vals = merged_oms_search_encoded(db, delta, q_enc, q_raw,
                                                  mplan, srv.k)
            plan = mplan
            num_decoys = db.num_decoys + delta.num_decoys
        elif srv.encoder is not None and srv.fused_e2e:
            plan = oms_plan(db, prec_padded, srv.oms)
            batch = srv._levels_batch(reqs, bucket)
            sorted_batch = np.concatenate([batch[:n][order], batch[n:]])
            idx, vals = oms_search_levels(
                db, srv.encoder, jax.device_put(sorted_batch), plan, srv.k,
                fused_e2e=True)
        else:
            plan = oms_plan(db, prec_padded, srv.oms)
            batch = srv._encode_batch(reqs, db, bucket, tenant)
            sorted_batch = np.concatenate([batch[:n][order], batch[n:]])
            idx, vals = oms_search_encoded(
                db, jax.device_put(sorted_batch), plan, srv.k)
        valid = plan.has_candidate[:n][inv]
        srv._oms_batches += 1
        srv._oms_cand_frac += plan.candidate_fraction
        srv._oms_scan_frac += plan.scanned_fraction
        srv._oms_no_candidate += int((~valid).sum())
        return BatchHandle(reqs=reqs, tenant=tenant, db=db, n=n, idx=idx,
                           vals=vals, valid=valid, inv=inv, oms=True,
                           num_decoys=num_decoys)

    def _dispatch_cluster(self, reqs: list[Request], tenant: str
                          ) -> ClusterBatchHandle:
        """Clustering dispatch: launch the batch-vs-centroids distance
        matrix (device, async) against the tenant's current snapshot;
        the assign-or-spawn loop runs at finalize."""
        srv = self.server
        cl = srv.clusterers.setdefault(
            tenant, StreamingClusterer(srv.clustering))
        n = len(reqs)
        bucket = bucket_for(n, srv.buckets)
        srv._bucket_counts[bucket] += 1
        hvs = np.zeros((bucket, srv.clustering.dim), np.int8)
        for i, r in enumerate(reqs):
            hvs[i] = r.query
        dists = cl.snapshot_distances(hvs)
        return ClusterBatchHandle(reqs=reqs, tenant=tenant, n=n, hvs=hvs,
                                  dists=dists, c0=cl.num_clusters,
                                  struct_version=cl.struct_version)

    def poll(self, handle) -> bool:
        arr = (handle.dists if isinstance(handle, ClusterBatchHandle)
               else handle.vals)
        if arr is None:
            return True
        return bool(getattr(arr, "is_ready", lambda: True)())

    def _finalize_cluster(self, handle: ClusterBatchHandle) -> list[Request]:
        srv = self.server
        cl = srv.clusterers[handle.tenant]
        dists = (None if handle.dists is None
                 else np.asarray(handle.dists)[:handle.n])  # blocks
        assigns = cl.assign_batch(handle.hvs[:handle.n], dists, handle.c0,
                                  handle.struct_version)
        t_done = srv._clock()
        live: list[Request] = []
        for r, a in zip(handle.reqs, assigns):
            if r.cancelled:
                # the spectrum still entered the cluster state (it was
                # ingested); only the response is dropped
                continue
            r.result = a
            r.t_done = t_done
            live.append(r)
        srv._cluster_requests += len(live)
        if live:
            srv.stats.record_batch(live)
            srv.tenant_stats.setdefault(
                handle.tenant, LatencyStats()).record_batch(live)
        return live

    def finalize(self, handle) -> list[Request]:
        if isinstance(handle, ClusterBatchHandle):
            return self._finalize_cluster(handle)
        srv = self.server
        n = handle.n
        idx = np.asarray(handle.idx)[:n]   # blocks until the device is done
        vals = np.asarray(handle.vals)[:n]
        if handle.inv is not None:
            idx, vals = idx[handle.inv], vals[handle.inv]
        valid = None if handle.valid is None else jnp.asarray(handle.valid)
        routed = fdr_route(handle.db, jnp.asarray(idx), jnp.asarray(vals),
                           fdr=srv.fdr, valid=valid,
                           num_decoys=handle.num_decoys)
        t_done = srv._clock()
        live: list[Request] = []
        for i, r in enumerate(handle.reqs):
            if r.cancelled:
                continue
            r.result = QueryResult(
                indices=routed.indices[i], scores=routed.scores[i],
                is_target=bool(routed.is_target[i]),
                accept=bool(routed.accept[i]), match=int(routed.match[i]),
                has_candidate=(True if routed.valid is None
                               else bool(routed.valid[i])))
            r.t_done = t_done
            live.append(r)
        if live:
            srv.stats.record_batch(live)
            srv.tenant_stats.setdefault(
                handle.tenant, LatencyStats()).record_batch(live)
        return live


class DBSearchServer:
    """Micro-batched, multi-tenant sharded DB-search server (host loop).

    Requests carry already-encoded bipolar query HVs (D,) plus a tenant
    name; each tenant searches its own bank. The server accepts either a
    single :class:`ShardedDatabase` (registered as the pinned ``default``
    tenant) or a :class:`~repro.serve.cache.BankRegistry` of per-tenant
    banks, which are sharded lazily on first use and LRU-evicted when
    cold.

    Per flush (tenant-homogeneous, per the
    :class:`~repro.serve.queue.MicroBatchQueue` policy + fairness cap):
    query rows are encoded through the content-hash
    :class:`~repro.serve.cache.QueryHVCache` (misses batch-encoded once),
    the batch is padded up to the nearest shape bucket (a bounded set of
    jit signatures shared across tenants of equal bank geometry; pad rows
    are sliced off before FDR so they never pollute the estimate), the
    sharded search runs, merged results route through per-batch FDR, and
    latency lands in both the aggregate and the per-tenant
    :class:`~repro.serve.queue.LatencyStats`.

    The cache is a pure memo of the deterministic encode, so cached and
    cold paths return bit-identical results.

    **Queue modes.** Flush-sync (default): ``step`` runs one micro-batch
    synchronously when the queue's flush policy fires — simple, but every
    request in a flush waits for the whole batch, and the *next* flush
    can't start until this one finishes. Continuous (``continuous=True``):
    a :class:`~repro.serve.scheduler.ContinuousScheduler` keeps
    ``num_slots`` batches in flight, retiring completed slots and
    admitting queued requests into freed slots every ``step`` — tail
    latency collapses because nothing waits on a flush timeout or an
    unrelated batch (``flush_timeout_s`` is inert in this mode). Both
    modes run the identical :class:`SearchExecutor` device path, so
    results are bit-identical across modes.

    **Query forms.** With ``encoder=`` (a :class:`QueryEncoder`), submits
    carry raw (F,) quantized level vectors and the server encodes on the
    device — staged (cacheable, default) or, with ``fused_e2e=True``, as
    one fused encode->pack->search kernel dispatch per shard. Without an
    encoder, submits carry pre-encoded bipolar (D,) HVs as before.

    **Live banks.** ``append`` streams new refs/decoys into a tenant's
    bank through the registry's delta path (:mod:`repro.serve.delta`) —
    searches stay exact and bit-identical to a rebuilt bank — and, with
    ``compact_threshold=``, ``step`` folds oversized deltas back into
    the packed base between batches.

    **Clustering endpoint.** With ``clustering=`` (a
    :class:`~repro.serve.clustering.ClusteringConfig`),
    ``submit_cluster`` enqueues spectra for per-tenant streaming
    assign-or-spawn clustering — a second request *kind* sharing the
    queue, fairness policy, buckets, and (continuous mode) scheduler
    slots with search; results are
    :class:`~repro.serve.clustering.ClusterAssignment` objects.
    """

    def __init__(self, db: ShardedDatabase | BankRegistry, *, k: int = 4,
                 fdr: float = 0.01, max_batch_size: int = 32,
                 flush_timeout_s: float = 0.01,
                 clock: Callable[[], float] = time.monotonic,
                 cache_bytes: int | None = 64 << 20,
                 buckets: int | Sequence[int] | None = None,
                 fairness_cap: int | None = None,
                 oms: OMSConfig | None = None,
                 encoder: QueryEncoder | None = None,
                 fused_e2e: bool = False,
                 continuous: bool = False, num_slots: int = 2,
                 executor=None,
                 compact_threshold: float | None = None,
                 clustering: ClusteringConfig | None = None):
        if isinstance(db, BankRegistry):
            self.db = None
            self.banks = db
        else:
            self.db = db
            self.banks = BankRegistry(mesh=db.mesh, axis=db.axis)
            self.banks.adopt("default", db, pin=True)
        self.k = int(k)
        self.fdr = float(fdr)
        self.max_batch_size = int(max_batch_size)
        if buckets is None:
            self.buckets: tuple[int, ...] = (self.max_batch_size,)
        elif isinstance(buckets, int):
            self.buckets = make_buckets(self.max_batch_size, buckets)
        else:
            sizes = {int(b) for b in buckets if 1 <= int(b) <= max_batch_size}
            self.buckets = tuple(sorted(sizes | {self.max_batch_size}))
        self.queue = MicroBatchQueue(max_batch_size=max_batch_size,
                                     flush_timeout_s=flush_timeout_s,
                                     clock=clock, fairness_cap=fairness_cap)
        self.query_cache = (QueryHVCache(cache_bytes) if cache_bytes
                            else None)
        self.stats = LatencyStats()
        self.tenant_stats: dict[str, LatencyStats] = {}
        self._tenant_cache: dict[str, list[int]] = {}  # tenant -> [hits, misses]
        self._bucket_counts: collections.Counter[int] = collections.Counter()
        self._clock = clock
        self.oms = oms
        self._oms_batches = 0
        self._oms_cand_frac = 0.0
        self._oms_scan_frac = 0.0
        self._oms_no_candidate = 0
        self.encoder = encoder
        self.fused_e2e = bool(fused_e2e)
        if self.fused_e2e and encoder is None:
            raise ValueError("fused_e2e=True requires encoder=")
        if compact_threshold is not None and not 0 < compact_threshold <= 1:
            raise ValueError(f"compact_threshold must be in (0, 1], got "
                             f"{compact_threshold}")
        self.compact_threshold = compact_threshold
        self.clustering = clustering
        self.clusterers: dict[str, StreamingClusterer] = {}
        self._cluster_requests = 0
        self.executor = SearchExecutor(self) if executor is None else executor
        self.scheduler = (ContinuousScheduler(self.queue, self.executor,
                                              num_slots=num_slots,
                                              clock=clock)
                          if continuous else None)

    def submit(self, query_hv, tenant: str = "default",
               precursor: float | None = None) -> int:
        """Enqueue one query for ``tenant`` (which must be registered);
        returns the request id. The query is an encoded bipolar HV (D,) —
        or, when the server was built with ``encoder=``, a raw quantized
        level vector (F,). OMS-mode servers require the query's precursor
        mass."""
        dim = self.banks.dim(tenant)  # KeyError for unknown tenants
        if self.encoder is not None:
            if self.encoder.dim != dim:
                raise ValueError(f"encoder dim {self.encoder.dim} != "
                                 f"bank dim {dim} for tenant {tenant!r}")
            q = np.asarray(query_hv, dtype=np.int32)
            if q.shape != (self.encoder.num_features,):
                raise ValueError(
                    f"query shape {q.shape} != "
                    f"({self.encoder.num_features},) levels")
        else:
            q = np.asarray(query_hv, dtype=np.int8)
            if q.shape != (dim,):
                raise ValueError(f"query shape {q.shape} != ({dim},)")
        if self.oms is not None and precursor is None:
            raise ValueError("OMS serving mode requires precursor= on submit")
        return self.queue.submit(q, tenant=tenant, precursor=precursor)

    def submit_cluster(self, query_hv, tenant: str = "default") -> int:
        """Enqueue one spectrum HV for the clustering endpoint (requires
        the server was built with ``clustering=``). Clustering tenants
        are independent of bank tenants — state is created on first use.
        The result is a :class:`~repro.serve.clustering.ClusterAssignment`."""
        if self.clustering is None:
            raise ValueError("server was built without clustering=; pass a "
                             "ClusteringConfig to serve the clustering "
                             "endpoint")
        q = np.asarray(query_hv, dtype=np.int8)
        if q.shape != (self.clustering.dim,):
            raise ValueError(
                f"query shape {q.shape} != ({self.clustering.dim},)")
        return self.queue.submit(q, tenant=tenant, kind="cluster")

    def append(self, tenant: str, refs, decoys=None, *, precursor=None,
               decoy_precursor=None) -> int:
        """Stream new refs/decoys into a tenant's bank (delegates to
        :meth:`~repro.serve.cache.BankRegistry.append`); subsequent
        searches take the exact merged base+delta path until compaction
        folds the delta in."""
        return self.banks.append(tenant, refs, decoys, precursor=precursor,
                                 decoy_precursor=decoy_precursor)

    def cancel(self, rid: int) -> bool:
        """Best-effort cancel: un-queue a pending request, or (continuous
        mode) drop an in-flight one's result at retire time."""
        if self.scheduler is not None:
            return self.scheduler.cancel(rid)
        return self.queue.cancel(rid)

    def _encode_rows(self, db: ShardedDatabase, qs: jax.Array) -> jax.Array:
        """Encode stacked raw queries into the bank's storage form: the
        deterministic bank-form cast for pre-encoded HVs, or the staged
        Eq. 1 encode first when the server carries a query encoder."""
        if self.encoder is not None:
            hv = encode_levels_batch(qs.astype(jnp.int32),
                                     self.encoder.id_hvs,
                                     self.encoder.level_hvs)
            return encode_queries(db, hv)
        return encode_queries(db, qs)

    def _raw_batch(self, reqs: list[Request], bucket: int) -> np.ndarray:
        """Stacked raw bipolar (bucket, D) int8 rows — the query form the
        *unpacked* delta side of a merged search scores against. Encoder
        servers stage the deterministic Eq. 1 encode first, so these are
        exactly the HVs the base side packs."""
        if self.encoder is not None:
            levels = self._levels_batch(reqs, bucket)
            hv = encode_levels_batch(jnp.asarray(levels, jnp.int32),
                                     self.encoder.id_hvs,
                                     self.encoder.level_hvs)
            return np.asarray(hv, np.int8)
        dim = len(reqs[0].query)
        out = np.zeros((bucket, dim), np.int8)
        for i, r in enumerate(reqs):
            out[i] = r.query
        return out

    def _levels_batch(self, reqs: list[Request], bucket: int) -> np.ndarray:
        """Assemble the raw (bucket, F) level batch for the fused-e2e
        route. Pad rows are all-zero (every peak absent) — inert under
        Eq. 1, and sliced off before FDR like any bucket padding."""
        out = np.zeros((bucket, self.encoder.num_features), np.int32)
        for i, r in enumerate(reqs):
            out[i] = r.query
        return out

    def _encode_batch(self, reqs: list[Request], db: ShardedDatabase,
                      bucket: int, tenant: str) -> np.ndarray:
        """Assemble the (bucket, width) encoded batch, through the cache.
        In e2e mode the cache memoizes *levels -> bank-form row* under a
        distinct variant tag, so the staged e2e route keeps cache reuse
        (the fused route skips the cache by design: nothing intermediate
        exists to memoize)."""
        width = db.data.shape[-1]
        out = np.zeros((bucket, width), dtype=np.dtype(db.data.dtype))
        cache = self.query_cache
        if cache is None:
            qs = jnp.asarray(np.stack([r.query for r in reqs]))
            out[: len(reqs)] = np.asarray(self._encode_rows(db, qs))
            return out
        variant = (f"{'e2e:' if self.encoder is not None else ''}"
                   f"{'packed' if db.packed else 'int8'}:{db.dim}")
        miss_pos, miss_keys = [], []
        hits = 0
        for i, r in enumerate(reqs):
            key = cache.content_key(r.query, variant=variant)
            row = cache.lookup(key)
            if row is None:
                miss_pos.append(i)
                miss_keys.append(key)
            else:
                out[i] = row
                hits += 1
        if miss_pos:
            qs = jnp.asarray(np.stack([reqs[i].query for i in miss_pos]))
            enc = np.asarray(self._encode_rows(db, qs))
            for j, i in enumerate(miss_pos):
                out[i] = enc[j]
                cache.insert(miss_keys[j], enc[j].copy())
        tc = self._tenant_cache.setdefault(tenant, [0, 0])
        tc[0] += hits
        tc[1] += len(miss_pos)
        return out

    def step(self, force: bool = False) -> list[Request]:
        """One serving-loop iteration; returns the requests completed this
        step (``result``/``t_done`` filled), [] when nothing finished.

        Flush-sync mode runs at most one micro-batch synchronously when
        the queue policy says so — or unconditionally (pending > 0) with
        ``force``, used to drain on shutdown. Continuous mode retires
        completed slots and refills them from the queue without blocking
        (``force`` waits out in-flight slots instead). Either way, due
        compactions run first — "background" compaction happens between
        batches, never under one, so no queued request is dropped (slots
        already in flight keep their pre-compaction bank handle, whose
        merged results are bit-identical anyway)."""
        self._maybe_compact()
        if self.scheduler is not None:
            return self.scheduler.step(block=force)
        if not (self.queue.ready() or (force and len(self.queue))):
            return []
        reqs = self.queue.take_batch()
        if not reqs:
            return []
        return self.executor.finalize(self.executor.dispatch(reqs))

    def _maybe_compact(self) -> int:
        """Fold every delta past ``compact_threshold`` (delta fraction)
        into its base bank; returns the number of tenants compacted."""
        if self.compact_threshold is None:
            return 0
        done = 0
        for t in self.banks.tenants_with_delta():
            if self.banks.delta_fraction(t) >= self.compact_threshold:
                if self.banks.compact(t):
                    done += 1
        return done

    def run_until_drained(self) -> list[Request]:
        """Serve until queue and in-flight slots are empty; returns all
        completed requests."""
        if self.scheduler is not None:
            return self.scheduler.drain()
        done: list[Request] = []
        while len(self.queue):
            done.extend(self.step(force=True))
        return done

    def summary(self) -> dict:
        """Aggregate latency stats plus per-tenant accounting, query-cache
        counters, bank-registry counters, and bucket usage."""
        s = self.stats.summary()
        tenants = {}
        for t, st in self.tenant_stats.items():
            d = st.summary()
            h, m = self._tenant_cache.get(t, (0, 0))
            d["cache_hits"] = h
            d["cache_misses"] = m
            d["cache_hit_rate"] = h / (h + m) if h + m else 0.0
            tenants[t] = d
        s["tenants"] = tenants
        s["banks"] = self.banks.summary()
        s["query_cache"] = (self.query_cache.summary()
                            if self.query_cache else None)
        s["buckets"] = {int(b): int(c)
                        for b, c in sorted(self._bucket_counts.items())}
        s["mode"] = "continuous" if self.scheduler is not None else "flush-sync"
        s["scheduler"] = (None if self.scheduler is None
                          else self.scheduler.summary())
        s["ingest"] = {
            "compact_threshold": self.compact_threshold,
            "appends": self.banks.appends,
            "compactions": self.banks.compactions,
            "tenants_with_delta": self.banks.tenants_with_delta(),
        }
        s["clustering"] = (None if self.clustering is None else {
            "requests": self._cluster_requests,
            "tenants": {t: c.summary()
                        for t, c in self.clusterers.items()},
        })
        s["e2e"] = (None if self.encoder is None else {
            "fused": self.fused_e2e,
            "num_features": self.encoder.num_features,
            "num_levels": self.encoder.num_levels,
        })
        if self.oms is not None:
            nb = max(self._oms_batches, 1)
            s["oms"] = {
                "tol": self.oms.tol,
                "open_tol": self.oms.open_tol,
                "open_search": self.oms.open_search,
                "batches": self._oms_batches,
                "candidate_fraction": self._oms_cand_frac / nb,
                "scanned_fraction": self._oms_scan_frac / nb,
                "no_candidate": self._oms_no_candidate,
            }
        else:
            s["oms"] = None
        return s
