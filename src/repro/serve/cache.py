"""Serving cache layer: query-HV memoization + multi-tenant bank registry.

Two observations drive this module (the serving-scale analogue of the
paper's own argument that the spectral library is the stable, reusable
artifact):

  * **Hot queries repeat.** Re-encoding/bit-packing the same query HV on
    every arrival wastes the cheapest win in the serving path.
    :class:`QueryHVCache` memoizes the *encoded* (packed-uint32 or int8)
    form keyed by a content hash of the raw bipolar HV, under an LRU
    policy with a byte budget — hit/miss/eviction counters included, so
    the hit rate is a first-class serving metric.
  * **Banks are per-tenant and mostly cold.** A multi-tenant server holds
    one :class:`~repro.serve.db_search.ShardedDatabase` per client
    library. :class:`BankRegistry` keeps the raw reference HVs as cheap
    host-side specs and shards a bank onto the mesh only on first use
    (lazy shard-on-first-use); cold built banks are LRU-evicted beyond
    ``max_banks`` (their spec stays registered, so a later request simply
    rebuilds), and hot tenants can be pinned to exempt them.

Cached and cold paths are **bit-identical** by construction: the cache
stores the deterministic output of
:func:`repro.serve.db_search.encode_queries`, never scores or results.
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
from typing import Any

import numpy as np


# --------------------------------------------------------------------------
# query-HV cache
# --------------------------------------------------------------------------

class QueryHVCache:
    """Content-hash-keyed LRU cache of encoded query hypervectors.

    Entries are host numpy rows (the packed-uint32 or int8 encoding of one
    query). Eviction is LRU under ``capacity_bytes``; a value that alone
    exceeds the budget is rejected (counted as an eviction) rather than
    flushing the whole cache for a single oversized row.
    """

    def __init__(self, capacity_bytes: int = 64 << 20):
        if capacity_bytes <= 0:
            raise ValueError(f"capacity_bytes must be > 0, got {capacity_bytes}")
        self.capacity_bytes = int(capacity_bytes)
        self._entries: collections.OrderedDict[bytes, np.ndarray] = (
            collections.OrderedDict())
        self._bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    @staticmethod
    def content_key(raw: Any, *, variant: str = "") -> bytes:
        """Digest of the raw query content (+ dtype/shape/encoding variant).

        ``variant`` must distinguish encodings that map the same raw bytes
        to different values (e.g. ``"packed:512"`` vs ``"int8:512"``), so
        tenants that share an encoding also share cache entries.
        """
        a = np.ascontiguousarray(raw)
        h = hashlib.blake2b(digest_size=16)
        h.update(variant.encode())
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
        return h.digest()

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: bytes) -> bool:
        """Non-mutating membership test (no LRU touch, no counters)."""
        return key in self._entries

    @property
    def current_bytes(self) -> int:
        return self._bytes

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def lookup(self, key: bytes) -> np.ndarray | None:
        """Return the cached row for ``key`` (LRU-touching it), else None."""
        row = self._entries.get(key)
        if row is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        return row

    def insert(self, key: bytes, value: np.ndarray) -> bool:
        """Store one encoded row; evicts LRU entries down to the budget.

        Returns False when the value alone exceeds ``capacity_bytes`` (the
        entry is not stored).
        """
        value = np.asarray(value)
        if value.nbytes > self.capacity_bytes:
            self.evictions += 1
            return False
        old = self._entries.pop(key, None)
        if old is not None:
            self._bytes -= old.nbytes
        self._entries[key] = value
        self._bytes += value.nbytes
        while self._bytes > self.capacity_bytes:
            _, evicted = self._entries.popitem(last=False)
            self._bytes -= evicted.nbytes
            self.evictions += 1
        return True

    def get_or_encode(self, raw: Any, encode, *, variant: str = ""
                      ) -> tuple[np.ndarray, bool]:
        """Memoized ``encode(raw)``. Returns (encoded row, was_hit)."""
        key = self.content_key(raw, variant=variant)
        row = self.lookup(key)
        if row is not None:
            return row, True
        row = np.asarray(encode(raw))
        self.insert(key, row)
        return row, False

    def summary(self) -> dict:
        return {
            "entries": len(self._entries),
            "bytes": self._bytes,
            "capacity_bytes": self.capacity_bytes,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "hit_rate": self.hit_rate,
        }


# --------------------------------------------------------------------------
# multi-tenant bank registry
# --------------------------------------------------------------------------

@dataclasses.dataclass
class _BankSpec:
    """Host-side recipe for one tenant's bank (cheap until first use)."""

    refs: Any
    decoys: Any | None
    dim: int
    pinned: bool = False
    precursor: Any | None = None        # target precursor masses (OMS)
    decoy_precursor: Any | None = None  # decoy precursor masses (OMS)


class BankRegistry:
    """Per-tenant :class:`~repro.serve.db_search.ShardedDatabase` handles.

    ``register`` only records the raw reference/decoy HVs; the sharded
    (device-resident, possibly bit-packed) bank is built by the first
    ``get`` for that tenant — and rebuilt transparently if it was evicted
    in between. At most ``max_banks`` built banks are held; beyond that
    the least-recently-used *unpinned* bank is dropped.

    **Streaming ingestion**: ``append`` lands new refs/decoys in a small
    unpacked per-tenant :class:`~repro.serve.delta.DeltaBank`; callers
    that search via ``get_with_delta`` get an exact merged top-k over
    base + delta (bit-identical to re-registering the concatenated
    arrays — see :mod:`repro.serve.delta`). ``compact`` folds the delta
    back into the bit-packed base: the merged bank is built *before* the
    spec/built swap, so a failed build leaves the registry untouched, and
    invalidation is scoped to the compacted tenant (every other tenant's
    built bank and the content-keyed query-HV cache are unaffected).
    """

    def __init__(self, *, mesh=None, axis: str = "model",
                 pack: bool | str = "auto", max_banks: int | None = None,
                 emulate_shards: int | None = None, fused: bool = False,
                 block_q: int | None = None, block_r: int | None = None,
                 word_chunk: int | None = None):
        if max_banks is not None and max_banks < 1:
            raise ValueError(f"max_banks must be >= 1, got {max_banks}")
        self.mesh = mesh
        self.axis = axis
        self.pack = pack
        self.max_banks = max_banks
        self.emulate_shards = emulate_shards
        self.fused = fused
        # explicit kernel tile overrides applied to every bank this
        # registry builds (None defers to tuning table / defaults)
        self.block_q = block_q
        self.block_r = block_r
        self.word_chunk = word_chunk
        self._specs: dict[str, _BankSpec] = {}
        self._built: collections.OrderedDict[str, Any] = collections.OrderedDict()
        self._deltas: dict[str, Any] = {}  # tenant -> DeltaBank
        self.builds = 0
        self.hits = 0
        self.evictions = 0
        self.appends = 0
        self.compactions = 0

    def __len__(self) -> int:
        return len(self._specs)

    def tenants(self) -> list[str]:
        return list(self._specs)

    def register(self, tenant: str, refs, decoys=None, *,
                 pin: bool = False, precursor=None,
                 decoy_precursor=None) -> None:
        """Record a tenant's bank recipe (no sharding/packing happens yet).

        With ``precursor`` (per-target masses; ``decoy_precursor``
        defaulting to the same array), the built bank carries the
        precursor-sorted OMS index (see :mod:`repro.serve.oms`).
        Re-registering replaces the spec and drops any stale built bank.
        """
        self._specs[tenant] = _BankSpec(
            refs=refs, decoys=decoys, dim=int(refs.shape[-1]), pinned=pin,
            precursor=precursor, decoy_precursor=decoy_precursor)
        self._built.pop(tenant, None)
        self._deltas.pop(tenant, None)

    def adopt(self, tenant: str, db, *, pin: bool = True) -> None:
        """Install an already-built bank (no spec; cannot be rebuilt if
        evicted, hence pinned by default). Used for the single-tenant
        back-compat path of :class:`~repro.serve.db_search.DBSearchServer`."""
        self._specs[tenant] = _BankSpec(
            refs=None, decoys=None, dim=db.dim, pinned=pin)
        self._built[tenant] = db
        self._built.move_to_end(tenant)
        self._deltas.pop(tenant, None)

    def dim(self, tenant: str) -> int:
        """The tenant's HV dimension — available without building the bank."""
        return self._specs[tenant].dim

    def is_built(self, tenant: str) -> bool:
        return tenant in self._built

    def pin(self, tenant: str) -> None:
        self._specs[tenant].pinned = True

    def unpin(self, tenant: str) -> None:
        self._specs[tenant].pinned = False

    def get(self, tenant: str):
        """The tenant's ShardedDatabase, building (sharding) it on first
        use and LRU-touching it."""
        spec = self._specs[tenant]  # KeyError for unknown tenants
        db = self._built.get(tenant)
        if db is None:
            if spec.refs is None:
                raise KeyError(
                    f"tenant {tenant!r} bank was adopted pre-built, then "
                    f"evicted; re-register or adopt it again")
            from repro.serve.db_search import shard_database
            db = shard_database(spec.refs, decoys=spec.decoys, mesh=self.mesh,
                                axis=self.axis, pack=self.pack,
                                emulate_shards=self.emulate_shards,
                                fused=self.fused, precursor=spec.precursor,
                                decoy_precursor=spec.decoy_precursor,
                                block_q=self.block_q, block_r=self.block_r,
                                word_chunk=self.word_chunk)
            self.builds += 1
            self._built[tenant] = db
        else:
            self.hits += 1
        self._built.move_to_end(tenant)
        self._evict_cold()
        return db

    # -- streaming ingestion (delta banks + compaction) --------------------

    def append(self, tenant: str, refs, decoys=None, *, precursor=None,
               decoy_precursor=None) -> int:
        """Land new refs (+ optional decoys) in the tenant's delta bank.

        O(delta) per call — the bit-packed base is untouched; search via
        :meth:`get_with_delta` merges exactly. Returns the delta's total
        row count. Adopted (spec-less) banks cannot accept appends: a
        later compaction could not rebuild them.
        """
        spec = self._specs[tenant]  # KeyError for unknown tenants
        if spec.refs is None:
            raise ValueError(
                f"tenant {tenant!r} bank was adopted pre-built; appends "
                f"need the raw spec so compaction can rebuild — use "
                f"register() instead of adopt()")
        delta = self._deltas.get(tenant)
        if delta is None:
            from repro.serve.delta import DeltaBank
            delta = DeltaBank(spec.dim, oms=spec.precursor is not None)
            self._deltas[tenant] = delta
        rows = delta.append(refs, decoys, precursor=precursor,
                            decoy_precursor=decoy_precursor)
        self.appends += 1
        return rows

    def delta(self, tenant: str):
        """The tenant's DeltaBank, or None when it has no appended rows."""
        d = self._deltas.get(tenant)
        return d if d is not None and d.num_rows else None

    def get_with_delta(self, tenant: str):
        """(base bank, delta-or-None) — the pair a merged search needs."""
        return self.get(tenant), self.delta(tenant)

    def tenants_with_delta(self) -> list[str]:
        return [t for t, d in self._deltas.items() if d.num_rows]

    def _base_rows(self, tenant: str) -> int:
        spec = self._specs[tenant]
        if spec.refs is None:
            db = self._built.get(tenant)
            return db.num_rows if db is not None else 0
        rows = int(np.asarray(spec.refs).shape[0])
        if spec.decoys is not None:
            rows += int(np.asarray(spec.decoys).shape[0])
        return rows

    def delta_fraction(self, tenant: str) -> float:
        """Appended rows / total rows — the compaction trigger metric."""
        d = self.delta(tenant)
        if d is None:
            return 0.0
        total = self._base_rows(tenant) + d.num_rows
        return d.num_rows / total if total else 0.0

    def compact(self, tenant: str) -> bool:
        """Fold the tenant's delta into its bit-packed base.

        Builds the merged bank from the concatenated spec + delta arrays
        *first*, then atomically swaps spec/built and drops the delta —
        a build failure leaves the registry exactly as it was, and other
        tenants' built banks are never touched. Returns False when there
        is nothing to compact.
        """
        d = self.delta(tenant)
        if d is None:
            return False
        spec = self._specs[tenant]
        refs = np.concatenate([np.asarray(spec.refs, np.int8), d.refs])
        decoys = None
        old_dec = (np.asarray(spec.decoys, np.int8)
                   if spec.decoys is not None
                   else np.zeros((0, spec.dim), np.int8))
        if old_dec.shape[0] or d.num_decoys:
            decoys = np.concatenate([old_dec, d.decoys])
        precursor = decoy_precursor = None
        if spec.precursor is not None:
            precursor = np.concatenate(
                [np.asarray(spec.precursor, np.float32), d.precursor])
            if decoys is not None:
                base_dprec = (spec.decoy_precursor
                              if spec.decoy_precursor is not None
                              else spec.precursor)
                base_dprec = np.asarray(base_dprec,
                                        np.float32)[:old_dec.shape[0]]
                decoy_precursor = np.concatenate(
                    [base_dprec, d.decoy_precursor])
        from repro.serve.db_search import shard_database
        db = shard_database(refs, decoys=decoys, mesh=self.mesh,
                            axis=self.axis, pack=self.pack,
                            emulate_shards=self.emulate_shards,
                            fused=self.fused, precursor=precursor,
                            decoy_precursor=decoy_precursor,
                            block_q=self.block_q, block_r=self.block_r,
                            word_chunk=self.word_chunk)
        self.builds += 1
        # atomic swap: spec + built bank + delta change together, and only
        # for this tenant
        self._specs[tenant] = _BankSpec(
            refs=refs, decoys=decoys, dim=spec.dim, pinned=spec.pinned,
            precursor=precursor, decoy_precursor=decoy_precursor)
        self._built[tenant] = db
        self._built.move_to_end(tenant)
        del self._deltas[tenant]
        self.compactions += 1
        self._evict_cold()
        return True

    def _evict_cold(self) -> None:
        if self.max_banks is None:
            return
        while len(self._built) > self.max_banks:
            victim = next((t for t in self._built
                           if not self._specs[t].pinned), None)
            if victim is None:  # everything pinned: nothing evictable
                return
            del self._built[victim]
            self.evictions += 1

    def summary(self) -> dict:
        return {
            "registered": len(self._specs),
            "built": len(self._built),
            "pinned": sum(s.pinned for s in self._specs.values()),
            "builds": self.builds,
            "hits": self.hits,
            "evictions": self.evictions,
            "appends": self.appends,
            "compactions": self.compactions,
            "delta_rows": sum(d.num_rows for d in self._deltas.values()),
            "tenants_with_delta": len(self.tenants_with_delta()),
        }
