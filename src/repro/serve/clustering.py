"""Streaming spectral clustering as a serving endpoint (SpecPCM §III.C).

The paper's *other* full-stack task next to DB search: incoming spectra
are clustered online instead of matched against a reference bank. This
module gives it the same serving surface — per-tenant state behind
:class:`~repro.serve.db_search.DBSearchServer`'s queue/scheduler, with
the heavy compute (a query batch against the centroid bank) on the
device and the tiny sequential decision loop on the host:

  * **assign-or-spawn**: each spectrum HV scores against the current
    centroid snapshot via :func:`repro.core.hd.clustering.cross_distances`
    (the packed XOR+popcount kernel when ``D % 32 == 0`` — the in-array
    distance step of the paper's pipeline); a spectrum joins the nearest
    cluster within ``threshold`` (ties to the lowest-numbered cluster,
    matching ``complete_linkage``'s canonical-min labeling), else spawns
    a new one. Centroids are bipolar majority bundles — the running
    element sum with a sign readout, the HD analogue of a mean.
  * **periodic re-consolidation**: greedy streaming can split one true
    cluster across arrival order; every ``consolidate_every`` spectra
    the centroid bank itself is re-clustered with the paper's
    :func:`~repro.core.hd.clustering.complete_linkage` and merged
    clusters fold their accumulators together. Old cluster ids stay
    resolvable through :meth:`StreamingClusterer.resolve`.

Batching semantics (what makes replay deterministic): distances for a
batch are computed against the snapshot taken at dispatch; the host
decision loop is sequential *within* the batch — a spectrum that spawns
a cluster is immediately assignable to the rest of its batch (exact
host-side distances, same (D - <q,c>)/2 map the device uses). In
flush-sync serving, batches finalize in submit order, so replaying a
stream through any batch partition yields the same final partition of
points whenever assignments are unambiguous (well-separated clusters);
the continuous scheduler may interleave *different tenants'* batches
freely — per-tenant state makes that safe.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.hd.clustering import (
    complete_linkage,
    cross_distances,
    pairwise_distances,
)
from repro.core.hd.similarity import bitpack_bipolar


@dataclasses.dataclass(frozen=True)
class ClusteringConfig:
    """Per-server clustering policy.

    threshold: assign a spectrum to its nearest centroid when the Hamming
      distance is <= this, else spawn a new cluster.
    link_threshold: complete-linkage threshold for periodic consolidation
      (defaults to ``threshold``).
    consolidate_every: re-consolidate after this many assigned spectra
      per tenant; 0 disables (pure greedy streaming).
    pack: bit-pack centroids for the popcount distance kernel — True /
      False / "auto" (pack when D % 32 == 0), like ``shard_database``.
    """

    dim: int
    threshold: float
    link_threshold: float | None = None
    consolidate_every: int = 0
    pack: bool | str = "auto"

    @property
    def packed(self) -> bool:
        if self.pack == "auto":
            return self.dim % 32 == 0
        return bool(self.pack)

    @property
    def merge_threshold(self) -> float:
        return (self.threshold if self.link_threshold is None
                else self.link_threshold)


@dataclasses.dataclass
class ClusterAssignment:
    """Per-request clustering result (the endpoint's ``QueryResult``)."""

    cluster_id: int    # public id (stable across consolidations via resolve)
    spawned: bool      # this spectrum started a new cluster
    distance: float    # Hamming distance to the assigned centroid
                       # (0.0 for a spawn: a cluster's founder is its centroid)


class StreamingClusterer:
    """Online assign-or-spawn cluster state for one tenant.

    Host state is the integer accumulator (sum of member bipolar HVs) per
    cluster plus its sign snapshot; the device holds a (possibly packed)
    copy of the snapshot, rebuilt lazily after batches mutate it and
    row-padded to a small power-of-two ladder so repeated batches share
    jit signatures. Public cluster ids are allocated in spawn order and
    survive consolidation through a remap chain.
    """

    def __init__(self, cfg: ClusteringConfig):
        self.cfg = cfg
        self._acc = np.zeros((0, cfg.dim), np.int32)
        self._counts = np.zeros((0,), np.int64)
        self._cent = np.zeros((0, cfg.dim), np.int8)  # sign(_acc), 0 -> +1
        self._ids: list[int] = []                     # public id per row
        self._next_id = 0
        self._remap: dict[int, int] = {}              # merged-away -> target
        self._cent_dev = None                         # (array, rows_covered)
        self._since_consol = 0
        self.struct_version = 0   # bumped when consolidation moves rows
        self.assigned = 0
        self.spawned = 0
        self.consolidations = 0
        self.merges = 0

    @property
    def num_clusters(self) -> int:
        return len(self._ids)

    # -- device side (called at dispatch) ---------------------------------

    def snapshot_distances(self, hvs: np.ndarray):
        """Launch (Q, C) Hamming distances of a bucket-padded int8 batch
        against the current centroid snapshot; None when no clusters
        exist yet (the whole batch spawns). The returned array is an
        unrealized device value — the executor polls it like any search
        handle."""
        c = self.num_clusters
        if c == 0:
            return None
        if self._cent_dev is None or self._cent_dev[1] != c:
            # pad centroid rows to the next power of two (>= 8) so the
            # distance jit signature changes O(log C) times, not per spawn
            rows = 8
            while rows < c:
                rows *= 2
            bank = np.zeros((rows, self.cfg.dim), np.int8)
            bank[:c] = self._cent
            bank_dev = (bitpack_bipolar(jnp.asarray(bank))
                        if self.cfg.packed else jnp.asarray(bank))
            self._cent_dev = (bank_dev, c, rows)
        bank_dev = self._cent_dev[0]
        q = jnp.asarray(hvs, jnp.int8)
        if self.cfg.packed:
            q = bitpack_bipolar(q)
        return cross_distances(q, bank_dev, dim=self.cfg.dim)

    # -- host side (called at finalize) -----------------------------------

    def _host_distance(self, hv: np.ndarray, row: int) -> float:
        # same exact map as the device path: dist = (D - <q, c>) / 2
        dot = int(hv.astype(np.int32) @ self._cent[row].astype(np.int32))
        return (self.cfg.dim - dot) / 2.0

    def assign_batch(self, hvs: np.ndarray, dists: np.ndarray | None,
                     c0: int, struct_version: int | None = None
                     ) -> list[ClusterAssignment]:
        """Sequentially assign-or-spawn one batch.

        dists: realized (Q, >=c0) snapshot distances (None when c0 == 0);
        c0 is the cluster count the snapshot covered at dispatch. Rows
        spawned after the snapshot — by earlier requests in this batch,
        or by another batch that finalized in between — are scored
        host-side with the identical distance map, so results don't
        depend on where the batch boundary fell. If a consolidation
        restructured the rows since dispatch (detected via
        ``struct_version``), the snapshot columns no longer line up and
        the whole batch is scored host-side instead.
        """
        if (struct_version is not None
                and struct_version != self.struct_version):
            dists, c0 = None, 0
        out: list[ClusterAssignment] = []
        touched: set[int] = set()
        for i in range(hvs.shape[0]):
            hv = hvs[i]
            best_row, best_d = -1, np.inf
            c_snap = min(c0, self.num_clusters)
            if dists is not None and c_snap:
                row = int(np.argmin(dists[i, :c_snap]))  # ties -> lowest row
                best_row, best_d = row, float(dists[i, row])
            for row in range(c_snap, self.num_clusters):
                d = self._host_distance(hv, row)
                if d < best_d:  # strict: ties keep the lower row
                    best_row, best_d = row, d
            if best_row >= 0 and best_d <= self.cfg.threshold:
                self._acc[best_row] += hv.astype(np.int32)
                self._counts[best_row] += 1
                touched.add(best_row)
                out.append(ClusterAssignment(
                    cluster_id=self._ids[best_row], spawned=False,
                    distance=best_d))
            else:
                cid = self._spawn(hv)
                out.append(ClusterAssignment(
                    cluster_id=cid, spawned=True, distance=0.0))
        for row in sorted(touched):
            self._refresh_row(row)
        if touched:
            self._cent_dev = None
        self.assigned += hvs.shape[0]
        self._since_consol += hvs.shape[0]
        self.maybe_consolidate()
        return out

    def _spawn(self, hv: np.ndarray) -> int:
        self._acc = np.concatenate([self._acc,
                                    hv.astype(np.int32)[None, :]])
        self._counts = np.concatenate([self._counts,
                                       np.ones((1,), np.int64)])
        self._cent = np.concatenate([self._cent,
                                     hv.astype(np.int8)[None, :]])
        cid = self._next_id
        self._next_id += 1
        self._ids.append(cid)
        self._cent_dev = None
        self.spawned += 1
        return cid

    def _refresh_row(self, row: int) -> None:
        # bipolar majority bundle: sign of the element sum, zeros -> +1
        self._cent[row] = np.where(self._acc[row] >= 0, 1, -1).astype(np.int8)

    def maybe_consolidate(self) -> bool:
        """Re-cluster the centroid bank with complete linkage when due;
        merged clusters sum their accumulators and the dropped ids remap
        to the survivor (canonical = lowest-numbered row, i.e. oldest)."""
        cfg = self.cfg
        if (not cfg.consolidate_every
                or self._since_consol < cfg.consolidate_every):
            return False
        self._since_consol = 0
        if self.num_clusters < 2:
            return False
        cent = jnp.asarray(self._cent)
        if cfg.packed:
            cent = bitpack_bipolar(cent)
        dist = pairwise_distances(cent, dim=cfg.dim)
        res = complete_linkage(dist, cfg.merge_threshold)
        labels = np.asarray(res.labels)
        self.consolidations += 1
        if int(res.num_merges) == 0:
            return False
        keep = sorted(set(int(x) for x in labels))
        row_of = {lab: i for i, lab in enumerate(keep)}
        acc = np.zeros((len(keep), cfg.dim), np.int32)
        counts = np.zeros((len(keep),), np.int64)
        for old_row, lab in enumerate(labels):
            new_row = row_of[int(lab)]
            acc[new_row] += self._acc[old_row]
            counts[new_row] += self._counts[old_row]
            if old_row != int(lab):
                self._remap[self._ids[old_row]] = self._ids[int(lab)]
                self.merges += 1
        self._acc, self._counts = acc, counts
        self._ids = [self._ids[lab] for lab in keep]
        self._cent = np.zeros((len(keep), cfg.dim), np.int8)
        for row in range(len(keep)):
            self._refresh_row(row)
        self._cent_dev = None
        self.struct_version += 1
        return True

    def resolve(self, cluster_id: int) -> int:
        """Follow the merge chain: the current canonical id for a cluster
        id handed out earlier (identity for live clusters)."""
        seen = set()
        while cluster_id in self._remap and cluster_id not in seen:
            seen.add(cluster_id)
            cluster_id = self._remap[cluster_id]
        return cluster_id

    def centroid(self, cluster_id: int) -> np.ndarray:
        """The (D,) int8 centroid snapshot for a (resolved) cluster id."""
        row = self._ids.index(self.resolve(cluster_id))
        return self._cent[row].copy()

    def labels_for(self, assignments: list[ClusterAssignment]) -> np.ndarray:
        """Resolved cluster id per assignment — the replayed-stream view
        comparable against a batch ``complete_linkage`` partition."""
        return np.asarray([self.resolve(a.cluster_id) for a in assignments],
                          np.int64)

    def summary(self) -> dict:
        return {
            "clusters": self.num_clusters,
            "assigned": self.assigned,
            "spawned": self.spawned,
            "consolidations": self.consolidations,
            "merges": self.merges,
            "threshold": self.cfg.threshold,
            "packed": self.cfg.packed,
        }
