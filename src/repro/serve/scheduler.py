"""Continuous-batching scheduler: a fixed pool of in-flight batch slots.

The flush-and-wait loop (``DBSearchServer.step`` pre-continuous) is the
p95 killer in the serving bench: every request admitted into a flush
waits for the whole batch to finish before the next flush even starts,
and a lone straggler waits out the full flush timeout on top. LLM
serving schedulers solved the same shape of problem with **continuous
batching**: keep a small fixed pool of in-flight batch slots, retire any
slot whose device work has completed, and immediately re-admit queued
requests into the freed slot — per *step*, not per *flush*.

This module is the host-side half of that design, deliberately built
around two injectable seams so every scheduling decision is
deterministically unit-testable (the seams are as much the deliverable
as the scheduler — see ``tests/test_scheduler.py``):

  * **time** — the ``clock`` callable (shared with
    :class:`~repro.serve.queue.MicroBatchQueue`), so admission order,
    fairness, and latency accounting run against a fake clock in tests;
  * **device dispatch** — an *executor* object with three methods::

        dispatch(reqs) -> handle   # assemble + launch, stamp t_dispatch;
                                   # must NOT block on device work
        poll(handle) -> bool       # True when the handle's work is done
        finalize(handle) -> list[Request]
                                   # block on the handle, fill results,
                                   # stamp t_done, record stats; returns
                                   # the non-cancelled requests

    Production uses :class:`~repro.serve.db_search.SearchExecutor`
    (async JAX dispatch + ``jax.device_put``; ``poll`` via
    ``Array.is_ready``); tests use recording/simulated executors.
    Handles are *opaque* to the scheduler — which is how the clustering
    endpoint rides the same slot pool: the executor hands back a
    ``ClusterBatchHandle`` for ``kind="cluster"`` batches and a
    ``BatchHandle`` for search, and the scheduler never looks inside.

**Backlog policy is the queue's.** The scheduler reuses
:class:`~repro.serve.queue.MicroBatchQueue` unchanged as its backlog:
``take_batch`` already implements tenant-homogeneous FIFO batches, the
globally-oldest-first tenant pick (no starvation: a cold tenant's head
request only ages until it *is* the oldest), and the fairness cap with
skip-last-served rotation. Continuous batching changes only *when*
batches leave the queue: whenever a slot is free and requests are
pending — never waiting for a full lane or a flush timeout. Under light
load that admits singleton batches immediately (latency-optimal); under
load the slots stay busy and the backlog coalesces into larger batches
between admissions (throughput recovers) — the classic continuous-
batching behavior.

**Cancellation.** ``cancel`` removes a still-pending request from the
queue outright; an already in-flight request is only *marked* (its slot
keeps its position and batch shape — device work is not restartable) and
its result is dropped at retire time. Slot accounting is unaffected
either way, which is exactly what the tests pin.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

from repro.serve.queue import MicroBatchQueue, Request


@dataclasses.dataclass
class Slot:
    """One in-flight batch: its requests and the executor's handle."""

    sid: int
    reqs: list[Request]
    handle: Any
    t_dispatch: float


class ContinuousScheduler:
    """Fixed-slot continuous batching over a ``MicroBatchQueue`` backlog.

    ``step()`` is the one-call serving loop body: retire every completed
    slot (collecting finished requests), then admit queued batches into
    the freed slots — retire-then-admit, so a slot freed this step is
    refilled this same step and the pool never idles while work is
    queued.
    """

    def __init__(self, queue: MicroBatchQueue, executor, *,
                 num_slots: int = 2,
                 clock: Callable[[], float] = time.monotonic):
        if num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {num_slots}")
        self.queue = queue
        self.executor = executor
        self.num_slots = int(num_slots)
        self._clock = clock
        self._slots: dict[int, Slot] = {}
        self._next_sid = 0
        self.dispatched_batches = 0
        self.retired_batches = 0
        self.cancellations = 0

    @property
    def in_flight(self) -> int:
        """Slots currently occupied (always <= num_slots)."""
        return len(self._slots)

    @property
    def free_slots(self) -> int:
        return self.num_slots - len(self._slots)

    def in_flight_requests(self) -> int:
        return sum(len(s.reqs) for s in self._slots.values())

    def cancel(self, rid: int) -> bool:
        """Drop a request: un-queue it if still pending, else mark the
        in-flight copy cancelled (result discarded at retire; the slot's
        accounting is untouched). Returns False for unknown/finished
        rids."""
        if self.queue.cancel(rid):
            self.cancellations += 1
            return True
        for slot in self._slots.values():
            for r in slot.reqs:
                if r.rid == rid and not r.cancelled:
                    r.cancelled = True
                    self.cancellations += 1
                    return True
        return False

    def admit(self) -> int:
        """Fill free slots from the backlog; returns batches admitted.

        Each admission is one ``take_batch`` — tenant-homogeneous, FIFO,
        fairness-capped by the queue's own policy — dispatched through
        the executor without blocking on the device.
        """
        admitted = 0
        while len(self._slots) < self.num_slots and len(self.queue):
            reqs = self.queue.take_batch()
            if not reqs:
                break
            handle = self.executor.dispatch(reqs)
            slot = Slot(sid=self._next_sid, reqs=reqs, handle=handle,
                        t_dispatch=self._clock())
            self._next_sid += 1
            self._slots[slot.sid] = slot
            self.dispatched_batches += 1
            admitted += 1
        return admitted

    def retire(self, block: bool = False) -> list[Request]:
        """Finalize completed slots (all in-flight slots with ``block``);
        returns the finished, non-cancelled requests."""
        done: list[Request] = []
        for sid in list(self._slots):
            slot = self._slots[sid]
            if block or self.executor.poll(slot.handle):
                done.extend(self.executor.finalize(slot.handle))
                del self._slots[sid]
                self.retired_batches += 1
        return done

    def step(self, block: bool = False) -> list[Request]:
        """One scheduler step: retire completed slots, then refill free
        slots from the queue. Returns the requests finished this step."""
        done = self.retire(block=block)
        self.admit()
        return done

    def drain(self) -> list[Request]:
        """Run steps with blocking retires until queue and slots are empty."""
        done: list[Request] = []
        while self._slots or len(self.queue):
            self.admit()
            done.extend(self.retire(block=True))
        return done

    def summary(self) -> dict:
        return {
            "num_slots": self.num_slots,
            "in_flight": self.in_flight,
            "dispatched_batches": self.dispatched_batches,
            "retired_batches": self.retired_batches,
            "cancellations": self.cancellations,
        }
