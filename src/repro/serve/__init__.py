"""Serving subsystem: sharded, micro-batched DB-search serving.

The paper's headline workload — spectral-library search expressed as
integer matmuls — is served here at scale by combining the two mesh axes
of the production topology (see ``repro.launch.mesh``):

  * the packed HD reference database is **sharded over 'model'**
    (``db_search.shard_database``), each shard computes a local top-k and
    only ``Q x k`` candidates per shard cross the interconnect for the
    global merge — never the full ``Q x R`` score matrix;
  * incoming queries are **batched over 'data'** behind a FIFO
    micro-batching request queue (``queue.MicroBatchQueue``) that flushes
    on a max batch size or a flush timeout, with per-request latency
    accounting.

``db_search.DBSearchServer`` glues both together and routes the merged
results through target-decoy FDR filtering (``repro.spectra.fdr``).
``repro.launch.serve_db`` is the runnable entry point.
"""

from repro.serve.db_search import (
    DBSearchServer,
    ShardedDatabase,
    search_database,
    search_with_fdr,
    shard_database,
    sharded_topk_search,
)
from repro.serve.queue import LatencyStats, MicroBatchQueue, Request

__all__ = [
    "DBSearchServer",
    "ShardedDatabase",
    "search_database",
    "search_with_fdr",
    "shard_database",
    "sharded_topk_search",
    "LatencyStats",
    "MicroBatchQueue",
    "Request",
]
