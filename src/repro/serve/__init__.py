"""Serving subsystem: sharded, micro-batched, multi-tenant DB-search serving.

The paper's headline workload — spectral-library search expressed as
integer matmuls — is served here at scale by combining the two mesh axes
of the production topology (see ``repro.launch.mesh``):

  * the packed HD reference database is **sharded over 'model'**
    (``db_search.shard_database``), each shard computes a local top-k and
    only ``Q x k`` candidates per shard cross the interconnect for the
    global merge — never the full ``Q x R`` score matrix;
  * incoming queries are **batched over 'data'** behind a tenant-aware
    FIFO micro-batching request queue (``queue.MicroBatchQueue``) that
    flushes on a max batch size or a flush timeout, with per-request
    latency accounting and a per-flush fairness cap across tenants.

On top sits the serving cache layer (``cache``): ``QueryHVCache``
memoizes encoded/packed query HVs under a content-hash LRU with a byte
budget, and ``BankRegistry`` holds per-tenant ``ShardedDatabase`` handles
with lazy shard-on-first-use, pinning, and LRU eviction of cold banks.

``db_search.DBSearchServer`` glues all of it together — shape-bucketed
batch dispatch, per-tenant latency/cache accounting — and routes the
merged results through target-decoy FDR filtering (``repro.spectra.fdr``).
Device work sits behind the ``SearchExecutor`` dispatch/poll/finalize
seam so the synchronous flush loop and the continuous-batching
``scheduler.ContinuousScheduler`` (fixed in-flight slot pool, per-step
admission — the tail-latency mode) share one code path; with a
``QueryEncoder`` the server accepts raw quantized spectra and runs the
fused encode->pack->search kernel end to end. ``repro.launch.serve_db``
is the runnable entry point.

The server is a live read/write system: ``BankRegistry.append`` streams
new refs into small unpacked per-tenant delta banks (``delta.DeltaBank``)
with exact merged base+delta search — provably bit-identical to a
from-scratch rebuild, OMS included — and background ``compact`` folds
deltas into the packed base past a threshold. The paper's other
full-stack task, spectral clustering, is a second serving endpoint
(``clustering.StreamingClusterer``) sharing the queue/scheduler as its
own request kind; ``repro.launch.serve_cluster`` is its entry point.
"""

from repro.serve.cache import BankRegistry, QueryHVCache
from repro.serve.clustering import (
    ClusterAssignment,
    ClusteringConfig,
    StreamingClusterer,
)
from repro.serve.delta import (
    DeltaBank,
    MergedOMSPlan,
    merged_layout,
    merged_oms_plan,
    merged_oms_search_encoded,
    merged_search_encoded,
)
from repro.serve.db_search import (
    DBSearchServer,
    QueryEncoder,
    SearchExecutor,
    ShardedDatabase,
    bucket_for,
    encode_queries,
    make_buckets,
    oms_plan,
    oms_search,
    oms_search_encoded,
    oms_search_levels,
    oms_search_with_fdr,
    search_database,
    search_database_encoded,
    search_database_levels,
    search_with_fdr,
    shard_database,
    sharded_topk_search,
)
from repro.serve.scheduler import ContinuousScheduler, Slot
from repro.serve.oms import (
    OMSConfig,
    OMSPlan,
    PrecursorIndex,
    build_precursor_index,
    plan_candidates,
)
from repro.serve.queue import LatencyStats, MicroBatchQueue, Request

__all__ = [
    "BankRegistry",
    "ClusterAssignment",
    "ClusteringConfig",
    "ContinuousScheduler",
    "DBSearchServer",
    "DeltaBank",
    "LatencyStats",
    "MergedOMSPlan",
    "MicroBatchQueue",
    "OMSConfig",
    "OMSPlan",
    "PrecursorIndex",
    "QueryEncoder",
    "QueryHVCache",
    "Request",
    "SearchExecutor",
    "ShardedDatabase",
    "Slot",
    "StreamingClusterer",
    "bucket_for",
    "build_precursor_index",
    "encode_queries",
    "make_buckets",
    "merged_layout",
    "merged_oms_plan",
    "merged_oms_search_encoded",
    "merged_search_encoded",
    "oms_plan",
    "oms_search",
    "oms_search_encoded",
    "oms_search_levels",
    "oms_search_with_fdr",
    "plan_candidates",
    "search_database",
    "search_database_encoded",
    "search_database_levels",
    "search_with_fdr",
    "shard_database",
    "sharded_topk_search",
]
