"""Sharded, multi-tenant DB-search serving launcher.

Builds the debug mesh, HD-encodes one synthetic spectral library
(+ decoys) per tenant, registers them in a lazy
:class:`~repro.serve.cache.BankRegistry` (banks shard onto the 'model'
axis on first use; tenant 0 is pinned hot), then streams bursty,
hot-tenant-skewed queries — drawn with replacement, so repeats hit the
content-hash :class:`~repro.serve.cache.QueryHVCache` — through the
micro-batching :class:`~repro.serve.DBSearchServer`, batching over
'data' with shape-bucketed padding and a per-flush fairness cap. Reports
queries/sec, aggregate and per-tenant p50/p95 latency, cache hit rate,
bank builds/evictions, and identification quality at the requested FDR.

Usage:
  PYTHONPATH=src python -m repro.launch.serve_db --reduced
  PYTHONPATH=src python -m repro.launch.serve_db --reduced --tenants 4 \\
      --cache-mb 16 --buckets 3 --fairness-cap 8
"""

from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core import SpecPCMConfig, encode_and_pack
from repro.core.hd.encoding import quantize_levels
from repro.dist.sharding import set_mesh
from repro.launch.mesh import make_debug_mesh
from repro.serve import (
    BankRegistry,
    DBSearchServer,
    OMSConfig,
    QueryEncoder,
    oms_plan,
    oms_search_levels,
    oms_search_with_fdr,
    search_database_levels,
    search_with_fdr,
)
from repro.serve.db_search import fdr_route
from repro.spectra import SyntheticMSConfig, generate_dataset
from repro.spectra.fdr import make_decoys
from repro.spectra.synthetic import generate_query_set


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--reduced", action="store_true",
                    help="small sizes for CPU smoke runs")
    ap.add_argument("--hd-dim", type=int, default=None)
    ap.add_argument("--identities", type=int, default=None)
    ap.add_argument("--refs-per-identity", type=int, default=None)
    ap.add_argument("--queries", type=int, default=None,
                    help="requests per tenant")
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=None)
    ap.add_argument("--flush-ms", type=float, default=5.0)
    ap.add_argument("--fdr", type=float, default=0.01)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-pack", action="store_true",
                    help="disable the bit-packed XOR+popcount shard path")
    ap.add_argument("--fused", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="route per-shard search through the fused "
                         "streaming top-k Pallas kernel (O(Q*k) candidate "
                         "traffic; interpret-mode — slow — off TPU)")
    ap.add_argument("--tenants", type=int, default=1,
                    help="number of tenant banks (tenant 0 is pinned hot)")
    ap.add_argument("--cache-mb", type=float, default=64.0,
                    help="query-HV cache byte budget in MiB (0 disables)")
    ap.add_argument("--buckets", type=int, default=4,
                    help="batch-shape buckets (geometric ladder up to "
                         "--max-batch; 1 = always pad to max)")
    ap.add_argument("--fairness-cap", type=int, default=None,
                    help="max requests one tenant may take per flush while "
                         "others wait (default: no cap)")
    ap.add_argument("--max-banks", type=int, default=None,
                    help="LRU-evict cold built banks beyond this many "
                         "(default: keep all)")
    ap.add_argument("--oms", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="open-modification serving mode: banks are "
                         "precursor-sorted and each query scans only its "
                         "precursor window (query - ref in "
                         "(-tolerance, open-tol))")
    ap.add_argument("--tolerance", type=float, default=20.0,
                    help="precursor tolerance on the light side (and both "
                         "sides for exact search)")
    ap.add_argument("--open-tol", type=float, default=200.0,
                    help="how much heavier than a reference an OMS query "
                         "may be (the modification-mass budget)")
    ap.add_argument("--continuous", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="continuous-batching mode: keep --num-slots "
                         "batches in flight and admit queued requests the "
                         "moment a slot frees, instead of flush-and-wait "
                         "(collapses tail latency; --flush-ms is inert)")
    ap.add_argument("--num-slots", type=int, default=2,
                    help="in-flight batch slots for --continuous (2 = "
                         "double-buffered host prep vs device search)")
    ap.add_argument("--fused-e2e", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="submit raw quantized spectra and run the fused "
                         "encode->pack->search kernel per shard (one device "
                         "dispatch; the query HV never touches HBM)")
    ap.add_argument("--append", type=float, default=0.0, metavar="FRAC",
                    help="hold this fraction of every bank out of the "
                         "initial registration and stream it back in with "
                         "server.append() halfway through the run — "
                         "searches after the append take the exact merged "
                         "base+delta path (0 disables)")
    ap.add_argument("--compact-threshold", type=float, default=None,
                    help="fold a tenant's delta into its packed base when "
                         "the delta exceeds this fraction of total rows "
                         "(default: never compact)")
    args = ap.parse_args(argv)

    if args.tenants < 1:
        raise SystemExit("--tenants must be >= 1")
    if args.reduced:
        dim = args.hd_dim or 512
        n_id = args.identities or 48
        per_id = args.refs_per_identity or 2
        n_q = args.queries or 64
        max_batch = args.max_batch or 16
        num_bins = 256
    else:
        dim = args.hd_dim or 2048
        n_id = args.identities or 256
        per_id = args.refs_per_identity or 4
        n_q = args.queries or 256
        max_batch = args.max_batch or 32
        num_bins = 1024

    mesh = make_debug_mesh()
    set_mesh(mesh)
    print(f"mesh: {dict(mesh.shape)}")

    # SLC (1-bit) encoding keeps the HVs bipolar so the server can take the
    # bit-packed shard path whenever D % 32 == 0.
    cfg = SpecPCMConfig(hd_dim=dim, mlc_bits=1, num_levels=16, ideal=True,
                        seed=args.seed)
    pack = False if args.no_pack else "auto"
    registry = BankRegistry(mesh=mesh, pack=pack, max_banks=args.max_banks,
                            fused=args.fused)

    # OMS traffic: modified queries carry a heavier precursor (a phospho-like
    # mass addition), the case the open window exists for.
    oms_cfg = (OMSConfig(tol=args.tolerance, open_tol=args.open_tol)
               if args.oms else None)
    mod_range = (60.0, 0.75 * args.open_tol) if args.oms else (0.0, 0.0)

    if not 0.0 <= args.append < 1.0:
        raise SystemExit("--append must be in [0, 1)")
    datasets, query_pools, precursor_pools = {}, {}, {}
    holdouts = {}  # tenant -> (refs, decoys, precursor) appended mid-run
    for t in range(args.tenants):
        tenant = f"tenant{t}"
        ms = SyntheticMSConfig(num_identities=n_id,
                               spectra_per_identity=per_id,
                               num_bins=num_bins, seed=args.seed + 31 * t,
                               modification_mass_range=mod_range)
        ds = generate_dataset(ms)
        refs_hv = encode_and_pack(ds.spectra, cfg)
        decoys_hv = encode_and_pack(make_decoys(ds.spectra), cfg)
        prec = np.asarray(ds.precursor) if args.oms else None
        n_refs = int(refs_hv.shape[0])
        keep = n_refs - int(args.append * n_refs)
        if args.append and keep < n_refs:
            # hold out a *suffix* so append restores the original row
            # order — the identity arrays keep indexing matches directly
            holdouts[tenant] = (
                np.asarray(refs_hv[keep:], np.int8),
                np.asarray(decoys_hv[keep:], np.int8),
                None if prec is None else prec[keep:].astype(np.float32))
            refs_hv, decoys_hv = refs_hv[:keep], decoys_hv[:keep]
            prec = None if prec is None else prec[:keep]
        registry.register(tenant, refs_hv, decoys=decoys_hv, pin=t == 0,
                          precursor=prec)
        qs = generate_query_set(ds, ms, num_queries=n_q,
                                seed=args.seed + 31 * t + 1)
        datasets[tenant] = (np.asarray(ds.identity), np.asarray(qs.identity))
        if args.fused_e2e:
            # raw quantized spectra: the server encodes on the device, fused
            query_pools[tenant] = np.asarray(
                quantize_levels(qs.spectra, cfg.num_levels), np.int32)
        else:
            query_pools[tenant] = np.asarray(encode_and_pack(qs.spectra, cfg))
        precursor_pools[tenant] = np.asarray(qs.precursor, np.float32)
    print(f"{args.tenants} tenant bank(s) registered (lazy; built on first "
          f"request), D={dim}, pack={pack}, fused={args.fused}, "
          f"oms={args.oms}, fused_e2e={args.fused_e2e}, "
          f"mode={'continuous' if args.continuous else 'flush-sync'}")

    # every tenant encodes with the same SpecPCMConfig, so one query-side
    # codebook bundle serves the whole fleet (bit-identical to the
    # encode_and_pack the banks were built with: mlc_bits=1 packs to
    # identity)
    encoder = (QueryEncoder.from_config(
        dim=dim, num_features=num_bins, num_levels=cfg.num_levels,
        seed=args.seed) if args.fused_e2e else None)

    server = DBSearchServer(
        registry, k=args.k, fdr=args.fdr, max_batch_size=max_batch,
        flush_timeout_s=args.flush_ms / 1e3,
        cache_bytes=int(args.cache_mb * 2**20) or None,
        buckets=args.buckets, fairness_cap=args.fairness_cap, oms=oms_cfg,
        encoder=encoder, fused_e2e=args.fused_e2e,
        continuous=args.continuous, num_slots=args.num_slots,
        compact_threshold=args.compact_threshold)

    # warm the jit cache on the hot tenant (search + FDR routing) for the
    # largest bucket so latency numbers measure serving, not compile; cold
    # tenants pay their lazy shard+compile on first flush by design.
    db0 = registry.get("tenant0")
    warm_prec = None
    if args.oms:
        warm_prec = precursor_pools["tenant0"][:max_batch]
        if warm_prec.shape[0] < max_batch:
            warm_prec = np.resize(warm_prec, max_batch)
        warm_prec = np.sort(warm_prec)
    if args.fused_e2e:
        warm_q = jnp.zeros((max_batch, num_bins), jnp.int32)
        if args.oms:
            plan = oms_plan(db0, warm_prec, oms_cfg)
            idx, vals = oms_search_levels(db0, encoder, warm_q, plan,
                                          args.k, fused_e2e=True)
            fdr_route(db0, idx, vals, fdr=args.fdr,
                      valid=jnp.asarray(plan.has_candidate))
        else:
            idx, vals = search_database_levels(db0, encoder, warm_q, args.k,
                                               fused_e2e=True)
            fdr_route(db0, idx, vals, fdr=args.fdr)
    elif args.oms:
        oms_search_with_fdr(db0, jnp.zeros((max_batch, dim), jnp.int8),
                            warm_prec, k=args.k, fdr=args.fdr, cfg=oms_cfg)
    else:
        search_with_fdr(db0, jnp.zeros((max_batch, dim), jnp.int8), k=args.k,
                        fdr=args.fdr)

    # bursty, hot-tenant-skewed traffic; queries drawn WITH replacement so
    # repeats exercise the content-hash cache.
    rng = np.random.default_rng(args.seed)
    tenant_names = list(query_pools)
    # tenant 0 gets ~half the traffic, the rest split the remainder
    probs = np.asarray([2.0] + [1.0] * (args.tenants - 1)
                       if args.tenants > 1 else [1.0])
    probs = probs / probs.sum()
    total = n_q * args.tenants
    meta = {}  # rid -> (tenant, query row)
    done = []
    sent = 0
    while sent < total:
        if holdouts and sent >= total // 2:
            # stream the held-out rows back in: every later flush takes
            # the exact merged base+delta path (until compaction, if on)
            t0 = time.perf_counter()
            for tenant, (h_refs, h_dec, h_prec) in holdouts.items():
                server.append(tenant, h_refs, h_dec, precursor=h_prec)
            dt = time.perf_counter() - t0
            print(f"appended {sum(h[0].shape[0] + h[1].shape[0] for h in holdouts.values())} "
                  f"rows across {len(holdouts)} tenant(s) in {dt * 1e3:.1f} ms")
            holdouts = {}
        burst = int(rng.integers(1, max_batch + 1))
        for _ in range(min(burst, total - sent)):
            tenant = tenant_names[int(rng.choice(args.tenants, p=probs))]
            qi = int(rng.integers(0, query_pools[tenant].shape[0]))
            rid = server.submit(
                query_pools[tenant][qi], tenant=tenant,
                precursor=(float(precursor_pools[tenant][qi])
                           if args.oms else None))
            meta[rid] = (tenant, qi)
            sent += 1
        done.extend(server.step())
        # continuous mode decouples submission from device completion;
        # with no pacing the driver is an infinite-rate open loop and
        # latency just measures overload depth. Closed-loop backpressure
        # (block-retire once the backlog exceeds a bucket) keeps the run
        # below saturation so the numbers measure scheduling.
        while args.continuous and len(server.queue) >= max_batch:
            done.extend(server.step(force=True))
        if rng.random() < 0.3:  # idle gap: lets the flush timeout fire
            time.sleep(args.flush_ms / 1e3)
            done.extend(server.step())
    done.extend(server.run_until_drained())
    assert len(done) == total, (len(done), total)

    accepted = 0
    correct = 0
    for r in done:
        tenant, qi = meta[r.rid]
        if r.result.match >= 0:
            accepted += 1
            ref_ident, q_ident = datasets[tenant]
            correct += int(ref_ident[r.result.match] == q_ident[qi])

    s = server.summary()
    print(f"served {s['count']} queries in {s['batches']} micro-batches "
          f"(mean batch {s['mean_batch']:.1f}; "
          f"bucket usage {s['buckets']})")
    print(f"throughput: {s['qps']:.1f} queries/sec")
    print(f"latency: p50 {s['p50_ms']:.2f} ms, p95 {s['p95_ms']:.2f} ms, "
          f"mean {s['mean_ms']:.2f} ms (queue wait p50 "
          f"{s['queue_wait_p50_ms']:.2f} ms, p95 "
          f"{s['queue_wait_p95_ms']:.2f} ms)")
    sched = s.get("scheduler")
    if sched is not None:
        print(f"scheduler: {sched['num_slots']} slots, "
              f"{sched['dispatched_batches']} dispatched / "
              f"{sched['retired_batches']} retired batches, "
              f"{sched['cancellations']} cancellations")
    qc = s["query_cache"]
    if qc is not None:
        print(f"query-HV cache: {qc['hits']} hits / {qc['misses']} misses "
              f"(hit rate {qc['hit_rate']:.1%}), {qc['entries']} entries, "
              f"{qc['bytes'] / 2**20:.2f}/{qc['capacity_bytes'] / 2**20:.0f} "
              f"MiB, {qc['evictions']} evictions")
    b = s["banks"]
    print(f"banks: {b['built']}/{b['registered']} built ({b['builds']} "
          f"builds, {b['evictions']} evictions, {b['pinned']} pinned)")
    if args.append:
        ing = s["ingest"]
        print(f"ingest: {b['appends']} appends, {b['compactions']} "
              f"compactions, {b['delta_rows']} delta rows pending "
              f"(compact threshold {ing['compact_threshold']})")
    for tenant in sorted(s["tenants"]):
        ts = s["tenants"][tenant]
        print(f"  {tenant}: {ts['count']} reqs, p50 {ts['p50_ms']:.2f} ms, "
              f"p95 {ts['p95_ms']:.2f} ms, "
              f"cache hit rate {ts['cache_hit_rate']:.1%}")
    o = s.get("oms")
    if o is not None:
        print(f"oms: window (-{o['tol']:g}, +{o['open_tol']:g}), candidate "
              f"fraction {o['candidate_fraction']:.3f}, scanned fraction "
              f"{o['scanned_fraction']:.3f}, {o['no_candidate']} queries "
              f"with empty windows")
    print(f"identified at {args.fdr:.0%} FDR: {accepted}/{total} "
          f"({correct} correct identity)")
    return s


if __name__ == "__main__":
    main()
