"""Sharded DB-search serving launcher.

Builds the debug mesh, HD-encodes a synthetic spectral library (+ decoys),
shards the bank over the 'model' axis, then streams encoded queries through
the micro-batching :class:`repro.serve.DBSearchServer` — batching over
'data' — and reports queries/sec and p50/p95 request latency alongside the
identification quality at the requested FDR.

Usage:
  PYTHONPATH=src python -m repro.launch.serve_db --reduced
"""

from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core import SpecPCMConfig, encode_and_pack
from repro.dist.sharding import set_mesh
from repro.launch.mesh import make_debug_mesh
from repro.serve import DBSearchServer, search_with_fdr, shard_database
from repro.spectra import SyntheticMSConfig, generate_dataset
from repro.spectra.fdr import make_decoys
from repro.spectra.synthetic import generate_query_set


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--reduced", action="store_true",
                    help="small sizes for CPU smoke runs")
    ap.add_argument("--hd-dim", type=int, default=None)
    ap.add_argument("--identities", type=int, default=None)
    ap.add_argument("--refs-per-identity", type=int, default=None)
    ap.add_argument("--queries", type=int, default=None)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=None)
    ap.add_argument("--flush-ms", type=float, default=5.0)
    ap.add_argument("--fdr", type=float, default=0.01)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-pack", action="store_true",
                    help="disable the bit-packed XOR+popcount shard path")
    args = ap.parse_args(argv)

    if args.reduced:
        dim = args.hd_dim or 512
        n_id = args.identities or 48
        per_id = args.refs_per_identity or 2
        n_q = args.queries or 64
        max_batch = args.max_batch or 16
        num_bins = 256
    else:
        dim = args.hd_dim or 2048
        n_id = args.identities or 256
        per_id = args.refs_per_identity or 4
        n_q = args.queries or 256
        max_batch = args.max_batch or 32
        num_bins = 1024

    mesh = make_debug_mesh()
    set_mesh(mesh)
    print(f"mesh: {dict(mesh.shape)}")

    ms = SyntheticMSConfig(num_identities=n_id, spectra_per_identity=per_id,
                           num_bins=num_bins, seed=args.seed)
    ds = generate_dataset(ms)
    # SLC (1-bit) encoding keeps the HVs bipolar so the server can take the
    # bit-packed shard path whenever D % 32 == 0.
    cfg = SpecPCMConfig(hd_dim=dim, mlc_bits=1, num_levels=16, ideal=True,
                        seed=args.seed)
    refs_hv = encode_and_pack(ds.spectra, cfg)
    decoys_hv = encode_and_pack(make_decoys(ds.spectra), cfg)
    pack = False if args.no_pack else "auto"
    db = shard_database(refs_hv, decoys=decoys_hv, mesh=mesh, pack=pack)
    print(f"bank: {db.num_targets} targets + {db.num_decoys} decoys, D={dim}, "
          f"{db.num_shards} shard(s) x {db.shard_rows} rows, "
          f"packed={db.packed}")

    qs = generate_query_set(ds, ms, num_queries=n_q, seed=args.seed + 1)
    q_hv = np.asarray(encode_and_pack(qs.spectra, cfg))
    n_q = q_hv.shape[0]

    server = DBSearchServer(db, k=args.k, fdr=args.fdr,
                            max_batch_size=max_batch,
                            flush_timeout_s=args.flush_ms / 1e3)
    # warm the jit cache (search + FDR routing) so latency numbers measure
    # serving, not compile
    search_with_fdr(db, jnp.zeros((max_batch, dim), jnp.int8), k=args.k,
                    fdr=args.fdr)

    rng = np.random.default_rng(args.seed)
    done = []
    i = 0
    while i < n_q:
        burst = int(rng.integers(1, max_batch + 1))  # bursty arrivals
        for j in range(i, min(i + burst, n_q)):
            server.submit(q_hv[j])
        i += burst
        done.extend(server.step())
        if rng.random() < 0.3:  # idle gap: lets the flush timeout fire
            time.sleep(args.flush_ms / 1e3)
            done.extend(server.step())
    done.extend(server.run_until_drained())
    assert len(done) == n_q, (len(done), n_q)

    ref_ident = np.asarray(ds.identity)
    q_ident = np.asarray(qs.identity)
    done.sort(key=lambda r: r.rid)
    matched = np.asarray([r.result.match for r in done])
    accepted = matched >= 0
    correct = accepted & (ref_ident[np.where(accepted, matched, 0)]
                          == q_ident[: n_q])
    s = server.summary()
    print(f"served {s['count']} queries in {s['batches']} micro-batches "
          f"(mean batch {s['mean_batch']:.1f})")
    print(f"throughput: {s['qps']:.1f} queries/sec")
    print(f"latency: p50 {s['p50_ms']:.2f} ms, p95 {s['p95_ms']:.2f} ms, "
          f"mean {s['mean_ms']:.2f} ms")
    print(f"identified at {args.fdr:.0%} FDR: {int(accepted.sum())}/{n_q} "
          f"({int(correct.sum())} correct identity)")
    return s


if __name__ == "__main__":
    main()
