"""ERT-style empirical autotuner CLI.

Measures this device's real compute and memory-bandwidth ceilings
(growing-matmul and growing-copy sweeps, :mod:`repro.tune.microbench`),
sweeps the Pallas kernel block sizes against representative workloads
(:mod:`repro.tune.sweep`), and persists the winning configs in a JSON
tuning table keyed by (device kind, shape bucket). Point
``REPRO_TUNING_TABLE`` at the written file and every kernel ops layer —
and every serving path built on them — resolves its tile sizes from the
table at trace time, falling back to the hand-tuned defaults for shapes
(or device kinds) the table doesn't cover. ``repro.launch.dryrun``
prices its roofline terms with the measured ceilings whenever such a
table is active.

Usage:
  PYTHONPATH=src python -m repro.launch.tune --out artifacts/tuning_table.json
  PYTHONPATH=src python -m repro.launch.tune --quick --ops topk_hamming,imc_mvm
"""

from __future__ import annotations

import argparse
import json

from repro.tune import ENV_VAR
from repro.tune.sweep import OPS, build_tuning_table, tuned_vs_default_ratio


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="artifacts/tuning_table.json",
                    help="tuning-table JSON path (written atomically)")
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweep for CI / CPU smoke runs")
    ap.add_argument("--ops", default=None,
                    help=f"comma-separated subset of {','.join(OPS)}")
    ap.add_argument("--iters", type=int, default=3,
                    help="timing iterations per candidate (median taken)")
    ap.add_argument("--skip-ceilings", action="store_true",
                    help="sweep blocks only; keep the table ceiling-free")
    args = ap.parse_args(argv)

    ops = None
    if args.ops:
        ops = tuple(s.strip() for s in args.ops.split(",") if s.strip())
        unknown = [o for o in ops if o not in OPS]
        if unknown:
            ap.error(f"unknown ops {unknown}; choose from {OPS}")

    table = build_tuning_table(args.out, quick=args.quick, ops=ops,
                               iters=args.iters,
                               skip_ceilings=args.skip_ceilings)

    print(f"device_kind: {table.device_kind}")
    if table.ceilings:
        print("ceilings: peak %.2f GFLOP/s, hbm %.2f GB/s"
              % (table.ceilings["peak_flops"] / 1e9,
                 table.ceilings["hbm_bw"] / 1e9))
    for op, buckets in table.ops.items():
        for bucket, entry in buckets.items():
            us, dus = entry.get("us"), entry.get("default_us")
            speedup = f" ({dus / us:.2f}x vs default)" if us and dus else ""
            print(f"  {op} [{bucket}]: {json.dumps(entry['blocks'])}"
                  f"{speedup}")
    print("worst tuned-vs-default ratio: %.3f"
          % tuned_vs_default_ratio(table))
    print(f"wrote {args.out}; activate with {ENV_VAR}={args.out}")
    return table


if __name__ == "__main__":
    main()
