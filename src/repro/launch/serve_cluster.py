"""Streaming spectral-clustering serving launcher (the paper's second task).

HD-encodes one synthetic spectrum stream per tenant and pushes it through
the clustering endpoint of :class:`~repro.serve.DBSearchServer`
(``submit_cluster``): per-tenant assign-or-spawn against packed centroid
HVs on the device, periodic complete-linkage re-consolidation, sharing
the micro-batch queue / bucket ladder / (optionally) the continuous
scheduler with DB search. Reports spectra/sec, latency, cluster counts,
and — ground truth being synthetic — the paper's clustering quality
metrics (clustered-spectra ratio, incorrect-clustering ratio).

Usage:
  PYTHONPATH=src python -m repro.launch.serve_cluster --reduced
  PYTHONPATH=src python -m repro.launch.serve_cluster --reduced \\
      --tenants 2 --consolidate-every 64 --continuous
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import SpecPCMConfig, encode_and_pack
from repro.core.hd.clustering import (
    clustered_spectra_ratio,
    incorrect_clustering_ratio,
)
from repro.dist.sharding import set_mesh
from repro.launch.mesh import make_debug_mesh
from repro.serve import BankRegistry, ClusteringConfig, DBSearchServer
from repro.spectra import SyntheticMSConfig, generate_dataset


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--reduced", action="store_true",
                    help="small sizes for CPU smoke runs")
    ap.add_argument("--hd-dim", type=int, default=None)
    ap.add_argument("--identities", type=int, default=None)
    ap.add_argument("--spectra-per-identity", type=int, default=None)
    ap.add_argument("--max-batch", type=int, default=None)
    ap.add_argument("--flush-ms", type=float, default=5.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tenants", type=int, default=1,
                    help="independent cluster streams (per-tenant state)")
    ap.add_argument("--threshold-frac", type=float, default=0.36,
                    help="assign threshold as a fraction of D (Hamming "
                         "distance to the nearest centroid; random HVs sit "
                         "near 0.5D, same-identity synthetic spectra near "
                         "0.3D)")
    ap.add_argument("--consolidate-every", type=int, default=0,
                    help="re-run complete linkage over the centroid bank "
                         "every this many assigned spectra (0 disables)")
    ap.add_argument("--no-pack", action="store_true",
                    help="disable the bit-packed popcount distance kernel")
    ap.add_argument("--continuous", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="continuous-batching mode (shared scheduler slots)")
    ap.add_argument("--num-slots", type=int, default=2)
    args = ap.parse_args(argv)

    if args.tenants < 1:
        raise SystemExit("--tenants must be >= 1")
    if args.reduced:
        dim = args.hd_dim or 512
        n_id = args.identities or 24
        per_id = args.spectra_per_identity or 6
        max_batch = args.max_batch or 16
        num_bins = 256
    else:
        dim = args.hd_dim or 2048
        n_id = args.identities or 128
        per_id = args.spectra_per_identity or 8
        max_batch = args.max_batch or 32
        num_bins = 1024

    mesh = make_debug_mesh()
    set_mesh(mesh)
    print(f"mesh: {dict(mesh.shape)}")

    cfg = SpecPCMConfig(hd_dim=dim, mlc_bits=1, num_levels=16, ideal=True,
                        seed=args.seed)
    ccfg = ClusteringConfig(
        dim=dim, threshold=args.threshold_frac * dim,
        consolidate_every=args.consolidate_every,
        pack=False if args.no_pack else "auto")

    streams = {}  # tenant -> (hvs (N, D) int8, identity (N,))
    for t in range(args.tenants):
        tenant = f"tenant{t}"
        ms = SyntheticMSConfig(num_identities=n_id,
                               spectra_per_identity=per_id,
                               num_bins=num_bins, seed=args.seed + 31 * t)
        ds = generate_dataset(ms)
        hvs = np.asarray(encode_and_pack(ds.spectra, cfg), np.int8)
        streams[tenant] = (hvs, np.asarray(ds.identity))
    n_per = n_id * per_id
    print(f"{args.tenants} stream(s) of {n_per} spectra, D={dim}, "
          f"threshold={ccfg.threshold:g} "
          f"({args.threshold_frac:g}*D), packed={ccfg.packed}, "
          f"consolidate_every={args.consolidate_every}, "
          f"mode={'continuous' if args.continuous else 'flush-sync'}")

    server = DBSearchServer(
        BankRegistry(), k=1, max_batch_size=max_batch,
        flush_timeout_s=args.flush_ms / 1e3, buckets=4,
        clustering=ccfg, continuous=args.continuous,
        num_slots=args.num_slots)

    # interleaved round-robin streaming in bursts, arrival order shuffled
    # within each tenant's stream
    rng = np.random.default_rng(args.seed)
    orders = {t: rng.permutation(n_per) for t in streams}
    cursors = {t: 0 for t in streams}
    meta = {}  # rid -> (tenant, stream position)
    done = []
    total = n_per * args.tenants
    sent = 0
    while sent < total:
        burst = int(rng.integers(1, max_batch + 1))
        for _ in range(min(burst, total - sent)):
            tenant = f"tenant{int(rng.integers(args.tenants))}"
            if cursors[tenant] >= n_per:
                tenant = next(t for t in streams if cursors[t] < n_per)
            pos = orders[tenant][cursors[tenant]]
            cursors[tenant] += 1
            rid = server.submit_cluster(streams[tenant][0][pos],
                                        tenant=tenant)
            meta[rid] = (tenant, int(pos))
            sent += 1
        done.extend(server.step())
        while args.continuous and len(server.queue) >= max_batch:
            done.extend(server.step(force=True))
        if rng.random() < 0.3:
            time.sleep(args.flush_ms / 1e3)
            done.extend(server.step())
    done.extend(server.run_until_drained())
    assert len(done) == total, (len(done), total)

    s = server.summary()
    print(f"clustered {s['count']} spectra in {s['batches']} micro-batches "
          f"(mean batch {s['mean_batch']:.1f})")
    print(f"throughput: {s['qps']:.1f} spectra/sec")
    print(f"latency: p50 {s['p50_ms']:.2f} ms, p95 {s['p95_ms']:.2f} ms")

    quality = {}
    for tenant, (hvs, identity) in streams.items():
        cl = server.clusterers[tenant]
        reqs = sorted((r for r in done if meta[r.rid][0] == tenant),
                      key=lambda r: r.rid)
        # labels in *stream* order, remapped to the request's point index
        labels = np.zeros(n_per, np.int64)
        for r in reqs:
            labels[meta[r.rid][1]] = cl.resolve(r.result.cluster_id)
        # cluster ids are spawn-order ints < n_per, so the paper's quality
        # metrics apply directly
        csr = float(clustered_spectra_ratio(labels))
        icr = float(incorrect_clustering_ratio(labels, identity))
        cs = cl.summary()
        quality[tenant] = {"clusters": cs["clusters"],
                           "clustered_ratio": csr,
                           "incorrect_ratio": icr, **cs}
        print(f"  {tenant}: {cs['clusters']} clusters over {n_per} spectra "
              f"({n_id} true identities), {cs['spawned']} spawned, "
              f"{cs['merges']} merges / {cs['consolidations']} "
              f"consolidations; clustered ratio {csr:.3f}, incorrect "
              f"ratio {icr:.3f}")
    s["cluster_quality"] = quality
    return s


if __name__ == "__main__":
    main()
