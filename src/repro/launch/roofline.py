"""Roofline-term derivation from AOT-compiled artifacts.

Three terms per (arch, shape, mesh) — all in seconds, per step, per chip:

  compute    = HLO_FLOPs / (chips * PEAK_FLOPS)
  memory     = HLO_bytes / (chips * HBM_BW)
  collective = collective_bytes / (chips * ICI_BW)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (whole-program,
already per-partition on SPMD — we detect and normalize). collective_bytes
is parsed from the partitioned HLO text: the summed operand bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Ceilings come from :func:`active_profile`: the empirical per-device
numbers measured by ``repro.tune`` when a tuning table for this device
kind is active, else the hardcoded TPU v5e-class defaults (per chip):
  197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12      # bf16 / chip (v5e default)
HBM_BW = 819e9           # bytes/s / chip (v5e default)
ICI_BW = 50e9            # bytes/s/link / chip (v5e default)

# Sizes accumulate in BITS and divide by 8 once at the aggregation
# boundary, so sub-byte types (s4/u4 = 4 bits) price at 0.5 bytes per
# element instead of rounding every element up to a whole byte and
# double-counting packed-int4 traffic.
_DTYPE_BITS = {
    "pred": 8, "s4": 4, "u4": 4, "s8": 8, "u8": 8, "s16": 16, "u16": 16,
    "f16": 16, "bf16": 16, "s32": 32, "u32": 32, "f32": 32, "s64": 64,
    "u64": 64, "f64": 64, "c64": 64, "c128": 128, "f8e4m3fn": 8,
    "f8e5m2": 8,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.  bf16[16,4096,512]{2,1,0}
_SHAPE_RE = re.compile(r"\b(pred|bf16|f16|f32|f64|f8e4m3fn|f8e5m2|s4|u4|s8|u8|"
                       r"s16|u16|s32|u32|s64|u64|c64|c128)\[([0-9,]*)\]")
# instruction definition:  %name = <result types> opname(<operands>), ...
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\((.*)$", re.M)


def _shape_bits(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BITS[dtype]


def _all_shape_bits(s: str) -> int:
    return sum(_shape_bits(m.group(1), m.group(2))
               for m in _SHAPE_RE.finditer(s))


_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*\))?\s*->.*\{\s*$")
_WHILE_ATTR_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_FIRST_OPERAND_RE = re.compile(r"%?([\w.\-]+)")
# one inline-typed operand:  f32[16,64]{1,0} %name   (layout optional)
_TYPED_OPERAND_RE = re.compile(
    r"\b(pred|bf16|f16|f32|f64|f8e4m3fn|f8e5m2|s4|u4|s8|u8|s16|u16|s32|u32|"
    r"s64|u64|c64|c128)\[([0-9,]*)\](?:\{[0-9,]*\})?\s+%?([\w.\-]+)")


def _parse_operands(operand_str: str) -> list[tuple[str, str, str]]:
    """[(name, dtype, dims)] for inline-typed operands; dtype/dims are ''
    when the printer omitted the type (resolve via the symbol table)."""
    typed = _TYPED_OPERAND_RE.findall(operand_str)
    if typed:
        return [(name, dt, dims) for dt, dims, name in typed]
    return [(tok.strip().lstrip("%"), "", "")
            for tok in operand_str.split(",") if tok.strip()]


def _split_computations(hlo_text: str) -> dict[str, list[str]]:
    """computation name -> its instruction lines."""
    comps: dict[str, list[str]] = {}
    cur: str | None = None
    for line in hlo_text.splitlines():
        m = _COMP_HEADER_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum collective operand bytes per kind across the whole program
    EXECUTION, i.e. collectives inside while-loop (lax.scan) bodies are
    multiplied by the loop trip count (read from the loop condition's
    integer constant), recursively for nested scans.

    The HLO printer usually omits inline operand types, so a symbol table
    (instruction name -> result bits) resolves operands; totals accumulate
    in bits and convert to bytes once at the end (sub-byte types price
    exactly). Async '-start'/'-done' pairs count once (at -start).
    """
    comps = _split_computations(hlo_text)

    # global symbol table (instruction names are unique across computations)
    sizes: dict[str, int] = {}
    for m in _DEF_RE.finditer(hlo_text):
        name, result_types, _, _ = m.groups()
        sizes[name] = _all_shape_bits(result_types)

    def trip_count(cond_name: str) -> int:
        consts = [int(c) for line in comps.get(cond_name, ())
                  for c in _CONST_RE.findall(line)]
        return max(consts) if consts else 1

    def comp_bits(name: str, seen: frozenset) -> dict[str, int]:
        out = {k: 0 for k in _COLLECTIVES}
        if name in seen:
            return out
        for line in comps.get(name, ()):
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            _, _, opname, rest = dm.groups()
            base = opname
            for suffix in ("-start", "-done"):
                if base.endswith(suffix):
                    base = base[: -len(suffix)]
            if base in _COLLECTIVES and not opname.endswith("-done"):
                total = 0
                for oname, odt, odims in _parse_operands(rest.split(")")[0]):
                    total += (_shape_bits(odt, odims) if odt
                              else sizes.get(oname, 0))
                out[base] += total
            elif base == "while":
                wm = _WHILE_ATTR_RE.search(line)
                if wm:
                    cond, body = wm.groups()
                    trips = trip_count(cond)
                    inner = comp_bits(body, seen | {name})
                    for k, v in inner.items():
                        out[k] += trips * v
        return out

    entry = None
    for line in hlo_text.splitlines():
        if line.strip().startswith("ENTRY"):
            m = _COMP_HEADER_RE.match(line.strip())
            if m:
                entry = m.group(1)
            break
    if entry is None:
        # fall back: flat sum
        flat = {k: 0 for k in _COLLECTIVES}
        for name in comps:
            for k, v in comp_bits(name, frozenset({"__flat__"})).items():
                flat[k] += v
        return {k: v / 8 for k, v in flat.items()}
    return {k: v / 8 for k, v in comp_bits(entry, frozenset()).items()}


def exec_cost(hlo_text: str) -> tuple[float, float]:
    """Execution-weighted (flops, hbm_bytes) from scheduled HLO text.

    ``compiled.cost_analysis()`` counts each while-loop body ONCE, so for a
    scan-over-layers program it underreports flops/bytes by ~num_layers.
    This walks the computation call graph (while bodies x trip count,
    fusion/call/to_apply x1) and:
      * flops: every `dot` = 2 * prod(result dims) * prod(contracted lhs
        dims) (convolutions are not used by this framework);
      * bytes: per scheduled instruction, operand + result bytes (the
        module is post-fusion, so an instruction ~= one kernel and its
        operands/results ~= its HBM traffic), skipping shape-only ops.
        Accumulated in bits, converted to bytes once on return.
    """
    comps = _split_computations(hlo_text)
    shapes: dict[str, tuple[str, list[int]]] = {}
    for m in _DEF_RE.finditer(hlo_text):
        name, result_types, _, _ = m.groups()
        sm = _SHAPE_RE.search(result_types)
        if sm:
            dims = [int(d) for d in sm.group(2).split(",") if d.strip()]
            shapes[name] = (sm.group(1), dims)

    def nbits(name: str) -> int:
        if name not in shapes:
            return 0
        dt, dims = shapes[name]
        n = 1
        for d in dims:
            n *= d
        return n * _DTYPE_BITS[dt]

    def trip_count(cond: str) -> int:
        consts = [int(c) for line in comps.get(cond, ())
                  for c in _CONST_RE.findall(line)]
        return max(consts) if consts else 1

    _SKIP = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "copy", "after-all", "partition-id", "iota", "while", "call",
             "conditional"}
    memo: dict[tuple[str, bool], tuple[float, float]] = {}

    def comp_cost(name: str, stack: frozenset, count_bytes: bool = True
                  ) -> tuple[float, float]:
        key = (name, count_bytes)
        if key in memo:
            return memo[key]
        if name in stack:
            return (0.0, 0.0)
        flops = 0.0
        byts = 0.0
        for line in comps.get(name, ()):
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            iname, result_types, opname, rest = dm.groups()
            base = opname
            if base == "while":
                wm = _WHILE_ATTR_RE.search(line)
                if wm:
                    cond, body = wm.groups()
                    t = trip_count(cond)
                    f, b = comp_cost(body, stack | {name}, count_bytes)
                    fc, bc = comp_cost(cond, stack | {name}, count_bytes)
                    flops += t * (f + fc)
                    byts += t * (b + bc)
                continue
            if base in ("call", "conditional"):
                for cm in _CALLS_RE.finditer(line):
                    f, b = comp_cost(cm.group(1), stack | {name}, count_bytes)
                    flops += f
                    byts += b
            fusion_callees = []
            if base in ("fusion", "custom-call", "reduce", "sort",
                        "scatter", "map", "select-and-scatter"):
                # fused-computation internals live in registers: count only
                # their dots (flops); bytes come from the fusion op itself
                for cm in _CALLS_RE.finditer(line):
                    fusion_callees.append(cm.group(1))
                    f, _ = comp_cost(cm.group(1), stack | {name}, False)
                    flops += f
            if base == "dot":
                res_elems = 1
                sm = _SHAPE_RE.search(result_types)
                if sm:
                    for d in sm.group(2).split(","):
                        if d.strip():
                            res_elems *= int(d)
                # contraction size from the lhs operand: prefer its inline
                # type (the scheduled printer emits one), fall back to the
                # symbol table
                lhs_dims = None
                ops = _parse_operands(rest.split(")")[0])
                if ops:
                    oname, _, odims = ops[0]
                    if odims:
                        lhs_dims = [int(d) for d in odims.split(",")
                                    if d.strip()]
                    elif oname in shapes:
                        lhs_dims = shapes[oname][1]
                k = 1
                cm = _LHS_CONTRACT_RE.search(line)
                if cm and lhs_dims is not None:
                    for idx in cm.group(1).split(","):
                        if idx.strip() and int(idx) < len(lhs_dims):
                            k *= lhs_dims[int(idx)]
                flops += 2.0 * res_elems * k
            if count_bytes and base not in _SKIP:
                res_bytes = _all_shape_bits(result_types)
                operand_str = rest.split(")")[0]
                # per-operand bits (NOT one summed total: the DUS check
                # below needs to recognize the aliased full buffer among
                # the operands)
                op_bytes = []
                for oname, odt, odims in _parse_operands(operand_str):
                    if odt:
                        op_bytes.append(_shape_bits(odt, odims))
                    else:
                        op_bytes.append(nbits(oname))
                # in-place dynamic-update-slice (bare or fused): traffic is
                # the UPDATE region (write + read), not the whole — possibly
                # scan-carried, 100s-of-GB — buffer; likewise dynamic-slice
                # reads only the slice. Without this, a KV-cache write or a
                # stacked-gradient accumulation charges the full buffer once
                # per layer.
                callee_text = " ".join(
                    l for c in fusion_callees for l in comps.get(c, ()))
                is_dus = (base == "dynamic-update-slice"
                          or "dynamic-update-slice" in callee_text)
                is_ds = (base == "dynamic-slice"
                         or re.search(r"\bdynamic-slice\(", callee_text))
                if is_dus and res_bytes in op_bytes:
                    rest_ops = sorted(op_bytes)
                    rest_ops.remove(res_bytes)
                    byts += 2 * sum(b for b in rest_ops)
                    continue
                if is_ds and op_bytes and max(op_bytes) > 4 * max(res_bytes, 1):
                    byts += 2 * res_bytes + (sum(op_bytes) - max(op_bytes))
                    continue
                byts += res_bytes + sum(op_bytes)
        memo[key] = (flops, byts)
        return memo[key]

    entry = None
    for line in hlo_text.splitlines():
        if line.strip().startswith("ENTRY"):
            m = _COMP_HEADER_RE.match(line.strip())
            if m:
                entry = m.group(1)
            break
    if entry is None:
        return (0.0, 0.0)
    flops, bits = comp_cost(entry, frozenset())
    return (flops, bits / 8.0)


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    """Per-chip roofline ceilings — measured or the v5e defaults."""

    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW
    source: str = "default:v5e"


def active_profile() -> HardwareProfile:
    """The profile roofline terms are priced against: ceilings measured by
    ``repro.tune`` when a tuning table for this device kind is active
    (``REPRO_TUNING_TABLE`` or ``repro.tune.set_active_table``), else the
    hardcoded v5e-class defaults. ICI bandwidth is never measured by the
    single-host microbench, so it stays at the default either way."""
    try:
        from repro.tune.table import measured_ceilings
        ceil = measured_ceilings()
    except Exception:  # tuning layer must never break a dryrun
        ceil = None
    if ceil and ceil.get("peak_flops") and ceil.get("hbm_bw"):
        return HardwareProfile(
            peak_flops=float(ceil["peak_flops"]),
            hbm_bw=float(ceil["hbm_bw"]),
            ici_bw=float(ceil.get("ici_bw") or ICI_BW),
            source="measured")
    return HardwareProfile()


@dataclasses.dataclass
class RooflineReport:
    flops: float              # per-chip FLOPs per step
    hbm_bytes: float          # per-chip HBM traffic per step
    coll_bytes: float         # per-chip collective bytes per step
    coll_breakdown: dict[str, float]
    chips: int
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float = 0.0  # 6*N*D useful flops (whole job)
    peak_flops: float = PEAK_FLOPS   # ceiling the terms were priced with
    profile_source: str = "default:v5e"

    @property
    def step_time_lower_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization at the roofline-limited step time."""
        if self.model_flops <= 0 or self.step_time_lower_bound <= 0:
            return 0.0
        return (self.model_flops / self.chips / self.step_time_lower_bound
                / self.peak_flops)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self) | {
            "step_time_lower_bound": self.step_time_lower_bound,
            "mfu_bound": self.mfu_bound,
        }


def roofline_from_compiled(compiled, chips: int, model_flops: float = 0.0,
                           profile: HardwareProfile | None = None
                           ) -> RooflineReport:
    # NOTE: compiled.cost_analysis() counts while-loop (lax.scan) bodies
    # once, underreporting a scanned L-layer model ~L-fold. exec_cost walks
    # the partitioned HLO with trip-count expansion instead; the module is
    # per-device so all terms are already /chip.
    if profile is None:
        profile = active_profile()
    text = compiled.as_text()
    flops, hbm = exec_cost(text)
    coll = collective_bytes(text)
    cbytes = float(sum(coll.values()))
    t_c = flops / profile.peak_flops
    t_m = hbm / profile.hbm_bw
    t_x = cbytes / profile.ici_bw
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    return RooflineReport(
        flops=flops, hbm_bytes=hbm, coll_bytes=cbytes, coll_breakdown=coll,
        chips=chips, t_compute=t_c, t_memory=t_m, t_collective=t_x,
        bottleneck=bottleneck, model_flops=model_flops,
        peak_flops=profile.peak_flops, profile_source=profile.source,
    )


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS = 6 * N_active * D_tokens (dense) per step; decode counts
    one token per sequence."""
    # active params per token
    d, hd = cfg.d_model, cfg.resolved_head_dim
    attn = d * (cfg.num_heads + 2 * cfg.num_kv_heads) * hd \
        + cfg.num_heads * hd * d
    if cfg.is_moe:
        ffn_active = 3 * d * cfg.expert_d_ff * (cfg.top_k + cfg.num_shared_experts)
    elif cfg.family == "ssm":
        d_inner = 2 * d
        attn = 0
        ffn_active = d * 2 * d_inner + 3 * d_inner * (d_inner // max(cfg.num_heads, 1)) \
            + d_inner * d
    else:
        nmat = 3 if cfg.activation in ("swiglu", "geglu") else 2
        ffn_active = nmat * d * cfg.d_ff
    if cfg.family == "hybrid":
        ffn_active += d * 2 * d + 2 * d * cfg.ssm_state + d * d
    n_active = cfg.num_layers * (attn + ffn_active)
    n_active += cfg.padded_vocab * d  # embedding/unembed (once)
    if cfg.is_encoder_decoder:
        n_active += cfg.num_encoder_layers * (attn + ffn_active)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
