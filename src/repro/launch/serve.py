"""Batched serving launcher: prefill + decode loop with a KV/state cache.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch hymba_1_5b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.tokens import TokenPipeline
from repro.dist.sharding import set_mesh
from repro.launch.mesh import make_debug_mesh
from repro.models.model_zoo import build_model
from repro.train.serve_step import make_decode_step, make_prefill


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_debug_mesh()
    set_mesh(mesh)
    model = build_model(cfg)

    with mesh:
        params, _ = model.init(jax.random.PRNGKey(0))
        pipe = TokenPipeline(batch=args.batch, seq=args.prompt_len,
                             vocab=cfg.vocab_size)
        batch = pipe.get_for(cfg, 0)
        max_len = args.prompt_len + args.gen
        cache = model.init_cache(args.batch, max_len)

        prefill = jax.jit(make_prefill(model))
        decode = jax.jit(make_decode_step(model))

        t0 = time.time()
        logits, cache = prefill(params, batch, cache)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        t_prefill = time.time() - t0

        out_tokens = [tok]
        pos0 = (batch["tokens"].shape[1]
                if cfg.family != "vlm"
                else batch["tokens"].shape[1] + batch["patches"].shape[1])
        t0 = time.time()
        key = jax.random.PRNGKey(1)
        for i in range(args.gen - 1):
            logits, cache = decode(params, tok, cache,
                                   jnp.asarray(pos0 + i, jnp.int32))
            if args.temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(
                    sub, logits[:, -1] / args.temperature)[:, None]
                tok = tok.astype(jnp.int32)
            else:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out_tokens.append(tok)
        gen = jnp.concatenate(out_tokens, axis=1)
        t_decode = time.time() - t0
        print(f"prefill: {t_prefill:.3f}s for {args.batch}x{args.prompt_len}")
        print(f"decode:  {t_decode:.3f}s for {args.gen - 1} steps "
              f"({1000 * t_decode / max(args.gen - 1, 1):.1f} ms/tok)")
        print("generated token ids (first row):", gen[0][:16].tolist())
        return gen


if __name__ == "__main__":
    main()
