"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first jax use.

Mesh axes:
  single-pod:  (16, 16)      -> ('data', 'model')   = 256 chips (one v5e pod)
  multi-pod:   (2, 16, 16)   -> ('pod', 'data', 'model') = 512 chips

'pod'  — pure data parallelism across pods (grad all-reduce over DCN),
'data' — data parallel + FSDP weight sharding within a pod,
'model'— tensor/expert parallelism within a pod.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None):
    """Small mesh over however many (host) devices exist — used by tests."""
    n = n_devices or len(jax.devices())
    model = 1
    for cand in (4, 2, 1):
        if n % cand == 0:
            model = cand
            break
    return jax.make_mesh((n // model, model), ("data", "model"))
