import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("REPRO_XLA_EXTRA", ""))

"""Multi-pod dry-run: AOT lower + compile every (arch x shape) cell on the
production meshes, proving the distribution config is coherent, and record
memory/cost/collective analyses for the roofline table.

MUST be run as its own process (the XLA_FLAGS line above executes before any
jax import — do not import this module from a live jax process).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2_7b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out artifacts/dryrun
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.configs.shapes import applicable
from repro.dist.sharding import logical_to_sharding, set_mesh
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (
    active_profile,
    model_flops_estimate,
    roofline_from_compiled,
)
from repro.models.model_zoo import build_model
from repro.train.serve_step import make_decode_step, make_prefill
from repro.train.train_step import (
    TrainConfig,
    abstract_train_state,
    make_train_step,
    state_axes,
)


def _leaf_axes(x):
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None)))
                                        for e in x)


def _serve_cast(pshapes):
    """Serving deployments hold weights in bf16 (fp32 master copies live in
    the training job); reflect that in the serve-shape dry-runs."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
        if s.dtype == jnp.float32 else s, pshapes)


def batch_axes_for(cfg, specs: dict) -> dict:
    out = {}
    for k, v in specs.items():
        out[k] = ("batch",) + (None,) * (len(v.shape) - 1)
    return out


def cache_axes_for(cfg, cache):
    """Logical axes for a cache pytree (dispatch on node dataclass types)."""
    from repro.models.layers import KVCache, QuantKVCache
    from repro.models.recurrent import MambaState, MLSTMState, SLSTMState

    stacked = cfg.family != "ssm"

    def kv_axes(leaf):
        pre = ("layer",) if stacked and leaf.ndim == 5 else ()
        return pre + ("batch", "kv_seq", "kv_heads", None)

    def scale_axes(leaf):
        pre = ("layer",) if stacked and leaf.ndim == 4 else ()
        return pre + ("batch", "kv_seq", "kv_heads")

    def node_axes(node):
        if isinstance(node, QuantKVCache):
            return QuantKVCache(k=kv_axes(node.k), v=kv_axes(node.v),
                                k_scale=scale_axes(node.k_scale),
                                v_scale=scale_axes(node.v_scale))
        if isinstance(node, KVCache):
            return KVCache(k=kv_axes(node.k), v=kv_axes(node.v))
        if isinstance(node, MambaState):
            pre = ("layer",) if stacked and node.h.ndim == 4 else ()
            return MambaState(h=pre + ("batch", None, None))
        if isinstance(node, MLSTMState):
            pre = ("layer",) if stacked and node.C.ndim == 5 else ()
            return MLSTMState(C=pre + ("batch", "heads", None, None),
                              n=pre + ("batch", "heads", None))
        if isinstance(node, SLSTMState):
            pre = ("layer",) if stacked and node.c.ndim == 3 else ()
            return SLSTMState(c=pre + ("batch", None),
                              n=pre + ("batch", None))
        if isinstance(node, tuple):
            return tuple(node_axes(e) for e in node)
        if isinstance(node, list):
            return [node_axes(e) for e in node]
        # bare array (cross-attn kv): (L, B, S, KV, hd) or (B, S, KV, hd)
        pre = ("layer",) if stacked and node.ndim == 5 else ()
        return pre + ("batch", None, "kv_heads", None)

    def is_node(x):
        return isinstance(x, (KVCache, MambaState, MLSTMState, SLSTMState)) \
            or hasattr(x, "shape")

    if isinstance(cache, list):
        return [node_axes(c) for c in cache]
    return node_axes(cache)


def shardings_of(axes_tree, shapes_tree, mesh):
    return jax.tree.map(
        lambda ax, sh: logical_to_sharding(ax, tuple(sh.shape), mesh),
        axes_tree, shapes_tree, is_leaf=_leaf_axes)


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path,
             remat: str = "full", rules=None, cast_params: bool = False,
             kv_quant: bool = False, tag_suffix: str = "") -> dict:
    cfg = get_config(arch)
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant_int8=True)
    shape = SHAPES[shape_name]
    if not applicable(cfg.family, shape_name, cfg.supports_long_decode):
        result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                  "status": "skipped",
                  "reason": "long_500k requires sub-quadratic decode "
                            "(DESIGN.md §4); this arch is pure full-attention"}
        out_dir.mkdir(parents=True, exist_ok=True)
        (out_dir / f"{arch}__{shape_name}__{mesh_kind}.json").write_text(
            json.dumps(result, indent=1))
        return result
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    set_mesh(mesh, rules)
    model = build_model(cfg)
    t0 = time.time()

    if shape.kind == "train":
        state, axes = abstract_train_state(model)
        st_axes = state_axes(axes)
        state_sh = shardings_of(st_axes, state, mesh)
        specs = model.input_specs(shape)
        b_axes = batch_axes_for(cfg, specs)
        batch_sh = shardings_of(b_axes, specs, mesh)
        step = make_train_step(model, TrainConfig(
            remat=remat, cast_params_bf16=cast_params))
        jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         donate_argnums=(0,))
        with mesh:
            lowered = jitted.lower(state, specs)
    elif shape.kind == "prefill":
        pshapes, axes = model.abstract_params()
        pshapes = _serve_cast(pshapes)
        param_sh = shardings_of(axes, pshapes, mesh)
        spec = model.input_specs(shape)
        bspecs, cspecs = spec["batch"], spec["cache"]
        b_axes = batch_axes_for(cfg, bspecs)
        batch_sh = shardings_of(b_axes, bspecs, mesh)
        c_axes = cache_axes_for(cfg, cspecs)
        cache_sh = shardings_of(c_axes, cspecs, mesh)
        fn = make_prefill(model)
        jitted = jax.jit(fn, in_shardings=(param_sh, batch_sh, cache_sh),
                         donate_argnums=(2,))
        with mesh:
            lowered = jitted.lower(pshapes, bspecs, cspecs)
    else:  # decode
        pshapes, axes = model.abstract_params()
        pshapes = _serve_cast(pshapes)
        param_sh = shardings_of(axes, pshapes, mesh)
        spec = model.input_specs(shape)
        tok, cspecs, pos = spec["token"], spec["cache"], spec["pos"]
        tok_sh = logical_to_sharding(("batch", None), tuple(tok.shape), mesh)
        c_axes = cache_axes_for(cfg, cspecs)
        cache_sh = shardings_of(c_axes, cspecs, mesh)
        fn = make_decode_step(model)
        jitted = jax.jit(fn, in_shardings=(param_sh, tok_sh, cache_sh, None),
                         donate_argnums=(2,))
        with mesh:
            lowered = jitted.lower(pshapes, tok, cspecs, pos)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for field in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
            if hasattr(ma, field):
                mem[field] = int(getattr(ma, field))
        print("memory_analysis:", mem)
    except Exception as e:  # pragma: no cover
        mem = {"error": str(e)}

    mf = model_flops_estimate(cfg, shape)
    prof = active_profile()
    roof = roofline_from_compiled(compiled, chips, model_flops=mf,
                                  profile=prof)
    print("cost_analysis: flops/chip=%.3e bytes/chip=%.3e coll/chip=%.3e "
          "(ceilings: %s)"
          % (roof.flops, roof.hbm_bytes, roof.coll_bytes, prof.source))

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "status": "ok", "chips": chips,
        "variant": {"cast_params": cast_params, "kv_quant": kv_quant,
                    "remat": remat},
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem,
        "roofline": roof.to_dict(),
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    tag = f"{arch}__{shape_name}__{mesh_kind}{tag_suffix}"
    (out_dir / f"{tag}.json").write_text(json.dumps(result, indent=1))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--cast-params", action="store_true",
                    help="bf16 cast before FSDP all-gather (perf variant)")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache (perf variant)")
    ap.add_argument("--kv-seq-shard", action="store_true",
                    help="stripe KV cache seq axis over the model axis")
    ap.add_argument("--rules", default="default",
                    help="sharding rule preset (default | fsdp_only)")
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    args = ap.parse_args()

    from repro.dist.sharding import RULE_PRESETS
    rules = RULE_PRESETS[args.rules]
    if args.kv_seq_shard:
        rules = rules.replace(kv_seq="model")

    out = Path(args.out)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    for a in archs:
        for s in shapes:
            for m in meshes:
                cells.append((a, s, m))

    failures = 0
    for a, s, m in cells:
        tag = f"{a}__{s}__{m}"
        if args.skip_existing and (out / f"{tag}.json").exists():
            print(f"[skip-existing] {tag}")
            continue
        print(f"=== {tag} ===", flush=True)
        try:
            r = run_cell(a, s, m, out, remat=args.remat, rules=rules,
                         cast_params=args.cast_params,
                         kv_quant=args.kv_quant, tag_suffix=args.tag)
            print(f"[{r['status']}] {tag} "
                  + (f"compile={r.get('compile_s')}s "
                     f"bottleneck={r['roofline']['bottleneck']}"
                     if r["status"] == "ok" else r.get("reason", "")),
                  flush=True)
        except Exception:
            failures += 1
            err = traceback.format_exc()
            print(f"[FAIL] {tag}\n{err}", flush=True)
            out.mkdir(parents=True, exist_ok=True)
            (out / f"{tag}.json").write_text(json.dumps(
                {"arch": a, "shape": s, "mesh": m, "status": "fail",
                 "error": err.splitlines()[-1]}, indent=1))
    print(f"done: {len(cells)} cells, {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
