"""Production training launcher.

Wires together: config system -> model -> sharded train step -> data
pipeline -> checkpointing (auto-resume, async, keep-N) -> straggler monitor.
Single-host it runs on whatever devices exist (CPU included); multi-host it
is the same code under ``jax.distributed.initialize`` (the mesh helper and
per-host data slicing are already process-count aware by construction).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch xlstm_125m --reduced \
      --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt --ckpt-every 50
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config
from repro.data.tokens import TokenPipeline
from repro.dist.checkpoint import CheckpointManager
from repro.dist.sharding import is_axes_leaf, logical_to_sharding, set_mesh
from repro.dist.straggler import Action, StragglerMonitor
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models.model_zoo import build_model
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import (
    TrainConfig,
    init_train_state,
    make_train_step,
    state_axes,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8", "topk"],
                    help="legacy in-graph compression of the already-"
                         "reduced grads (simulation only)")
    ap.add_argument("--dcn-compression", default="none",
                    choices=["none", "int8", "topk", "topk_ef"],
                    help="wire compression on the cross-pod (DCN) hop of "
                         "the hierarchical gradient reduction")
    ap.add_argument("--dcn-pods", type=int, default=0,
                    help="per-pod gradient slices; 0 = size of the mesh's "
                         "'pod' axis (1 when absent)")
    ap.add_argument("--dcn-topk-frac", type=float, default=0.01)
    ap.add_argument("--seed", type=int, default=0,
                    help="base of the per-step stochastic-rounding key")
    ap.add_argument("--imc-linear", action="store_true",
                    help="route FFN down-projections through the SpecPCM "
                         "IMC quantized-matmul model")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--mesh", default="debug",
                    choices=["debug", "single", "multi"])
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.imc_linear:
        import dataclasses
        cfg = dataclasses.replace(cfg, imc_linear=True)

    if args.mesh == "debug":
        mesh = make_debug_mesh()
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    set_mesh(mesh)
    print(f"mesh: {dict(mesh.shape)} devices={mesh.devices.size}")

    model = build_model(cfg)
    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=args.lr, total_steps=args.steps),
        remat=args.remat, microbatches=args.microbatches,
        grad_compression=args.grad_compression,
        dcn_compression=args.dcn_compression, dcn_pods=args.dcn_pods,
        dcn_topk_frac=args.dcn_topk_frac, seed=args.seed,
    )

    with mesh:
        state, axes = init_train_state(model, jax.random.PRNGKey(0),
                                       tcfg, mesh)
        st_axes = state_axes(axes, tcfg)
        state_sh = jax.tree.map(
            lambda ax, x: logical_to_sharding(ax, tuple(x.shape), mesh),
            st_axes, state, is_leaf=is_axes_leaf)
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else x,
            state, state_sh)
        raw_step = make_train_step(model, tcfg, mesh)
        step_fn = jax.jit(raw_step, donate_argnums=(0,))
        if raw_step.dcn_route != "global":
            print(f"grad sync: {raw_step.dcn_route} hierarchy over "
                  f"{raw_step.dcn_pods} pod(s), "
                  f"dcn_compression={tcfg.dcn_compression}")

        pipe = TokenPipeline(batch=args.batch, seq=args.seq,
                             vocab=cfg.vocab_size)

        start_step = 0
        ckpt = None
        if args.ckpt_dir:
            ckpt = CheckpointManager(args.ckpt_dir, keep=3)
            restored = ckpt.restore_latest(state, state_sh)
            if restored is not None:
                start_step, state = restored
                print(f"resumed from checkpoint step {start_step}")

        monitor = StragglerMonitor(
            on_warn=lambda s, dt: print(f"[straggler] step {s}: {dt:.3f}s"),
            on_evict=lambda s, dt: print(
                f"[straggler] step {s}: {dt:.3f}s — would evict+reshard"),
        )

        t_start = time.time()
        for step in range(start_step, args.steps):
            monitor.step_start()
            batch = pipe.get_for(cfg, step)
            state, metrics = step_fn(state, batch)
            action = monitor.step_end()
            if action == Action.EVICT and ckpt is not None:
                ckpt.save_async(step + 1, state)
            if (step + 1) % args.log_every == 0 or step == start_step:
                loss = float(metrics["loss"])
                gn = float(metrics["grad_norm"])
                dcn = ""
                if float(metrics["dcn_bytes"]) > 0:
                    dcn = (f" dcn={float(metrics['dcn_bytes']) / 2**20:.2f}"
                           f"MiB/pod ({float(metrics['dcn_raw_bytes']) / max(float(metrics['dcn_bytes']), 1.0):.1f}x"
                           " smaller)")
                print(f"step {step + 1}: loss={loss:.4f} grad_norm={gn:.3f} "
                      f"({(time.time() - t_start) / (step - start_step + 1):.2f}s/step)"
                      + dcn, flush=True)
            if ckpt is not None and (step + 1) % args.ckpt_every == 0:
                ckpt.save_async(step + 1, state)
        if ckpt is not None:
            ckpt.save(args.steps, state)
            ckpt.wait()
        print(f"done: {args.steps - start_step} steps in "
              f"{time.time() - t_start:.1f}s")
        return state


if __name__ == "__main__":
    main()
