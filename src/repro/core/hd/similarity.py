"""Similarity search primitives (SpecPCM DB search, §III.C).

Hamming similarity of bipolar HVs equals their dot product up to an affine
map: for a, b ∈ {-1, +1}^D,  <a, b> = D - 2 * hamming(a, b). All search is
therefore expressed as (packed) integer matmuls — precisely the operation the
PCM array executes in-memory. The IMC-quantized variants live in
``repro.core.imc.array``; these are the exact (noise-free) versions used as
oracles and as the fast host path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dot_similarity(queries: jax.Array, refs: jax.Array) -> jax.Array:
    """(Q, D') x (R, D') -> (Q, R) int32 dot-product scores."""
    return jnp.einsum(
        "qd,rd->qr",
        queries.astype(jnp.int32),
        refs.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )


def hamming_similarity(queries: jax.Array, refs: jax.Array) -> jax.Array:
    """Hamming *similarity* (number of agreeing positions) for bipolar HVs."""
    d = queries.shape[-1]
    dots = dot_similarity(queries, refs)
    return (d + dots) // 2


def top1_search(queries: jax.Array, refs: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Best match per query. Returns (indices (Q,), scores (Q,))."""
    scores = dot_similarity(queries, refs)
    idx = jnp.argmax(scores, axis=-1)
    best = jnp.take_along_axis(scores, idx[:, None], axis=-1)[:, 0]
    return idx, best


def topk_search(
    queries: jax.Array, refs: jax.Array, k: int
) -> tuple[jax.Array, jax.Array]:
    """Top-k matches per query. Returns (indices (Q,k), scores (Q,k))."""
    scores = dot_similarity(queries, refs)
    vals, idx = jax.lax.top_k(scores, k)
    return idx, vals


def bitpack_bipolar(hv: jax.Array) -> jax.Array:
    """Pack bipolar (..., D) into uint32 words (..., D/32): +1 -> bit 1.

    Beyond-paper host/TPU optimization: SLC similarity via XOR+popcount runs
    32 dims per lane. D must be a multiple of 32.
    """
    *lead, D = hv.shape
    if D % 32 != 0:
        raise ValueError(f"D={D} must be a multiple of 32")
    bits = (hv > 0).astype(jnp.uint32).reshape(*lead, D // 32, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return (bits << shifts).sum(axis=-1, dtype=jnp.uint32)


def hamming_similarity_packed(q_packed: jax.Array, r_packed: jax.Array, dim: int) -> jax.Array:
    """Hamming similarity from bit-packed uint32 HVs: D - popcount(q ^ r)."""
    x = q_packed[:, None, :] ^ r_packed[None, :, :]
    dist = jax.lax.population_count(x).astype(jnp.int32).sum(axis=-1)
    return dim - dist


def topk_search_packed(
    q_packed: jax.Array, r_packed: jax.Array, dim: int, k: int,
    *, fused: bool = False
) -> tuple[jax.Array, jax.Array]:
    """Top-k matches over bit-packed HVs — the packed twin of :func:`topk_search`.

    Scores are returned on the *dot-product* scale: for bipolar HVs,
    ``<q, r> = dim - 2 * popcount(q ^ r)`` exactly, so both indices and
    scores are bit-identical to ``topk_search`` on the unpacked vectors
    (``lax.top_k`` tie-breaking included). This is the fast host/TPU path
    the sharded DB-search server uses whenever ``dim % 32 == 0``.

    With ``fused=True`` the search runs through the streaming Pallas
    kernel (:func:`repro.kernels.topk_hamming.topk_hamming_pallas`),
    which keeps the running top-k in VMEM and never writes the (Q, R)
    score matrix to HBM — same results, O(Q·k) instead of O(Q·R) output
    traffic.

    >>> import jax.numpy as jnp
    >>> refs = jnp.where(jnp.arange(4 * 64).reshape(4, 64) % 3 == 0, 1, -1)
    >>> idx, scores = topk_search_packed(
    ...     bitpack_bipolar(refs[1:2]), bitpack_bipolar(refs), dim=64, k=2)
    >>> int(idx[0, 0]), int(scores[0, 0]), int(idx[0, 1])
    (1, 64, 2)
    """
    if fused:
        # deferred: keeps the core algorithm layer import-light — the
        # kernel package is only pulled in when the fused path is taken
        from repro.kernels.topk_hamming import topk_hamming_pallas
        return topk_hamming_pallas(q_packed, r_packed, dim=dim, k=k)
    sims = hamming_similarity_packed(q_packed, r_packed, dim)
    scores = 2 * sims - dim  # back to the dot-product scale, exactly
    vals, idx = jax.lax.top_k(scores, k)
    return idx, vals
