from repro.core.hd.clustering import (
    ClusteringResult,
    complete_linkage,
    pairwise_distances,
)
from repro.core.hd.encoding import (
    HDEncoderConfig,
    encode_batch,
    encode_batch_reference,
    make_codebooks,
)
from repro.core.hd.packing import pack_dimensions, unpack_dimensions
from repro.core.hd.similarity import (
    bitpack_bipolar,
    dot_similarity,
    hamming_similarity,
    hamming_similarity_packed,
    top1_search,
    topk_search,
    topk_search_packed,
)

__all__ = [
    "HDEncoderConfig",
    "make_codebooks",
    "encode_batch",
    "encode_batch_reference",
    "pack_dimensions",
    "unpack_dimensions",
    "bitpack_bipolar",
    "dot_similarity",
    "hamming_similarity",
    "hamming_similarity_packed",
    "top1_search",
    "topk_search",
    "topk_search_packed",
    "pairwise_distances",
    "complete_linkage",
    "ClusteringResult",
]
