from repro.core.hd.encoding import (
    HDEncoderConfig,
    make_codebooks,
    encode_batch,
    encode_batch_reference,
)
from repro.core.hd.packing import pack_dimensions, unpack_dimensions
from repro.core.hd.similarity import (
    dot_similarity,
    hamming_similarity,
    top1_search,
    topk_search,
)
from repro.core.hd.clustering import (
    pairwise_distances,
    complete_linkage,
    ClusteringResult,
)

__all__ = [
    "HDEncoderConfig",
    "make_codebooks",
    "encode_batch",
    "encode_batch_reference",
    "pack_dimensions",
    "unpack_dimensions",
    "dot_similarity",
    "hamming_similarity",
    "top1_search",
    "topk_search",
    "pairwise_distances",
    "complete_linkage",
    "ClusteringResult",
]
