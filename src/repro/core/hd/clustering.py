"""Complete-linkage agglomerative clustering over HD distances (SpecPCM §III.C).

The paper computes an all-pairs distance matrix inside the PCM array, then a
near-memory ASIC iteratively merges the closest pair of clusters under
*complete linkage* (cluster distance = max element-pair distance) until the
minimum cluster distance exceeds a threshold.

This module implements exactly that, as a ``lax.while_loop`` over a fixed
(N, N) distance matrix so it jits and shards. Complete linkage has the key
property that the merged row is an elementwise ``max`` of the two merged rows,
so the matrix update is O(N) per merge — identical to the ASIC's update rule.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ClusteringResult:
    labels: jax.Array       # (N,) int32 cluster id per point (canonical: min index in cluster)
    num_merges: jax.Array   # () int32
    num_clusters: jax.Array  # () int32


def pairwise_distances(hvs: jax.Array, dim: int | None = None) -> jax.Array:
    """Hamming distances between (packed or bipolar) HVs.

    For bipolar HVs: hamming = (D - <a,b>) / 2. For MLC-packed HVs the packed
    dot product estimates <a,b> so the same map applies with the *unpacked* D.

    uint32 input takes the bit-packed fast path: for bipolar HVs packed with
    :func:`repro.core.hd.similarity.bitpack_bipolar` the distance is exactly
    ``popcount(a ^ b)``, computed by the ``hamming_pop`` Pallas kernel at
    32 dims per lane — bit-identical to the einsum path on the unpacked
    vectors. ``dim`` cancels out of the distance on this path (accepted
    for API symmetry only).

    Args:
      hvs: (N, D') integer HVs, or (N, D/32) uint32 bit-packed bipolar HVs.
      dim: original (unpacked) dimensionality D; defaults to D'.
    """
    n, dp = hvs.shape
    d = dim if dim is not None else dp
    if hvs.dtype == jnp.uint32:
        from repro.kernels.hamming_pop import hamming_pop_pallas
        # hamming_pop returns agreements (d - popcount); distance is the
        # complement — exact for bipolar inputs, no /2 estimation step
        dist = (d - hamming_pop_pallas(hvs, hvs, dim=d)).astype(jnp.float32)
    else:
        dots = jnp.einsum(
            "id,jd->ij", hvs.astype(jnp.int32), hvs.astype(jnp.int32),
            preferred_element_type=jnp.int32,
        )
        dist = (d - dots).astype(jnp.float32) * 0.5
    # zero the diagonal: self-distance is 0 even under packing estimation noise
    return dist * (1.0 - jnp.eye(n, dtype=jnp.float32))


def cross_distances(a: jax.Array, b: jax.Array,
                    dim: int | None = None) -> jax.Array:
    """Hamming distances between two *different* HV sets — (Na, Nb).

    The cross-set twin of :func:`pairwise_distances` (same packed-popcount
    fast path and (D - <a,b>)/2 map, no diagonal zeroing since a[i] and
    b[i] are unrelated points). This is the streaming-clustering inner
    step: a batch of query HVs against the current centroid bank.
    """
    d = dim if dim is not None else a.shape[-1]
    if a.dtype == jnp.uint32:
        from repro.kernels.hamming_pop import hamming_pop_pallas
        return (d - hamming_pop_pallas(a, b, dim=d)).astype(jnp.float32)
    dots = jnp.einsum(
        "id,jd->ij", a.astype(jnp.int32), b.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    return (d - dots).astype(jnp.float32) * 0.5


@partial(jax.jit, static_argnames=())
def complete_linkage(dist: jax.Array, threshold: jax.Array | float) -> ClusteringResult:
    """Complete-linkage clustering of a symmetric (N, N) distance matrix.

    Merges until min inter-cluster distance > threshold. Returns canonical
    labels where each point's label is the smallest point-index in its
    cluster (stable, permutation-checkable against scipy).
    """
    n = dist.shape[0]
    big = jnp.float32(jnp.finfo(jnp.float32).max)
    eye = jnp.eye(n, dtype=bool)
    dmat = jnp.where(eye, big, dist.astype(jnp.float32))
    labels0 = jnp.arange(n, dtype=jnp.int32)
    active0 = jnp.ones((n,), bool)
    thr = jnp.float32(threshold)

    def masked(dm, active):
        m = active[:, None] & active[None, :] & ~eye
        return jnp.where(m, dm, big)

    # the masked matrix rides in the carry so each merge iteration computes
    # it exactly once (in body, for the next cond + argmin) instead of once
    # in cond and again in body
    def cond(state):
        dm, md, labels, active, merges = state
        return jnp.min(md) <= thr

    def body(state):
        dm, md, labels, active, merges = state
        flat = jnp.argmin(md)
        i, j = flat // n, flat % n
        lo, hi = jnp.minimum(i, j), jnp.maximum(i, j)
        # complete linkage: merged row/col is the elementwise max
        newrow = jnp.maximum(dm[lo], dm[hi])
        dm = dm.at[lo, :].set(newrow).at[:, lo].set(newrow)
        dm = dm.at[lo, lo].set(big)
        active = active.at[hi].set(False)
        labels = jnp.where(labels == hi, lo, labels)
        return dm, masked(dm, active), labels, active, merges + 1

    state = (dmat, masked(dmat, active0), labels0, active0, jnp.int32(0))
    dm, _, labels, active, merges = jax.lax.while_loop(cond, body, state)
    return ClusteringResult(
        labels=labels,
        num_merges=merges,
        num_clusters=jnp.sum(active.astype(jnp.int32)),
    )


def clustered_spectra_ratio(labels: jax.Array) -> jax.Array:
    """Fraction of points in clusters of size >= 2 (paper's quality metric)."""
    n = labels.shape[0]
    sizes = jnp.zeros((n,), jnp.int32).at[labels].add(1)
    mysize = sizes[labels]
    return jnp.mean((mysize >= 2).astype(jnp.float32))


def incorrect_clustering_ratio(labels: jax.Array, truth: jax.Array) -> jax.Array:
    """Fraction of *clustered* points whose cluster's majority ground-truth
    identity differs from their own (paper's x-axis in Fig. 9)."""
    n = labels.shape[0]
    # majority truth per cluster via one-hot vote counting; truth ids must be
    # in [0, n) (guaranteed by the synthetic generator)
    votes = jnp.zeros((n, n), jnp.int32).at[labels, truth].add(1)
    majority = jnp.argmax(votes[labels], axis=-1)
    sizes = jnp.zeros((n,), jnp.int32).at[labels].add(1)
    clustered = sizes[labels] >= 2
    wrong = clustered & (majority != truth)
    denom = jnp.maximum(jnp.sum(clustered.astype(jnp.int32)), 1)
    return jnp.sum(wrong.astype(jnp.float32)) / denom.astype(jnp.float32)
