"""ID-level hyperdimensional encoding (SpecPCM Eq. 1).

Each spectrum is a fixed-length feature vector (binned intensities). Encoding:

    HV = sign( sum_i  LV[level_i] * ID_i )

where ``ID_i`` is a random bipolar hypervector unique to feature position i
and ``LV[l]`` is the level hypervector for quantized intensity level l.
Level HVs are built by progressive bit-flipping so that nearby levels are
similar (standard ID-level construction used by HyperSpec/HyperOMS).

Everything is pure JAX so it jits, vmaps, and shards.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class HDEncoderConfig:
    """Configuration for the ID-level HD encoder.

    Attributes:
      dim: HD dimensionality D (paper: 2048 clustering / 8192 DB search).
      num_features: number of m/z bins per spectrum (feature positions).
      num_levels: number of quantization levels m for intensities.
      seed: PRNG seed for codebook generation.
    """

    dim: int = 2048
    num_features: int = 1024
    num_levels: int = 32
    seed: int = 0

    def __post_init__(self):
        if self.dim <= 0 or self.num_features <= 0 or self.num_levels < 2:
            raise ValueError(f"invalid HDEncoderConfig: {self}")


def make_codebooks(cfg: HDEncoderConfig) -> tuple[jax.Array, jax.Array]:
    """Build (id_hvs, level_hvs).

    id_hvs:    (num_features, dim) bipolar int8, i.i.d. random.
    level_hvs: (num_levels, dim) bipolar int8. LV_0 is random; LV_{k+1} flips
      a fixed block of dim/(num_levels-1) positions of LV_k so that
      sim(LV_a, LV_b) decays linearly with |a-b| and LV_0 ⟂ LV_{m-1}.
    """
    key = jax.random.PRNGKey(cfg.seed)
    k_id, k_lv, k_perm = jax.random.split(key, 3)
    id_hvs = jax.random.rademacher(k_id, (cfg.num_features, cfg.dim), dtype=jnp.int8)

    base = jax.random.rademacher(k_lv, (cfg.dim,), dtype=jnp.int8)
    # Positions are flipped in a random order; level k flips the first
    # floor(k * (dim/2) / (m-1)) positions of the shuffled index set, so
    # LV_0 and LV_{m-1} differ in dim/2 positions (orthogonal, not
    # anti-correlated) and similarity decays linearly with level distance.
    perm = jax.random.permutation(k_perm, cfg.dim)
    thresholds = (
        jnp.arange(cfg.num_levels, dtype=jnp.int32)
        * (cfg.dim // 2)
        // (cfg.num_levels - 1)
    )
    # rank[j] = position of dim-index j in the flip order
    rank = jnp.zeros((cfg.dim,), jnp.int32).at[perm].set(jnp.arange(cfg.dim, dtype=jnp.int32))
    flip = rank[None, :] < thresholds[:, None]  # (m, dim) bool
    level_hvs = jnp.where(flip, -base[None, :], base[None, :]).astype(jnp.int8)
    return id_hvs, level_hvs


def quantize_levels(values: jax.Array, num_levels: int) -> jax.Array:
    """Quantize feature values in [0, 1] to integer levels [0, m-1].

    Level 0 means *absent* (zero-intensity bin): spectra are sparse peak
    lists, and only present peaks contribute to the encoding — empty bins
    shared by all spectra would otherwise add a large correlated baseline to
    every pairwise similarity. Present peaks map to levels 1..m-1.
    """
    v = jnp.clip(values, 0.0, 1.0)
    present = v > 1e-6
    lvl = 1 + jnp.minimum((v * (num_levels - 1)).astype(jnp.int32), num_levels - 2)
    return jnp.where(present, lvl, 0)


def encode_levels_batch(
    levels: jax.Array,
    id_hvs: jax.Array,
    level_hvs: jax.Array,
) -> jax.Array:
    """Eq. 1 from *already quantized* levels. levels: (B, F) int in [0, m).

    Level 0 is the absent-peak sentinel and contributes nothing; sign ties
    (acc == 0) resolve to -1. This is the levels-in entry point shared by
    :func:`encode_batch_reference` and the serving raw-spectrum path
    (``repro.serve.db_search.search_database_levels``), and the oracle the
    fused encode->search kernel (``repro.kernels.encode_search``) must
    match bit-exactly. Returns bipolar (B, D) int8 hypervectors.
    """
    lv = level_hvs[levels]  # (B, F, D) int8
    present = (levels > 0).astype(jnp.int32)  # level 0 = absent peak
    acc = jnp.einsum(
        "bf,bfd,fd->bd",
        present,
        lv.astype(jnp.int32),
        id_hvs.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    # sign with tie -> +1 (paper: sign outputs 1 when input positive else -1;
    # zero maps to -1 there. We match the paper exactly.)
    return jnp.where(acc > 0, jnp.int8(1), jnp.int8(-1))


def encode_batch_reference(
    features: jax.Array,
    id_hvs: jax.Array,
    level_hvs: jax.Array,
) -> jax.Array:
    """Pure-jnp oracle for Eq. 1. features: (B, F) float in [0,1].

    Returns bipolar (B, D) int8 hypervectors.
    """
    levels = quantize_levels(features, level_hvs.shape[0])  # (B, F)
    return encode_levels_batch(levels, id_hvs, level_hvs)


@partial(jax.jit, static_argnames=("block_features",))
def encode_batch(
    features: jax.Array,
    id_hvs: jax.Array,
    level_hvs: jax.Array,
    *,
    block_features: int = 128,
) -> jax.Array:
    """Memory-bounded ID-level encoder.

    Identical math to :func:`encode_batch_reference` but accumulates over
    feature blocks with ``lax.scan`` so the (B, F, D) intermediate never
    materializes — the same blocking the SpecPCM near-memory ASIC applies.
    """
    B, F = features.shape
    num_levels, D = level_hvs.shape
    if F % block_features != 0:
        pad = block_features - F % block_features
        # padded features encode level 0 with a zero ID so they are inert
        features = jnp.pad(features, ((0, 0), (0, pad)))
        id_hvs = jnp.pad(id_hvs, ((0, pad), (0, 0)))
        F += pad
    levels = quantize_levels(features, num_levels)  # (B, F)
    nblk = F // block_features
    levels_b = levels.reshape(B, nblk, block_features).transpose(1, 0, 2)
    ids_b = id_hvs.reshape(nblk, block_features, D)

    def step(acc, blk):
        lvl, ids = blk
        lv = level_hvs[lvl]  # (B, bf, D)
        present = (lvl > 0).astype(jnp.int32)
        acc = acc + jnp.einsum(
            "bf,bfd,fd->bd",
            present,
            lv.astype(jnp.int32),
            ids.astype(jnp.int32),
            preferred_element_type=jnp.int32,
        )
        return acc, None

    acc0 = jnp.zeros((B, D), jnp.int32)
    acc, _ = jax.lax.scan(step, acc0, (levels_b, ids_b))
    return jnp.where(acc > 0, jnp.int8(1), jnp.int8(-1))
