"""Dimension packing (SpecPCM §III.B).

A bipolar HV of length D is compressed to length D/n by summing n adjacent
elements; each packed value lies in [-n, n] and is stored in one n-bit MLC
PCM cell (as a signed conductance pair). Dot products are preserved *in
expectation* and empirically with negligible accuracy loss:

    <pack(a), b_packed_inputs> approximates <a, b>

because sum_j (a_{ni+j}) * sum_j (b_{ni+j}) counts the diagonal terms of the
block exactly and the cross terms are zero-mean for random HVs.

Packing the *stored* side with n-bit cells and driving the *input* side with
the packed query reproduces the paper's MLC dataflow exactly: both operands
are packed and the in-array MVM computes the packed dot product.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def pack_dimensions(hv: jax.Array, bits_per_cell: int) -> jax.Array:
    """Pack bipolar (±1) vectors along the last axis.

    Args:
      hv: (..., D) bipolar int array.
      bits_per_cell: n; n=1 returns the input unchanged (SLC).

    Returns:
      (..., D // n) int8 array with values in [-n, n].
    """
    n = int(bits_per_cell)
    if n < 1:
        raise ValueError(f"bits_per_cell must be >= 1, got {n}")
    if n == 1:
        return hv.astype(jnp.int8)
    *lead, D = hv.shape
    if D % n != 0:
        raise ValueError(f"D={D} not divisible by bits_per_cell={n}")
    packed = hv.reshape(*lead, D // n, n).astype(jnp.int32).sum(axis=-1)
    return packed.astype(jnp.int8)


def unpack_dimensions(packed: jax.Array, bits_per_cell: int, dim: int) -> jax.Array:
    """Approximate inverse of :func:`pack_dimensions` (lossy for n>1).

    Reconstructs a bipolar vector whose blockwise sums match ``packed`` as
    closely as possible: within each block of n, the first (n+s)/2 entries are
    +1 and the rest -1 where s is the stored sum (parity-rounded). Used only
    for diagnostics/tests — the pipeline operates on packed vectors.
    """
    n = int(bits_per_cell)
    if n == 1:
        return packed.astype(jnp.int8)
    *lead, Dp = packed.shape
    if Dp * n != dim:
        raise ValueError(f"packed dim {Dp} * n {n} != dim {dim}")
    s = packed.astype(jnp.int32)
    num_pos = jnp.clip((n + s) // 2 + (n + s) % 2, 0, n)  # ceil((n+s)/2) in [0,n]
    idx = jnp.arange(n, dtype=jnp.int32)
    block = jnp.where(idx < num_pos[..., None], jnp.int8(1), jnp.int8(-1))
    return block.reshape(*lead, dim)


def packed_levels(bits_per_cell: int) -> int:
    """Number of distinct stored values for n-bit packing: n+1 magnitudes on
    each sign → 2n+1 levels total; an n-bit MLC pair (2 cells, 2T2R) encodes
    them as a signed difference. n=3 → 7 levels, fits 3 bits per cell pair."""
    return 2 * int(bits_per_cell) + 1
