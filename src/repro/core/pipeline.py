"""End-to-end SpecPCM pipelines: spectral clustering and DB search (Figs 1/2).

These are the paper's two applications, wired through the full stack:

  spectra -> preprocess -> HD encode (Eq. 1) -> dimension packing (§III.B)
          -> program PCM arrays (write noise, §III.E)
          -> IMC MVM with DAC/ADC quantization (§III.C)
          -> [clustering] complete-linkage merge loop
          -> [DB search] argmax + target-decoy FDR

Every hardware knob (bits/cell, write-verify, ADC bits, HD dim, material) is
an argument — the same knobs the ISA exposes — so the benchmark sweeps drive
these functions directly. Set ``ideal=True`` to bypass the analog chain
(exact integer math) for algorithm-only baselines.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hd.clustering import (
    clustered_spectra_ratio,
    complete_linkage,
    incorrect_clustering_ratio,
)
from repro.core.hd.encoding import HDEncoderConfig, encode_batch, make_codebooks
from repro.core.hd.packing import pack_dimensions
from repro.core.hd.similarity import dot_similarity
from repro.core.imc import energy as energy_mod
from repro.core.imc.array import ArrayConfig, imc_mvm_reference
from repro.core.imc.device import DeviceConfig, apply_write_noise
from repro.spectra.fdr import fdr_filter, make_decoys
from repro.spectra.preprocess import bucket_by_precursor, candidate_window_mask


@dataclasses.dataclass(frozen=True)
class SpecPCMConfig:
    """Software-visible configuration (the ISA parameter block)."""
    hd_dim: int = 2048
    num_levels: int = 32
    mlc_bits: int = 3
    adc_bits: int = 6
    dac_bits: int = 3
    write_verify: int = 0
    material: str = "sb2te3"
    ideal: bool = False        # bypass analog non-idealities
    seed: int = 0

    def array_cfg(self) -> ArrayConfig:
        return ArrayConfig(dac_bits=self.dac_bits, adc_bits=self.adc_bits,
                           bits_per_cell=self.mlc_bits)

    def device_cfg(self) -> DeviceConfig:
        return DeviceConfig(material=self.material, bits_per_cell=self.mlc_bits,
                            write_verify_cycles=self.write_verify)


def encode_and_pack(spectra: jax.Array, cfg: SpecPCMConfig) -> jax.Array:
    """spectra (N, F) in [0,1] -> packed HVs (N, D/n) int8."""
    enc_cfg = HDEncoderConfig(dim=cfg.hd_dim, num_features=spectra.shape[1],
                              num_levels=cfg.num_levels, seed=cfg.seed)
    id_hvs, level_hvs = make_codebooks(enc_cfg)
    hvs = encode_batch(spectra, id_hvs, level_hvs)
    return pack_dimensions(hvs, cfg.mlc_bits)


def imc_scores(queries_packed: jax.Array, refs_packed: jax.Array,
               cfg: SpecPCMConfig, key: jax.Array) -> jax.Array:
    """(Q, Dp) x (R, Dp) -> (Q, R) scores through the modeled analog chain."""
    if cfg.ideal:
        return dot_similarity(queries_packed, refs_packed).astype(jnp.float32)
    noisy = apply_write_noise(key, refs_packed, cfg.device_cfg())
    return imc_mvm_reference(queries_packed.astype(jnp.float32), noisy,
                             cfg.array_cfg())


# --------------------------------------------------------------------------
# clustering (Fig. 1)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class ClusterReport:
    labels: np.ndarray
    clustered_ratio: float
    incorrect_ratio: float
    num_clusters: int
    cost: "energy_mod.CostReport"


def run_clustering(
    spectra: jax.Array,
    precursor: jax.Array,
    identity: jax.Array,
    cfg: SpecPCMConfig,
    threshold_frac: float = 0.80,
    bucket_width: float = 60.0,
) -> ClusterReport:
    """Full clustering pipeline. ``threshold_frac`` is the merge threshold as
    a fraction of hd_dim/2 (the expected hamming distance of unrelated HVs);
    replicate spectra land around 0.6-0.7 of that scale, unrelated at ~1.0,
    so 0.8 splits the two modes."""
    key = jax.random.PRNGKey(cfg.seed + 17)
    packed = encode_and_pack(spectra, cfg)
    n = spectra.shape[0]
    labels = np.arange(n, dtype=np.int64)
    threshold = threshold_frac * cfg.hd_dim / 2

    buckets = bucket_by_precursor(np.asarray(precursor), bucket_width)
    for bidx in buckets:
        if len(bidx) < 2:
            continue
        key, sub = jax.random.split(key)
        hv_b = packed[jnp.asarray(bidx)]
        scores = imc_scores(hv_b, hv_b, cfg, sub)
        # distance from (noisy, quantized) packed dot product
        dist = (cfg.hd_dim - scores) * 0.5
        dist = jnp.maximum(dist * (1.0 - jnp.eye(len(bidx))), 0.0)
        res = complete_linkage(dist, threshold)
        local = np.asarray(res.labels)
        labels[bidx] = bidx[local]

    labels_j = jnp.asarray(labels, jnp.int32)
    clustered = float(clustered_spectra_ratio(labels_j))
    incorrect = float(incorrect_clustering_ratio(labels_j, identity.astype(jnp.int32)))
    cost = energy_mod.clustering_cost(
        num_spectra=n, hd_dim=cfg.hd_dim, mlc_bits=cfg.mlc_bits,
        adc_bits=cfg.adc_bits, write_verify=cfg.write_verify,
        material=cfg.material,
    )
    return ClusterReport(
        labels=labels, clustered_ratio=clustered, incorrect_ratio=incorrect,
        num_clusters=len(np.unique(labels)), cost=cost,
    )


# --------------------------------------------------------------------------
# DB search (Fig. 2)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class SearchReport:
    matches: np.ndarray          # (Q,) matched reference index (-1 if rejected)
    accepted: np.ndarray         # (Q,) bool — passed FDR
    num_identified: int
    recall: float                # vs ground truth, over accepted
    cost: "energy_mod.CostReport"
    num_no_candidate: int = 0    # queries with an empty precursor window


def run_db_search(
    query_spectra: jax.Array,
    query_precursor: jax.Array,
    ref_spectra: jax.Array,
    ref_precursor: jax.Array,
    cfg: SpecPCMConfig,
    query_identity: jax.Array | None = None,
    ref_identity: jax.Array | None = None,
    fdr: float = 0.01,
    open_search: bool = True,
) -> SearchReport:
    """Full DB search pipeline with decoy competition + FDR filtering."""
    key = jax.random.PRNGKey(cfg.seed + 29)
    k1, k2 = jax.random.split(key)
    q_packed = encode_and_pack(query_spectra, cfg)
    r_packed = encode_and_pack(ref_spectra, cfg)
    d_packed = encode_and_pack(make_decoys(ref_spectra), cfg)

    mask = candidate_window_mask(query_precursor, ref_precursor,
                                 open_search=open_search)
    neg = jnp.float32(-1e9)
    s_t = jnp.where(mask, imc_scores(q_packed, r_packed, cfg, k1), neg)
    s_d = jnp.where(mask, imc_scores(q_packed, d_packed, cfg, k2), neg)

    best_t = jnp.max(s_t, axis=1)
    best_d = jnp.max(s_d, axis=1)
    match_idx = jnp.argmax(s_t, axis=1)
    is_target = best_t > best_d
    best = jnp.maximum(best_t, best_d)
    # Queries with an empty candidate window match nothing — excluding them
    # from the FDR estimate (rather than letting their best_t == best_d tie
    # count as a decoy win) keeps the decoy count honest. They stay in the
    # matches array (as -1) and in the recall denominator: an unmatchable
    # query is still an unidentified spectrum.
    has_candidate = mask.any(axis=1)
    accept = fdr_filter(best, is_target, fdr=fdr, valid=has_candidate)

    matches = np.where(np.asarray(accept), np.asarray(match_idx), -1)
    recall = 0.0
    if query_identity is not None and ref_identity is not None:
        qi = np.asarray(query_identity)
        ri = np.asarray(ref_identity)
        acc = np.asarray(accept)
        good = acc & (ri[np.asarray(match_idx)] == qi)
        recall = float(good.sum() / max(qi.shape[0], 1))

    cand_frac = float(jnp.mean(mask.astype(jnp.float32)))
    cost = energy_mod.db_search_cost(
        num_queries=q_packed.shape[0], num_refs=r_packed.shape[0] * 2,
        hd_dim=cfg.hd_dim, mlc_bits=cfg.mlc_bits, adc_bits=cfg.adc_bits,
        write_verify=cfg.write_verify, candidate_fraction=max(cand_frac, 1e-4),
        material=cfg.material,
    )
    return SearchReport(
        matches=matches, accepted=np.asarray(accept),
        num_identified=int(np.asarray(accept).sum()), recall=recall, cost=cost,
        num_no_candidate=int((~np.asarray(has_candidate)).sum()),
    )
