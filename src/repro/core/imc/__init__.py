from repro.core.imc.array import (
    ArrayConfig,
    IMCArrayState,
    adc_quantize,
    dac_quantize,
    imc_mvm,
    imc_mvm_reference,
    program_hvs,
)
from repro.core.imc.device import (
    MATERIALS,
    SB2TE3_GST,
    TITE2_GST,
    DeviceConfig,
    PCMMaterial,
    apply_write_noise,
    bit_error_rate,
    noise_sigma,
)
from repro.core.imc.energy import (
    DEFAULT_HW,
    HardwareModel,
    clustering_cost,
    db_search_cost,
)
from repro.core.imc.isa import (
    Instruction,
    ISAExecutor,
    Opcode,
    decode_instruction,
    encode_instruction,
)

__all__ = [
    "PCMMaterial", "SB2TE3_GST", "TITE2_GST", "MATERIALS",
    "DeviceConfig", "noise_sigma", "bit_error_rate", "apply_write_noise",
    "ArrayConfig", "IMCArrayState", "program_hvs", "imc_mvm",
    "imc_mvm_reference", "adc_quantize", "dac_quantize",
    "Opcode", "Instruction", "encode_instruction", "decode_instruction",
    "ISAExecutor",
    "HardwareModel", "DEFAULT_HW", "clustering_cost", "db_search_cost",
]
