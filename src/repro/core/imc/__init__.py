from repro.core.imc.device import (
    PCMMaterial,
    SB2TE3_GST,
    TITE2_GST,
    MATERIALS,
    DeviceConfig,
    noise_sigma,
    bit_error_rate,
    apply_write_noise,
)
from repro.core.imc.array import (
    ArrayConfig,
    IMCArrayState,
    program_hvs,
    imc_mvm,
    imc_mvm_reference,
    adc_quantize,
    dac_quantize,
)
from repro.core.imc.isa import (
    Opcode,
    Instruction,
    encode_instruction,
    decode_instruction,
    ISAExecutor,
)
from repro.core.imc.energy import (
    HardwareModel,
    DEFAULT_HW,
    clustering_cost,
    db_search_cost,
)

__all__ = [
    "PCMMaterial", "SB2TE3_GST", "TITE2_GST", "MATERIALS",
    "DeviceConfig", "noise_sigma", "bit_error_rate", "apply_write_noise",
    "ArrayConfig", "IMCArrayState", "program_hvs", "imc_mvm",
    "imc_mvm_reference", "adc_quantize", "dac_quantize",
    "Opcode", "Instruction", "encode_instruction", "decode_instruction",
    "ISAExecutor",
    "HardwareModel", "DEFAULT_HW", "clustering_cost", "db_search_cost",
]
