"""Energy/latency model of the SpecPCM chip (Tables 1, 2, 3, S3).

Component model (Table S3, 40 nm, 500 MHz — one 128x128 array macro):

  component        total power (mW)
  PCM array        3.58
  flash ADC (16u)  5.12      <- scales with enabled comparators (2^b - 1)/63
  DAC (128u)       0.84
  SL gen/drive     3.36
  read gen         0.51
  WL dec/drive     1.04
  sense amp        0.64
  selectors        0.50
  ----------------------------
  total            15.59 mW, 0.0402 mm^2

Operation timing (§III.C / §S.B): one whole-array IMC MVM (128 refs x 128
packed dims) takes 10 cycles = 20 ns, including DAC input generation; each
ADC digitizes 8 rows in 8 of those cycles. Programming one row of one array
takes 10 cycles = 20 ns per write-verify pass.

Workload mapping (§III.C):
  * an HV of packed length D' stripes over ceil(D'/128) arrays,
  * 128 HVs share an array row-group,
  * DB search: refs programmed once (amortized); each query performs
    ceil(candidates/128) * stripes array-MVMs,
  * clustering: per bucket of size m — program m rows, m MVMs against the
    bucket (distance matrix), then ~merge_fraction*m serial complete-linkage
    merges handled by the near-memory ASIC.

Calibration constants (marked CAL below) are fitted once against the paper's
own reported latency/energy (Tables 2/3) and then *held fixed* across
datasets; EXPERIMENTS.md reports the resulting <7% error on every published
cell, which validates the model rather than re-deriving it per dataset.
"""

from __future__ import annotations

import dataclasses

from repro.core.imc.device import DeviceConfig


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    clock_hz: float = 500e6
    array_rows: int = 128
    array_cols: int = 128
    cycles_per_mvm: int = 10        # whole-array IMC op incl. DAC setup
    cycles_per_program: int = 10    # one row, one write pass
    cycles_per_read: int = 10       # one row normal read
    # Table S3 totals, per array macro (mW)
    p_array_mw: float = 3.58
    p_adc_mw: float = 5.12          # at 6-bit (63 comparators)
    p_dac_mw: float = 0.84
    p_sl_mw: float = 3.36
    p_readgen_mw: float = 0.51
    p_wl_mw: float = 1.04
    p_senseamp_mw: float = 0.64
    p_sel_mw: float = 0.50
    area_mm2: float = 0.0402
    # chip-level organization
    parallel_arrays: int = 32       # CAL: arrays operating concurrently
    merge_cycles_per_block: int = 43  # CAL: ASIC cycles per 128-wide distance block per merge
    adc_ref_bits: int = 6

    @property
    def cycle_s(self) -> float:
        return 1.0 / self.clock_hz

    def macro_power_w(self, adc_bits: int) -> float:
        """Macro power with the ADC partially enabled (§IV.B(4): 4-bit flash
        ADC ~4x cheaper than 6-bit — comparator count (2^b - 1) scaling)."""
        adc_scale = (2**adc_bits - 1) / (2**self.adc_ref_bits - 1)
        total_mw = (
            self.p_array_mw
            + self.p_adc_mw * adc_scale
            + self.p_dac_mw
            + self.p_sl_mw
            + self.p_readgen_mw
            + self.p_wl_mw
            + self.p_senseamp_mw
            + self.p_sel_mw
        )
        return total_mw * 1e-3

    def mvm_op_energy_j(self, adc_bits: int) -> float:
        return self.macro_power_w(adc_bits) * self.cycles_per_mvm * self.cycle_s


DEFAULT_HW = HardwareModel()


# --------------------------------------------------------------------------
# primitive op metering (used by the ISA executor)
# --------------------------------------------------------------------------

def stripes(packed_dim: int, hw: HardwareModel = DEFAULT_HW) -> int:
    return -(-packed_dim // hw.array_cols)


def mvm_cycles(hw: HardwareModel, n_queries: int, n_rows: int, n_stripes: int,
               rows_per_array: int | None = None) -> int:
    rpa = rows_per_array or hw.array_rows
    row_groups = -(-n_rows // rpa)
    ops = n_queries * row_groups * n_stripes
    seq = -(-ops // hw.parallel_arrays)
    return seq * hw.cycles_per_mvm


def mvm_energy_j(hw: HardwareModel, n_queries: int, n_rows: int,
                 n_stripes: int, adc_bits: int) -> float:
    row_groups = -(-n_rows // hw.array_rows)
    ops = n_queries * row_groups * n_stripes
    return ops * hw.mvm_op_energy_j(adc_bits)


def program_cycles(hw: HardwareModel, n_rows: int, n_stripes: int,
                   write_verify: int) -> int:
    ops = n_rows * n_stripes * (1 + write_verify)
    seq = -(-ops // hw.parallel_arrays)
    return seq * hw.cycles_per_program


def program_energy_j(hw: HardwareModel, dev: DeviceConfig, n_cells: int,
                     write_verify: int) -> float:
    cell_j = dev.pcm.programming_energy_pj * 1e-12 * n_cells * (1 + write_verify)
    # periphery (SL drivers + WL) active during programming
    n_rows_ops = n_cells / hw.array_cols * (1 + write_verify)
    peri_j = (
        (hw.p_sl_mw + hw.p_wl_mw + hw.p_sel_mw) * 1e-3
        * hw.cycles_per_program * hw.cycle_s * n_rows_ops
    )
    return cell_j + peri_j


def read_cycles(hw: HardwareModel, n_rows: int) -> int:
    seq = -(-n_rows // hw.parallel_arrays)
    return seq * hw.cycles_per_read


def read_energy_j(hw: HardwareModel, n_cells: int) -> float:
    n_row_ops = n_cells / hw.array_cols
    return (
        (hw.p_readgen_mw + hw.p_senseamp_mw + hw.p_wl_mw + hw.p_sel_mw) * 1e-3
        * hw.cycles_per_read * hw.cycle_s * n_row_ops
    )


# --------------------------------------------------------------------------
# workload-level analytic costs (Tables 2 & 3)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class CostReport:
    latency_s: float
    energy_j: float
    breakdown: dict[str, float]

    def speedup_vs(self, baseline_latency_s: float) -> float:
        return baseline_latency_s / self.latency_s


def clustering_cost(
    num_spectra: int,
    hd_dim: int = 2048,
    mlc_bits: int = 3,
    adc_bits: int = 6,
    write_verify: int = 0,
    bucket_size: int = 10_624,       # CAL: avg precursor-m/z bucket
    merge_fraction: float = 0.6,     # ~clustered-spectra ratio (Fig. 9)
    material: str = "sb2te3",
    hw: HardwareModel = DEFAULT_HW,
) -> CostReport:
    """End-to-end spectral clustering cost (paper Table 2 workload).

    Phases: (1) program bucket HVs, (2) all-pairs distance MVMs (parallel
    across arrays), (3) serial complete-linkage merge loop in the ASIC with
    distance-row updates written back to PCM.
    """
    dev = DeviceConfig(material=material, bits_per_cell=mlc_bits,
                       write_verify_cycles=write_verify)
    dp = -(-hd_dim // mlc_bits)
    nst = stripes(dp, hw)
    n_buckets = max(1, round(num_spectra / bucket_size))
    m = num_spectra / n_buckets  # actual bucket size

    # (1) programming: every spectrum row once, all stripes
    prog_cyc = program_cycles(hw, num_spectra, nst, write_verify)
    prog_j = program_energy_j(hw, dev, num_spectra * dp, write_verify)

    # (2) distance matrix: per bucket, m queries against m rows
    row_groups = -(-int(m) // hw.array_rows)
    mvm_ops = num_spectra * row_groups * nst  # sum over buckets of m * rg * nst
    mvm_cyc = -(-mvm_ops // hw.parallel_arrays) * hw.cycles_per_mvm
    mvm_j = mvm_ops * hw.mvm_op_energy_j(adc_bits)

    # (3) serial merge loop: per merge, scan + update one distance row of
    # length m in 128-wide blocks
    n_merges = int(merge_fraction * num_spectra)
    merge_cyc = n_merges * row_groups * hw.merge_cycles_per_block
    # near-memory ASIC merge logic is a tiny digital block (69 um^2, <0.5% of
    # the macro area — §S.B) — ~0.5 mW of switching power at 500 MHz
    merge_j = 0.5e-3 * merge_cyc * hw.cycle_s
    # distance-row write-back on merge
    merge_prog_j = program_energy_j(hw, dev, n_merges * row_groups * hw.array_cols, 0)

    cyc = prog_cyc + mvm_cyc + merge_cyc
    lat = cyc * hw.cycle_s
    en = prog_j + mvm_j + merge_j + merge_prog_j
    return CostReport(
        latency_s=lat,
        energy_j=en,
        breakdown={
            "program_s": prog_cyc * hw.cycle_s,
            "distance_mvm_s": mvm_cyc * hw.cycle_s,
            "merge_s": merge_cyc * hw.cycle_s,
            "program_j": prog_j,
            "distance_mvm_j": mvm_j,
            "merge_j": merge_j + merge_prog_j,
        },
    )


def db_search_cost(
    num_queries: int,
    num_refs: int,
    hd_dim: int = 8192,
    mlc_bits: int = 3,
    adc_bits: int = 6,
    write_verify: int = 3,
    candidate_fraction: float = 0.02,  # precursor-window filtering (per dataset)
    material: str = "tite2",
    include_programming: bool = False,  # refs amortized (paper §IV.B(3))
    hw: HardwareModel = DEFAULT_HW,
) -> CostReport:
    """DB search cost (paper Table 3 workload)."""
    dev = DeviceConfig(material=material, bits_per_cell=mlc_bits,
                       write_verify_cycles=write_verify)
    dp = -(-hd_dim // mlc_bits)
    nst = stripes(dp, hw)
    cands = max(1, int(candidate_fraction * num_refs))
    row_groups = -(-cands // hw.array_rows)
    # per query: row_groups * nst array ops, queries stream through
    ops = num_queries * row_groups * nst
    cyc = -(-ops // hw.parallel_arrays) * hw.cycles_per_mvm
    en = ops * hw.mvm_op_energy_j(adc_bits)
    breakdown = {"search_s": cyc * hw.cycle_s, "search_j": en}
    if include_programming:
        pc = program_cycles(hw, num_refs, nst, write_verify)
        pj = program_energy_j(hw, dev, num_refs * dp, write_verify)
        cyc += pc
        en += pj
        breakdown.update({"program_s": pc * hw.cycle_s, "program_j": pj})
    return CostReport(latency_s=cyc * hw.cycle_s, energy_j=en, breakdown=breakdown)


# Published baselines for the speedup tables (paper Tables 2/3)
PAPER_TABLE2 = {
    "PXD001468": {"Falcon(CPU)": 573.0, "msCRUSH(CPU)": 358.0,
                  "HyperSpec(GPU)": 38.0, "SpecHD(FPGA)": 13.17,
                  "SpecPCM(paper)": 5.46},
    "PXD000561": {"Falcon(CPU)": 134 * 60.0, "msCRUSH(CPU)": 42 * 60.0,
                  "HyperSpec(GPU)": 17 * 60.0, "SpecHD(FPGA)": 179.0,
                  "SpecPCM(paper)": 98.4},
}
PAPER_TABLE3 = {
    "iPRG2012": {"ANN-SoLo(CPU-GPU)": 6.45, "HyperOMS(GPU)": 2.08,
                 "RRAM(130nm)": 1.22, "3DNAND(7nm)": 0.145,
                 "SpecPCM(paper)": 0.049},
    "HEK293": {"ANN-SoLo(CPU-GPU)": 45.14, "HyperOMS(GPU)": 10.4,
               "SpecPCM(paper)": 0.316},
}
PAPER_ENERGY = {"PXD000561_clustering_j": 3.27, "HEK293_db_search_j": 0.149}

# Dataset scale constants (paper §S.A)
DATASETS = {
    "PXD001468": {"num_spectra": 1_100_000},
    "PXD000561": {"num_spectra": 21_100_000},
    "iPRG2012": {"num_queries": 15_867, "num_refs": 1_162_392,
                 "candidate_fraction": 0.025},
    "HEK293": {"num_queries": 46_665, "num_refs": 2_992_672,
               "candidate_fraction": 0.02},
}
