"""Instruction Set Architecture for IMC control (SpecPCM §III.F, Table S2).

Three instructions manage the memory system:

  STORE_HV   (data, arr_idx, col_addr, row_addr, MLC_bits, write_cycles)
  READ_HV    (data_size, arr_idx, col_addr, row_addr, MLC_bits)
  MVM_COMPUTE(row_addr, num_activated_row, ADC_bits, MLC_bits)

Instructions encode to 64-bit words (fields below) and the `ISAExecutor`
interprets a stream against the array model while metering energy/latency via
``repro.core.imc.energy``. The executor is the single place where software
knobs (bits/cell, write-verify, ADC bits, HD dim) meet the hardware model —
mirroring the paper's software-controlled trade-off loop.

64-bit encoding (LSB-first):
  [0:4]   opcode
  [4:20]  arr_idx       (16 bits)
  [20:28] col_addr      (8 bits)
  [28:44] row_addr / num rows for MVM (16 bits)
  [44:48] mlc_bits      (4 bits)
  [48:54] write_cycles / adc_bits (6 bits)
  [54:64] reserved
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Iterable

import jax
import jax.numpy as jnp

from repro.core.imc import energy as energy_mod
from repro.core.imc.array import (
    ArrayConfig,
    IMCArrayState,
    imc_mvm_reference,
    program_hvs,
)
from repro.core.imc.device import DeviceConfig


class Opcode(enum.IntEnum):
    STORE_HV = 1
    READ_HV = 2
    MVM_COMPUTE = 3


@dataclasses.dataclass(frozen=True)
class Instruction:
    opcode: Opcode
    arr_idx: int = 0
    col_addr: int = 0
    row_addr: int = 0
    mlc_bits: int = 3
    aux: int = 0  # write_cycles for STORE, adc_bits for MVM, data_size for READ

    def __post_init__(self):
        if not (0 <= self.arr_idx < 2**16):
            raise ValueError(f"arr_idx out of range: {self.arr_idx}")
        if not (0 <= self.col_addr < 2**8):
            raise ValueError(f"col_addr out of range: {self.col_addr}")
        if not (0 <= self.row_addr < 2**16):
            raise ValueError(f"row_addr out of range: {self.row_addr}")
        if not (0 <= self.mlc_bits < 2**4):
            raise ValueError(f"mlc_bits out of range: {self.mlc_bits}")
        if not (0 <= self.aux < 2**6):
            raise ValueError(f"aux out of range: {self.aux}")


def encode_instruction(inst: Instruction) -> int:
    w = int(inst.opcode) & 0xF
    w |= (inst.arr_idx & 0xFFFF) << 4
    w |= (inst.col_addr & 0xFF) << 20
    w |= (inst.row_addr & 0xFFFF) << 28
    w |= (inst.mlc_bits & 0xF) << 44
    w |= (inst.aux & 0x3F) << 48
    return w


def decode_instruction(word: int) -> Instruction:
    return Instruction(
        opcode=Opcode(word & 0xF),
        arr_idx=(word >> 4) & 0xFFFF,
        col_addr=(word >> 20) & 0xFF,
        row_addr=(word >> 28) & 0xFFFF,
        mlc_bits=(word >> 44) & 0xF,
        aux=(word >> 48) & 0x3F,
    )


@dataclasses.dataclass
class ExecutionTrace:
    cycles: int = 0
    energy_j: float = 0.0
    instructions: int = 0

    def merge(self, other: "ExecutionTrace") -> "ExecutionTrace":
        return ExecutionTrace(
            cycles=self.cycles + other.cycles,
            energy_j=self.energy_j + other.energy_j,
            instructions=self.instructions + other.instructions,
        )


class ISAExecutor:
    """Interprets an instruction stream against a logical bank of arrays.

    The executor owns:
      * a staging buffer (`stage`) that STORE_HV consumes and READ_HV fills,
      * the programmed bank state (one logical dense weight matrix striped
        over `arrays_per_hv` physical arrays),
      * an ExecutionTrace metering cycles and energy per the paper's
        component model (energy.py).
    """

    def __init__(
        self,
        array_cfg: ArrayConfig,
        device_cfg: DeviceConfig,
        hw: "energy_mod.HardwareModel | None" = None,
        seed: int = 0,
    ):
        self.array_cfg = array_cfg
        self.device_cfg = device_cfg
        self.hw = hw or energy_mod.DEFAULT_HW
        self.key = jax.random.PRNGKey(seed)
        self.state: IMCArrayState | None = None
        self.stage: jax.Array | None = None
        self.trace = ExecutionTrace()

    # -- host-side helpers ---------------------------------------------------
    def _split(self) -> jax.Array:
        self.key, sub = jax.random.split(self.key)
        return sub

    def load_stage(self, packed_hvs: jax.Array) -> None:
        """Host DMA into the staging buffer (not an ISA instruction)."""
        self.stage = packed_hvs

    # -- ISA ------------------------------------------------------------------
    def execute(self, stream: Iterable[Instruction]) -> ExecutionTrace:
        for inst in stream:
            self.execute_one(inst)
        return self.trace

    def execute_one(self, inst: Instruction) -> None:
        cfg = self.array_cfg
        if inst.opcode == Opcode.STORE_HV:
            if self.stage is None:
                raise RuntimeError("STORE_HV with empty staging buffer")
            dev = dataclasses.replace(
                self.device_cfg,
                bits_per_cell=inst.mlc_bits,
                write_verify_cycles=inst.aux,
            )
            acfg = dataclasses.replace(cfg, bits_per_cell=inst.mlc_bits)
            self.state = program_hvs(self._split(), self.stage, acfg, dev)
            rows, dp = self.stage.shape
            n_arrays = -(-dp // cfg.cols)
            row_groups = -(-rows // cfg.rows)
            self.trace = self.trace.merge(
                ExecutionTrace(
                    cycles=energy_mod.program_cycles(self.hw, rows, n_arrays, inst.aux),
                    energy_j=energy_mod.program_energy_j(
                        self.hw, dev, rows * dp, inst.aux
                    ),
                    instructions=1,
                )
            )
            del row_groups
        elif inst.opcode == Opcode.READ_HV:
            if self.state is None:
                raise RuntimeError("READ_HV before STORE_HV")
            rows = max(inst.aux, 1)
            dp = self.state.weights.shape[1]
            n_arrays = -(-dp // cfg.cols)
            self.stage = jnp.round(
                jax.lax.dynamic_slice_in_dim(self.state.weights, inst.row_addr, rows, 0)
            ).astype(jnp.int8)
            self.trace = self.trace.merge(
                ExecutionTrace(
                    cycles=energy_mod.read_cycles(self.hw, rows),
                    energy_j=energy_mod.read_energy_j(self.hw, rows * dp),
                    instructions=1,
                )
            )
        elif inst.opcode == Opcode.MVM_COMPUTE:
            if self.state is None or self.stage is None:
                raise RuntimeError("MVM_COMPUTE needs programmed state + staged query")
            acfg = dataclasses.replace(
                cfg, adc_bits=max(inst.aux, 1), bits_per_cell=inst.mlc_bits
            )
            nrow = inst.row_addr if inst.row_addr > 0 else self.state.weights.shape[0]
            w = self.state.weights[:nrow]
            self.result = imc_mvm_reference(self.stage.astype(jnp.float32), w, acfg)
            q, dp = self.stage.shape
            n_arrays = -(-dp // cfg.cols)
            self.trace = self.trace.merge(
                ExecutionTrace(
                    cycles=energy_mod.mvm_cycles(self.hw, q, nrow, n_arrays, cfg.rows),
                    energy_j=energy_mod.mvm_energy_j(
                        self.hw, q, nrow, n_arrays, acfg.adc_bits
                    ),
                    instructions=1,
                )
            )
        else:  # pragma: no cover
            raise ValueError(f"unknown opcode {inst.opcode}")


def compile_db_search(
    num_refs: int,
    packed_dim: int,
    cfg: ArrayConfig,
    write_cycles: int,
    adc_bits: int,
    mlc_bits: int,
) -> list[Instruction]:
    """Tiny 'compiler': DB-search instruction stream = program refs once,
    then one MVM per staged query batch."""
    return [
        Instruction(Opcode.STORE_HV, mlc_bits=mlc_bits, aux=write_cycles),
        Instruction(Opcode.MVM_COMPUTE, row_addr=0, mlc_bits=mlc_bits, aux=adc_bits),
    ]
