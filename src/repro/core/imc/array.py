"""IMC array model (SpecPCM §III.C, Table 1).

A bank is a 128x128 array of 2T2R cell pairs; each pair stores one signed
packed level in [-n, n]. An HV of packed length D' is striped across
ceil(D'/128) arrays at the same row index; 128 HV segments share an array
(one per row). MVM drives the packed query through 3-bit DACs on the source
lines, all word lines fire, and per-array analog partial sums appear on the
bit lines, digitized by 6-bit flash ADCs.

The numerics we model faithfully:

  * DAC quantization of the query to `dac_bits` signed levels,
  * PCM conductance noise on the stored weights (device.py),
  * per-array (i.e. per-128-column-tile) partial sums,
  * ADC clamp + uniform quantization of each partial sum to `adc_bits`,
  * digital accumulation of quantized partials across arrays.

`imc_mvm_reference` is the pure-jnp oracle; the Pallas kernel in
``repro.kernels.imc_mvm`` computes the same function with explicit VMEM
tiling (the 128x128 array maps 1:1 onto an MXU tile).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.imc.device import DeviceConfig, apply_write_noise


@dataclasses.dataclass(frozen=True)
class ArrayConfig:
    """ISA-visible IMC array parameters (defaults = paper Table 1)."""
    rows: int = 128
    cols: int = 128
    dac_bits: int = 3
    adc_bits: int = 6
    bits_per_cell: int = 3
    full_scale: float | None = None  # override ADC full scale (tests/ideal)

    @property
    def dac_levels(self) -> int:
        # signed DAC: levels in [-(2^(b-1)-1), 2^(b-1)-1]; 3-bit -> [-3, 3]
        return 2 ** (self.dac_bits - 1) - 1

    @property
    def adc_levels(self) -> int:
        # signed flash ADC with 2^b - 1 comparators -> range [-(2^(b-1)-1), ...]
        return 2 ** (self.adc_bits - 1) - 1


@dataclasses.dataclass
class IMCArrayState:
    """Programmed bank contents: noisy conductance-domain weights.

    weights: (num_rows_total, packed_dim) float32 — conductance-noise-applied
    packed levels, logically striped over ceil(packed_dim/cols) physical
    arrays. Kept dense here; physical striping is an indexing detail that the
    energy model accounts for.
    """
    weights: jax.Array
    cfg: ArrayConfig
    device: DeviceConfig


def dac_quantize(x: jax.Array, cfg: ArrayConfig) -> jax.Array:
    """Clamp+round the (packed, integer) query to DAC range. For 3-bit DAC
    and 3-bit packing the ranges coincide ([-3, 3]) and this is exact —
    the co-design the paper exploits."""
    lim = cfg.dac_levels
    return jnp.clip(jnp.round(x.astype(jnp.float32)), -lim, lim)


def adc_quantize(partial: jax.Array, cfg: ArrayConfig, full_scale: float) -> jax.Array:
    """Flash-ADC transfer function for one array's analog partial sum.

    The BL voltage is proportional to the partial dot product; the ADC spans
    [-full_scale, +full_scale] with 2^b - 1 uniformly spaced codes (63
    comparators at 6 bits). Values beyond full scale saturate.
    """
    lvl = cfg.adc_levels
    lsb = full_scale / lvl
    code = jnp.clip(jnp.round(partial / lsb), -lvl, lvl)
    return code * lsb


def default_full_scale(cfg: ArrayConfig) -> float:
    """ADC full-scale: for random bipolar data, the per-array partial sum of
    `rows=128` products of values in [-n,n]x[-n,n] has std ~= sqrt(128)*E|w*x|.
    Spec'd at 4 sigma of the zero-mean distribution so clipping is rare —
    this matches the paper's observation that HD partial sums concentrate
    near zero (§IV.B(4))."""
    n = cfg.bits_per_cell
    d = cfg.dac_levels
    if cfg.full_scale is not None:
        return cfg.full_scale
    per_prod_std = (n * d) / 3.0  # rough E[(wx)^2]^0.5 for uniform-ish levels
    return 4.0 * per_prod_std * (cfg.cols ** 0.5)


def program_hvs(
    key: jax.Array,
    packed_hvs: jax.Array,
    cfg: ArrayConfig,
    device: DeviceConfig,
) -> IMCArrayState:
    """Program packed HVs into the bank with write noise (write-verify folded
    into the device sigma)."""
    noisy = apply_write_noise(key, packed_hvs, device)
    return IMCArrayState(weights=noisy, cfg=cfg, device=device)


@partial(jax.jit, static_argnames=("cfg",))
def _imc_mvm_impl(
    queries: jax.Array, weights: jax.Array, cfg: ArrayConfig
) -> jax.Array:
    q = dac_quantize(queries, cfg)  # (Q, Dp)
    Qn, Dp = q.shape
    R = weights.shape[0]
    cols = cfg.cols
    pad = (-Dp) % cols
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad)))
        weights = jnp.pad(weights, ((0, 0), (0, pad)))
        Dp += pad
    ntiles = Dp // cols
    qt = q.reshape(Qn, ntiles, cols)
    wt = weights.reshape(R, ntiles, cols)
    # per-array analog partial sums: (Q, R, ntiles)
    partial_sums = jnp.einsum(
        "qtc,rtc->qrt", qt, wt.astype(jnp.float32), preferred_element_type=jnp.float32
    )
    fs = default_full_scale(cfg)
    quant = adc_quantize(partial_sums, cfg, fs)
    return quant.sum(axis=-1)


def imc_mvm(queries: jax.Array, state: IMCArrayState) -> jax.Array:
    """IMC matrix-vector (batched) product with full analog-chain modeling.

    queries: (Q, Dp) packed integer HVs. Returns (Q, R) float32 scores.
    """
    return _imc_mvm_impl(queries, state.weights, state.cfg)


def imc_mvm_reference(
    queries: jax.Array,
    weights: jax.Array,
    cfg: ArrayConfig,
) -> jax.Array:
    """Pure-jnp oracle (same math as `imc_mvm`, explicit for kernels/tests)."""
    return _imc_mvm_impl(queries, weights, cfg)
