"""PCM device models (SpecPCM §III.E, Table S1, Fig. 7).

Two superlattice PCM technologies with measured parameters from Table S1:

  * Sb2Te3/Ge4Sb6Te7 — low programming energy (1.12 pJ), 30 h retention at
    105C, on/off 150x. Used for *clustering* (write-intensive).
  * TiTe2/Ge4Sb6Te7  — 2.88 pJ programming, >1e5 h retention, lower error.
    Used for *DB search* (read-intensive, long retention).

Noise model (§S.B): a stored value W is read back as Ŵ = W * (1 + η),
η ~ N(0, σ²). σ shrinks with write-verify cycles; we fit an exponential-
floor model to the paper's Fig. 7 measurement (BER vs write-verify cycles for
3-bit cells: ~13% at 0 cycles falling toward a ~6-8% floor — §II.C notes
MLC error rates "often exceeding 10% even after meticulous write-verify"):

    σ(c) = σ_floor + (σ_0 − σ_floor) · exp(−c / c_decay)

and map σ → bit error rate analytically for n-bit packed cells: a stored
level is misread when the multiplicative perturbation crosses half the level
spacing. Both materials share the curve shape; TiTe2 has a lower floor.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PCMMaterial:
    name: str
    programming_current_ua: float
    programming_voltage_v: float
    programming_energy_pj: float
    retention_hours_105c: float
    low_resistance_kohm: float
    on_off_ratio: float
    # fitted noise curve (relative conductance std)
    sigma_0: float        # std with no write-verify
    sigma_floor: float    # asymptotic std with many write-verify cycles
    c_decay: float        # write-verify decay constant (cycles)
    endurance_cycles: float = 1e8


SB2TE3_GST = PCMMaterial(
    name="Sb2Te3/Ge4Sb6Te7",
    programming_current_ua=80.0,
    programming_voltage_v=0.7,
    programming_energy_pj=1.12,
    retention_hours_105c=30.0,
    low_resistance_kohm=30.0,
    on_off_ratio=150.0,
    sigma_0=0.26,
    sigma_floor=0.185,
    c_decay=2.2,
)

TITE2_GST = PCMMaterial(
    name="TiTe2/Ge4Sb6Te7",
    programming_current_ua=160.0,
    programming_voltage_v=0.9,
    programming_energy_pj=2.88,
    retention_hours_105c=1e5,
    low_resistance_kohm=10.0,
    on_off_ratio=100.0,
    sigma_0=0.22,
    sigma_floor=0.155,
    c_decay=2.2,
)

MATERIALS: dict[str, PCMMaterial] = {
    "sb2te3": SB2TE3_GST,
    "tite2": TITE2_GST,
}


@dataclasses.dataclass(frozen=True)
class DeviceConfig:
    """Per-deployment device knobs (ISA-visible)."""
    material: str = "tite2"          # key into MATERIALS
    bits_per_cell: int = 3           # MLC depth (1 = SLC)
    write_verify_cycles: int = 3     # Fig. 7 x-axis

    @property
    def pcm(self) -> PCMMaterial:
        return MATERIALS[self.material]


def noise_sigma(cfg: DeviceConfig) -> float:
    """Relative read-noise std after the configured write-verify cycles."""
    m = cfg.pcm
    c = float(cfg.write_verify_cycles)
    return m.sigma_floor + (m.sigma_0 - m.sigma_floor) * math.exp(-c / m.c_decay)


def bit_error_rate(cfg: DeviceConfig) -> float:
    """Analytic level-error probability for an n-bit packed cell.

    Stored levels for n-bit packing are the 2n+1 integers in [-n, n],
    realized as a conductance difference of a 2T2R pair with full-scale G_max.
    A level s is misread when |η·s| > 0.5 level spacings, with spacing
    G_max/n on the normalized scale. Averaging the Gaussian tail over the
    (binomially distributed) levels of random bipolar data gives the BER.
    Reproduces the Fig. 7 shape: ~12% at c=0 → ~5% at c=5 for n=3 on TiTe2.
    """
    n = cfg.bits_per_cell
    sigma = noise_sigma(cfg)
    if sigma <= 0:
        return 0.0
    # P(level = s) for s = sum of n Rademacher vars: C(n, (n+s)/2) / 2^n
    total = 0.0
    for k in range(n + 1):
        s = 2 * k - n
        p_level = math.comb(n, k) / (2.0**n)
        if s == 0:
            # differential pair reads near zero; spacing/2 away from next level
            # error prob is the chance additive-equivalent noise (sigma * 1 unit
            # reference magnitude) crosses half a spacing
            eff = sigma * 1.0
        else:
            eff = sigma * abs(s)
        # half-spacing is 0.5 (levels are integers on this scale)
        z = 0.5 / max(eff, 1e-12)
        p_err = math.erfc(z / math.sqrt(2.0))
        total += p_level * p_err
    return total


def apply_write_noise(
    key: jax.Array, weights: jax.Array, cfg: DeviceConfig
) -> jax.Array:
    """Simulate programming + read of `weights` on the configured device:
    multiplicative Gaussian conductance noise (paper §S.B noise model).

    weights: integer packed levels in [-n, n]; returned as float32 noisy
    conductance-domain values (the array model re-quantizes at the ADC).
    """
    sigma = noise_sigma(cfg)
    eta = jax.random.normal(key, weights.shape, jnp.float32) * sigma
    return weights.astype(jnp.float32) * (1.0 + eta)


def programming_energy_j(cfg: DeviceConfig, num_cells: int) -> float:
    """Energy to program `num_cells` cell-pairs including write-verify passes.

    Each write-verify cycle adds one (read + conditional partial write); we
    charge a full programming pulse per verify cycle (conservative, matches
    the paper's 'linearly increases latency and energy' statement)."""
    pulses = 1 + cfg.write_verify_cycles
    return num_cells * cfg.pcm.programming_energy_pj * 1e-12 * pulses
