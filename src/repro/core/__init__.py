"""SpecPCM core: hyperdimensional computing + PCM in-memory-compute models."""

from repro.core.pipeline import (
    ClusterReport,
    SearchReport,
    SpecPCMConfig,
    encode_and_pack,
    imc_scores,
    run_clustering,
    run_db_search,
)

__all__ = [
    "SpecPCMConfig", "encode_and_pack", "imc_scores",
    "run_clustering", "run_db_search", "ClusterReport", "SearchReport",
]
