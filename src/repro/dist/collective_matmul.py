"""Collective matmuls: decomposed collectives interleaved with compute.

The XLA-default pattern for a TP matmul is matmul-then-all-reduce (or
all-gather-then-matmul): the collective and the MXU serialize. These
kernels decompose the collective into ``n-1`` ring steps (ppermute) and
issue a partial matmul per step, so the interconnect and the MXU run
concurrently — the "collective matmul" trick (Wang et al., ASPLOS'23)
that the roofline cells show is required once ICI time ~= compute time.

Mesh axes: both kernels ring over a single named axis — ``'model'`` by
default, the fast-ICI tensor-parallel axis of the production mesh
(``repro.launch.mesh``). ``ring_matmul_reduce`` shards the contraction
dim of ``x`` and the rows of ``w`` over it; ``ag_matmul_pipelined``
shards the rows of ``x`` and the columns of ``w``.

Degradation/fallback: both functions compute exactly ``x @ w`` for any
mesh-axis size. A size-1 axis degrades to a plain local matmul (the
ring has zero ppermute steps), and dims not divisible by the axis size
fall back to the unsharded ``x @ w`` rather than erroring — the same
replicate-on-indivisibility contract as ``repro.dist.sharding``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


@functools.lru_cache(maxsize=None)
def _ring_fn(mesh: Mesh, axis: str):
    n = mesh.shape[axis]

    def local(xl, wl):
        partial = xl @ wl
        acc = partial
        chunk = partial
        perm = [(i, (i + 1) % n) for i in range(n)]
        for _ in range(n - 1):
            chunk = jax.lax.ppermute(chunk, axis, perm)
            acc = acc + chunk
        return acc

    return jax.jit(shard_map(local, mesh=mesh,
                             in_specs=(P(None, axis), P(axis, None)),
                             out_specs=P(None, None), check_rep=False))


def ring_matmul_reduce(x: jax.Array, w: jax.Array, mesh: Mesh,
                       axis: str = "model") -> jax.Array:
    """x @ w with the contraction dim sharded over ``axis``.

    Each device matmuls its k-shard into a full-size partial, then the
    partials circulate the ring accumulating — an unrolled all-reduce
    whose steps overlap the next shard's compute. Output is replicated
    over ``axis``.
    """
    if x.shape[-1] % mesh.shape[axis]:
        # indivisible contraction dim: no sharding to exploit
        return x @ w
    return _ring_fn(mesh, axis)(x, w)


@functools.lru_cache(maxsize=None)
def _ag_fn(mesh: Mesh, axis: str):
    n = mesh.shape[axis]

    def local(xl, wl):
        m_l = xl.shape[0]
        idx = jax.lax.axis_index(axis)
        out = jnp.zeros((m_l * n, wl.shape[-1]), jnp.result_type(xl, wl))
        chunk = xl
        perm = [(i, (i + 1) % n) for i in range(n)]
        for t in range(n):
            src = jnp.mod(idx - t, n)
            out = jax.lax.dynamic_update_slice(out, chunk @ wl,
                                               (src * m_l, 0))
            if t < n - 1:
                chunk = jax.lax.ppermute(chunk, axis, perm)
        return out

    return jax.jit(shard_map(local, mesh=mesh,
                             in_specs=(P(axis, None), P(None, axis)),
                             out_specs=P(None, axis), check_rep=False))


def ag_matmul_pipelined(x: jax.Array, w: jax.Array, mesh: Mesh,
                        axis: str = "model") -> jax.Array:
    """x @ w with x row-sharded and w column-sharded over ``axis``.

    Each device needs all rows of x for its column shard of the output;
    instead of a blocking all-gather, row-chunks of x circulate the ring
    and each arriving chunk is matmul'd immediately into its slot of the
    local output block (pipelined all-gather + matmul).
    """
    n = mesh.shape[axis]
    if x.shape[0] % n or w.shape[-1] % n:
        return x @ w
    return _ag_fn(mesh, axis)(x, w)
