"""Gradient compression for cross-pod sync: stochastic-rounding int8,
magnitude top-k, error-feedback top-k, compressed all-reduces, and the
DCN wire-format accounting behind the train step's ``dcn_bytes`` metric.

All compressors are simulate-on-device: they return the *decompressed*
values (same shapes/dtypes as the input) so they compose with any
optimizer; the wire format is implied by the math (int8 codes + one fp32
scale per leaf, or exactly-k (index, value) pairs) and is what
``tree_wire_bytes`` accounts.

Stochastic rounding (``floor(x/s + u)``, u ~ U[0,1)) keeps int8
quantization unbiased — E[q·s] = x — so compressed SGD converges like a
noisier uncompressed SGD instead of accumulating rounding bias. The
rounding key should change every step (``per_step_key``; the train step
folds ``TrainState.step`` in) — a fixed key draws the *same* noise each
step, which correlates the rounding error across the whole run. Top-k
alone silently drops small coordinates forever; ``topk_ef_compress``
carries the error state so every coordinate is eventually transmitted
(the EF-SGD invariant: sent + new_err == grads + old_err, exactly).

Mesh axes: the collectives here sum over exactly one named axis — by
convention ``'pod'``, the slow DCN hop of the multi-pod mesh
(``repro.launch.mesh``). ``cross_pod_allreduce`` is the single-array
form; ``dcn_allreduce_tree`` is the train-step form, taking a gradient
pytree stacked along a leading per-pod dim plus the per-pod
error-feedback state, compressing each pod's payload *before* the psum
crosses the axis. The in-graph compressors (``compress_tree``,
``topk_ef_compress``, ``dcn_send``) are axis-free and run under any
sharding. Degradation/fallback: ``method='none'`` short-circuits to the
identity (resp. a plain psum on the wire path, bit-identical to an
uncompressed all-reduce); a size-1 axis makes the psum a no-op so the
code needs no special case; the shard_map closure is lru-cached per
(mesh, axis, method, rank) so per-step calls never retrace.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

DCN_METHODS = ("none", "int8", "topk", "topk_ef")


def per_step_key(seed: int, step) -> jax.Array:
    """Per-step rounding key: PRNGKey(seed) with the step counter folded
    in, so stochastic-rounding noise decorrelates across steps."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), step)


def _int8_stochastic(x: jax.Array, key: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.abs(xf).max(), 1e-30) / 127.0
    u = jax.random.uniform(key, xf.shape)
    q = jnp.clip(jnp.floor(xf / scale + u), -127, 127)
    return (q * scale).astype(x.dtype)


def topk_count(n: int, frac: float) -> int:
    """Coordinates kept by top-k on an n-element leaf: max(round(frac*n), 1)."""
    return max(int(round(frac * n)), 1)


def _topk_mask(x: jax.Array, frac: float) -> jax.Array:
    """0/1 mask selecting *exactly* ``topk_count`` coordinates by |value|,
    ties broken toward the lower flat index (``lax.top_k`` order) — exact
    cardinality is what the (index, value)-pair wire accounting assumes."""
    flat = jnp.abs(x.astype(jnp.float32)).reshape(-1)
    k = topk_count(flat.size, frac)
    idx = jax.lax.top_k(flat, k)[1]
    mask = jnp.zeros(flat.shape, x.dtype).at[idx].set(1)
    return mask.reshape(x.shape)


def _topk(x: jax.Array, frac: float) -> jax.Array:
    return x * _topk_mask(x, frac)


def compress_tree(grads, method: str = "int8", topk_frac: float = 0.01,
                  key: jax.Array | None = None):
    """Compress+decompress every leaf. ``method``: none | int8 | topk.

    ``key`` seeds the int8 stochastic rounding. The default is the fixed
    legacy key (deterministic under jit, still unbiased per element draw,
    but *identical noise every call*) — training callers should pass
    ``per_step_key(seed, step)`` so rounding noise decorrelates across
    steps instead of accumulating a correlated bias."""
    if method == "none":
        return grads
    if method == "topk":
        return jax.tree.map(lambda g: _topk(g, topk_frac), grads)
    if method != "int8":
        raise ValueError(f"unknown compression method: {method}")
    if key is None:
        key = jax.random.PRNGKey(0)
    leaves, treedef = jax.tree.flatten(grads)
    out = [_int8_stochastic(g, jax.random.fold_in(key, i))
           for i, g in enumerate(leaves)]
    return jax.tree.unflatten(treedef, out)


def init_error_state(grads):
    """Zero error-feedback residuals mirroring the grad tree (fp32)."""
    return jax.tree.map(
        lambda g: jnp.zeros(jnp.shape(g), jnp.float32), grads)


def topk_ef_compress(grads, error_state, topk_frac: float = 0.01):
    """Error-feedback top-k: returns (sent, new_error_state).

    sent + new_error == grads + old_error holds exactly (the masks are
    complementary selections of the same accumulator), which is the
    invariant that makes EF-SGD converge at the uncompressed rate."""
    def one(g, e):
        acc = g.astype(jnp.float32) + e
        mask = _topk_mask(acc, topk_frac)
        return acc * mask, acc * (1.0 - mask)

    pairs = jax.tree.map(one, grads, error_state)
    sent = jax.tree.map(lambda p: p[0], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda p: p[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return sent, err


def dcn_send(grads, error, method: str = "int8", topk_frac: float = 0.01,
             key: jax.Array | None = None):
    """One pod's DCN payload: ``(sent, new_error)``.

    The unit shared by the emulated and shard_map hierarchical reduces
    (and property-tested directly): ``sent`` is what this pod puts on the
    wire, ``new_error`` the residual it keeps. ``error`` is ``{}`` for
    the stateless methods (none/int8/topk) and a grads-shaped fp32 tree
    for ``topk_ef`` (the EF-SGD invariant ``sent + new_error == grads +
    error`` holds bit-for-bit). ``method='none'`` is the identity."""
    if method == "none":
        return grads, error
    if method == "topk_ef":
        return topk_ef_compress(grads, error, topk_frac)
    return compress_tree(grads, method=method, topk_frac=topk_frac,
                         key=key), error


def leaf_wire_bytes(n: int, method: str, topk_frac: float = 0.01) -> int:
    """Bytes one n-element fp32 leaf costs on the DCN per pod per step.

    none: 4n (raw fp32). int8: n codes + one fp32 scale. topk/topk_ef:
    exactly-k (int32 index, fp32 value) pairs, k = ``topk_count``."""
    if method == "none":
        return 4 * n
    if method == "int8":
        return n + 4
    if method in ("topk", "topk_ef"):
        return 8 * topk_count(n, topk_frac)
    raise ValueError(f"unknown compression method: {method}")


def tree_wire_bytes(tree, method: str, topk_frac: float = 0.01) -> int:
    """Total per-pod DCN bytes for one send of a gradient pytree."""
    return sum(leaf_wire_bytes(math.prod(jnp.shape(l)) or 1, method,
                               topk_frac)
               for l in jax.tree.leaves(tree))


def dcn_allreduce_tree(grads_stacked, error, mesh: Mesh, axis: str = "pod",
                       method: str = "int8", topk_frac: float = 0.01,
                       key: jax.Array | None = None):
    """Compressed all-reduce of a *stacked* gradient pytree over one mesh
    axis — the train step's DCN hop.

    ``grads_stacked`` leaves are ``(P, *shape)`` with the leading per-pod
    dim sharded over ``axis`` (P = axis size); ``error`` is ``{}`` or a
    matching ``(P, *shape)`` per-pod EF tree. Each pod compresses its own
    slice (rounding key = ``fold_in(key, axis_index)``, matching the
    emulated route's ``fold_in(key, pod)``) and only then psums across
    ``axis``, so the slow hop carries the compressed payload while the
    in-pod reduction that produced the slice stayed uncompressed on ICI.

    Memory note: compression is whole-leaf (one int8 scale / one top-k
    selection per leaf, the same math as the emulated route), so entering
    the collective gathers each pod's full gradient tree onto its devices
    — the same footprint as an unsharded all-reduce buffer. Keeping
    gradient FSDP sharding *through* the collective would need
    shard-local compression (per-shard top-k/scales), a different wire
    format tracked as a ROADMAP follow-up.
    Returns ``(summed tree without the leading dim, new per-pod error)``;
    scaling by 1/P is the caller's job. ``method='none'`` degrades to a
    plain psum — bit-identical to an uncompressed all-reduce.

    Per-step callers MUST pass a fresh ``key`` (the train step threads
    ``per_step_key(seed, step)``): the ``None`` default is the fixed
    legacy key, which draws *identical* int8 rounding noise every call —
    the correlated-bias failure mode this module exists to avoid."""
    if method not in DCN_METHODS:
        raise ValueError(f"unknown compression method: {method}")
    if key is None:
        key = jax.random.PRNGKey(0)

    def local(gP, eP, k):
        g = jax.tree.map(lambda x: jnp.squeeze(x, 0), gP)
        e = jax.tree.map(lambda x: jnp.squeeze(x, 0), eP)
        pod = jax.lax.axis_index(axis)
        sent, new_e = dcn_send(g, e, method, topk_frac,
                               jax.random.fold_in(k, pod))
        red = jax.tree.map(lambda x: jax.lax.psum(x, axis), sent)
        return red, jax.tree.map(lambda x: x[None], new_e)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(axis), P(axis), P()),
                   out_specs=(P(), P(axis)), check_rep=False)
    return fn(grads_stacked, error, key)


@functools.lru_cache(maxsize=None)
def _allreduce_fn(mesh: Mesh, axis: str, method: str, topk_frac: float,
                  ndim: int):
    """Build + jit once per (mesh, axis, method, rank): callers invoke
    this every step, so the closure must be cached or each call would
    retrace and recompile."""
    spec = P(axis, *([None] * (ndim - 1)))

    def local(xl, key):
        if method == "int8":
            idx = jax.lax.axis_index(axis)
            xl = _int8_stochastic(xl, jax.random.fold_in(key, idx))
        elif method == "topk":
            xl = _topk(xl, topk_frac)
        return jax.lax.psum(xl, axis)

    return jax.jit(shard_map(local, mesh=mesh, in_specs=(spec, P(None)),
                             out_specs=spec, check_rep=False))


def cross_pod_allreduce(x: jax.Array, mesh: Mesh, axis: str = "pod",
                        method: str = "int8", topk_frac: float = 0.01,
                        key: jax.Array | None = None) -> jax.Array:
    """All-reduce (sum) over one mesh axis with per-shard compression
    applied before the wire — the cheap DCN cross-pod gradient sync.

    ``x`` is sharded over ``axis`` on its leading dim; the result has the
    same sharding with every shard holding the full sum (all-reduce
    semantics), compressed to ~8 bits/element for ``method='int8'``.
    Per-step callers should pass ``key=per_step_key(seed, step)`` for
    fresh rounding noise; with no key, the fixed legacy key is used.
    """
    if method not in ("none", "int8", "topk"):
        raise ValueError(f"unknown compression method: {method}")
    if key is None:
        key = jax.random.PRNGKey(0)
    return _allreduce_fn(mesh, axis, method, topk_frac, x.ndim)(x, key)
