"""Gradient compression for cross-pod sync: stochastic-rounding int8,
magnitude top-k, error-feedback top-k, and a compressed all-reduce.

All compressors are simulate-on-device: they return the *decompressed*
values (same shapes/dtypes as the input) so they compose with any
optimizer; the wire format is implied by the math (int8 codes + one fp32
scale per leaf, or top-k (index, value) pairs).

Stochastic rounding (``floor(x/s + u)``, u ~ U[0,1)) keeps int8
quantization unbiased — E[q·s] = x — so compressed SGD converges like a
noisier uncompressed SGD instead of accumulating rounding bias. Top-k
alone silently drops small coordinates forever; ``topk_ef_compress``
carries the error state so every coordinate is eventually transmitted
(the EF-SGD invariant: sent + new_err == grads + old_err, exactly).

Mesh axes: ``cross_pod_allreduce`` is the only collective here and sums
over exactly one named axis — by convention ``'pod'``, the slow DCN hop
of the multi-pod mesh (``repro.launch.mesh``); the in-graph compressors
(``compress_tree``, ``topk_ef_compress``) are axis-free and run under
any sharding. Degradation/fallback: ``method='none'`` short-circuits to
the identity (resp. a plain psum on the wire path); a size-1 axis makes
the psum a no-op so the code needs no special case; the shard_map
closure is lru-cached per (mesh, axis, method, rank) so per-step calls
never retrace.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def _int8_stochastic(x: jax.Array, key: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.abs(xf).max(), 1e-30) / 127.0
    u = jax.random.uniform(key, xf.shape)
    q = jnp.clip(jnp.floor(xf / scale + u), -127, 127)
    return (q * scale).astype(x.dtype)


def _topk_mask(x: jax.Array, frac: float) -> jax.Array:
    flat = jnp.abs(x.astype(jnp.float32)).reshape(-1)
    k = max(int(round(frac * flat.size)), 1)
    kth = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= kth).astype(x.dtype)


def _topk(x: jax.Array, frac: float) -> jax.Array:
    return x * _topk_mask(x, frac)


def compress_tree(grads, method: str = "int8", topk_frac: float = 0.01,
                  key: jax.Array | None = None):
    """Compress+decompress every leaf. ``method``: none | int8 | topk.

    ``key`` seeds the int8 stochastic rounding (defaults to a fixed key:
    deterministic under jit, still unbiased per element draw)."""
    if method == "none":
        return grads
    if method == "topk":
        return jax.tree.map(lambda g: _topk(g, topk_frac), grads)
    if method != "int8":
        raise ValueError(f"unknown compression method: {method}")
    if key is None:
        key = jax.random.PRNGKey(0)
    leaves, treedef = jax.tree.flatten(grads)
    out = [_int8_stochastic(g, jax.random.fold_in(key, i))
           for i, g in enumerate(leaves)]
    return jax.tree.unflatten(treedef, out)


def init_error_state(grads):
    """Zero error-feedback residuals mirroring the grad tree (fp32)."""
    return jax.tree.map(
        lambda g: jnp.zeros(jnp.shape(g), jnp.float32), grads)


def topk_ef_compress(grads, error_state, topk_frac: float = 0.01):
    """Error-feedback top-k: returns (sent, new_error_state).

    sent + new_error == grads + old_error holds exactly (the masks are
    complementary selections of the same accumulator), which is the
    invariant that makes EF-SGD converge at the uncompressed rate."""
    def one(g, e):
        acc = g.astype(jnp.float32) + e
        mask = _topk_mask(acc, topk_frac)
        return acc * mask, acc * (1.0 - mask)

    pairs = jax.tree.map(one, grads, error_state)
    sent = jax.tree.map(lambda p: p[0], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda p: p[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return sent, err


@functools.lru_cache(maxsize=None)
def _allreduce_fn(mesh: Mesh, axis: str, method: str, topk_frac: float,
                  ndim: int):
    """Build + jit once per (mesh, axis, method, rank): callers invoke
    this every step, so the closure must be cached or each call would
    retrace and recompile."""
    spec = P(axis, *([None] * (ndim - 1)))

    def local(xl, key):
        if method == "int8":
            idx = jax.lax.axis_index(axis)
            xl = _int8_stochastic(xl, jax.random.fold_in(key, idx))
        elif method == "topk":
            xl = _topk(xl, topk_frac)
        return jax.lax.psum(xl, axis)

    return jax.jit(shard_map(local, mesh=mesh, in_specs=(spec, P(None)),
                             out_specs=spec, check_rep=False))


def cross_pod_allreduce(x: jax.Array, mesh: Mesh, axis: str = "pod",
                        method: str = "int8", topk_frac: float = 0.01,
                        key: jax.Array | None = None) -> jax.Array:
    """All-reduce (sum) over one mesh axis with per-shard compression
    applied before the wire — the cheap DCN cross-pod gradient sync.

    ``x`` is sharded over ``axis`` on its leading dim; the result has the
    same sharding with every shard holding the full sum (all-reduce
    semantics), compressed to ~8 bits/element for ``method='int8'``.
    """
    if method not in ("none", "int8", "topk"):
        raise ValueError(f"unknown compression method: {method}")
    if key is None:
        key = jax.random.PRNGKey(0)
    return _allreduce_fn(mesh, axis, method, topk_frac, x.ndim)(x, key)
