"""Distribution substrate: logical-axis sharding, elastic checkpointing,
gradient compression, collective-matmul kernels, and straggler handling.

The sharding model (``repro.dist.sharding``) is logical-axis based: model
code never names mesh axes. Layers annotate params and activations with
*logical* names — ``batch``, ``heads``, ``ff``, ``experts``, ``fsdp``,
``seq_shard``, ... — and a :class:`~repro.dist.sharding.ShardingRules`
table maps each logical name to zero or more *mesh* axes (``pod``,
``data``, ``model``). ``logical_to_spec`` resolves a tuple of logical
names against a concrete mesh into a ``PartitionSpec`` with three
degradation guarantees so one model definition runs on every mesh from a
1-CPU debug host to the 512-chip multi-pod production mesh:

  * **missing mesh axes degrade** — a rule naming ``('pod', 'data')``
    silently drops ``pod`` on a single-pod mesh;
  * **indivisible dims replicate** — a dim not divisible by the mapped
    mesh-axis product falls back to replication rather than erroring;
  * **each mesh axis is used once** — when two tensor dims map to the
    same mesh axis, the later dim replicates (no illegal double-use).

``set_mesh``/``constrain`` give layer code a zero-argument way to apply
sharding constraints: with no mesh set (unit tests, single-device runs)
``constrain`` is the identity, so the same layer code is testable on CPU
and sharded in production. The remaining modules build on this substrate:

  * ``checkpoint`` — atomic step directories, keep-N GC, async save, and
    elastic reshard-on-load (restore into *different* shardings);
  * ``compression`` — stochastic-rounding int8 and error-feedback top-k
    gradient compression, compressed cross-pod all-reduces (single-array
    ``cross_pod_allreduce`` and the train step's stacked-tree
    ``dcn_allreduce_tree``), and the DCN wire-format accounting
    (``tree_wire_bytes``) behind the ``dcn_bytes`` train metric;
  * ``collective_matmul`` — ring reduce / pipelined all-gather matmuls
    that overlap collective steps with compute;
  * ``straggler`` — EWMA step-time spike detection and host heartbeats.
"""

from repro.dist import (
    checkpoint,
    collective_matmul,
    compression,
    sharding,
    straggler,
)

__all__ = [
    "checkpoint",
    "collective_matmul",
    "compression",
    "sharding",
    "straggler",
]
