"""Filesystem checkpointing: atomic step directories, keep-N GC, async
save, integrity validation, and elastic reshard-on-load.

Layout (one directory per step, renamed into place atomically):

    <dir>/step_00000042/arrays.npz   # leaves, insertion order
    <dir>/step_00000042/meta.json    # treedef repr, leaf shapes/dtypes, crc

A torn write only ever leaves a ``step_XXXXXXXX.tmp-*`` directory behind,
which ``list_steps`` ignores. ``restore_latest`` walks steps newest-first
and skips any checkpoint whose CRC or structure does not validate, so a
corrupt newest step degrades to the previous one instead of failing the
job. Passing ``shardings=`` to restore device_puts each leaf into the
given (possibly different-mesh) layout — the elastic resume path.
"""

from __future__ import annotations

import json
import re
import shutil
import threading
import uuid
import zlib
from concurrent.futures import Future, ThreadPoolExecutor
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{8})$")

# numpy-native dtypes serialize directly; anything else (bf16, fp8) is
# stored as a uint8 byte view and re-viewed on load.
_NATIVE_KINDS = "biufc"


def _encode_leaf(x) -> tuple[np.ndarray, dict]:
    arr = np.asarray(jax.device_get(x))
    if not arr.flags["C_CONTIGUOUS"]:
        arr = np.ascontiguousarray(arr)
        arr = arr.reshape(np.shape(x))  # ascontiguousarray promotes 0-d
    meta = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
    if arr.dtype.kind not in _NATIVE_KINDS or arr.dtype.str.startswith("|V"):
        arr = arr.view(np.uint8)
        meta["raw"] = True
    return arr, meta


def _decode_leaf(arr: np.ndarray, meta: dict) -> jnp.ndarray:
    if meta.get("raw"):
        arr = arr.view(jnp.dtype(meta["dtype"])).reshape(meta["shape"])
    return jnp.asarray(arr)


class CheckpointManager:
    """Save/restore pytrees of arrays under a root directory.

    ``keep=N`` garbage-collects all but the newest N steps after each
    save; ``keep=None`` keeps everything. ``save_async`` runs saves on a
    single background thread (serialized, so concurrent calls cannot
    interleave GC with a rename); ``wait()`` drains and re-raises.
    """

    def __init__(self, directory, keep: int | None = None):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._lock = threading.Lock()
        # created eagerly: lazy creation would be a check-then-set race
        # under concurrent first save_async calls (no thread is spawned
        # until the first submit)
        self._executor = ThreadPoolExecutor(max_workers=1,
                                            thread_name_prefix="ckpt")
        self._futures: list[Future] = []

    # -- listing / validation ------------------------------------------------

    def _step_dir(self, step: int) -> Path:
        return self.dir / f"step_{step:08d}"

    def list_steps(self) -> list[int]:
        out = []
        for p in self.dir.iterdir():
            m = _STEP_RE.match(p.name)
            if m and p.is_dir():
                out.append(int(m.group(1)))
        return sorted(out)

    def validate(self, step: int) -> bool:
        """True iff the checkpoint's files parse and the arrays CRC
        matches what was recorded at save time."""
        d = self._step_dir(step)
        try:
            meta = json.loads((d / "meta.json").read_text())
            blob = (d / "arrays.npz").read_bytes()
            if zlib.crc32(blob) != meta["crc32"]:
                return False
            with np.load(d / "arrays.npz") as z:
                return len(z.files) == len(meta["leaves"])
        except Exception:
            return False

    # -- save ----------------------------------------------------------------

    def save(self, step: int, tree) -> None:
        self._write(step, *self._snapshot(tree))

    def _snapshot(self, tree):
        """Materialize the tree on host. MUST run in the caller's thread:
        trainers jit with donated arguments, so the device buffers may be
        invalidated by the very next step — the host copy is the only
        consistent snapshot an async save can rely on."""
        leaves, treedef = jax.tree.flatten(tree)
        return [_encode_leaf(l) for l in leaves], treedef

    def _write(self, step: int, encoded, treedef) -> None:
        meta = {
            "step": step,
            "structure": str(treedef),
            "leaves": [m for _, m in encoded],
        }
        with self._lock:
            tmp = self.dir / f"step_{step:08d}.tmp-{uuid.uuid4().hex[:8]}"
            tmp.mkdir(parents=True)
            try:
                np.savez(tmp / "arrays.npz",
                         **{f"leaf_{i:05d}": a for i, (a, _) in enumerate(encoded)})
                meta["crc32"] = zlib.crc32((tmp / "arrays.npz").read_bytes())
                (tmp / "meta.json").write_text(json.dumps(meta))
                final = self._step_dir(step)
                if final.exists():
                    shutil.rmtree(final)
                tmp.rename(final)
            except BaseException:
                shutil.rmtree(tmp, ignore_errors=True)
                raise
            self._gc()

    def _gc(self) -> None:
        if self.keep is None:
            return
        steps = self.list_steps()
        for s in steps[:max(len(steps) - self.keep, 0)]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def save_async(self, step: int, tree) -> Future:
        encoded, treedef = self._snapshot(tree)  # sync: see _snapshot
        fut = self._executor.submit(self._write, step, encoded, treedef)
        self._futures.append(fut)
        return fut

    def wait(self) -> None:
        futs, self._futures = self._futures, []
        for f in futs:
            f.result()

    # -- restore -------------------------------------------------------------

    def restore(self, step: int, target, shardings=None):
        """Load step ``step`` into the structure of ``target``.

        Raises ValueError if the stored pytree structure or leaf
        shapes/dtypes do not match ``target``. With ``shardings`` (a
        pytree of NamedShardings mirroring ``target``) every leaf is
        device_put into that layout — values are layout-independent, so
        this is the elastic reshard-on-load path.
        """
        d = self._step_dir(step)
        meta = json.loads((d / "meta.json").read_text())
        t_leaves, treedef = jax.tree.flatten(target)
        if meta["structure"] != str(treedef):
            raise ValueError(
                f"checkpoint step {step} structure mismatch:\n"
                f"  saved:  {meta['structure']}\n  target: {treedef}")
        if len(meta["leaves"]) != len(t_leaves):
            raise ValueError("checkpoint leaf count mismatch")
        for i, (m, t) in enumerate(zip(meta["leaves"], t_leaves)):
            tshape = list(np.shape(t))
            tdtype = str(getattr(t, "dtype", np.asarray(t).dtype))
            if m["shape"] != tshape:
                raise ValueError(
                    f"leaf {i}: saved shape {m['shape']} != target {tshape}")
            if m["dtype"] != tdtype:
                raise ValueError(
                    f"leaf {i}: saved dtype {m['dtype']} != target {tdtype}")
        with np.load(d / "arrays.npz") as z:
            leaves = [_decode_leaf(z[f"leaf_{i:05d}"], m)
                      for i, m in enumerate(meta["leaves"])]
        out = jax.tree.unflatten(treedef, leaves)
        if shardings is not None:
            out = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None else x,
                out, shardings)
        return out

    def restore_latest(self, target, shardings=None):
        """(step, tree) from the newest checkpoint that validates and
        matches ``target``'s structure; None if no usable checkpoint."""
        for step in reversed(self.list_steps()):
            if not self.validate(step):
                continue
            try:
                return step, self.restore(step, target, shardings)
            except (ValueError, OSError, KeyError):
                continue
        return None
