"""Straggler detection: EWMA step-time spike monitor + host heartbeats.

A synchronous SPMD job runs at the speed of its slowest participant, so
one degraded host (thermal throttle, flaky NIC, preemption) silently
taxes the whole fleet. The monitor tracks an EWMA of *healthy* step
times — spikes are excluded from the statistics so a straggler cannot
poison its own detection threshold — and escalates WARN -> EVICT after
``consecutive_limit`` consecutive slow steps. The trainer reacts to
EVICT by checkpointing so the job can restart on a reduced/replaced
host set (see launch/train.py).

Mesh axes: none directly — detection is host-side wall-clock logic, so
it works identically on any mesh (a straggler on any of 'pod', 'data'
or 'model' stalls the same synchronous step). Degradation/fallback: the
monitor only *observes*; until EVICT fires it changes nothing about the
job, warmup steps always return OK (compile spikes can't trip it), and
spike samples are excluded from the EWMA so a degraded host cannot
inflate its own threshold. Heartbeats degrade the same way: a missing
host is reported, never fenced here — eviction/re-meshing policy lives
with the trainer and ``CheckpointManager.restore(..., shardings=)``.
"""

from __future__ import annotations

import enum
import time
from typing import Callable


class Action(enum.Enum):
    OK = "ok"
    WARN = "warn"
    EVICT = "evict"


class StragglerMonitor:
    """Per-step wall-time monitor.

    warmup_steps      observations that only build statistics (compile
                      steps, cache warmup) and always return OK
    spike_factor      dt > spike_factor * mean counts as slow
    consecutive_limit slow streak length that triggers EVICT
    ewma_alpha        smoothing for the healthy-step mean
    on_warn/on_evict  callbacks ``(step, dt)``
    """

    def __init__(self, warmup_steps: int = 10, spike_factor: float = 2.0,
                 consecutive_limit: int = 3, ewma_alpha: float = 0.1,
                 on_warn: Callable[[int, float], None] | None = None,
                 on_evict: Callable[[int, float], None] | None = None):
        self.warmup_steps = warmup_steps
        self.spike_factor = spike_factor
        self.consecutive_limit = consecutive_limit
        self.ewma_alpha = ewma_alpha
        self.on_warn = on_warn
        self.on_evict = on_evict
        self.mean: float | None = None
        self.consecutive = 0
        self.count = 0
        self._t0: float | None = None

    def _update_mean(self, dt: float) -> None:
        if self.mean is None:
            self.mean = dt
        else:
            a = self.ewma_alpha
            self.mean = (1.0 - a) * self.mean + a * dt

    def observe(self, dt: float) -> Action:
        self.count += 1
        if self.count <= self.warmup_steps or self.mean is None:
            self._update_mean(dt)
            return Action.OK
        if dt > self.spike_factor * self.mean:
            # slow step: escalate, and do NOT fold into the EWMA
            self.consecutive += 1
            if self.consecutive >= self.consecutive_limit:
                self.consecutive = 0
                if self.on_evict is not None:
                    self.on_evict(self.count, dt)
                return Action.EVICT
            if self.on_warn is not None:
                self.on_warn(self.count, dt)
            return Action.WARN
        self.consecutive = 0
        self._update_mean(dt)
        return Action.OK

    # convenience wall-clock interface used by the trainer loop
    def step_start(self) -> None:
        self._t0 = time.monotonic()

    def step_end(self) -> Action:
        if self._t0 is None:
            return Action.OK
        dt = time.monotonic() - self._t0
        self._t0 = None
        return self.observe(dt)


class HeartbeatRegistry:
    """Dead-host detection by missed heartbeats.

    Hosts call ``beat(host)`` each step; the coordinator calls ``tick()``
    once per step and gets back the hosts whose last beat is at least
    ``timeout_steps`` ticks old.
    """

    def __init__(self, num_hosts: int, timeout_steps: int = 3):
        self.num_hosts = num_hosts
        self.timeout_steps = timeout_steps
        self._tick = 0
        self._last_seen = {h: 0 for h in range(num_hosts)}

    def beat(self, host: int) -> None:
        self._last_seen[host] = self._tick

    def tick(self) -> list[int]:
        self._tick += 1
        return [h for h in range(self.num_hosts)
                if self._tick - self._last_seen[h] >= self.timeout_steps]
