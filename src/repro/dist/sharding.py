"""Logical-axis sharding rules and mesh-global constraint helpers.

See the package docstring (``repro.dist``) for the model. The global
mesh/rules pair set by ``set_mesh`` is what lets layer code call
``constrain(x, "batch", None, "heads", None)`` without threading a mesh
through every function signature; with no mesh set the call is a no-op.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
from typing import Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# a logical axis maps to: no mesh axis (replicate), one mesh axis, or an
# ordered preference of mesh axes (all that exist + divide are used)
Rule = Union[None, str, tuple]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical-axis -> mesh-axis mapping (the GSPMD "logical axis rules"
    idiom). Field names are the logical axes used by ``repro.models``."""

    batch: Rule = ("pod", "data")      # data-parallel batch dim
    dcn_pod: Rule = "pod"              # stacked per-pod dim (grads/EF state)
    fsdp: Rule = "data"                # FSDP-sharded param dim
    heads: Rule = "model"              # attention query heads (TP)
    kv_heads: Rule = "model"           # attention kv heads (TP)
    ff: Rule = "model"                 # FFN hidden dim (TP)
    experts: Rule = "model"            # MoE expert dim (EP)
    vocab: Rule = "model"              # embedding/unembed vocab dim
    seq: Rule = None                   # sequence dim (context parallelism)
    seq_shard: Rule = "model"          # TP sequence-parallel activations
    kv_seq: Rule = None                # KV-cache sequence dim
    layer: Rule = None                 # stacked-layer leading dim

    def lookup(self, name: str) -> Rule:
        return getattr(self, name)

    def replace(self, **kw) -> "ShardingRules":
        return dataclasses.replace(self, **kw)


DEFAULT_RULES = ShardingRules()

RULE_PRESETS = {
    "default": DEFAULT_RULES,
    # pure FSDP: no tensor/expert parallelism, weights sharded over 'data'
    "fsdp_only": ShardingRules(heads=None, kv_heads=None, ff=None,
                               experts=None, vocab=None, seq_shard=None),
}

_STATE: dict = {"mesh": None, "rules": DEFAULT_RULES}


def set_mesh(mesh: Mesh | None, rules: ShardingRules | None = None) -> None:
    """Install the process-global mesh (+ optional rules) used by
    ``constrain``. ``set_mesh(None)`` returns to single-device no-op mode."""
    _STATE["mesh"] = mesh
    _STATE["rules"] = rules or DEFAULT_RULES


def get_mesh() -> Mesh | None:
    return _STATE["mesh"]


def pod_axis_size(mesh: Mesh | None) -> int:
    """Size of the 'pod' (DCN) axis of a mesh, 1 when absent / no mesh."""
    if mesh is None:
        return 1
    return dict(mesh.shape).get("pod", 1)


def get_rules() -> ShardingRules:
    return _STATE["rules"]


def without_axis(rule: Rule, axis: str) -> Rule:
    """Drop one mesh axis from a rule (None/str/tuple all handled)."""
    if rule is None:
        return None
    if isinstance(rule, str):
        return None if rule == axis else rule
    kept = tuple(a for a in rule if a != axis)
    return kept or None


@contextlib.contextmanager
def rules_override(**kw):
    """Temporarily replace rule fields on the installed global rules.

    Trace-time scoping tool: the hierarchical train step vmaps the model
    over a stacked per-pod dim whose slices must resolve ``batch`` against
    the ICI axes only (the ``pod`` axis is consumed by the stacking dim),
    so it traces the per-pod body under ``rules_override(batch=...)``.
    """
    old = _STATE["rules"]
    _STATE["rules"] = old.replace(**kw)
    try:
        yield _STATE["rules"]
    finally:
        _STATE["rules"] = old


def baseline_mode() -> bool:
    """REPRO_BASELINE=1 disables the tuned sharding-constraint placements
    (perf A/B lever; see models/transformer.py)."""
    return os.environ.get("REPRO_BASELINE", "0") == "1"


def logical_to_spec(axes: tuple, shape: tuple, mesh: Mesh,
                    rules: ShardingRules | None = None) -> PartitionSpec:
    """Resolve logical axis names against a mesh into a PartitionSpec.

    Degradation, per dim: mesh axes absent from the mesh are dropped; a
    mesh axis already consumed by an earlier dim is dropped; a dim not
    divisible by the accumulated mesh-axis product stops accumulating
    (possibly at zero axes = replicated).
    """
    rules = rules or get_rules()
    used: set[str] = set()
    entries = []
    for name, dim in zip(axes, shape):
        rule = rules.lookup(name) if name else None
        if rule is None:
            entries.append(None)
            continue
        cands = (rule,) if isinstance(rule, str) else tuple(rule)
        picked = []
        prod = 1
        for c in cands:
            if c not in mesh.shape or c in used:
                continue
            if dim % (prod * mesh.shape[c]) != 0:
                continue
            picked.append(c)
            prod *= mesh.shape[c]
        used.update(picked)
        if not picked:
            entries.append(None)
        elif len(picked) == 1:
            entries.append(picked[0])
        else:
            entries.append(tuple(picked))
    return PartitionSpec(*entries)


def logical_to_sharding(axes: tuple, shape: tuple, mesh: Mesh,
                        rules: ShardingRules | None = None) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(axes, shape, mesh, rules))


def is_axes_leaf(x) -> bool:
    """True for a logical-axes tuple leaf like ('batch', None, 'heads') —
    the ``is_leaf`` predicate for mapping over axes pytrees."""
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None)))
                                        for e in x)


_leaf_axes = is_axes_leaf  # internal alias, kept for existing callers


def tree_shardings(axes_tree, shapes_tree, mesh: Mesh,
                   rules: ShardingRules | None = None):
    """Map a pytree of logical-axis tuples + a matching pytree of arrays /
    ShapeDtypeStructs to a pytree of NamedShardings."""
    return jax.tree.map(
        lambda ax, s: logical_to_sharding(ax, tuple(s.shape), mesh, rules),
        axes_tree, shapes_tree, is_leaf=_leaf_axes)


def constrain(x: jax.Array, *axes) -> jax.Array:
    """with_sharding_constraint against the global mesh; identity when no
    mesh is set (single-device tests)."""
    mesh = get_mesh()
    if mesh is None:
        return x
    sh = logical_to_sharding(tuple(axes), tuple(x.shape), mesh)
    return jax.lax.with_sharding_constraint(x, sh)
