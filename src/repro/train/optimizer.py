"""AdamW with global-norm clipping and linear-warmup cosine schedule.

Self-contained (no optax dependency); states live on the same shardings as
their parameters so optimizer memory scales down with FSDP.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def adamw_init(params: Any) -> dict:
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adamw_update(cfg: AdamWConfig, params: Any, grads: Any, state: dict
                 ) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / bc1
        nhat = nu / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * p
        return (p - lr * delta).astype(p.dtype), mu, nu

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, metrics
