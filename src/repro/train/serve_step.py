"""Serving step factories: prefill (prompt -> logits + cache) and decode
(one token against the cache). These are what the decode_* / long_* dry-run
shapes lower."""

from __future__ import annotations

from typing import Callable

from repro.models.model_zoo import Model


def make_prefill(model: Model) -> Callable:
    def prefill(params, batch, cache):
        logits, cache = model.prefill(params, batch, cache)
        return logits[:, -1:], cache
    return prefill


def make_decode_step(model: Model) -> Callable:
    def decode_step(params, token, cache, pos):
        logits, cache = model.decode_step(params, token, cache, pos)
        return logits, cache
    return decode_step
