from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.train.serve_step import make_decode_step, make_prefill
from repro.train.train_step import (
    TrainConfig,
    TrainState,
    init_train_state,
    make_train_step,
)

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update",
    "TrainConfig", "TrainState", "make_train_step", "init_train_state",
    "make_prefill", "make_decode_step",
]
