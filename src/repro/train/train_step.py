"""Train step factory: loss + grad + AdamW, with microbatch gradient
accumulation, remat policy, optional gradient compression, and logical-axis
output shardings — the single step function that both the real trainer and
the multi-pod dry-run lower.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.model_zoo import Model
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    remat: str = "full"              # full | dots | none
    microbatches: int = 1            # gradient accumulation
    grad_compression: str = "none"   # none | int8 | topk (dist/compression)
    # cast fp32 master params to bf16 *before* the FSDP all-gather so the
    # gather moves half the bytes (mixed-precision training; §Perf lever).
    cast_params_bf16: bool = False


@dataclasses.dataclass
class TrainState:
    params: Params
    opt: dict
    step: jax.Array

jax.tree_util.register_dataclass(
    TrainState, data_fields=["params", "opt", "step"], meta_fields=[])


def init_train_state(model: Model, key: jax.Array) -> tuple[TrainState, Params]:
    params, axes = model.init(key)
    return TrainState(params=params, opt=adamw_init(params),
                      step=jnp.zeros((), jnp.int32)), axes


def abstract_train_state(model: Model) -> tuple[TrainState, Any]:
    """ShapeDtypeStruct TrainState + axes, no allocation (dry-run path)."""
    pshapes, axes = model.abstract_params()
    opt = jax.eval_shape(adamw_init, pshapes)
    state = TrainState(params=pshapes, opt=opt,
                       step=jax.ShapeDtypeStruct((), jnp.int32))
    return state, axes


def state_axes(axes: Params) -> TrainState:
    """Logical axes pytree matching TrainState (mu/nu mirror params)."""
    return TrainState(
        params=axes,
        opt={"mu": axes, "nu": axes, "step": ()},
        step=(),
    )


def make_train_step(model: Model, tcfg: TrainConfig) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        if tcfg.cast_params_bf16:
            params = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if (p.dtype == jnp.float32 and p.ndim > 1) else p, params)
        return model.loss(params, batch, remat=tcfg.remat)

    def compute_grads(params, batch):
        if tcfg.microbatches <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        mb = tcfg.microbatches

        def split(x):
            b = x.shape[0]
            assert b % mb == 0, (b, mb)
            return x.reshape(mb, b // mb, *x.shape[1:])

        batches = jax.tree.map(split, batch)

        def body(carry, mbatch):
            loss_acc, grad_acc = carry
            l, g = jax.value_and_grad(loss_fn)(params, mbatch)
            return (loss_acc + l, jax.tree.map(jnp.add, grad_acc, g)), None

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zero), batches)
        inv = 1.0 / mb
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        loss, grads = compute_grads(state.params, batch)
        if tcfg.grad_compression != "none":
            from repro.dist.compression import compress_tree
            grads = compress_tree(grads, method=tcfg.grad_compression)
        params, opt, metrics = adamw_update(
            tcfg.optimizer, state.params, grads, state.opt)
        metrics = dict(metrics, loss=loss)
        return TrainState(params=params, opt=opt, step=state.step + 1), metrics

    return train_step
