"""Train step factory: loss + grad + AdamW, with microbatch gradient
accumulation, remat policy, hierarchical ICI/DCN gradient reduction with
optional wire compression, and logical-axis output shardings — the single
step function that both the real trainer and the multi-pod dry-run lower.

Reduction contract
------------------
With ``dcn_compression='none'`` and no explicitly requested pod split
(``dcn_pods`` 0 or 1 — the default, on any mesh) the step is the classic
global path: one AD pass over the full batch, XLA inserts whatever
all-reduces GSPMD needs. An uncompressed hierarchy would cost
collective-buffer memory for zero wire savings, so it is never engaged
implicitly.

Otherwise the data-parallel reduction is split into a two-level
hierarchy: the global batch is stacked into P per-pod slices, each pod
computes its *own* gradients (grads arrive pre-psum per pod-slice — the
in-pod reduction runs uncompressed over ICI), each pod's payload is
compressed (``repro.dist.compression.dcn_send``), and only the
compressed payload crosses the ``pod`` axis (DCN). Two routes share that
math:

* **emulated** (any device count, incl. the 1-CPU test tier): a
  ``lax.scan`` over pod slices that accumulates compressed sends in pod
  order — with ``dcn_compression='none'`` this is *bit-identical* to the
  pre-existing microbatch-accumulation path with ``microbatches=P``
  (same slicing, same left-fold adds, same 1/P scaling).
* **shard_map** (mesh has a ``pod`` axis of size P): per-pod grads via
  ``vmap`` over the stacked dim (so in-pod GSPMD sharding still applies
  inside each slice), then ``repro.dist.compression.dcn_allreduce_tree``
  performs the compressed psum over ``'pod'`` only.

``topk_ef`` carries a per-pod error-feedback residual tree in
``TrainState.ef`` (leaves ``(P, *param_shape)``, sharded over ``pod``,
checkpointed with the rest of the state) so compression is unbiased
across steps: sent + new_err == grads + old_err exactly, every step.
Stochastic int8 rounding keys fold in both ``TrainState.step`` and the
pod index, so noise decorrelates across steps *and* pods. Degradation:
with a size-1 ``pod`` axis (or no mesh) the hierarchy collapses to the
emulated route with P=1, whose fold is exact — compression still
applies, the DCN hop is simply free.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.dist.compression import (
    DCN_METHODS,
    compress_tree,
    dcn_allreduce_tree,
    dcn_send,
    per_step_key,
    tree_wire_bytes,
)
from repro.dist.sharding import (
    get_mesh,
    get_rules,
    is_axes_leaf,
    logical_to_sharding,
    pod_axis_size,
    rules_override,
    without_axis,
)
from repro.models.model_zoo import Model
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    remat: str = "full"              # full | dots | none
    microbatches: int = 1            # gradient accumulation (within a pod)
    grad_compression: str = "none"   # legacy in-graph simulation applied to
    #                                  the *reduced* grads (none | int8 | topk)
    # cast fp32 master params to bf16 *before* the FSDP all-gather so the
    # gather moves half the bytes (mixed-precision training; §Perf lever).
    cast_params_bf16: bool = False
    # hierarchical ICI/DCN reduction (the real cross-pod wire path):
    dcn_compression: str = "none"    # none | int8 | topk | topk_ef
    dcn_pods: int = 0                # per-pod slices; 0 = auto from the
    #                                  mesh's 'pod' axis (1 when absent)
    dcn_topk_frac: float = 0.01
    seed: int = 0                    # base of the per-step rounding key


@dataclasses.dataclass
class TrainState:
    params: Params
    opt: dict
    step: jax.Array
    ef: Any = dataclasses.field(default_factory=dict)  # per-pod EF residuals

jax.tree_util.register_dataclass(
    TrainState, data_fields=["params", "opt", "step", "ef"], meta_fields=[])


def resolve_pods(tcfg: TrainConfig, mesh=None) -> int:
    """Effective pod count: explicit ``dcn_pods``, or (when 0) the size of
    the installed mesh's ``pod`` axis (1 with no mesh / no pod axis)."""
    if tcfg.dcn_pods > 0:
        return tcfg.dcn_pods
    return pod_axis_size(mesh if mesh is not None else get_mesh())


def _uses_hierarchy(tcfg: TrainConfig) -> bool:
    """The hierarchy only engages when it buys something: compression on
    the DCN hop, or an *explicitly requested* pod split. With the
    defaults (``dcn_compression='none'``, ``dcn_pods=0``) a multi-pod
    mesh keeps the pre-hierarchy global GSPMD reduction — an
    uncompressed shard_map hop would cost collective-buffer memory for
    zero wire savings."""
    return tcfg.dcn_compression != "none" or tcfg.dcn_pods > 1


def init_ef_state(params: Params, tcfg: TrainConfig | None,
                  mesh=None) -> Any:
    """Per-pod error-feedback residuals: ``(P, *shape)`` fp32 zeros when
    ``dcn_compression`` carries state, else ``{}`` (an empty pytree)."""
    if tcfg is None or tcfg.dcn_compression != "topk_ef":
        return {}
    pods = resolve_pods(tcfg, mesh)
    return jax.tree.map(
        lambda p: jnp.zeros((pods, *jnp.shape(p)), jnp.float32), params)


def init_train_state(model: Model, key: jax.Array,
                     tcfg: TrainConfig | None = None,
                     mesh=None) -> tuple[TrainState, Params]:
    params, axes = model.init(key)
    return TrainState(params=params, opt=adamw_init(params),
                      step=jnp.zeros((), jnp.int32),
                      ef=init_ef_state(params, tcfg, mesh)), axes


def abstract_train_state(model: Model, tcfg: TrainConfig | None = None,
                         mesh=None) -> tuple[TrainState, Any]:
    """ShapeDtypeStruct TrainState + axes, no allocation (dry-run path)."""
    pshapes, axes = model.abstract_params()
    opt = jax.eval_shape(adamw_init, pshapes)
    ef = jax.eval_shape(lambda p: init_ef_state(p, tcfg, mesh), pshapes)
    state = TrainState(params=pshapes, opt=opt,
                       step=jax.ShapeDtypeStruct((), jnp.int32), ef=ef)
    return state, axes


def state_axes(axes: Params, tcfg: TrainConfig | None = None) -> TrainState:
    """Logical axes pytree matching TrainState (mu/nu mirror params; EF
    residuals mirror params behind a leading per-pod ``dcn_pod`` dim)."""
    ef_axes: Any = {}
    if tcfg is not None and tcfg.dcn_compression == "topk_ef":
        ef_axes = jax.tree.map(lambda a: ("dcn_pod", *a), axes,
                               is_leaf=is_axes_leaf)
    return TrainState(
        params=axes,
        opt={"mu": axes, "nu": axes, "step": ()},
        step=(),
        ef=ef_axes,
    )


def make_train_step(model: Model, tcfg: TrainConfig,
                    mesh=None) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    The returned function carries a ``dcn_route`` attribute naming the
    reduction path it was built for: ``'global'`` (pre-hierarchy GSPMD
    reduction), ``'emulated'`` (in-graph pod fold), or ``'shard_map'``
    (real ``pod``-axis collective via ``dcn_allreduce_tree``)."""
    if tcfg.dcn_compression not in DCN_METHODS:
        raise ValueError(
            f"unknown dcn_compression: {tcfg.dcn_compression}")
    mesh = mesh if mesh is not None else get_mesh()
    pods = resolve_pods(tcfg, mesh)
    if _uses_hierarchy(tcfg):
        route = ("shard_map" if pods > 1 and pod_axis_size(mesh) == pods
                 else "emulated")
    else:
        route = "global"
        pods = 1

    def loss_fn(params, batch):
        if tcfg.cast_params_bf16:
            params = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if (p.dtype == jnp.float32 and p.ndim > 1) else p, params)
        return model.loss(params, batch, remat=tcfg.remat)

    def _split(x, n):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])

    def compute_grads(params, batch):
        """Pod-local (or global-path) grads: one AD pass, or the
        microbatch-accumulation scan when ``microbatches > 1``."""
        if tcfg.microbatches <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        mb = tcfg.microbatches

        batches = jax.tree.map(lambda x: _split(x, mb), batch)

        def body(carry, mbatch):
            loss_acc, grad_acc = carry
            l, g = jax.value_and_grad(loss_fn)(params, mbatch)
            return (loss_acc + l, jax.tree.map(jnp.add, grad_acc, g)), None

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zero), batches)
        inv = 1.0 / mb
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def hier_grads_emulated(params, batch, ef, key):
        """Per-pod grads + compressed reduce as one in-graph left-fold —
        pod order matches the microbatch scan, so with
        ``dcn_compression='none'`` this is bit-identical to the global
        path with ``microbatches=pods``."""
        batches = jax.tree.map(lambda x: _split(x, pods), batch)
        keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(
            jnp.arange(pods))
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, xs):
            loss_acc, grad_acc = carry
            pod_batch, ef_p, key_p = xs
            l, g = compute_grads(params, pod_batch)
            sent, new_ef_p = dcn_send(g, ef_p, tcfg.dcn_compression,
                                      tcfg.dcn_topk_frac, key_p)
            return (loss_acc + l,
                    jax.tree.map(jnp.add, grad_acc, sent)), new_ef_p

        (loss, gsum), new_ef = jax.lax.scan(
            body, (jnp.zeros(()), zero), (batches, ef, keys))
        inv = 1.0 / pods
        return loss * inv, jax.tree.map(lambda g: g * inv, gsum), new_ef

    def hier_grads_shardmap(params, batch, ef, key):
        """Per-pod grads via vmap over the stacked dim (in-pod GSPMD
        sharding stays live inside each slice), compressed psum over the
        ``pod`` axis only — the DCN hop carries compressed payloads."""
        batches = jax.tree.map(lambda x: _split(x, pods), batch)
        batches = jax.tree.map(
            lambda x: jax.lax.with_sharding_constraint(
                x, logical_to_sharding(
                    ("dcn_pod", "batch") + (None,) * (x.ndim - 2),
                    tuple(x.shape), mesh)), batches)
        # inside a pod slice, 'batch' must resolve to ICI axes only — the
        # pod axis is already consumed by the stacking dim
        with rules_override(batch=without_axis(get_rules().batch, "pod")):
            losses, grads_p = jax.vmap(compute_grads, in_axes=(None, 0))(
                params, batches)
        red, new_ef = dcn_allreduce_tree(
            grads_p, ef, mesh, axis="pod", method=tcfg.dcn_compression,
            topk_frac=tcfg.dcn_topk_frac, key=key)
        inv = 1.0 / pods
        return (jnp.sum(losses) * inv,
                jax.tree.map(lambda g: g * inv, red), new_ef)

    hier_grads = (hier_grads_shardmap if route == "shard_map"
                  else hier_grads_emulated)

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        if route == "global":
            loss, grads = compute_grads(state.params, batch)
            new_ef = state.ef
            dcn_bytes = 0
        else:
            key = per_step_key(tcfg.seed, state.step)
            loss, grads, new_ef = hier_grads(state.params, batch,
                                             state.ef, key)
            dcn_bytes = tree_wire_bytes(grads, tcfg.dcn_compression,
                                        tcfg.dcn_topk_frac)
        raw_bytes = tree_wire_bytes(grads, "none")
        if tcfg.grad_compression != "none":
            # distinct stream from the DCN pod keys (pod indices < pods)
            legacy_key = jax.random.fold_in(
                per_step_key(tcfg.seed, state.step), 0x7e6)
            grads = compress_tree(grads, method=tcfg.grad_compression,
                                  key=legacy_key)
        params, opt, metrics = adamw_update(
            tcfg.optimizer, state.params, grads, state.opt)
        metrics = dict(metrics, loss=loss,
                       dcn_bytes=jnp.float32(dcn_bytes),
                       dcn_raw_bytes=jnp.float32(raw_bytes))
        return TrainState(params=params, opt=opt, step=state.step + 1,
                          ef=new_ef), metrics

    train_step.dcn_route = route
    train_step.dcn_pods = pods
    return train_step
