"""Recurrent sequence-mixing layers: Mamba-style selective SSM (Hymba),
mLSTM and sLSTM (xLSTM). All are linear recurrences executed *chunkwise*:
``lax.scan`` over fixed-size time chunks carrying the recurrent state, with
parallel (attention-like or associative-scan) math inside each chunk — the
TPU-native adaptation of these GPU kernels (DESIGN.md §2).

Each layer exposes:
  init_*           -> (params, axes)
  *_train          -> full-sequence forward (chunked recurrence)
  *_decode         -> single-token step against an explicit state
  init_*_state     -> zero state for decoding

States are bounded (O(d * state) per layer), which is what makes the
long_500k decode shape feasible for the ssm/hybrid archs.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Mamba-style selective SSM (diagonal A), used by Hymba's SSM heads
# ---------------------------------------------------------------------------

def init_mamba(key: jax.Array, cfg: ArchConfig) -> tuple[Params, Params]:
    d, n = cfg.d_model, cfg.ssm_state
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    s = d ** -0.5
    p = {
        "w_in": jax.random.normal(k1, (d, 2 * d), jnp.float32) * s,   # x, z
        "w_b": jax.random.normal(k2, (d, n), jnp.float32) * s,
        "w_c": jax.random.normal(k3, (d, n), jnp.float32) * s,
        "w_dt": jax.random.normal(k4, (d, 1), jnp.float32) * s,
        "a_log": jnp.log(jnp.linspace(1.0, float(n), n))[None, :]
                 * jnp.ones((d, 1), jnp.float32),                      # (d, n)
        "d_skip": jnp.ones((d,), jnp.float32),
        "w_out": jax.random.normal(k5, (d, d), jnp.float32) * s,
        "dt_bias": jax.random.uniform(k6, (d,), jnp.float32, -4.0, -2.0),
    }
    a = {
        "w_in": ("fsdp", "ff"), "w_b": ("fsdp", None), "w_c": ("fsdp", None),
        "w_dt": ("fsdp", None), "a_log": (None, None), "d_skip": (None,),
        "w_out": ("fsdp", None), "dt_bias": (None,),
    }
    return p, a


def _mamba_scan_chunk(h0, xb, dtb, Bb, Cb, a):
    """One chunk of the diagonal-SSM recurrence via associative scan.

    h0:  (B, d, n) carry;  xb/dtb: (B, T, d);  Bb/Cb: (B, T, n); a: (d, n)
    h_t = exp(dt_t * a) * h_{t-1} + dt_t * B_t * x_t ;  y_t = C_t . h_t
    """
    decay = jnp.exp(dtb[..., None] * a)                    # (B,T,d,n)
    inp = (dtb * xb)[..., None] * Bb[:, :, None, :]        # (B,T,d,n)

    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    aa, bb = jax.lax.associative_scan(comb, (decay, inp), axis=1)
    h = aa * h0[:, None] + bb                              # (B,T,d,n)
    y = jnp.einsum("btdn,btn->btd", h, Cb)
    return h[:, -1], y


def mamba_train(p: Params, x: jax.Array, cfg: ArchConfig, chunk: int = 64
                ) -> jax.Array:
    dt_ = x.dtype
    b, s, d = x.shape
    xz = x @ p["w_in"].astype(dt_)
    xi, z = jnp.split(xz, 2, axis=-1)
    xi_f = xi.astype(jnp.float32)
    Bt = (x @ p["w_b"].astype(dt_)).astype(jnp.float32)
    Ct = (x @ p["w_c"].astype(dt_)).astype(jnp.float32)
    dt = jax.nn.softplus(
        (x @ p["w_dt"].astype(dt_)).astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])                                # (d, n) negative

    c = min(chunk, s)
    assert s % c == 0
    nch = s // c
    xs = (xi_f.reshape(b, nch, c, d).swapaxes(0, 1),
          dt.reshape(b, nch, c, d).swapaxes(0, 1),
          Bt.reshape(b, nch, c, -1).swapaxes(0, 1),
          Ct.reshape(b, nch, c, -1).swapaxes(0, 1))

    def body(h, xs_c):
        xb, dtb, Bb, Cb = xs_c
        h, y = _mamba_scan_chunk(h, xb, dtb, Bb, Cb, a)
        return h, y

    h0 = jnp.zeros((b, d, cfg.ssm_state), jnp.float32)
    _, ys = jax.lax.scan(body, h0, xs)
    y = ys.swapaxes(0, 1).reshape(b, s, d)
    y = y + xi_f * p["d_skip"]
    y = (y.astype(dt_) * jax.nn.silu(z))
    return y @ p["w_out"].astype(dt_)


@dataclasses.dataclass
class MambaState:
    h: jax.Array  # (B, d, n) float32

jax.tree_util.register_dataclass(MambaState, data_fields=["h"], meta_fields=[])


def init_mamba_state(cfg: ArchConfig, batch: int) -> MambaState:
    return MambaState(h=jnp.zeros((batch, cfg.d_model, cfg.ssm_state),
                                  jnp.float32))


def mamba_decode(p: Params, x: jax.Array, cfg: ArchConfig, state: MambaState
                 ) -> tuple[jax.Array, MambaState]:
    """x: (B, 1, D)."""
    dt_ = x.dtype
    xz = x @ p["w_in"].astype(dt_)
    xi, z = jnp.split(xz, 2, axis=-1)
    xi_f = xi[:, 0].astype(jnp.float32)                     # (B, d)
    Bt = (x @ p["w_b"].astype(dt_))[:, 0].astype(jnp.float32)
    Ct = (x @ p["w_c"].astype(dt_))[:, 0].astype(jnp.float32)
    dt = jax.nn.softplus(
        (x @ p["w_dt"].astype(dt_))[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt[..., None] * a)                      # (B,d,n)
    h = state.h * decay + (dt * xi_f)[..., None] * Bt[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, Ct) + xi_f * p["d_skip"]
    y = (y[:, None].astype(dt_) * jax.nn.silu(z))
    return y @ p["w_out"].astype(dt_), MambaState(h=h)


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory block)
# ---------------------------------------------------------------------------

def _mlstm_dims(cfg: ArchConfig) -> tuple[int, int, int]:
    d_inner = 2 * cfg.d_model
    h = cfg.num_heads
    return d_inner, h, d_inner // h


def init_mlstm(key: jax.Array, cfg: ArchConfig) -> tuple[Params, Params]:
    d = cfg.d_model
    d_inner, h, dh = _mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    s, si = d ** -0.5, d_inner ** -0.5
    p = {
        "w_up": jax.random.normal(ks[0], (d, 2 * d_inner), jnp.float32) * s,
        "w_q": jax.random.normal(ks[1], (d_inner, h, dh), jnp.float32) * si,
        "w_k": jax.random.normal(ks[2], (d_inner, h, dh), jnp.float32) * si,
        "w_v": jax.random.normal(ks[3], (d_inner, h, dh), jnp.float32) * si,
        "w_i": jax.random.normal(ks[4], (d_inner, h), jnp.float32) * si,
        "w_f": jax.random.normal(ks[5], (d_inner, h), jnp.float32) * si,
        "f_bias": jnp.full((h,), 3.0, jnp.float32),  # open forget gates
        "w_down": jax.random.normal(ks[6], (d_inner, d), jnp.float32) * si,
    }
    a = {
        "w_up": ("fsdp", "ff"),
        "w_q": (None, "heads", None), "w_k": (None, "heads", None),
        "w_v": (None, "heads", None),
        "w_i": (None, "heads"), "w_f": (None, "heads"),
        "f_bias": (None,),
        "w_down": ("ff", "fsdp"),
    }
    return p, a


def mlstm_train(p: Params, x: jax.Array, cfg: ArchConfig, chunk: int = 256
                ) -> jax.Array:
    """Chunkwise-parallel mLSTM with sigmoid forget gates.

    Within a chunk: decay-weighted attention-like scores; across chunks: the
    (C, n) matrix/normalizer state is carried by lax.scan. Sigmoid f <= 1
    keeps cumulative decays in (0, 1] so no max-stabilizer is needed.
    """
    dt_ = x.dtype
    b, s, d = x.shape
    d_inner, h, dh = _mlstm_dims(cfg)
    up = x @ p["w_up"].astype(dt_)
    xi, z = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bsd,dhk->bshk", xi, p["w_q"].astype(dt_)).astype(jnp.float32)
    k = jnp.einsum("bsd,dhk->bshk", xi, p["w_k"].astype(dt_)).astype(jnp.float32)
    v = jnp.einsum("bsd,dhk->bshk", xi, p["w_v"].astype(dt_)).astype(jnp.float32)
    xf = xi.astype(jnp.float32)
    ig = jnp.exp(jnp.clip(jnp.einsum("bsd,dh->bsh", xf, p["w_i"]), -10., 5.))
    fg = jax.nn.sigmoid(jnp.einsum("bsd,dh->bsh", xf, p["w_f"]) + p["f_bias"])
    q = q * dh ** -0.5

    c = min(chunk, s)
    assert s % c == 0
    nch = s // c
    resh = lambda t: t.reshape(b, nch, c, *t.shape[2:]).swapaxes(0, 1)
    qs, ks, vs, is_, fs_ = map(resh, (q, k, v, ig, fg))

    def body(carry, xs_c):
        C, n = carry                      # (b,h,dh,dh), (b,h,dh)
        qb, kb, vb, ib, fb = xs_c         # (b,c,h,*)
        logf = jnp.log(jnp.maximum(fb, 1e-9))               # (b,c,h)
        F = jnp.cumsum(logf, axis=1)                        # prod f_1..t
        # intra-chunk decay matrix D[t, u] = exp(F_t - F_u) * i_u for u <= t
        Ft = F[:, :, None, :]
        Fu = F[:, None, :, :]
        mask = (jnp.arange(c)[:, None] >= jnp.arange(c)[None, :])[None, :, :, None]
        D = jnp.where(mask, jnp.exp(Ft - Fu) * ib[:, None, :, :], 0.0)  # (b,t,u,h)
        scores = jnp.einsum("bthk,buhk->btuh", qb, kb) * D
        h_intra = jnp.einsum("btuh,buhk->bthk", scores, vb)
        # inter-chunk: contribution of the carried state, decayed by f_1..f_t
        decay_t = jnp.exp(F)                                # (b,c,h)
        h_inter = jnp.einsum("bthk,bhkl,bth->bthl", qb, C, decay_t)
        n_inter = jnp.einsum("bthk,bhk,bth->bth", qb, n, decay_t)
        # normalizer: n_t = q_t . (sum_u D[t,u] k_u) + carried part
        nk = jnp.einsum("btuh,buhk->bthk", D, kb)
        n_t = jnp.einsum("bthk,bthk->bth", qb, nk) + n_inter
        h_t = h_intra + h_inter
        denom = jnp.maximum(jnp.abs(n_t), 1.0)[..., None]
        out = h_t / denom
        # state update
        FT = F[:, -1, :]                                    # (b,h)
        wk = jnp.exp(FT[:, None, :] - F) * ib               # (b,c,h)
        C_new = C * jnp.exp(FT)[..., None, None] + jnp.einsum(
            "buhk,buhl,buh->bhkl", kb, vb, wk)
        n_new = n * jnp.exp(FT)[..., None] + jnp.einsum(
            "buhk,buh->bhk", kb, wk)
        return (C_new, n_new), out

    C0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, h, dh), jnp.float32)
    _, outs = jax.lax.scan(body, (C0, n0), (qs, ks, vs, is_, fs_))
    out = outs.swapaxes(0, 1).reshape(b, s, h * dh).astype(dt_)
    out = out * jax.nn.silu(z)
    return out @ p["w_down"].astype(dt_)


@dataclasses.dataclass
class MLSTMState:
    C: jax.Array  # (B, H, dh, dh)
    n: jax.Array  # (B, H, dh)

jax.tree_util.register_dataclass(MLSTMState, data_fields=["C", "n"],
                                 meta_fields=[])


def init_mlstm_state(cfg: ArchConfig, batch: int) -> MLSTMState:
    _, h, dh = _mlstm_dims(cfg)
    return MLSTMState(C=jnp.zeros((batch, h, dh, dh), jnp.float32),
                      n=jnp.zeros((batch, h, dh), jnp.float32))


def mlstm_decode(p: Params, x: jax.Array, cfg: ArchConfig, state: MLSTMState
                 ) -> tuple[jax.Array, MLSTMState]:
    dt_ = x.dtype
    b = x.shape[0]
    d_inner, h, dh = _mlstm_dims(cfg)
    up = x @ p["w_up"].astype(dt_)
    xi, z = jnp.split(up, 2, axis=-1)
    xf = xi[:, 0].astype(jnp.float32)
    q = jnp.einsum("bd,dhk->bhk", xf, p["w_q"].astype(jnp.float32)) * dh ** -0.5
    k = jnp.einsum("bd,dhk->bhk", xf, p["w_k"].astype(jnp.float32))
    v = jnp.einsum("bd,dhk->bhk", xf, p["w_v"].astype(jnp.float32))
    ig = jnp.exp(jnp.clip(xf @ p["w_i"], -10., 5.))          # (b,h)
    fg = jax.nn.sigmoid(xf @ p["w_f"] + p["f_bias"])
    C = state.C * fg[..., None, None] + ig[..., None, None] * jnp.einsum(
        "bhk,bhl->bhkl", k, v)
    n = state.n * fg[..., None] + ig[..., None] * k
    num = jnp.einsum("bhk,bhkl->bhl", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q, n)), 1.0)
    out = (num / den[..., None]).reshape(b, 1, h * dh).astype(dt_)
    out = out * jax.nn.silu(z)
    return out @ p["w_down"].astype(dt_), MLSTMState(C=C, n=n)


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar-memory block) — elementwise linear recurrence
# ---------------------------------------------------------------------------

def init_slstm(key: jax.Array, cfg: ArchConfig) -> tuple[Params, Params]:
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    s = d ** -0.5
    p = {
        "w_z": jax.random.normal(ks[0], (d, d), jnp.float32) * s,
        "w_i": jax.random.normal(ks[1], (d, d), jnp.float32) * s,
        "w_f": jax.random.normal(ks[2], (d, d), jnp.float32) * s,
        "w_o": jax.random.normal(ks[3], (d, d), jnp.float32) * s,
        "f_bias": jnp.full((d,), 3.0, jnp.float32),
        "w_down": jax.random.normal(ks[4], (d, d), jnp.float32) * s,
    }
    a = {"w_z": ("fsdp", None), "w_i": ("fsdp", None), "w_f": ("fsdp", None),
         "w_o": ("fsdp", None), "f_bias": (None,), "w_down": ("fsdp", None)}
    return p, a


def slstm_train(p: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    dt_ = x.dtype
    xf = x.astype(jnp.float32)
    z = jnp.tanh(xf @ p["w_z"])
    i = jax.nn.sigmoid(xf @ p["w_i"])
    f = jax.nn.sigmoid(xf @ p["w_f"] + p["f_bias"])
    o = jax.nn.sigmoid(xf @ p["w_o"])

    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    # c_t = f_t c_{t-1} + i_t z_t ; n_t = f_t n_{t-1} + i_t
    c_a, c_b = jax.lax.associative_scan(comb, (f, i * z), axis=1)
    n_a, n_b = jax.lax.associative_scan(comb, (f, i), axis=1)
    c = c_b   # zero initial state
    n = jnp.maximum(n_b, 1e-6)
    h = o * (c / n)
    return (h @ p["w_down"]).astype(dt_)


@dataclasses.dataclass
class SLSTMState:
    c: jax.Array  # (B, D)
    n: jax.Array  # (B, D)

jax.tree_util.register_dataclass(SLSTMState, data_fields=["c", "n"],
                                 meta_fields=[])


def init_slstm_state(cfg: ArchConfig, batch: int) -> SLSTMState:
    z = jnp.zeros((batch, cfg.d_model), jnp.float32)
    return SLSTMState(c=z, n=z)


def slstm_decode(p: Params, x: jax.Array, cfg: ArchConfig, state: SLSTMState
                 ) -> tuple[jax.Array, SLSTMState]:
    dt_ = x.dtype
    xf = x[:, 0].astype(jnp.float32)
    z = jnp.tanh(xf @ p["w_z"])
    i = jax.nn.sigmoid(xf @ p["w_i"])
    f = jax.nn.sigmoid(xf @ p["w_f"] + p["f_bias"])
    o = jax.nn.sigmoid(xf @ p["w_o"])
    c = f * state.c + i * z
    n = jnp.maximum(f * state.n + i, 1e-6)
    h = o * (c / n)
    return (h @ p["w_down"])[:, None].astype(dt_), SLSTMState(c=c, n=n)
