from repro.models.model_zoo import Model, build_model

__all__ = ["build_model", "Model"]
