"""Core transformer layers: norms, RoPE, GQA attention (full/chunked/
decode), FFN variants (SwiGLU/GeGLU/GELU, optional IMC-routed down-proj),
and GShard-style MoE with capacity-factor dispatch.

All layers are pure functions over explicit param pytrees. Init functions
return ``(params, axes)`` where ``axes`` mirrors ``params`` with tuples of
logical axis names consumed by ``repro.dist.sharding``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import baseline_mode, constrain


Params = dict[str, Any]


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ArchConfig, d: int | None = None) -> tuple[Params, Params]:
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        p = {"scale": jnp.ones((d,), jnp.float32),
             "bias": jnp.zeros((d,), jnp.float32)}
        a = {"scale": (None,), "bias": (None,)}
    else:
        p = {"scale": jnp.ones((d,), jnp.float32)}
        a = {"scale": (None,)}
    return p, a


def apply_norm(p: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-6) * p["scale"] + p["bias"]
    else:
        var = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6) * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) or (S,) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def init_attention(key: jax.Array, cfg: ArchConfig) -> tuple[Params, Params]:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": jax.random.normal(k1, (d, h, hd), jnp.float32) * s,
        "wk": jax.random.normal(k2, (d, kv, hd), jnp.float32) * s,
        "wv": jax.random.normal(k3, (d, kv, hd), jnp.float32) * s,
        "wo": jax.random.normal(k4, (h, hd, d), jnp.float32) * (h * hd) ** -0.5,
    }
    a = {
        "wq": ("fsdp", "heads", None),
        "wk": ("fsdp", "kv_heads", None),
        "wv": ("fsdp", "kv_heads", None),
        "wo": ("heads", None, "fsdp"),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), jnp.float32)
        p["bk"] = jnp.zeros((kv, hd), jnp.float32)
        p["bv"] = jnp.zeros((kv, hd), jnp.float32)
        a["bq"] = ("heads", None)
        a["bk"] = ("kv_heads", None)
        a["bv"] = ("kv_heads", None)
    return p, a


def _qkv(p: Params, x: jax.Array, cfg: ArchConfig, positions: jax.Array,
         use_rope: bool = True):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)
    return q, k, v


def _group_q(q: jax.Array, num_kv: int) -> jax.Array:
    """(B, S, H, hd) -> (B, S, KV, G, hd): group query heads by kv head so
    GQA/MQA attention never materializes repeated K/V (a 7-48x temp blowup
    for qwen/granite otherwise)."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, num_kv, h // num_kv, hd)


def _causal_band_mask(sq: int, skv: int, q_off: jax.Array | int,
                      window: int) -> jax.Array:
    """(sq, skv) bool mask: kv position j visible from query position
    (q_off + i) if j <= q_off+i and (window == 0 or j > q_off+i - window)."""
    qi = jnp.arange(sq)[:, None] + q_off
    kj = jnp.arange(skv)[None, :]
    m = kj <= qi
    if window:
        m = m & (kj > qi - window)
    return m


def attention_full(q, k, v, cfg: ArchConfig, q_off=0, causal=True) -> jax.Array:
    """Materialized-scores attention — used when seq is small."""
    hd = q.shape[-1]
    b, sq, h, _ = q.shape
    kv = k.shape[2]
    qg = _group_q(q, kv)
    logits = jnp.einsum("bqngk,bsnk->bngqs", qg, k) / (hd ** 0.5)
    if causal:
        mask = _causal_band_mask(sq, k.shape[1], q_off, cfg.sliding_window)
        logits = jnp.where(mask[None, None, None], logits.astype(jnp.float32),
                           -1e30)
    else:
        logits = logits.astype(jnp.float32)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bngqs,bsnk->bqngk", w, v)
    return out.reshape(b, sq, h, hd)


def attention_chunked(q, k, v, cfg: ArchConfig, chunk: int = 1024,
                      causal=True) -> jax.Array:
    """Online-softmax attention over KV chunks (jnp-level FlashAttention).

    Memory is O(S_q * chunk) instead of O(S_q * S_kv): the kernel-free TPU
    adaptation for 32k prefill. Scans over KV chunks carrying the running
    (max, denominator, weighted-sum) triple.
    """
    h = cfg.num_heads
    hd = q.shape[-1]
    b, sq = q.shape[0], q.shape[1]
    kv = k.shape[2]
    g = h // kv
    skv = k.shape[1]
    chunk = min(chunk, skv)
    assert skv % chunk == 0, (skv, chunk)
    nch = skv // chunk
    kc = k.reshape(b, nch, chunk, kv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nch, chunk, kv, hd).transpose(1, 0, 2, 3, 4)
    qg = _group_q(q, kv).astype(jnp.float32)   # (b, sq, kv, g, hd)
    scale = hd ** -0.5

    def body(carry, xs):
        m, denom, acc = carry                  # (b,kv,g,sq), ..., (b,kv,g,sq,hd)
        ci, kb, vb = xs
        logits = jnp.einsum("bqngk,bsnk->bngqs", qg,
                            kb.astype(jnp.float32)) * scale
        kj = ci * chunk + jnp.arange(chunk)[None, :]
        qi = jnp.arange(sq)[:, None]
        mask = kj <= qi
        if cfg.sliding_window:
            mask = mask & (kj > qi - cfg.sliding_window)
        if not causal:
            mask = jnp.ones_like(mask)
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        denom = denom * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bngqs,bsnk->bngqk", p, vb.astype(jnp.float32))
        return (m_new, denom, acc), None

    m0 = jnp.full((b, kv, g, sq), -jnp.inf, jnp.float32)
    d0 = jnp.zeros((b, kv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kv, g, sq, hd), jnp.float32)
    (m, denom, acc), _ = jax.lax.scan(
        body, (m0, d0, a0), (jnp.arange(nch), kc, vc))
    out = acc / jnp.maximum(denom[..., None], 1e-30)
    # (b, kv, g, sq, hd) -> (b, sq, h, hd)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd).astype(q.dtype)


def attention_train(p: Params, x: jax.Array, cfg: ArchConfig,
                    causal: bool = True, chunk_threshold: int = 8192
                    ) -> jax.Array:
    """Self-attention over a full sequence (training / encoder)."""
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    q, k, v = _qkv(p, x, cfg, positions)
    if s <= chunk_threshold:
        out = attention_full(q, k, v, cfg, causal=causal)
    else:
        out = attention_chunked(q, k, v, cfg, causal=causal)
    out = constrain(out, "batch", None, "heads", None)
    return jnp.einsum("bqhk,hkd->bqd", out, p["wo"].astype(x.dtype))


@dataclasses.dataclass
class KVCache:
    k: jax.Array  # (B, S_max, KV, hd)
    v: jax.Array

jax.tree_util.register_dataclass(KVCache, data_fields=["k", "v"], meta_fields=[])


@dataclasses.dataclass
class QuantKVCache:
    """int8 KV store with per-(batch, position, kv-head) scales — the
    SpecPCM MLC insight (quantized memory-resident store, §DESIGN.md
    Insight 2) applied to the KV cache: 2x less HBM traffic per decode
    step, with scales factoring out of the QK dot product per position."""
    k: jax.Array        # (B, S, KV, hd) int8
    v: jax.Array        # (B, S, KV, hd) int8
    k_scale: jax.Array  # (B, S, KV) f32
    v_scale: jax.Array  # (B, S, KV) f32

jax.tree_util.register_dataclass(
    QuantKVCache, data_fields=["k", "v", "k_scale", "v_scale"],
    meta_fields=[])


def _kv_quant(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(B, S, KV, hd) -> int8 codes + per-(B,S,KV) scale."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.abs(xf).max(-1), 1e-6) / 127.0
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int,
                  dtype=None):
    """For sliding-window layers the cache is bounded by the window."""
    size = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    if getattr(cfg, "kv_quant_int8", False):
        z = jnp.zeros((batch, size, kv, hd), jnp.int8)
        s = jnp.ones((batch, size, kv), jnp.float32)
        return QuantKVCache(k=z, v=z, k_scale=s, v_scale=s)
    dt = dtype or _dtype(cfg)
    z = jnp.zeros((batch, size, kv, hd), dt)
    return KVCache(k=z, v=z)


def attention_prefill(p: Params, x: jax.Array, cfg: ArchConfig, cache
                      ) -> tuple[jax.Array, "KVCache | QuantKVCache"]:
    """Training-shape attention that also materializes the KV cache."""
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    q, k, v = _qkv(p, x, cfg, positions)
    if s <= 8192:
        out = attention_full(q, k, v, cfg)
    else:
        out = attention_chunked(q, k, v, cfg)
    size = cache.k.shape[1]
    if isinstance(cache, QuantKVCache):
        k8, ks = _kv_quant(k[:, -size:])
        v8, vs = _kv_quant(v[:, -size:])
        cache = QuantKVCache(
            k=jax.lax.dynamic_update_slice_in_dim(cache.k, k8, 0, axis=1),
            v=jax.lax.dynamic_update_slice_in_dim(cache.v, v8, 0, axis=1),
            k_scale=jax.lax.dynamic_update_slice_in_dim(
                cache.k_scale, ks, 0, axis=1),
            v_scale=jax.lax.dynamic_update_slice_in_dim(
                cache.v_scale, vs, 0, axis=1),
        )
    else:
        kc = jax.lax.dynamic_update_slice_in_dim(cache.k, k[:, -size:], 0, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache.v, v[:, -size:], 0, axis=1)
        cache = KVCache(k=kc, v=vc)
    out = constrain(out, "batch", None, "heads", None)
    y = jnp.einsum("bqhk,hkd->bqd", out, p["wo"].astype(x.dtype))
    return y, cache


def attention_decode(p: Params, x: jax.Array, cfg: ArchConfig,
                     cache, pos: jax.Array
                     ) -> tuple[jax.Array, "KVCache | QuantKVCache"]:
    """One-token decode against the KV cache.

    x: (B, 1, D); pos: () int32 — absolute position of the new token.
    Sliding-window layers use the cache as a ring buffer of size `window`.
    With a QuantKVCache the QK dot runs against int8 codes and the
    per-position scale multiplies the logits afterwards (exact algebra).
    """
    b = x.shape[0]
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    kv = cfg.num_kv_heads
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _qkv(p, x, cfg, positions)
    size = cache.k.shape[1]
    slot = pos % size if cfg.sliding_window else pos
    quant = isinstance(cache, QuantKVCache)
    if quant:
        k8, ks = _kv_quant(k)
        v8, vs = _kv_quant(v)
        cache = QuantKVCache(
            k=jax.lax.dynamic_update_slice(cache.k, k8, (0, slot, 0, 0)),
            v=jax.lax.dynamic_update_slice(cache.v, v8, (0, slot, 0, 0)),
            k_scale=jax.lax.dynamic_update_slice(cache.k_scale, ks,
                                                 (0, slot, 0)),
            v_scale=jax.lax.dynamic_update_slice(cache.v_scale, vs,
                                                 (0, slot, 0)),
        )
        kc, vc = cache.k, cache.v
    else:
        kc = jax.lax.dynamic_update_slice(cache.k, k, (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache.v, v, (0, slot, 0, 0))
        cache = KVCache(k=kc, v=vc)
    qg = _group_q(q, kv)                                    # (b,1,kv,g,hd)
    logits = jnp.einsum("bqngk,bsnk->bngqs", qg.astype(jnp.float32),
                        kc.astype(jnp.float32)) / (hd ** 0.5)
    if quant:
        # scale (b,s,n) -> (b,n,1,1,s)
        logits = logits * cache.k_scale.transpose(0, 2, 1)[:, :, None, None, :]
    idx = jnp.arange(size)
    if cfg.sliding_window:
        valid = (idx <= slot) | (pos >= size)   # ring buffer fully valid once wrapped
    else:
        valid = idx <= pos
    logits = jnp.where(valid[None, None, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    if quant:
        w = w * cache.v_scale.transpose(0, 2, 1)[:, :, None, None, :]
    w = w.astype(jnp.float32)
    out = jnp.einsum("bngqs,bsnk->bqngk", w, vc.astype(jnp.float32))
    out = out.reshape(b, 1, h, hd).astype(x.dtype)
    y = jnp.einsum("bqhk,hkd->bqd", out, p["wo"].astype(x.dtype))
    return y, cache


# ---------------------------------------------------------------------------
# FFN (dense)
# ---------------------------------------------------------------------------

def init_ffn(key: jax.Array, cfg: ArchConfig, d_ff: int | None = None
             ) -> tuple[Params, Params]:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = d ** -0.5, f ** -0.5
    if cfg.activation in ("swiglu", "geglu"):
        p = {
            "w_gate": jax.random.normal(k1, (d, f), jnp.float32) * s_in,
            "w_up": jax.random.normal(k2, (d, f), jnp.float32) * s_in,
            "w_down": jax.random.normal(k3, (f, d), jnp.float32) * s_out,
        }
        a = {"w_gate": ("fsdp", "ff"), "w_up": ("fsdp", "ff"),
             "w_down": ("ff", "fsdp")}
    else:
        p = {
            "w_up": jax.random.normal(k1, (d, f), jnp.float32) * s_in,
            "w_down": jax.random.normal(k3, (f, d), jnp.float32) * s_out,
            "b_up": jnp.zeros((f,), jnp.float32),
            "b_down": jnp.zeros((d,), jnp.float32),
        }
        a = {"w_up": ("fsdp", "ff"), "w_down": ("ff", "fsdp"),
             "b_up": ("ff",), "b_down": (None,)}
    return p, a


def _imc_linear(x: jax.Array, w: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Route a matmul through the SpecPCM analog-chain model (DESIGN.md §3).

    Forward numerics: symmetric int quantization of activations to the DAC
    range and weights to the MLC range, per-128-column-tile partial sums,
    ADC clamp+quantize of partials, dequantized accumulation. Gradients use
    a straight-through estimator around the exact matmul.
    """
    from repro.core.imc.array import ArrayConfig, default_full_scale

    acfg = ArrayConfig(adc_bits=cfg.imc_adc_bits, bits_per_cell=cfg.imc_mlc_bits)
    dac = acfg.dac_levels
    mlc = cfg.imc_mlc_bits
    xf, wf = x.astype(jnp.float32), w.astype(jnp.float32)
    sx = jnp.maximum(jnp.abs(xf).max(-1, keepdims=True), 1e-6) / dac
    sw = jnp.maximum(jnp.abs(wf).max(0, keepdims=True), 1e-6) / mlc
    xq = jnp.round(xf / sx)
    wq = jnp.round(wf / sw)
    F = wq.shape[0]
    pad = (-F) % 128
    if pad:
        xq = jnp.pad(xq, [(0, 0)] * (xq.ndim - 1) + [(0, pad)])
        wq = jnp.pad(wq, ((0, pad), (0, 0)))
    t = xq.shape[-1] // 128
    xt = xq.reshape(*xq.shape[:-1], t, 128)
    wt = wq.reshape(t, 128, wq.shape[-1])
    part = jnp.einsum("...tc,tcd->...td", xt, wt)
    fs = default_full_scale(acfg)
    lsb = fs / acfg.adc_levels
    code = jnp.clip(jnp.round(part / lsb), -acfg.adc_levels, acfg.adc_levels)
    y_imc = (code * lsb).sum(-2) * sx * sw
    y_exact = xf @ wf
    # straight-through: value = imc, gradient = exact
    y = y_exact + jax.lax.stop_gradient(y_imc - y_exact)
    return y.astype(x.dtype)


def apply_ffn(p: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    dt = x.dtype
    if cfg.activation in ("swiglu", "geglu"):
        g = x @ p["w_gate"].astype(dt)
        u = x @ p["w_up"].astype(dt)
        act = jax.nn.silu(g) if cfg.activation == "swiglu" else jax.nn.gelu(g)
        h = act * u
    else:
        h = jax.nn.gelu(x @ p["w_up"].astype(dt) + p["b_up"].astype(dt))
    h = constrain(h, "batch", None, "ff")
    if cfg.imc_linear:
        y = _imc_linear(h, p["w_down"], cfg)
    else:
        y = h @ p["w_down"].astype(dt)
    if "b_down" in p:
        y = y + p["b_down"].astype(dt)
    return y


# ---------------------------------------------------------------------------
# MoE (GShard-style capacity dispatch + shared experts)
# ---------------------------------------------------------------------------

def init_moe(key: jax.Array, cfg: ArchConfig) -> tuple[Params, Params]:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.expert_d_ff
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    s_in, s_out = d ** -0.5, f ** -0.5
    p = {
        "router": jax.random.normal(k1, (d, e), jnp.float32) * s_in,
        "w_gate": jax.random.normal(k2, (e, d, f), jnp.float32) * s_in,
        "w_up": jax.random.normal(k3, (e, d, f), jnp.float32) * s_in,
        "w_down": jax.random.normal(k4, (e, f, d), jnp.float32) * s_out,
    }
    a = {
        "router": (None, None),
        "w_gate": ("experts", "fsdp", None),
        "w_up": ("experts", "fsdp", None),
        "w_down": ("experts", None, "fsdp"),
    }
    if cfg.num_shared_experts:
        fs_ = cfg.expert_d_ff * cfg.num_shared_experts
        p["shared_gate"] = jax.random.normal(k5, (d, fs_), jnp.float32) * s_in
        p["shared_up"] = jax.random.normal(k1, (d, fs_), jnp.float32) * s_in
        p["shared_down"] = jax.random.normal(k2, (fs_, d), jnp.float32) * s_out
        a["shared_gate"] = ("fsdp", "ff")
        a["shared_up"] = ("fsdp", "ff")
        a["shared_down"] = ("ff", "fsdp")
    return p, a


def apply_moe(p: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Top-k capacity-factor MoE with dense one-hot dispatch.

    Tokens are grouped (moe_group_size) so the dispatch tensor stays
    VMEM-friendly; the experts axis shards over 'model' (EP) and the SPMD
    partitioner turns the dispatch einsums into all-to-alls.
    """
    dt = x.dtype
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    g_sz = min(cfg.moe_group_size, b * s)
    tokens = x.reshape(-1, d)
    n = tokens.shape[0]
    assert n % g_sz == 0, (n, g_sz)
    g = n // g_sz
    xt = constrain(tokens.reshape(g, g_sz, d), "batch", None, None)
    cap = max(int(g_sz * k * cfg.capacity_factor / e), 1)

    gates = jax.nn.softmax(
        jnp.einsum("gsd,de->gse", xt.astype(jnp.float32), p["router"]), -1)
    topv, topi = jax.lax.top_k(gates, k)                      # (g, s, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # capacity assignment: position of each (token, slot) within its expert
    onehot = jax.nn.one_hot(topi, e, dtype=jnp.float32)       # (g, s, k, e)
    flat = onehot.reshape(g, g_sz * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat                     # arrival order
    pos = pos.reshape(g, g_sz, k, e)
    keep = (pos < cap) * onehot                               # fits capacity
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap,
                            dtype=jnp.float32) * keep[..., None]
    # dispatch tensor: (g, s, e, c), sharded over BOTH the group axis
    # (batch) and the expert axis (model). This makes the dispatch einsum
    # and the expert FFNs fully local: each device computes expert_in for
    # its expert shard from its token shard, and the only cross-device
    # traffic is the small (g, s, d) partial-sum reduce at combine — vs. a
    # 22 GB fp32 all-reduce of the dispatched tensor per layer otherwise
    # (§Perf MoE iteration 2).
    dispatch = pos_oh.sum(2)
    if not baseline_mode():
        dispatch = constrain(dispatch, "batch", None, "experts", None)
    combine = (dispatch * jnp.einsum("gsk,gske->gse", topv, onehot
                                     )[..., None])
    if not baseline_mode():
        combine = constrain(combine, "batch", None, "experts", None)

    expert_in = jnp.einsum("gsec,gsd->egcd", dispatch.astype(dt), xt)
    # keep the token-group axis sharded over the data axes: dropping it
    # forces the partitioner to all-gather every group onto every device
    # (a ~300x collective blowup on the multi-pod mesh — §Perf iteration 1
    # for the MoE cells). With both 'experts'->model and 'batch'->data kept,
    # the dispatch/combine einsums stay local and only the small combine
    # partial-sum crosses the wire.
    if baseline_mode():
        expert_in = constrain(expert_in, "experts", None, None, None)
    else:
        expert_in = constrain(expert_in, "experts", "batch", None, None)
    gate = jnp.einsum("egcd,edf->egcf", expert_in, p["w_gate"].astype(dt))
    up = jnp.einsum("egcd,edf->egcf", expert_in, p["w_up"].astype(dt))
    hidden = jax.nn.silu(gate) * up
    expert_out = jnp.einsum("egcf,efd->egcd", hidden, p["w_down"].astype(dt))
    expert_out = constrain(expert_out, "experts", "batch", None, None)
    y = jnp.einsum("gsec,egcd->gsd", combine.astype(dt), expert_out)
    y = constrain(y, "batch", None, None)

    if cfg.num_shared_experts:
        sg = jax.nn.silu(xt @ p["shared_gate"].astype(dt))
        su = xt @ p["shared_up"].astype(dt)
        y = y + (sg * su) @ p["shared_down"].astype(dt)
    return y.reshape(b, s, d)
