"""Unified Model API over all families.

``build_model(cfg)`` returns a ``Model`` whose methods cover the three shape
kinds: ``loss`` (train), ``prefill`` and ``decode_step`` (serving), plus
``input_specs(shape)`` producing ShapeDtypeStruct stand-ins for the dry-run
(no allocation) and ``init``/``init_cache`` for real runs.

Input conventions per family (DESIGN.md §4):
  * decoder LM / moe / ssm / hybrid: {"tokens": (B, S) int32}
  * vlm: {"patches": (B, S/8, D) dtype, "tokens": (B, S - S/8) int32}
    — patch embeddings come from the stub frontend
  * audio (enc-dec): {"frames": (B, S/2, D) dtype, "tokens": (B, S/2) int32}
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeSpec
from repro.models import transformer as T

Params = dict[str, Any]


def _xent(logits: jax.Array, targets: jax.Array, mask: jax.Array
          ) -> jax.Array:
    """Masked mean cross-entropy; logits fp32 (B, S, V)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


@dataclasses.dataclass
class Model:
    cfg: ArchConfig

    # ---- init -------------------------------------------------------------
    def init(self, key: jax.Array) -> tuple[Params, Params]:
        return T.init_lm(key, self.cfg)

    def abstract_params(self) -> tuple[Params, Params]:
        """(param ShapeDtypeStructs, logical axes) without any allocation.

        ``init_lm`` is traced under ``eval_shape`` (arrays stay abstract);
        the axes pytree is plain Python built during tracing and is smuggled
        out via a closure.
        """
        box: dict = {}

        def f():
            p, a = T.init_lm(jax.random.PRNGKey(0), self.cfg)
            box["axes"] = a
            return p

        shapes = jax.eval_shape(f)
        return shapes, box["axes"]

    # ---- train ------------------------------------------------------------
    def loss(self, params: Params, batch: dict, remat: str = "full"
             ) -> jax.Array:
        cfg = self.cfg
        if cfg.family == "vlm":
            patches = batch["patches"]
            tokens = batch["tokens"]
            tok_x = T.embed_tokens(params, tokens, cfg)
            x = jnp.concatenate([patches.astype(tok_x.dtype), tok_x], axis=1)
            logits = T.forward_train(params, x, cfg, remat=remat,
                                     is_embedded=True)
            # loss on text region only: positions P-1 .. P+St-2 predict tokens
            p_len = patches.shape[1]
            text_logits = logits[:, p_len - 1:-1]
            mask = jnp.ones(tokens.shape, jnp.float32)
            return _xent(text_logits, tokens, mask)
        if cfg.is_encoder_decoder:
            memory = T.encode(params, batch["frames"], cfg, remat=remat)
            tokens = batch["tokens"]
            logits = T.forward_train(params, tokens, cfg, remat=remat,
                                     memory=memory)
            return _xent(logits[:, :-1], tokens[:, 1:],
                         jnp.ones(tokens[:, 1:].shape, jnp.float32))
        tokens = batch["tokens"]
        logits = T.forward_train(params, tokens, cfg, remat=remat)
        return _xent(logits[:, :-1], tokens[:, 1:],
                     jnp.ones(tokens[:, 1:].shape, jnp.float32))

    # ---- serving ----------------------------------------------------------
    def init_cache(self, batch: int, max_len: int):
        return T.init_cache(self.cfg, batch, max_len)

    def prefill(self, params: Params, batch: dict, cache):
        cfg = self.cfg
        if cfg.family == "vlm":
            tok_x = T.embed_tokens(params, batch["tokens"], cfg)
            x = jnp.concatenate(
                [batch["patches"].astype(tok_x.dtype), tok_x], axis=1)
            return T.forward_prefill(params, x, cfg, cache, is_embedded=True)
        if cfg.is_encoder_decoder:
            memory = T.encode(params, batch["frames"], cfg)
            return T.forward_prefill(params, batch["tokens"], cfg, cache,
                                     memory=memory)
        return T.forward_prefill(params, batch["tokens"], cfg, cache)

    def decode_step(self, params: Params, token: jax.Array, cache,
                    pos: jax.Array):
        return T.forward_decode(params, token, self.cfg, cache, pos)

    # ---- dry-run input specs ----------------------------------------------
    def input_specs(self, shape: ShapeSpec) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        B, S = shape.global_batch, shape.seq_len
        tok = lambda b, s: jax.ShapeDtypeStruct((b, s), jnp.int32)

        if shape.kind == "train":
            if cfg.family == "vlm":
                p_len = S // cfg.vision_fraction
                return {"patches": jax.ShapeDtypeStruct((B, p_len, cfg.d_model), dt),
                        "tokens": tok(B, S - p_len)}
            if cfg.is_encoder_decoder:
                return {"frames": jax.ShapeDtypeStruct((B, S // 2, cfg.d_model), dt),
                        "tokens": tok(B, S // 2)}
            return {"tokens": tok(B, S)}

        if shape.kind == "prefill":
            specs = self.input_specs(dataclasses.replace(shape, kind="train"))
            cache = jax.eval_shape(lambda: self.init_cache(B, self._cache_len(S)))
            return {"batch": specs, "cache": cache}

        # decode: one new token against a seq_len-deep cache/state
        cache = jax.eval_shape(lambda: self.init_cache(B, self._cache_len(S)))
        return {
            "token": tok(B, 1),
            "cache": cache,
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }

    def _cache_len(self, seq_len: int) -> int:
        # enc-dec decodes seq_len//2 tokens (the other half is encoder frames)
        return seq_len // 2 if self.cfg.is_encoder_decoder else seq_len


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg=cfg)
