"""Model assembly: blocks per family, scan-over-layers forward passes,
KV/recurrent caches, decoder-only + encoder-decoder stacks.

Compile-time discipline: homogeneous layer stacks are initialized *stacked*
(leading 'layer' axis) and executed with ``lax.scan`` so HLO size — and
therefore dry-run compile time for 88-layer models on 512 host devices — is
independent of depth. Heterogeneous stacks (xLSTM's mLSTM/sLSTM mix) are
unrolled; they are small.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import constrain
from repro.models import layers as L
from repro.models import recurrent as R

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# block kinds
# ---------------------------------------------------------------------------

def block_kind(cfg: ArchConfig, layer_idx: int = 0) -> str:
    if cfg.family == "moe":
        return "attn_moe"
    if cfg.family == "hybrid":
        return "hybrid"
    if cfg.family == "ssm":
        if cfg.ssm_ratio and (layer_idx + 1) % cfg.ssm_ratio == 0:
            return "slstm"
        return "mlstm"
    return "attn_ffn"


def init_block(key: jax.Array, cfg: ArchConfig, kind: str
               ) -> tuple[Params, Params]:
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    if kind in ("attn_ffn", "attn_moe", "hybrid"):
        p["norm1"], a["norm1"] = L.init_norm(cfg)
        p["attn"], a["attn"] = L.init_attention(ks[0], cfg)
        p["norm2"], a["norm2"] = L.init_norm(cfg)
        if kind == "attn_moe":
            p["moe"], a["moe"] = L.init_moe(ks[1], cfg)
        else:
            p["ffn"], a["ffn"] = L.init_ffn(ks[1], cfg)
        if kind == "hybrid":
            p["mamba"], a["mamba"] = R.init_mamba(ks[2], cfg)
            p["alpha"] = jnp.ones((2,), jnp.float32) * 0.5
            a["alpha"] = (None,)
    elif kind == "mlstm":
        p["norm1"], a["norm1"] = L.init_norm(cfg)
        p["mix"], a["mix"] = R.init_mlstm(ks[0], cfg)
    elif kind == "slstm":
        p["norm1"], a["norm1"] = L.init_norm(cfg)
        p["mix"], a["mix"] = R.init_slstm(ks[0], cfg)
    elif kind == "enc":
        p["norm1"], a["norm1"] = L.init_norm(cfg)
        p["attn"], a["attn"] = L.init_attention(ks[0], cfg)
        p["norm2"], a["norm2"] = L.init_norm(cfg)
        p["ffn"], a["ffn"] = L.init_ffn(ks[1], cfg)
    elif kind == "dec_cross":
        p["norm1"], a["norm1"] = L.init_norm(cfg)
        p["attn"], a["attn"] = L.init_attention(ks[0], cfg)
        p["norm_x"], a["norm_x"] = L.init_norm(cfg)
        p["xattn"], a["xattn"] = L.init_attention(ks[1], cfg)
        p["norm2"], a["norm2"] = L.init_norm(cfg)
        p["ffn"], a["ffn"] = L.init_ffn(ks[2], cfg)
    else:
        raise ValueError(kind)
    return p, a


def _cross_attention(p: Params, x: jax.Array, memory_kv, cfg: ArchConfig
                     ) -> jax.Array:
    """Cross-attention with precomputed memory K/V (no RoPE)."""
    dt = x.dtype
    k, v = memory_kv
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    out = L.attention_full(q, k, v, cfg, causal=False)
    return jnp.einsum("bqhk,hkd->bqd", out, p["wo"].astype(dt))


def cross_kv(p: Params, memory: jax.Array, cfg: ArchConfig):
    dt = memory.dtype
    k = jnp.einsum("bsd,dhk->bshk", memory, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", memory, p["wv"].astype(dt))
    return k, v


# ---------------------------------------------------------------------------
# block application — training / full-sequence mode
# ---------------------------------------------------------------------------

def apply_block_train(p: Params, x: jax.Array, cfg: ArchConfig, kind: str,
                      memory: jax.Array | None = None) -> jax.Array:
    if kind in ("attn_ffn", "attn_moe", "hybrid", "enc"):
        h = L.apply_norm(p["norm1"], x, cfg)
        attn = L.attention_train(p["attn"], h, cfg, causal=(kind != "enc"))
        if kind == "hybrid":
            ssm = R.mamba_train(p["mamba"], h, cfg)
            attn = p["alpha"][0].astype(x.dtype) * attn \
                 + p["alpha"][1].astype(x.dtype) * ssm
        # constrain the TP partial-sum output to the seq-sharded layout
        # BEFORE the residual add: the partitioner can then reduce into the
        # sharded layout instead of all-reducing the full activation
        # (§Perf it.2; REPRO_BASELINE=1 restores the after-add constrain)
        from repro.dist.sharding import baseline_mode
        if not baseline_mode():
            attn = constrain(attn.astype(x.dtype), "batch", "seq_shard", None)
        x = x + attn
        if baseline_mode():
            x = constrain(x, "batch", "seq_shard", None)
        h = L.apply_norm(p["norm2"], x, cfg)
        if kind == "attn_moe":
            y = L.apply_moe(p["moe"], h, cfg)
        else:
            y = L.apply_ffn(p["ffn"], h, cfg)
        if not baseline_mode():
            y = constrain(y.astype(x.dtype), "batch", "seq_shard", None)
        x = x + y
        if baseline_mode():
            x = constrain(x, "batch", "seq_shard", None)
        return x
    if kind == "mlstm":
        return x + R.mlstm_train(p["mix"], L.apply_norm(p["norm1"], x, cfg), cfg)
    if kind == "slstm":
        return x + R.slstm_train(p["mix"], L.apply_norm(p["norm1"], x, cfg), cfg)
    if kind == "dec_cross":
        h = L.apply_norm(p["norm1"], x, cfg)
        x = x + L.attention_train(p["attn"], h, cfg, causal=True)
        h = L.apply_norm(p["norm_x"], x, cfg)
        x = x + _cross_attention(p["xattn"], h, cross_kv(p["xattn"], memory, cfg), cfg)
        h = L.apply_norm(p["norm2"], x, cfg)
        return x + L.apply_ffn(p["ffn"], h, cfg)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# block application — prefill / decode
# ---------------------------------------------------------------------------

def init_block_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int):
    if kind in ("attn_ffn", "attn_moe"):
        return L.init_kv_cache(cfg, batch, max_len)
    if kind == "hybrid":
        return (L.init_kv_cache(cfg, batch, max_len), R.init_mamba_state(cfg, batch))
    if kind == "mlstm":
        return R.init_mlstm_state(cfg, batch)
    if kind == "slstm":
        return R.init_slstm_state(cfg, batch)
    if kind == "dec_cross":
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        dt = jnp.dtype(cfg.dtype)
        xkv = (jnp.zeros((batch, max_len, kv, hd), dt),) * 2
        return (L.init_kv_cache(cfg, batch, max_len), xkv)
    raise ValueError(kind)


def apply_block_prefill(p: Params, x: jax.Array, cfg: ArchConfig, kind: str,
                        cache, memory: jax.Array | None = None):
    if kind in ("attn_ffn", "attn_moe", "hybrid"):
        h = L.apply_norm(p["norm1"], x, cfg)
        if kind == "hybrid":
            kvc, sst = cache
            attn, kvc = L.attention_prefill(p["attn"], h, cfg, kvc)
            ssm = R.mamba_train(p["mamba"], h, cfg)
            # roll the SSM state forward over the whole prompt
            sst = _mamba_state_after(p["mamba"], h, cfg)
            attn = p["alpha"][0].astype(x.dtype) * attn \
                 + p["alpha"][1].astype(x.dtype) * ssm
            cache = (kvc, sst)
        else:
            attn, cache = L.attention_prefill(p["attn"], h, cfg, cache)
        x = x + attn
        h = L.apply_norm(p["norm2"], x, cfg)
        y = L.apply_moe(p["moe"], h, cfg) if kind == "attn_moe" \
            else L.apply_ffn(p["ffn"], h, cfg)
        return x + y, cache
    if kind in ("mlstm", "slstm"):
        h = L.apply_norm(p["norm1"], x, cfg)
        if kind == "mlstm":
            y = R.mlstm_train(p["mix"], h, cfg)
            st = _mlstm_state_after(p["mix"], h, cfg)
        else:
            y = R.slstm_train(p["mix"], h, cfg)
            st = _slstm_state_after(p["mix"], h, cfg)
        return x + y, st
    if kind == "dec_cross":
        kvc, _ = cache
        h = L.apply_norm(p["norm1"], x, cfg)
        attn, kvc = L.attention_prefill(p["attn"], h, cfg, kvc)
        x = x + attn
        xkv = cross_kv(p["xattn"], memory, cfg)
        h = L.apply_norm(p["norm_x"], x, cfg)
        x = x + _cross_attention(p["xattn"], h, xkv, cfg)
        h = L.apply_norm(p["norm2"], x, cfg)
        return x + L.apply_ffn(p["ffn"], h, cfg), (kvc, xkv)
    raise ValueError(kind)


def apply_block_decode(p: Params, x: jax.Array, cfg: ArchConfig, kind: str,
                       cache, pos):
    if kind in ("attn_ffn", "attn_moe", "hybrid"):
        h = L.apply_norm(p["norm1"], x, cfg)
        if kind == "hybrid":
            kvc, sst = cache
            attn, kvc = L.attention_decode(p["attn"], h, cfg, kvc, pos)
            ssm, sst = R.mamba_decode(p["mamba"], h, cfg, sst)
            attn = p["alpha"][0].astype(x.dtype) * attn \
                 + p["alpha"][1].astype(x.dtype) * ssm
            cache = (kvc, sst)
        else:
            attn, cache = L.attention_decode(p["attn"], h, cfg, cache, pos)
        x = x + attn
        h = L.apply_norm(p["norm2"], x, cfg)
        # decode uses the dense path for MoE too (top-k of one token)
        y = L.apply_moe(p["moe"], h, cfg) if kind == "attn_moe" \
            else L.apply_ffn(p["ffn"], h, cfg)
        return x + y, cache
    if kind == "mlstm":
        h = L.apply_norm(p["norm1"], x, cfg)
        y, st = R.mlstm_decode(p["mix"], h, cfg, cache)
        return x + y, st
    if kind == "slstm":
        h = L.apply_norm(p["norm1"], x, cfg)
        y, st = R.slstm_decode(p["mix"], h, cfg, cache)
        return x + y, st
    if kind == "dec_cross":
        kvc, xkv = cache
        h = L.apply_norm(p["norm1"], x, cfg)
        attn, kvc = L.attention_decode(p["attn"], h, cfg, kvc, pos)
        x = x + attn
        h = L.apply_norm(p["norm_x"], x, cfg)
        x = x + _cross_attention(p["xattn"], h, xkv, cfg)
        h = L.apply_norm(p["norm2"], x, cfg)
        return x + L.apply_ffn(p["ffn"], h, cfg), (kvc, xkv)
    raise ValueError(kind)


# --- state-after-prompt helpers (prefill for recurrent layers) -------------

def _mamba_state_after(p, h, cfg) -> R.MambaState:
    # re-run the recurrence keeping only the final state (cheap vs. attn)
    dt_ = h.dtype
    xz = h @ p["w_in"].astype(dt_)
    xi, _ = jnp.split(xz, 2, axis=-1)
    xi_f = xi.astype(jnp.float32)
    Bt = (h @ p["w_b"].astype(dt_)).astype(jnp.float32)
    dt = jax.nn.softplus((h @ p["w_dt"].astype(dt_)).astype(jnp.float32)
                         + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt[..., None] * a)
    inp = (dt * xi_f)[..., None] * Bt[:, :, None, :]

    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    aa, bb = jax.lax.associative_scan(comb, (decay, inp), axis=1)
    return R.MambaState(h=bb[:, -1])


def _mlstm_state_after(p, h, cfg) -> R.MLSTMState:
    dt_ = h.dtype
    b, s, _ = h.shape
    d_inner, nh, dh = R._mlstm_dims(cfg)
    up = h @ p["w_up"].astype(dt_)
    xi, _ = jnp.split(up, 2, axis=-1)
    xf = xi.astype(jnp.float32)
    k = jnp.einsum("bsd,dhk->bshk", xf, p["w_k"].astype(jnp.float32))
    v = jnp.einsum("bsd,dhk->bshk", xf, p["w_v"].astype(jnp.float32))
    ig = jnp.exp(jnp.clip(jnp.einsum("bsd,dh->bsh", xf, p["w_i"]), -10., 5.))
    fg = jax.nn.sigmoid(jnp.einsum("bsd,dh->bsh", xf, p["w_f"]) + p["f_bias"])
    F = jnp.cumsum(jnp.log(jnp.maximum(fg, 1e-9)), axis=1)
    FT = F[:, -1]
    wk = jnp.exp(FT[:, None] - F) * ig
    C = jnp.einsum("bshk,bshl,bsh->bhkl", k, v, wk)
    n = jnp.einsum("bshk,bsh->bhk", k, wk)
    return R.MLSTMState(C=C, n=n)


def _slstm_state_after(p, h, cfg) -> R.SLSTMState:
    xf = h.astype(jnp.float32)
    z = jnp.tanh(xf @ p["w_z"])
    i = jax.nn.sigmoid(xf @ p["w_i"])
    f = jax.nn.sigmoid(xf @ p["w_f"] + p["f_bias"])

    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, c = jax.lax.associative_scan(comb, (f, i * z), axis=1)
    _, n = jax.lax.associative_scan(comb, (f, i), axis=1)
    return R.SLSTMState(c=c[:, -1], n=jnp.maximum(n[:, -1], 1e-6))


# ---------------------------------------------------------------------------
# full decoder-only model
# ---------------------------------------------------------------------------

def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # 'full': save only layer boundaries


def init_lm(key: jax.Array, cfg: ArchConfig) -> tuple[Params, Params]:
    """Init a decoder-only (or encoder-decoder) model with stacked layers."""
    k_e, k_l, k_h, k_enc = jax.random.split(key, 4)
    V, D = cfg.padded_vocab, cfg.d_model
    p: Params = {
        "embed": jax.random.normal(k_e, (V, D), jnp.float32) * D ** -0.5,
    }
    a: Params = {"embed": ("vocab", "fsdp")}

    if cfg.family == "ssm":
        # heterogeneous stack: per-layer params, unrolled
        blocks, baxes = [], []
        for i, k in enumerate(jax.random.split(k_l, cfg.num_layers)):
            bp, ba = init_block(k, cfg, block_kind(cfg, i))
            blocks.append(bp)
            baxes.append(ba)
        p["blocks"] = blocks
        a["blocks"] = baxes
    else:
        kind = "dec_cross" if cfg.is_encoder_decoder else block_kind(cfg)
        keys = jax.random.split(k_l, cfg.num_layers)
        bp = jax.vmap(lambda k: init_block(k, cfg, kind)[0])(keys)
        _, ba = init_block(keys[0], cfg, kind)
        p["layers"] = bp
        a["layers"] = jax.tree.map(
            lambda ax: ("layer",) + ax, ba,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))

    if cfg.is_encoder_decoder:
        keys = jax.random.split(k_enc, cfg.num_encoder_layers)
        ep = jax.vmap(lambda k: init_block(k, cfg, "enc")[0])(keys)
        _, ea = init_block(keys[0], cfg, "enc")
        p["enc_layers"] = ep
        a["enc_layers"] = jax.tree.map(
            lambda ax: ("layer",) + ax, ea,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))
        p["enc_norm"], a["enc_norm"] = L.init_norm(cfg)

    p["final_norm"], a["final_norm"] = L.init_norm(cfg)
    if not cfg.tie_embeddings:
        p["lm_head"] = jax.random.normal(k_h, (D, V), jnp.float32) * D ** -0.5
        a["lm_head"] = ("fsdp", "vocab")
    return p, a


def embed_tokens(p: Params, tokens: jax.Array, cfg: ArchConfig) -> jax.Array:
    x = jnp.take(p["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return constrain(x, "batch", "seq_shard", None)


def unembed(p: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, p["embed"].astype(x.dtype))
    else:
        logits = x @ p["lm_head"].astype(x.dtype)
    return logits.astype(jnp.float32)


def forward_train(p: Params, tokens_or_x, cfg: ArchConfig,
                  remat: str = "full", is_embedded: bool = False,
                  memory: jax.Array | None = None) -> jax.Array:
    """Full-sequence forward -> logits (B, S, V)."""
    x = tokens_or_x if is_embedded else embed_tokens(p, tokens_or_x, cfg)

    if cfg.family == "ssm":
        for i, bp in enumerate(p["blocks"]):
            body = _remat(
                functools.partial(apply_block_train, cfg=cfg,
                                  kind=block_kind(cfg, i)), remat)
            x = body(bp, x)
    else:
        kind = "dec_cross" if cfg.is_encoder_decoder else block_kind(cfg)

        def body(carry, lp):
            out = apply_block_train(lp, carry, cfg, kind, memory=memory)
            return out, None

        x, _ = jax.lax.scan(_remat(body, remat), x, p["layers"])

    x = L.apply_norm(p["final_norm"], x, cfg)
    return unembed(p, x, cfg)


def encode(p: Params, frames: jax.Array, cfg: ArchConfig,
           remat: str = "full") -> jax.Array:
    """Encoder stack over precomputed frame embeddings (+ sinusoids)."""
    b, s, d = frames.shape
    pos = jnp.arange(s, dtype=jnp.float32)
    half = d // 2
    freq = jnp.exp(-jnp.arange(half, dtype=jnp.float32) / half * 9.0)
    sin = jnp.sin(pos[:, None] * freq[None, :])
    cos = jnp.cos(pos[:, None] * freq[None, :])
    x = frames + jnp.concatenate([sin, cos], -1).astype(frames.dtype)[None]
    x = constrain(x, "batch", "seq_shard", None)

    def body(carry, lp):
        return apply_block_train(lp, carry, cfg, "enc"), None

    x, _ = jax.lax.scan(_remat(body, remat), x, p["enc_layers"])
    return L.apply_norm(p["enc_norm"], x, cfg)


# ---------------------------------------------------------------------------
# prefill / decode drivers
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    if cfg.family == "ssm":
        return [init_block_cache(cfg, block_kind(cfg, i), batch, max_len)
                for i in range(cfg.num_layers)]
    kind = "dec_cross" if cfg.is_encoder_decoder else block_kind(cfg)
    one = init_block_cache(cfg, kind, batch, max_len)
    # stack over layers
    return jax.tree.map(
        lambda z: jnp.broadcast_to(z[None], (cfg.num_layers,) + z.shape), one)


def forward_prefill(p: Params, tokens_or_x, cfg: ArchConfig, cache,
                    is_embedded: bool = False,
                    memory: jax.Array | None = None):
    x = tokens_or_x if is_embedded else embed_tokens(p, tokens_or_x, cfg)
    if cfg.family == "ssm":
        new_cache = []
        for i, bp in enumerate(p["blocks"]):
            x, c = apply_block_prefill(bp, x, cfg, block_kind(cfg, i), cache[i])
            new_cache.append(c)
        x = L.apply_norm(p["final_norm"], x, cfg)
        return unembed(p, x, cfg), new_cache

    kind = "dec_cross" if cfg.is_encoder_decoder else block_kind(cfg)

    def body(carry, xs):
        x_c, cache_c = carry
        i, lp = xs
        lc = jax.tree.map(lambda c: jax.lax.dynamic_index_in_dim(
            c, i, 0, keepdims=False), cache_c)
        out, c = apply_block_prefill(lp, x_c, cfg, kind, lc, memory=memory)
        cache_c = jax.tree.map(
            lambda full, new: jax.lax.dynamic_update_index_in_dim(
                full, new.astype(full.dtype), i, 0), cache_c, c)
        return (out, cache_c), None

    # cache rides in the carry (not xs/ys) so the while-loop updates it
    # in place — scanning it as ys doubles peak memory with a full copy
    (x, new_cache), _ = jax.lax.scan(
        body, (x, cache), (jnp.arange(cfg.num_layers), p["layers"]))
    x = L.apply_norm(p["final_norm"], x, cfg)
    return unembed(p, x, cfg), new_cache


def forward_decode(p: Params, token: jax.Array, cfg: ArchConfig, cache,
                   pos: jax.Array):
    """token: (B, 1) int32; pos: () int32 absolute position."""
    x = embed_tokens(p, token, cfg)
    if cfg.family == "ssm":
        new_cache = []
        for i, bp in enumerate(p["blocks"]):
            x, c = apply_block_decode(bp, x, cfg, block_kind(cfg, i),
                                      cache[i], pos)
            new_cache.append(c)
        x = L.apply_norm(p["final_norm"], x, cfg)
        return unembed(p, x, cfg), new_cache

    kind = "dec_cross" if cfg.is_encoder_decoder else block_kind(cfg)

    def body(carry, xs):
        x_c, cache_c = carry
        i, lp = xs
        lc = jax.tree.map(lambda c: jax.lax.dynamic_index_in_dim(
            c, i, 0, keepdims=False), cache_c)
        out, c = apply_block_decode(lp, x_c, cfg, kind, lc, pos)
        cache_c = jax.tree.map(
            lambda full, new: jax.lax.dynamic_update_index_in_dim(
                full, new.astype(full.dtype), i, 0), cache_c, c)
        return (out, cache_c), None

    (x, new_cache), _ = jax.lax.scan(
        body, (x, cache), (jnp.arange(cfg.num_layers), p["layers"]))
    x = L.apply_norm(p["final_norm"], x, cfg)
    return unembed(p, x, cfg), new_cache
