"""Target-decoy false-discovery-rate filtering (paper §II.B, [17]).

Every reference library is doubled with decoys (here: m/z-reversed
templates). After search, matches are sorted by score; the FDR at a score
threshold t is (#decoy matches >= t) / (#target matches >= t). We report the
number of identified peptides at a fixed FDR (1% in the paper's Tables)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def make_decoys(refs: jax.Array) -> jax.Array:
    """Decoy spectra: reverse the m/z axis (standard decoy generation)."""
    return refs[:, ::-1]


def decoy_competition(scores_target: jax.Array, scores_decoy: jax.Array
                      ) -> tuple[jax.Array, jax.Array]:
    """Per-query target-decoy competition: a hit survives if its best target
    score beats its best decoy score. Returns (is_target_win, best_score)."""
    return scores_target > scores_decoy, jnp.maximum(scores_target, scores_decoy)


def fdr_filter(best_scores: jax.Array, is_target: jax.Array, fdr: float = 0.01,
               valid: jax.Array | None = None) -> jax.Array:
    """Accept mask at the given FDR.

    best_scores: (Q,) best match score per query.
    is_target:   (Q,) True if the best match was a target (not decoy).
    valid:       (Q,) optional bool; False entries (queries with no candidate
                 in their precursor window) are excluded from the target/decoy
                 counts entirely — a query that matched *nothing* is not a
                 decoy win, and counting it as one depresses acceptance for
                 every other query in the batch. Invalid queries are never
                 accepted.
    Finds the lowest score threshold whose running FDR estimate
    (decoys/targets above threshold) stays <= fdr, vectorized.
    """
    order = jnp.argsort(-best_scores)
    tgt_sorted = is_target[order]
    if valid is None:
        valid_sorted = jnp.ones_like(tgt_sorted, dtype=bool)
    else:
        valid_sorted = valid[order]
    n_tgt = jnp.cumsum((tgt_sorted & valid_sorted).astype(jnp.int32))
    n_dec = jnp.cumsum((~tgt_sorted & valid_sorted).astype(jnp.int32))
    running_fdr = n_dec / jnp.maximum(n_tgt, 1)
    ok = running_fdr <= fdr
    # largest prefix with FDR under control
    k = jnp.max(jnp.where(ok, jnp.arange(ok.shape[0]) + 1, 0))
    accept_sorted = (jnp.arange(ok.shape[0]) < k) & tgt_sorted & valid_sorted
    accept = jnp.zeros_like(accept_sorted).at[order].set(accept_sorted)
    return accept
