"""Spectrum preprocessing: binning, normalization, precursor bucketing.

Mirrors the HyperSpec/HyperOMS preprocessing the paper reuses (§S.A): spectra
are binned over the m/z range, intensity-normalized, and — for clustering —
partitioned into buckets by precursor mass so the quadratic distance matrix
stays per-bucket (§II.B Fig. 1)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def bin_spectra(mz: jax.Array, intensity: jax.Array, num_bins: int,
                mz_range: tuple[float, float] = (200.0, 2000.0)) -> jax.Array:
    """Bin raw (peaks) spectra to fixed-length vectors.

    mz, intensity: (N, P) padded peak lists (zero-intensity pads ignored).
    Returns (N, num_bins) max-pooled, [0,1]-normalized vectors.
    """
    lo, hi = mz_range
    idx = jnp.clip(((mz - lo) / (hi - lo) * num_bins).astype(jnp.int32),
                   0, num_bins - 1)
    n = mz.shape[0]
    rows = jnp.repeat(jnp.arange(n)[:, None], mz.shape[1], axis=1)
    out = jnp.zeros((n, num_bins), jnp.float32)
    out = out.at[rows.reshape(-1), idx.reshape(-1)].max(intensity.reshape(-1))
    mx = jnp.maximum(out.max(axis=1, keepdims=True), 1e-6)
    return out / mx


def sqrt_normalize(spectra: jax.Array) -> jax.Array:
    """Square-root intensity transform (standard MS practice to de-emphasize
    dominant peaks) followed by re-normalization."""
    s = jnp.sqrt(jnp.clip(spectra, 0.0, None))
    mx = jnp.maximum(s.max(axis=1, keepdims=True), 1e-6)
    return s / mx


def bucket_by_precursor(precursor: np.ndarray, bucket_width: float = 40.0
                        ) -> list[np.ndarray]:
    """Partition spectrum indices into precursor-mass buckets.

    Host-side (drives the per-bucket jitted clustering); returns a list of
    index arrays sorted by bucket mass.
    """
    prec = np.asarray(precursor)
    if prec.size == 0:
        return []
    lo = float(prec.min())
    bucket_ids = ((prec - lo) / bucket_width).astype(np.int64)
    out = []
    for b in np.unique(bucket_ids):
        out.append(np.nonzero(bucket_ids == b)[0])
    return out


def candidate_window_mask(query_prec: jax.Array, ref_prec: jax.Array,
                          tol: float = 20.0, open_search: bool = True,
                          open_tol: float = 200.0) -> jax.Array:
    """(Q, R) bool mask of references within the precursor tolerance window.

    Open-modification search widens the window to +open_tol on the *query*
    side (mass additions: a modified query is heavier than its unmodified
    reference), i.e. ``query - ref`` must fall in the open interval
    ``(-tol, open_tol)``. This asymmetry is what makes HEK293-style searches
    expensive — and is the candidate_fraction knob of the energy model."""
    d = ref_prec[None, :] - query_prec[:, None]
    if open_search:
        # d = ref - query in (-open_tol, tol)  <=>  query - ref in (-tol, open_tol)
        return (d > -open_tol) & (d < tol)
    return jnp.abs(d) < tol
