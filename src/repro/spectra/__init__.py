from repro.spectra.synthetic import SyntheticMSConfig, generate_dataset, MSDataset
from repro.spectra.preprocess import bin_spectra, bucket_by_precursor
from repro.spectra.fdr import fdr_filter, decoy_competition

__all__ = [
    "SyntheticMSConfig", "generate_dataset", "MSDataset",
    "bin_spectra", "bucket_by_precursor",
    "fdr_filter", "decoy_competition",
]
