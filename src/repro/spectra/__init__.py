from repro.spectra.fdr import decoy_competition, fdr_filter
from repro.spectra.preprocess import bin_spectra, bucket_by_precursor
from repro.spectra.synthetic import MSDataset, SyntheticMSConfig, generate_dataset

__all__ = [
    "SyntheticMSConfig", "generate_dataset", "MSDataset",
    "bin_spectra", "bucket_by_precursor",
    "fdr_filter", "decoy_competition",
]
