"""Synthetic tandem-MS spectra with ground-truth identities.

The real datasets (PXD001468, PXD000561, iPRG2012, HEK293) are not available
offline, so we generate peptide-like spectra that preserve the statistics the
HD pipeline actually consumes:

  * each "peptide" is a sparse template of fragment peaks over an m/z range
    (drawn once per identity),
  * each observed spectrum is a template plus peak-intensity jitter, peak
    dropout, small m/z shifts, and chemical-noise peaks,
  * spectra carry a precursor mass used for bucketing (clustering) and
    candidate windowing (DB search),
  * open-modification variants shift a suffix of peaks by a delta mass — the
    case HyperOMS/ANN-SoLo target and the reason FDR filtering matters.

Ground truth (template id per spectrum) enables the paper's quality metrics:
clustered-spectra ratio at fixed incorrect-clustering ratio (Fig. 9) and
identified peptides at fixed FDR (Fig. 10).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SyntheticMSConfig:
    num_identities: int = 64          # distinct peptides
    spectra_per_identity: int = 16    # replicates (cluster sizes)
    num_bins: int = 1024              # m/z bins after preprocessing
    peaks_per_peptide: int = 48       # fragment peaks per template
    intensity_jitter: float = 0.25    # multiplicative log-normal-ish jitter
    dropout: float = 0.15             # per-peak missing probability
    # m/z calibration error in bins. 0 by default: preprocessing bins at the
    # instrument calibration width, so residual shift is sub-bin (ID-level
    # encoding is not shift-tolerant by construction — same as HyperSpec).
    mz_shift_bins: int = 0
    noise_peaks: int = 12             # chemical noise peaks per spectrum
    modification_rate: float = 0.0    # fraction of spectra with a mass shift
    # precursor-mass shift range for modified spectra (opt-in; (0, 0) keeps
    # the precursor at the unmodified identity's mass). A modification makes
    # the observed peptide *heavier*, which is what open-modification search
    # widens the window for — set e.g. (60.0, 90.0) to exercise OMS.
    modification_mass_range: tuple[float, float] = (0.0, 0.0)
    precursor_range: tuple[float, float] = (400.0, 1600.0)
    seed: int = 0            # instance noise (jitter/dropout/noise peaks)
    template_seed: int = 42  # peptide templates — fixed across query/ref sets


@dataclasses.dataclass
class MSDataset:
    spectra: jax.Array        # (N, num_bins) float32 in [0, 1]
    identity: jax.Array       # (N,) int32 ground-truth template id
    precursor: jax.Array      # (N,) float32 precursor mass
    is_modified: jax.Array    # (N,) bool
    templates: jax.Array      # (num_identities, num_bins)

    @property
    def num_spectra(self) -> int:
        return self.spectra.shape[0]


def _make_templates(key, cfg: SyntheticMSConfig) -> jax.Array:
    kp, ki = jax.random.split(key)
    # peak positions: distinct bins per identity
    pos = jax.random.uniform(kp, (cfg.num_identities, cfg.peaks_per_peptide))
    pos = (pos * cfg.num_bins).astype(jnp.int32) % cfg.num_bins
    inten = jax.random.uniform(
        ki, (cfg.num_identities, cfg.peaks_per_peptide), minval=0.2, maxval=1.0
    )
    templates = jnp.zeros((cfg.num_identities, cfg.num_bins), jnp.float32)
    ids = jnp.repeat(jnp.arange(cfg.num_identities), cfg.peaks_per_peptide)
    templates = templates.at[ids, pos.reshape(-1)].max(inten.reshape(-1))
    return templates


def generate_dataset(cfg: SyntheticMSConfig) -> MSDataset:
    key = jax.random.PRNGKey(cfg.seed)
    _, k_j, k_d, k_s, k_n, k_p, k_m, k_mod = jax.random.split(key, 8)
    k_t = jax.random.PRNGKey(cfg.template_seed)
    templates = _make_templates(k_t, cfg)
    n = cfg.num_identities * cfg.spectra_per_identity
    identity = jnp.repeat(jnp.arange(cfg.num_identities, dtype=jnp.int32),
                          cfg.spectra_per_identity)
    base = templates[identity]  # (N, bins)

    # intensity jitter (multiplicative)
    jit = 1.0 + cfg.intensity_jitter * jax.random.normal(k_j, base.shape)
    spec = base * jnp.clip(jit, 0.1, 2.0)

    # peak dropout
    keep = jax.random.uniform(k_d, base.shape) > cfg.dropout
    spec = jnp.where(keep, spec, 0.0)

    # m/z calibration shift: roll each spectrum by a small random offset
    shifts = jax.random.randint(
        k_s, (n,), -cfg.mz_shift_bins, cfg.mz_shift_bins + 1
    )
    idx = (jnp.arange(cfg.num_bins)[None, :] - shifts[:, None]) % cfg.num_bins
    spec = jnp.take_along_axis(spec, idx, axis=1)

    # chemical noise peaks
    npos = jax.random.randint(k_n, (n, cfg.noise_peaks), 0, cfg.num_bins)
    nint = jax.random.uniform(k_n, (n, cfg.noise_peaks), minval=0.05, maxval=0.35)
    rows = jnp.repeat(jnp.arange(n), cfg.noise_peaks)
    spec = spec.at[rows, npos.reshape(-1)].max(nint.reshape(-1))

    # open modification: shift the top half of the m/z axis by a delta
    is_mod = jax.random.uniform(k_mod, (n,)) < cfg.modification_rate
    delta = jax.random.randint(k_m, (n,), 8, 48)
    half = cfg.num_bins // 2
    midx = (jnp.arange(cfg.num_bins)[None, :] - delta[:, None]) % cfg.num_bins
    shifted = jnp.take_along_axis(spec, midx, axis=1)
    spec_mod = jnp.concatenate([spec[:, :half], shifted[:, half:]], axis=1)
    spec = jnp.where(is_mod[:, None], spec_mod, spec)

    # precursor mass: a *deterministic* function of identity (golden-ratio
    # hash over the mass range) so query sets generated with different seeds
    # still share precursors with their reference identities, plus small
    # measurement noise
    lo, hi = cfg.precursor_range
    phi = 0.6180339887498949
    ids = jnp.arange(cfg.num_identities, dtype=jnp.float32)
    prec_id = (lo + (hi - lo) * ((ids * phi) % 1.0)).astype(jnp.float32)
    precursor = prec_id[identity] + 0.02 * jax.random.normal(k_p, (n,))

    # opt-in: modified spectra get a heavier precursor (the OMS scenario);
    # keyed by fold_in so enabling it leaves every other random stream —
    # and therefore all default-config outputs — bit-identical
    m_lo, m_hi = cfg.modification_mass_range
    if m_hi > m_lo:
        shift = jax.random.uniform(jax.random.fold_in(key, 97), (n,),
                                   minval=m_lo, maxval=m_hi)
        precursor = jnp.where(is_mod, precursor + shift, precursor)
    elif m_lo == m_hi and m_hi > 0.0:
        precursor = jnp.where(is_mod, precursor + m_hi, precursor)

    # normalize to [0, 1] per spectrum
    mx = jnp.maximum(spec.max(axis=1, keepdims=True), 1e-6)
    spec = spec / mx
    return MSDataset(
        spectra=spec, identity=identity, precursor=precursor,
        is_modified=is_mod, templates=templates,
    )


def generate_query_set(
    dataset: MSDataset, cfg: SyntheticMSConfig, num_queries: int, seed: int = 1,
    modification_rate: float = 0.3,
) -> MSDataset:
    """Fresh replicates of a subset of identities, to use as DB-search
    queries against the dataset's templates (the reference library)."""
    qcfg = dataclasses.replace(
        cfg,
        spectra_per_identity=max(1, num_queries // cfg.num_identities),
        seed=seed,
        modification_rate=modification_rate,
    )
    return generate_dataset(qcfg)
