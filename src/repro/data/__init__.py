from repro.data.tokens import TokenPipeline, synthetic_batch

__all__ = ["TokenPipeline", "synthetic_batch"]
