"""Deterministic synthetic token pipeline.

Produces reproducible, shardable LM batches without any file I/O: token ids
are a hash of (step, position) pushed through a Zipf-ish transform so the
distribution is not uniform (uniform tokens make loss curves flat and hide
embedding-sharding bugs). Deterministic per (step, seed) so a restarted/
resharded job sees the identical stream — which is what makes the
checkpoint-restore and elastic tests exact.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


def _hash2(a: jax.Array, b: jax.Array) -> jax.Array:
    """Cheap stateless integer hash (xorshift-multiply)."""
    x = (a.astype(jnp.uint32) * jnp.uint32(0x9E3779B9)) ^ \
        (b.astype(jnp.uint32) * jnp.uint32(0x85EBCA6B))
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 13)
    return x


@partial(jax.jit, static_argnames=("batch", "seq", "vocab"))
def synthetic_batch(step: jax.Array, batch: int, seq: int, vocab: int,
                    seed: int = 0) -> dict:
    """Batch of (batch, seq) int32 tokens, Zipf-flavored, deterministic."""
    rows = jnp.arange(batch, dtype=jnp.uint32)[:, None]
    cols = jnp.arange(seq, dtype=jnp.uint32)[None, :]
    h = _hash2(rows * jnp.uint32(seq) + cols,
               jnp.uint32(step) + jnp.uint32(seed) * jnp.uint32(0x27D4EB2F))
    u = (h.astype(jnp.float32) / jnp.float32(2**32))  # U[0,1)
    # Zipf-ish: token = floor(vocab * u^3) concentrates mass on small ids
    tok = jnp.minimum((u ** 3 * vocab).astype(jnp.int32), vocab - 1)
    return {"tokens": tok}


@dataclasses.dataclass
class TokenPipeline:
    """Stateless data pipeline facade: batch(step) -> host-shardable pytree.

    In a multi-host deployment each host calls ``batch`` with its own
    process slice; determinism by construction means no data server and no
    skew after elastic resharding.
    """
    batch: int
    seq: int
    vocab: int
    seed: int = 0

    def get(self, step: int | jax.Array) -> dict:
        return synthetic_batch(jnp.asarray(step, jnp.int32), self.batch,
                               self.seq, self.vocab, self.seed)

    def vlm_get(self, step, d_model: int, vision_fraction: int = 8,
                dtype=jnp.bfloat16) -> dict:
        p_len = self.seq // vision_fraction
        t = synthetic_batch(jnp.asarray(step, jnp.int32), self.batch,
                            self.seq - p_len, self.vocab, self.seed)
        h = _hash2(
            jnp.arange(self.batch * p_len * d_model, dtype=jnp.uint32
                       ).reshape(self.batch, p_len, d_model),
            jnp.uint32(step),
        )
        patches = (h.astype(jnp.float32) / 2.0**31 - 1.0).astype(dtype) * 0.02
        return {"patches": patches, "tokens": t["tokens"]}

    def encdec_get(self, step, d_model: int, dtype=jnp.bfloat16) -> dict:
        s2 = self.seq // 2
        t = synthetic_batch(jnp.asarray(step, jnp.int32), self.batch, s2,
                            self.vocab, self.seed)
        h = _hash2(
            jnp.arange(self.batch * s2 * d_model, dtype=jnp.uint32
                       ).reshape(self.batch, s2, d_model),
            jnp.uint32(step) + jnp.uint32(7),
        )
        frames = (h.astype(jnp.float32) / 2.0**31 - 1.0).astype(dtype) * 0.02
        return {"frames": frames, "tokens": t["tokens"]}

    def get_for(self, cfg, step) -> dict:
        """Family-aware batch."""
        if cfg.family == "vlm":
            return self.vlm_get(step, cfg.d_model, cfg.vision_fraction,
                                jnp.dtype(cfg.dtype))
        if cfg.is_encoder_decoder:
            return self.encdec_get(step, cfg.d_model, jnp.dtype(cfg.dtype))
        return self.get(step)
