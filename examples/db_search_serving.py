"""Batched DB-search serving with the ISA executor: the software path a
deployment uses — program the reference bank once (STORE_HV with
write-verify), then stream query batches through MVM_COMPUTE, metering
cycles/energy per batch from the instruction trace.

    PYTHONPATH=src python examples/db_search_serving.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SpecPCMConfig, encode_and_pack
from repro.core.imc.array import ArrayConfig
from repro.core.imc.device import DeviceConfig
from repro.core.imc.isa import ISAExecutor, Instruction, Opcode
from repro.spectra import SyntheticMSConfig, generate_dataset
from repro.spectra.synthetic import generate_query_set


def main():
    ms = SyntheticMSConfig(num_identities=64, spectra_per_identity=2,
                           num_bins=1024)
    ds = generate_dataset(ms)
    cfg = SpecPCMConfig(hd_dim=2049, mlc_bits=3, num_levels=16,
                        material="tite2", write_verify=3)

    refs_packed = encode_and_pack(ds.spectra, cfg)
    ex = ISAExecutor(ArrayConfig(bits_per_cell=3),
                     DeviceConfig("tite2", 3, 3))

    # program the bank once (amortized, like the paper's reference store)
    ex.load_stage(refs_packed)
    ex.execute_one(Instruction(Opcode.STORE_HV, mlc_bits=3, aux=3))
    print(f"programmed {refs_packed.shape[0]} reference HVs "
          f"({ex.trace.cycles} cycles, {ex.trace.energy_j * 1e6:.2f} uJ)")

    # stream query batches
    q = generate_query_set(ds, ms, num_queries=64)
    q_packed = encode_and_pack(q.spectra, cfg)
    batch = 16
    hits = 0
    t0 = time.time()
    for i in range(0, q_packed.shape[0], batch):
        qb = q_packed[i:i + batch]
        ex.load_stage(qb)
        ex.execute_one(Instruction(Opcode.MVM_COMPUTE, mlc_bits=3, aux=6))
        match = np.asarray(jnp.argmax(ex.result, axis=1))
        truth = np.asarray(q.identity[i:i + batch])
        hits += (np.asarray(ds.identity)[match] == truth).sum()
    wall = time.time() - t0
    n = q_packed.shape[0]
    print(f"served {n} queries in {wall:.2f}s host wall-time; "
          f"top-1 identity accuracy {hits / n:.1%}")
    print(f"instruction trace: {ex.trace.instructions} instructions, "
          f"{ex.trace.cycles} chip cycles "
          f"({ex.trace.cycles / 500e6 * 1e6:.1f} us at 500 MHz), "
          f"{ex.trace.energy_j * 1e6:.2f} uJ")


if __name__ == "__main__":
    main()
