"""Sharded, cached, multi-tenant DB-search serving — the deployment path.

Two client libraries (tenants) are HD-encoded and registered in a lazy
BankRegistry: each reference bank (targets + decoys) is bit-packed and
sharded row-wise over the mesh's 'model' axis only when its first query
arrives, and cold banks LRU-evict while pinned (hot) tenants stay
resident. Queries stream through a tenant-aware micro-batching queue
(flush on max-batch or timeout, per-flush fairness cap); every query HV
is encoded once and memoized in a content-hash LRU cache, so the second
pass over the same stream is served from cache — bit-identical to the
cold pass. Search itself is the per-shard top-k + global merge that is
bit-identical to the unsharded oracle, and merged hits pass target-decoy
FDR filtering. The modeled SpecPCM chip cost for the same workload is
printed alongside.

    PYTHONPATH=src python examples/db_search_serving.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import SpecPCMConfig, encode_and_pack
from repro.core.imc.energy import db_search_cost
from repro.dist.sharding import set_mesh
from repro.launch.mesh import make_debug_mesh
from repro.serve import BankRegistry, DBSearchServer, search_with_fdr
from repro.spectra import SyntheticMSConfig, generate_dataset
from repro.spectra.fdr import make_decoys
from repro.spectra.synthetic import generate_query_set


def main():
    # 1. two tenant reference libraries: 64 peptides x 2 replicate spectra
    mesh = make_debug_mesh()
    set_mesh(mesh)
    cfg = SpecPCMConfig(hd_dim=1024, mlc_bits=1, num_levels=16, ideal=True)
    registry = BankRegistry(mesh=mesh, max_banks=2)
    tenants = {}
    for t, seed in enumerate((0, 1)):
        ms = SyntheticMSConfig(num_identities=64, spectra_per_identity=2,
                               num_bins=512, seed=seed)
        ds = generate_dataset(ms)
        refs_hv = encode_and_pack(ds.spectra, cfg)
        decoys_hv = encode_and_pack(make_decoys(ds.spectra), cfg)
        registry.register(f"lab{t}", refs_hv, decoys=decoys_hv, pin=t == 0)
        qs = generate_query_set(ds, ms, num_queries=32, seed=seed + 10)
        tenants[f"lab{t}"] = (ds, qs,
                              np.asarray(encode_and_pack(qs.spectra, cfg)))
    print(f"registered {len(registry)} tenant banks (lazy; none built yet: "
          f"{[registry.is_built(t) for t in registry.tenants()]})")

    # 2. the serving stack: micro-batching + query-HV cache + shape buckets
    server = DBSearchServer(registry, k=4, fdr=0.05, max_batch_size=16,
                            flush_timeout_s=0.005, cache_bytes=8 << 20,
                            buckets=3, fairness_cap=8)
    # warm the hot tenant's jit cache so p50/p95 measure serving, not the
    # first compile (lab1 pays its lazy build on first request, by design)
    search_with_fdr(registry.get("lab0"),
                    jnp.zeros((16, cfg.hd_dim), jnp.int8), k=4, fdr=0.05)

    # 3. two passes over the interleaved query streams: the first pass is
    # cold (encodes + inserts), the second is served from the cache
    done = []
    meta = {}  # rid -> (tenant, query row)
    for _ in range(2):
        for i in range(32):
            for name in tenants:
                meta[server.submit(tenants[name][2][i], tenant=name)] = (name, i)
            done.extend(server.step())
    done.extend(server.run_until_drained())

    # 4. quality + serving stats
    total = len(done)
    accepted = correct = 0
    for r in done:
        if r.result.match >= 0:
            accepted += 1
            ds, qs, _ = tenants[meta[r.rid][0]]
            correct += int(np.asarray(ds.identity)[r.result.match]
                           == np.asarray(qs.identity)[meta[r.rid][1]])
    s = server.summary()
    print(f"served {s['count']} queries in {s['batches']} micro-batches: "
          f"{s['qps']:.1f} queries/sec, "
          f"p50 {s['p50_ms']:.1f} ms / p95 {s['p95_ms']:.1f} ms")
    qc = s["query_cache"]
    print(f"query-HV cache: hit rate {qc['hit_rate']:.0%} "
          f"({qc['hits']} hits / {qc['misses']} misses, "
          f"{qc['entries']} entries) — pass 2 was served from cache")
    for name in sorted(s["tenants"]):
        ts = s["tenants"][name]
        print(f"  {name}: {ts['count']} reqs, p95 {ts['p95_ms']:.1f} ms, "
              f"cache hit rate {ts['cache_hit_rate']:.0%}")
    print(f"identified at 5% FDR: {accepted}/{total} "
          f"({correct} correct identity)")

    # 5. what would the same scan cost on the SpecPCM chip?
    db = registry.get("lab0")
    cost = db_search_cost(num_queries=total, num_refs=db.num_rows,
                          hd_dim=cfg.hd_dim, candidate_fraction=1.0)
    print(f"modeled chip cost for the same scan: {cost.latency_s * 1e6:.1f} us, "
          f"{cost.energy_j * 1e6:.2f} uJ")


if __name__ == "__main__":
    main()
