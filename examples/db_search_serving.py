"""Sharded, micro-batched DB-search serving — the deployment-shaped path.

The reference library (targets + decoys) is HD-encoded once, bit-packed,
and sharded row-wise over the mesh's 'model' axis; queries stream through
a FIFO micro-batching queue (flush on max-batch or timeout), are searched
with a per-shard top-k + global merge that is bit-identical to the
unsharded oracle, and the merged hits pass target-decoy FDR filtering.
The modeled SpecPCM chip cost for the same workload is printed alongside.

    PYTHONPATH=src python examples/db_search_serving.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import SpecPCMConfig, encode_and_pack
from repro.core.imc.energy import db_search_cost
from repro.dist.sharding import set_mesh
from repro.launch.mesh import make_debug_mesh
from repro.serve import DBSearchServer, search_with_fdr, shard_database
from repro.spectra import SyntheticMSConfig, generate_dataset
from repro.spectra.fdr import make_decoys
from repro.spectra.synthetic import generate_query_set


def main():
    # 1. reference library: 64 peptides x 2 replicate spectra
    ms = SyntheticMSConfig(num_identities=64, spectra_per_identity=2,
                           num_bins=512)
    ds = generate_dataset(ms)
    cfg = SpecPCMConfig(hd_dim=1024, mlc_bits=1, num_levels=16, ideal=True)

    # 2. encode targets + decoys and shard the bank over the 'model' axis
    mesh = make_debug_mesh()
    set_mesh(mesh)
    refs_hv = encode_and_pack(ds.spectra, cfg)
    decoys_hv = encode_and_pack(make_decoys(ds.spectra), cfg)
    db = shard_database(refs_hv, decoys=decoys_hv, mesh=mesh)
    print(f"bank: {db.num_targets} targets + {db.num_decoys} decoys, "
          f"{db.num_shards} shard(s), bit-packed={db.packed}")

    # 3. serve a query stream through the micro-batching queue
    qs = generate_query_set(ds, ms, num_queries=64)
    q_hv = np.asarray(encode_and_pack(qs.spectra, cfg))
    server = DBSearchServer(db, k=4, fdr=0.05, max_batch_size=16,
                            flush_timeout_s=0.005)
    # warm the jit cache (search + FDR routing) so p50/p95 measure serving,
    # not the first compile
    search_with_fdr(db, jnp.zeros((16, cfg.hd_dim), jnp.int8), k=4, fdr=0.05)
    done = []
    for hv in q_hv:
        server.submit(hv)
        done.extend(server.step())     # flushes whenever a batch is ready
    done.extend(server.run_until_drained())

    # 4. quality + serving stats
    ref_ident = np.asarray(ds.identity)
    q_ident = np.asarray(qs.identity)
    done.sort(key=lambda r: r.rid)
    match = np.asarray([r.result.match for r in done])
    ok = match >= 0
    correct = ok & (ref_ident[np.where(ok, match, 0)] == q_ident[: len(done)])
    s = server.summary()
    print(f"served {s['count']} queries in {s['batches']} micro-batches: "
          f"{s['qps']:.1f} queries/sec, "
          f"p50 {s['p50_ms']:.1f} ms / p95 {s['p95_ms']:.1f} ms")
    print(f"identified at 5% FDR: {int(ok.sum())}/{len(done)} "
          f"({int(correct.sum())} with the correct identity)")

    # 5. what would the same scan cost on the SpecPCM chip?
    cost = db_search_cost(num_queries=len(done), num_refs=db.num_rows,
                          hd_dim=cfg.hd_dim, candidate_fraction=1.0)
    print(f"modeled chip cost for the same scan: {cost.latency_s * 1e6:.1f} us, "
          f"{cost.energy_j * 1e6:.2f} uJ")


if __name__ == "__main__":
    main()
