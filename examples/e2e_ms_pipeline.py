"""End-to-end driver: the paper's full MS workflow on synthetic data.

  raw spectra -> preprocess -> HD encode -> dimension packing
    -> [clustering]  bucketed distance MVMs in PCM + complete linkage
    -> condensed reference library (cluster representatives)
    -> [DB search]   query HVs vs library + decoys -> 1% FDR filter
  with the chip-level latency/energy report for every stage.

    PYTHONPATH=src python examples/e2e_ms_pipeline.py [--identities 48]
"""

import argparse

import jax.numpy as jnp
import numpy as np

from repro.core import SpecPCMConfig, run_clustering, run_db_search
from repro.spectra import SyntheticMSConfig, generate_dataset
from repro.spectra.synthetic import generate_query_set


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--identities", type=int, default=48)
    ap.add_argument("--replicates", type=int, default=8)
    ap.add_argument("--queries", type=int, default=96)
    args = ap.parse_args(argv)

    ms = SyntheticMSConfig(num_identities=args.identities,
                           spectra_per_identity=args.replicates,
                           num_bins=1024)
    ds = generate_dataset(ms)
    print(f"[1/4] dataset: {ds.num_spectra} spectra "
          f"({args.identities} peptides x {args.replicates})")

    # --- clustering on the write-cheap Sb2Te3 material ---------------------
    c_cfg = SpecPCMConfig(hd_dim=2049, mlc_bits=3, num_levels=16,
                          material="sb2te3", write_verify=0)
    crep = run_clustering(ds.spectra, ds.precursor, ds.identity, c_cfg)
    print(f"[2/4] clustering: {crep.num_clusters} clusters, "
          f"clustered-ratio={crep.clustered_ratio:.2%}, "
          f"incorrect={crep.incorrect_ratio:.2%}")
    print(f"      chip model: {crep.cost.latency_s * 1e3:.3f} ms, "
          f"{crep.cost.energy_j * 1e6:.1f} uJ")

    # --- condensed library: one representative per cluster -----------------
    labels = crep.labels
    reps = np.unique(labels)
    lib = jnp.asarray(np.asarray(ds.spectra)[reps])
    lib_prec = jnp.asarray(np.asarray(ds.precursor)[reps])
    lib_ident = jnp.asarray(np.asarray(ds.identity)[reps])
    print(f"[3/4] condensed library: {len(reps)} representatives "
          f"({len(reps) / ds.num_spectra:.1%} of raw)")

    # --- DB search on the retention-optimized TiTe2 material ----------------
    s_cfg = SpecPCMConfig(hd_dim=8193, mlc_bits=3, num_levels=16,
                          material="tite2", write_verify=3)
    q = generate_query_set(ds, ms, num_queries=args.queries,
                           modification_rate=0.3)
    srep = run_db_search(q.spectra, q.precursor, lib, lib_prec, s_cfg,
                         query_identity=q.identity, ref_identity=lib_ident)
    print(f"[4/4] DB search: {srep.num_identified}/{q.spectra.shape[0]} "
          f"identified at 1% FDR, recall={srep.recall:.2%}")
    print(f"      chip model: {srep.cost.latency_s * 1e3:.3f} ms, "
          f"{srep.cost.energy_j * 1e6:.1f} uJ")


if __name__ == "__main__":
    main()
