"""Train a small LM with the paper's IMC quantized matmul in the loop.

Every FFN down-projection runs through the SpecPCM analog-chain model
(DAC-quantized activations x MLC-packed weights, per-tile ADC quantization,
straight-through gradients) — the accuracy-under-IMC study for transformer
workloads. Compares against an exact-matmul control.

    PYTHONPATH=src python examples/train_lm_imc.py --steps 300
"""

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data.tokens import TokenPipeline
from repro.models import build_model
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import TrainConfig, init_train_state, make_train_step


def train(cfg, steps, batch, seq, lr, label):
    model = build_model(cfg)
    state, _ = init_train_state(model, jax.random.PRNGKey(0))
    pipe = TokenPipeline(batch=batch, seq=seq, vocab=cfg.vocab_size)
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=lr, warmup_steps=20,
                                             total_steps=steps))
    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0,))
    losses = []
    t0 = time.time()
    for s in range(steps):
        state, m = step_fn(state, pipe.get_for(cfg, s))
        losses.append(float(m["loss"]))
        if (s + 1) % max(steps // 10, 1) == 0:
            print(f"  [{label}] step {s + 1}/{steps} loss={losses[-1]:.4f} "
                  f"({(time.time() - t0) / (s + 1):.2f}s/step)", flush=True)
    return losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    args = ap.parse_args(argv)

    base = dataclasses.replace(
        get_config("qwen2_7b").reduced(),
        num_layers=args.layers, d_model=args.d_model,
        num_heads=8, num_kv_heads=2, head_dim=32,
        d_ff=4 * args.d_model, vocab_size=4096,
    )
    # ~100M-class when scaled up; defaults stay CPU-friendly

    print(f"model: {args.layers}L d={args.d_model} "
          f"(~{6 * args.layers * args.d_model * args.d_model * 1e-6:.1f}M core params)")

    print("== control: exact matmuls ==")
    l_exact = train(base, args.steps, args.batch, args.seq, args.lr, "exact")

    print("== IMC: FFN down-proj through the SpecPCM analog chain ==")
    cfg_imc = dataclasses.replace(base, imc_linear=True)
    l_imc = train(cfg_imc, args.steps, args.batch, args.seq, args.lr, "imc")

    gap = l_imc[-1] - l_exact[-1]
    print(f"final loss: exact={l_exact[-1]:.4f} imc={l_imc[-1]:.4f} "
          f"gap={gap:+.4f}")
    print("conclusion:", "IMC-quantized training tracks the exact baseline"
          if abs(gap) < 0.3 else "IMC quantization is costing accuracy at "
          "this scale — increase ADC bits or HD dim")
    assert np.isfinite(l_imc).all()


if __name__ == "__main__":
    main()
