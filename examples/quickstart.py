"""Quickstart: encode spectra into hypervectors, pack them for 3-bit MLC,
program a (simulated) PCM bank, and run an in-memory similarity search.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SpecPCMConfig, encode_and_pack, imc_scores
from repro.core.imc.energy import db_search_cost
from repro.spectra import SyntheticMSConfig, generate_dataset


def main():
    # 1. make a small synthetic MS dataset (64 peptides x 4 replicates)
    ms = SyntheticMSConfig(num_identities=64, spectra_per_identity=4,
                           num_bins=1024)
    ds = generate_dataset(ms)
    print(f"dataset: {ds.num_spectra} spectra, {ms.num_bins} m/z bins")

    # 2. HD-encode + dimension-pack (Eq. 1 + §III.B of the paper)
    cfg = SpecPCMConfig(hd_dim=2049, mlc_bits=3, num_levels=16)
    packed = encode_and_pack(ds.spectra, cfg)
    print(f"packed HVs: {packed.shape} int8 (D={cfg.hd_dim} -> "
          f"D/n={packed.shape[1]} for {cfg.mlc_bits}-bit MLC)")

    # 3. search the first replicate of each identity against all others
    queries = packed[::4]
    scores = imc_scores(queries, packed, cfg, jax.random.PRNGKey(0))
    best = np.asarray(jnp.argsort(-scores, axis=1)[:, 1])  # skip self
    truth = np.asarray(ds.identity)
    acc = (truth[best] == truth[::4]).mean()
    print(f"nearest-neighbor identity accuracy through the analog chain: "
          f"{acc:.1%}")

    # 4. what would this cost on the SpecPCM chip?
    cost = db_search_cost(num_queries=64, num_refs=256, hd_dim=cfg.hd_dim,
                          candidate_fraction=1.0)
    print(f"modeled chip cost: {cost.latency_s * 1e6:.2f} us, "
          f"{cost.energy_j * 1e9:.1f} nJ")


if __name__ == "__main__":
    main()
