"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + finiteness; serve path (prefill + decode) consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.data.tokens import TokenPipeline
from repro.models import build_model
from repro.train.train_step import TrainConfig, init_train_state, make_train_step


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch).reduced()
            model = build_model(cfg)
            state, axes = init_train_state(model, jax.random.PRNGKey(0))
            cache[arch] = (cfg, model, state)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    # spot-check published numbers (the full table is in the config files)
    assert cfg.num_layers > 0 and cfg.d_model > 0 and cfg.vocab_size > 0
    assert cfg.num_heads % cfg.num_kv_heads == 0
    if cfg.is_moe:
        assert cfg.top_k >= 1 and cfg.num_experts > cfg.top_k
    assert cfg.padded_vocab >= cfg.vocab_size
    assert cfg.padded_vocab % 256 == 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch, arch_state):
    cfg, model, state = arch_state(arch)
    pipe = TokenPipeline(batch=4, seq=32, vocab=cfg.vocab_size)
    batch = pipe.get_for(cfg, 0)
    step = jax.jit(make_train_step(model, TrainConfig()))
    state1, m1 = step(state, batch)
    state2, m2 = step(state1, batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    assert float(m2["loss"]) < float(m1["loss"]) + 0.5  # not diverging
    assert int(state2.step) == 2
    # params actually changed
    d0 = jax.tree.leaves(state.params)[0]
    d2 = jax.tree.leaves(state2.params)[0]
    assert not np.array_equal(np.asarray(d0), np.asarray(d2))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_serve_smoke(arch, arch_state):
    cfg, model, state = arch_state(arch)
    params = state.params
    pipe = TokenPipeline(batch=2, seq=32, vocab=cfg.vocab_size)
    batch = pipe.get_for(cfg, 0)
    cache = model.init_cache(2, 32)
    logits, cache = model.prefill(params, batch, cache)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.padded_vocab
    assert np.isfinite(np.asarray(logits)).all()
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    pos0 = batch["tokens"].shape[1]
    if cfg.family == "vlm":
        pos0 += batch["patches"].shape[1]
    pos0 = min(pos0, 31)
    logits2, _ = model.decode_step(params, tok, cache,
                                   jnp.asarray(pos0, jnp.int32))
    assert logits2.shape == (2, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits2)).all()


@pytest.mark.parametrize("arch", ["qwen2_7b", "xlstm_125m", "hymba_1_5b"])
def test_prefill_decode_consistency(arch, arch_state):
    """Decoding token t+1 after a prefill of t tokens must match the
    training-mode forward at position t (same model, same math)."""
    cfg, model, state = arch_state(arch)
    params = state.params
    pipe = TokenPipeline(batch=1, seq=16, vocab=cfg.vocab_size)
    tokens = pipe.get(0)["tokens"]

    # full forward over seq: logits at position i predict token i+1
    from repro.models.transformer import forward_train
    full = forward_train(params, tokens, cfg, remat="none")

    # prefill on first 15 tokens, then decode the 16th
    cache = model.init_cache(1, 16)
    logits_p, cache = model.prefill(params, {"tokens": tokens[:, :15]}, cache)
    logits_d, _ = model.decode_step(params, tokens[:, 15:16], cache,
                                    jnp.asarray(15, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(logits_d[0, 0]), np.asarray(full[0, 15]),
        rtol=2e-2, atol=2e-2)


def test_long_500k_eligibility():
    eligible = [a for a in ARCH_IDS
                if get_config(a).supports_long_decode]
    assert sorted(eligible) == ["hymba_1_5b", "xlstm_125m"]


def test_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
