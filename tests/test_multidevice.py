"""Multi-device tests via subprocess (8 host devices): the dry-run machinery
on a small mesh, sharded training equivalence, and compressed cross-pod
all-reduce. Subprocesses are used because device count is fixed at jax init.
"""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def _run_py(code: str, devices: int = 8, timeout: int = 520):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("JAX_PLATFORMS", None)
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)


@pytest.mark.slow
def test_dryrun_machinery_small_mesh(tmp_path):
    """Exercise run_cell end-to-end on an 8-device (2, 4) mesh by shrinking
    the production mesh — proves lower/compile/analysis plumbing without the
    512-device cost."""
    r = _run_py(f"""
        import jax
        from pathlib import Path
        import repro.launch.mesh as mesh_mod
        mesh_mod.make_production_mesh = (
            lambda multi_pod=False: jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
            if multi_pod else jax.make_mesh((2, 4), ("data", "model")))
        import repro.launch.dryrun as dr
        import repro.configs.shapes as shp
        import dataclasses
        shp.SHAPES["train_4k"] = dataclasses.replace(
            shp.SHAPES["train_4k"], global_batch=8, seq_len=256)
        out = dr.run_cell("qwen2_7b", "train_4k", "single",
                          Path(r"{tmp_path}"))
        assert out["status"] == "ok", out
        assert out["roofline"]["flops"] > 0
        out2 = dr.run_cell("qwen2_7b", "train_4k", "multi",
                           Path(r"{tmp_path}"))
        assert out2["status"] == "ok", out2
        print("DRYRUN_OK")
    """)
    assert "DRYRUN_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_sharded_training_matches_single_device():
    """The same train step on a (2, 2, 2) mesh and on a host replica must
    produce identical losses (SPMD correctness)."""
    r = _run_py("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.configs import get_config
        from repro.models import build_model
        from repro.data.tokens import TokenPipeline
        from repro.dist.sharding import set_mesh, logical_to_sharding
        from repro.train.train_step import (TrainConfig, init_train_state,
                                            make_train_step, state_axes)

        cfg = get_config("qwen2_7b").reduced()
        model = build_model(cfg)
        pipe = TokenPipeline(batch=8, seq=32, vocab=cfg.vocab_size)
        losses = {}
        for mode in ("replicated", "sharded"):
            if mode == "sharded":
                mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
                set_mesh(mesh)
            else:
                set_mesh(None)
            state, axes = init_train_state(model, jax.random.PRNGKey(0))
            if mode == "sharded":
                st_axes = state_axes(axes)
                sh = jax.tree.map(
                    lambda ax, x: logical_to_sharding(ax, tuple(x.shape), mesh),
                    st_axes, state,
                    is_leaf=lambda x: isinstance(x, tuple) and all(
                        isinstance(e, (str, type(None))) for e in x))
                state = jax.tree.map(
                    lambda x, s: jax.device_put(x, s) if s is not None else x,
                    state, sh)
            step = jax.jit(make_train_step(model, TrainConfig()))
            ls = []
            for s in range(3):
                state, m = step(state, pipe.get_for(cfg, s))
                ls.append(float(m["loss"]))
            losses[mode] = ls
        np.testing.assert_allclose(losses["replicated"], losses["sharded"],
                                   rtol=1e-4)
        print("SPMD_OK", losses["sharded"])
    """)
    assert "SPMD_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_hierarchical_train_step_on_pod_mesh():
    """The real shard_map route of the hierarchical ICI/DCN train step on
    a (2, 2, 2) ('pod', 'data', 'model') mesh: with dcn_compression='none'
    it matches the single-device emulated fold (SPMD correctness), and
    topk_ef trains with pod-sharded EF residuals."""
    r = _run_py("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.configs import get_config
        from repro.models import build_model
        from repro.data.tokens import TokenPipeline
        from repro.dist.sharding import (set_mesh, is_axes_leaf,
                                         logical_to_sharding)
        from repro.train.train_step import (TrainConfig, init_train_state,
                                            make_train_step, state_axes)

        cfg = get_config("qwen2_7b").reduced()
        model = build_model(cfg)
        pipe = TokenPipeline(batch=8, seq=32, vocab=cfg.vocab_size)
        from repro.train.optimizer import AdamWConfig
        opt = AdamWConfig(lr=1e-3)

        def run(tcfg, mesh):
            set_mesh(mesh)
            state, axes = init_train_state(model, jax.random.PRNGKey(0),
                                           tcfg, mesh)
            if mesh is not None:
                sh = jax.tree.map(
                    lambda ax, x: logical_to_sharding(ax, tuple(x.shape), mesh),
                    state_axes(axes, tcfg), state, is_leaf=is_axes_leaf)
                state = jax.tree.map(
                    lambda x, s: jax.device_put(x, s) if s is not None else x,
                    state, sh)
            raw = make_train_step(model, tcfg, mesh)
            fn = jax.jit(raw)
            ls = []
            for s in range(3):
                state, m = fn(state, pipe.get_for(cfg, s))
                ls.append(float(m["loss"]))
            set_mesh(None)
            return raw, state, ls

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))

        # defaults on a pod mesh keep the pre-hierarchy global reduction
        # (an uncompressed shard_map hop would cost memory for nothing)
        raw_g = make_train_step(model, TrainConfig(optimizer=opt), mesh)
        assert raw_g.dcn_route == "global", raw_g.dcn_route

        raw_e, _, l_emulated = run(TrainConfig(optimizer=opt, dcn_pods=2),
                                   None)
        assert raw_e.dcn_route == "emulated", raw_e.dcn_route
        raw_s, _, l_shardmap = run(TrainConfig(optimizer=opt, dcn_pods=2),
                                   mesh)
        assert raw_s.dcn_route == "shard_map", raw_s.dcn_route
        assert raw_s.dcn_pods == 2
        np.testing.assert_allclose(l_emulated, l_shardmap, rtol=1e-4)

        raw_c, st, l_ef = run(TrainConfig(optimizer=opt, dcn_pods=0,
                                          dcn_compression="topk_ef"), mesh)
        assert raw_c.dcn_route == "shard_map"
        assert np.isfinite(l_ef).all()
        np.testing.assert_allclose(l_ef, l_shardmap, atol=0.05)
        leaves = jax.tree.leaves(st.ef)
        assert leaves and all(l.shape[0] == 2 for l in leaves)
        assert any("pod" in str(l.sharding.spec) for l in leaves)
        assert sum(float(jnp.abs(l).sum()) for l in leaves) > 0
        print("HIER_OK", l_shardmap)
    """)
    assert "HIER_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_compressed_cross_pod_allreduce():
    r = _run_py("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.dist.compression import cross_pod_allreduce
        mesh = jax.make_mesh((8,), ("pod",))
        x = jnp.arange(32, dtype=jnp.float32).reshape(8, 4)
        xs = jax.device_put(x, jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec("pod", None)))
        out = cross_pod_allreduce(xs, mesh, axis="pod", method="int8")
        expect = np.broadcast_to(np.asarray(x).sum(0, keepdims=True), (8, 4))
        err = np.abs(np.asarray(out) - expect).max() / expect.max()
        assert err < 0.05, err
        print("XPOD_OK")
    """)
    assert "XPOD_OK" in r.stdout, r.stdout + r.stderr
