"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.imc.array import ArrayConfig, default_full_scale
from repro.kernels.hamming_pop.ops import hamming_pop_pallas
from repro.kernels.hamming_pop.ref import hamming_pop_ref
from repro.kernels.hd_encode.ops import hd_encode_pallas
from repro.kernels.hd_encode.ref import hd_encode_ref
from repro.kernels.imc_mvm.ops import imc_mvm_pallas
from repro.kernels.imc_mvm.ref import imc_mvm_ref


class TestIMCMVMKernel:
    @pytest.mark.parametrize("q,r,dp", [
        (8, 16, 128),        # single tile
        (128, 128, 256),     # exact blocks
        (96, 200, 342),      # ragged everything (padding path)
        (1, 300, 684),       # single query
        (130, 7, 129),       # ragged blocks both sides
    ])
    def test_matches_ref_across_shapes(self, q, r, dp):
        key = jax.random.PRNGKey(q * 1000 + r + dp)
        k1, k2, k3 = jax.random.split(key, 3)
        qq = jax.random.randint(k1, (q, dp), -3, 4).astype(jnp.float32)
        ww = jax.random.randint(k2, (r, dp), -3, 4).astype(jnp.float32)
        ww = ww * (1 + 0.05 * jax.random.normal(k3, (r, dp)))
        fs = default_full_scale(ArrayConfig())
        out_k = imc_mvm_pallas(qq, ww, full_scale=fs)
        out_r = imc_mvm_ref(qq, ww, full_scale=fs)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   rtol=1e-5, atol=1e-3)

    @pytest.mark.parametrize("adc_levels", [7, 31, 127])
    def test_adc_precision_sweep(self, adc_levels):
        key = jax.random.PRNGKey(adc_levels)
        k1, k2 = jax.random.split(key)
        qq = jax.random.randint(k1, (32, 256), -3, 4).astype(jnp.float32)
        ww = jax.random.randint(k2, (64, 256), -3, 4).astype(jnp.float32)
        fs = 135.76
        out_k = imc_mvm_pallas(qq, ww, full_scale=fs, adc_levels=adc_levels)
        out_r = imc_mvm_ref(qq, ww, full_scale=fs, adc_levels=adc_levels)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   rtol=1e-5, atol=1e-3)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtype_sweep(self, dtype):
        key = jax.random.PRNGKey(0)
        k1, k2 = jax.random.split(key)
        qq = jax.random.randint(k1, (16, 128), -3, 4).astype(dtype)
        ww = jax.random.randint(k2, (16, 128), -3, 4).astype(dtype)
        fs = 135.76
        out_k = imc_mvm_pallas(qq, ww, full_scale=fs)
        out_r = imc_mvm_ref(qq.astype(jnp.float32), ww.astype(jnp.float32),
                            full_scale=fs)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   rtol=1e-2, atol=1.0)

    def test_block_shape_invariance(self):
        key = jax.random.PRNGKey(7)
        k1, k2 = jax.random.split(key)
        qq = jax.random.randint(k1, (64, 256), -3, 4).astype(jnp.float32)
        ww = jax.random.randint(k2, (64, 256), -3, 4).astype(jnp.float32)
        fs = 135.76
        a = imc_mvm_pallas(qq, ww, full_scale=fs, block_q=32, block_r=64)
        b = imc_mvm_pallas(qq, ww, full_scale=fs, block_q=64, block_r=128)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


class TestHDEncodeKernel:
    @pytest.mark.parametrize("b,f,m,d", [
        (8, 128, 16, 256),    # exact blocks
        (12, 200, 16, 500),   # ragged
        (1, 64, 4, 64),       # tiny
        (9, 300, 32, 1030),   # ragged all dims
    ])
    def test_matches_ref(self, b, f, m, d):
        key = jax.random.PRNGKey(b * 7 + f + d)
        k1, k2, k3 = jax.random.split(key, 3)
        levels = jax.random.randint(k1, (b, f), 0, m)
        id_hvs = jax.random.rademacher(k2, (f, d), dtype=jnp.int8)
        lv_hvs = jax.random.rademacher(k3, (m, d), dtype=jnp.int8)
        out_k = hd_encode_pallas(levels, id_hvs, lv_hvs)
        out_r = hd_encode_ref(levels, id_hvs, lv_hvs)
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))

    def test_all_absent_levels(self):
        levels = jnp.zeros((4, 128), jnp.int32)
        key = jax.random.PRNGKey(0)
        k1, k2 = jax.random.split(key)
        id_hvs = jax.random.rademacher(k1, (128, 256), dtype=jnp.int8)
        lv_hvs = jax.random.rademacher(k2, (8, 256), dtype=jnp.int8)
        out = hd_encode_pallas(levels, id_hvs, lv_hvs)
        assert np.all(np.asarray(out) == -1)


class TestHammingPopKernel:
    @pytest.mark.parametrize("q,r,w", [
        (128, 128, 32),   # exact blocks
        (50, 70, 17),     # ragged
        (1, 1, 1),        # minimal
        (200, 130, 64),   # multi-block
    ])
    def test_matches_ref(self, q, r, w):
        rng = np.random.default_rng(q + r + w)
        qp = jnp.asarray(rng.integers(0, 2**32, (q, w), dtype=np.uint32))
        rp = jnp.asarray(rng.integers(0, 2**32, (r, w), dtype=np.uint32))
        out_k = hamming_pop_pallas(qp, rp, dim=w * 32)
        out_r = hamming_pop_ref(qp, rp, w * 32)
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))

    def test_self_similarity_is_dim(self):
        rng = np.random.default_rng(0)
        qp = jnp.asarray(rng.integers(0, 2**32, (5, 8), dtype=np.uint32))
        out = hamming_pop_pallas(qp, qp, dim=256)
        assert (np.diag(np.asarray(out)) == 256).all()

    def test_consistency_with_dense_path(self):
        """Packed-kernel scores == dense bipolar dot-derived similarity."""
        from repro.core.hd.similarity import (
            bitpack_bipolar, hamming_similarity)
        rng = np.random.default_rng(1)
        a = jnp.asarray(rng.choice([-1, 1], (10, 128)).astype(np.int8))
        b = jnp.asarray(rng.choice([-1, 1], (12, 128)).astype(np.int8))
        dense = np.asarray(hamming_similarity(a, b))
        kernel = np.asarray(hamming_pop_pallas(
            bitpack_bipolar(a), bitpack_bipolar(b), dim=128))
        np.testing.assert_array_equal(dense, kernel)


class TestDecodeAttentionKernel:
    """Fused int8-KV decode attention (the §Perf cell-3 future kernel)."""

    def _inputs(self, b, s, kv, g, hd, seed=0, valid=None):
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.normal(size=(b, kv, g, hd)).astype(np.float32))
        k8 = jnp.asarray(rng.integers(-127, 128, (b, s, kv, hd), dtype=np.int8))
        v8 = jnp.asarray(rng.integers(-127, 128, (b, s, kv, hd), dtype=np.int8))
        ks = jnp.asarray(rng.uniform(0.005, 0.02, (b, s, kv)).astype(np.float32))
        vs = jnp.asarray(rng.uniform(0.005, 0.02, (b, s, kv)).astype(np.float32))
        vl = jnp.asarray(valid if valid is not None else s, jnp.int32)
        return q, k8, v8, ks, vs, vl

    @pytest.mark.parametrize("b,s,kv,g,hd", [
        (1, 128, 1, 4, 32),
        (2, 256, 2, 8, 64),
        (2, 96, 4, 7, 16),   # ragged seq (padding path), odd group count
    ])
    def test_matches_ref(self, b, s, kv, g, hd):
        from repro.kernels.decode_attention.ops import decode_attention_pallas
        from repro.kernels.decode_attention.ref import decode_attention_ref
        q, k8, v8, ks, vs, vl = self._inputs(b, s, kv, g, hd)
        out_k = decode_attention_pallas(q, k8, v8, ks, vs, vl, chunk=64)
        out_r = decode_attention_ref(q, k8, v8, ks, vs, vl)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   rtol=2e-4, atol=2e-4)

    def test_valid_len_masks_tail(self):
        from repro.kernels.decode_attention.ops import decode_attention_pallas
        q, k8, v8, ks, vs, _ = self._inputs(1, 128, 2, 4, 32, seed=1)
        vl = jnp.asarray(70, jnp.int32)
        out = decode_attention_pallas(q, k8, v8, ks, vs, vl, chunk=64)
        # perturbing masked positions must not change the output
        k8_b = k8.at[:, 80:].set(127)
        out_b = decode_attention_pallas(q, k8_b, v8, ks, vs, vl, chunk=64)
        np.testing.assert_allclose(np.asarray(out), np.asarray(out_b),
                                   rtol=1e-6)

    def test_matches_layer_decode_path(self):
        """Kernel output == the model's QuantKVCache decode attention."""
        import dataclasses
        from repro.configs import get_config
        from repro.kernels.decode_attention.ops import decode_attention_pallas
        from repro.models import layers as L

        cfg = dataclasses.replace(get_config("qwen2_7b").reduced(),
                                  kv_quant_int8=True)
        p, _ = L.init_attention(jax.random.PRNGKey(0), cfg)
        S = 16
        x = jax.random.normal(jax.random.PRNGKey(1), (1, S, cfg.d_model),
                              jnp.float32) * 0.1
        cache = L.init_kv_cache(cfg, 1, S)
        for t in range(S - 1):
            _, cache = L.attention_decode(p, x[:, t:t + 1], cfg, cache,
                                          jnp.asarray(t, jnp.int32))
        # layer path for the final token
        y_layer, cache2 = L.attention_decode(p, x[:, S - 1:S], cfg, cache,
                                             jnp.asarray(S - 1, jnp.int32))
        # kernel path on the same quantized cache
        hd = cfg.resolved_head_dim
        positions = jnp.full((1, 1), S - 1, jnp.int32)
        q, _, _ = L._qkv(p, x[:, S - 1:S], cfg, positions)
        qg = L._group_q(q, cfg.num_kv_heads)[:, 0] * hd ** -0.5  # (B,KV,G,hd)
        out = decode_attention_pallas(
            qg, cache2.k, cache2.v, cache2.k_scale, cache2.v_scale,
            jnp.asarray(S, jnp.int32), chunk=8)
        b, kv, g, _ = out.shape
        out = out.reshape(1, 1, cfg.num_heads, hd).astype(x.dtype)
        y_kernel = jnp.einsum("bqhk,hkd->bqd", out, p["wo"].astype(x.dtype))
        np.testing.assert_allclose(np.asarray(y_layer), np.asarray(y_kernel),
                                   rtol=2e-2, atol=2e-3)
