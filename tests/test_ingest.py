"""Streaming ingestion tests: append-only delta banks, exact merged
base+delta search (property-tested bit-identical to a from-scratch
rebuild, exact and OMS, across emulated shard counts, packed/int8
storage, and injected score ties), background compaction (threshold,
atomicity under injected build failures, idempotence, interleaved
queries), registry counters/validation, and the full server delta path
through FDR. The real 8-device mesh variant lives in the slow tier."""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.serve import (
    BankRegistry,
    DBSearchServer,
    DeltaBank,
    OMSConfig,
    encode_queries,
    merged_oms_plan,
    merged_oms_search_encoded,
    merged_search_encoded,
    oms_search,
    search_database,
    shard_database,
)

REPO = Path(__file__).resolve().parent.parent

D = 64
K = 5


def _bip(rng, shape):
    return rng.choice([-1, 1], size=shape).astype(np.int8)


def _fixture(seed):
    """Fixed shapes (so jit signatures are shared across property
    examples), random content, ties injected across every block pair."""
    rng = np.random.default_rng(seed)
    refs0, dec0 = _bip(rng, (41, D)), _bip(rng, (23, D))
    refs1, dec1 = _bip(rng, (7, D)), _bip(rng, (5, D))
    refs1[0] = refs0[3]     # delta target == base target: exact score tie
    dec1[1] = dec0[2]       # delta decoy == base decoy
    refs1[2] = dec0[4]      # delta target == base decoy: decoy must win ties
    q = _bip(rng, (12, D))
    q[5] = refs1[0]         # a query sitting exactly on the tied rows
    return refs0, dec0, refs1, dec1, q


def _rebuilt(refs0, dec0, refs1, dec1, **kw):
    return shard_database(jnp.asarray(np.concatenate([refs0, refs1])),
                          decoys=jnp.asarray(np.concatenate([dec0, dec1])),
                          **kw)


# --------------------------------------------------------------------------
# library level: merged base+delta search == from-scratch rebuild
# --------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([1, 2, 4, 8]))
def test_merged_search_bit_identical_to_rebuild(seed, shards):
    refs0, dec0, refs1, dec1, q = _fixture(seed)
    qj = jnp.asarray(q)
    for pack in (True, False):
        base = shard_database(jnp.asarray(refs0), decoys=jnp.asarray(dec0),
                              pack=pack, emulate_shards=shards)
        delta = DeltaBank(D, oms=False)
        delta.append(refs1[:3], dec1[:2])
        delta.append(refs1[3:], dec1[2:])  # accumulation across appends
        mi, mv = merged_search_encoded(base, delta, encode_queries(base, qj),
                                       qj, K)
        oi, ov = search_database(
            _rebuilt(refs0, dec0, refs1, dec1, pack=pack,
                     emulate_shards=shards), qj, K)
        assert (np.asarray(mi) == np.asarray(oi)).all(), (seed, shards, pack)
        assert (np.asarray(mv) == np.asarray(ov)).all(), (seed, shards, pack)


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([1, 2, 4, 8]))
def test_merged_oms_bit_identical_to_rebuild(seed, shards):
    refs0, dec0, refs1, dec1, q = _fixture(seed)
    rng = np.random.default_rng(seed + 1)
    prec0 = rng.uniform(400, 1600, refs0.shape[0]).astype(np.float32)
    prec1 = rng.uniform(400, 1600, refs1.shape[0]).astype(np.float32)
    prec1[0] = prec0[3]  # tied rows share a mass: both inside any window
    qprec = np.sort(rng.uniform(420, 1650, q.shape[0]).astype(np.float32))
    cfg = OMSConfig(tol=15.0, open_tol=150.0)
    qj = jnp.asarray(q)
    for pack in (True, False):
        base = shard_database(jnp.asarray(refs0), decoys=jnp.asarray(dec0),
                              pack=pack, emulate_shards=shards,
                              precursor=prec0,
                              decoy_precursor=prec0[:dec0.shape[0]])
        delta = DeltaBank(D, oms=True)
        delta.append(refs1, dec1, precursor=prec1,
                     decoy_precursor=prec1[:dec1.shape[0]])
        mplan = merged_oms_plan(base, delta, qprec, cfg)
        rebuilt = _rebuilt(refs0, dec0, refs1, dec1, pack=pack,
                           emulate_shards=shards,
                           precursor=np.concatenate([prec0, prec1]),
                           decoy_precursor=np.concatenate(
                               [prec0[:dec0.shape[0]],
                                prec1[:dec1.shape[0]]]))
        oi, ov, oplan = oms_search(rebuilt, qj, qprec, K, cfg)
        # the merged index reproduces the rebuilt bank's candidate plan
        assert (mplan.starts == oplan.starts).all(), (seed, shards, pack)
        assert (mplan.lens == oplan.lens).all(), (seed, shards, pack)
        assert (mplan.has_candidate == oplan.has_candidate).all()
        mi, mv = merged_oms_search_encoded(
            base, delta, encode_queries(base, qj), qj, mplan, K)
        assert (np.asarray(mi) == np.asarray(oi)).all(), (seed, shards, pack)
        assert (np.asarray(mv) == np.asarray(ov)).all(), (seed, shards, pack)


def test_merged_search_degenerate_block_shapes():
    """Tiny deltas (rows < k), decoy-less deltas, and decoy-less bases
    all merge bit-identically."""
    rng = np.random.default_rng(7)
    refs0, dec0 = _bip(rng, (19, D)), _bip(rng, (11, D))
    q = jnp.asarray(_bip(rng, (6, D)))
    # delta of a single ref, no decoys (delta rows < k)
    one = _bip(rng, (1, D))
    base = shard_database(jnp.asarray(refs0), decoys=jnp.asarray(dec0),
                          emulate_shards=2)
    delta = DeltaBank(D, oms=False)
    delta.append(one)
    mi, mv = merged_search_encoded(base, delta, encode_queries(base, q), q, K)
    oracle = shard_database(jnp.asarray(np.concatenate([refs0, one])),
                            decoys=jnp.asarray(dec0), emulate_shards=2)
    oi, ov = search_database(oracle, q, K)
    assert (np.asarray(mi) == np.asarray(oi)).all()
    assert (np.asarray(mv) == np.asarray(ov)).all()
    # decoy-less base, delta carrying both refs and decoys
    base2 = shard_database(jnp.asarray(refs0), emulate_shards=2)
    delta2 = DeltaBank(D, oms=False)
    refs1, dec1 = _bip(rng, (4, D)), _bip(rng, (3, D))
    delta2.append(refs1, dec1)
    mi2, mv2 = merged_search_encoded(base2, delta2,
                                     encode_queries(base2, q), q, K)
    oracle2 = shard_database(jnp.asarray(np.concatenate([refs0, refs1])),
                             decoys=jnp.asarray(dec1), emulate_shards=2)
    oi2, ov2 = search_database(oracle2, q, K)
    assert (np.asarray(mi2) == np.asarray(oi2)).all()
    assert (np.asarray(mv2) == np.asarray(ov2)).all()


# --------------------------------------------------------------------------
# DeltaBank / BankRegistry validation + counters
# --------------------------------------------------------------------------

def test_delta_bank_validation():
    d = DeltaBank(D, oms=False)
    with pytest.raises(ValueError, match="refs shape"):
        d.append(np.zeros((3, D + 1), np.int8))
    with pytest.raises(ValueError, match="decoys shape"):
        d.append(np.zeros((3, D), np.int8), np.zeros((3, D - 1), np.int8))
    with pytest.raises(ValueError, match="at least one"):
        d.append(np.zeros((0, D), np.int8))
    with pytest.raises(ValueError, match="no precursor"):
        d.append(np.zeros((2, D), np.int8), precursor=np.ones(2))
    assert d.num_rows == 0 and d.version == 0  # failed appends land nothing

    o = DeltaBank(D, oms=True)
    with pytest.raises(ValueError, match="requires precursor"):
        o.append(np.ones((2, D), np.int8))
    with pytest.raises(ValueError, match="precursor has 3"):
        o.append(np.ones((2, D), np.int8), precursor=np.ones(3))
    with pytest.raises(ValueError, match="decoy_precursor has 1"):
        o.append(np.ones((2, D), np.int8), np.ones((2, D), np.int8),
                 precursor=np.ones(2), decoy_precursor=np.ones(1))
    assert o.append(np.ones((2, D), np.int8), precursor=np.ones(2)) == 2


def test_registry_append_counters_and_guards():
    rng = np.random.default_rng(3)
    reg = BankRegistry(emulate_shards=2)
    refs, dec = _bip(rng, (20, D)), _bip(rng, (10, D))
    reg.register("a", jnp.asarray(refs), decoys=jnp.asarray(dec))
    with pytest.raises(KeyError):
        reg.append("nope", _bip(rng, (1, D)))
    # adopted (spec-less) banks cannot accept appends
    reg.adopt("pre", shard_database(jnp.asarray(refs)))
    with pytest.raises(ValueError, match="adopted"):
        reg.append("pre", _bip(rng, (1, D)))

    assert reg.delta("a") is None and reg.delta_fraction("a") == 0.0
    assert reg.append("a", _bip(rng, (4, D)), _bip(rng, (2, D))) == 6
    assert reg.append("a", _bip(rng, (2, D))) == 8
    assert reg.appends == 2 and reg.tenants_with_delta() == ["a"]
    assert reg.delta_fraction("a") == pytest.approx(8 / 38)
    s = reg.summary()
    assert s["appends"] == 2 and s["compactions"] == 0
    assert s["delta_rows"] == 8 and s["tenants_with_delta"] == 1
    # re-registering drops the pending delta with the stale spec
    reg.register("a", jnp.asarray(refs), decoys=jnp.asarray(dec))
    assert reg.delta("a") is None and reg.tenants_with_delta() == []


def test_compaction_folds_delta_and_is_idempotent():
    rng = np.random.default_rng(11)
    reg = BankRegistry(emulate_shards=2)
    refs, dec = _bip(rng, (24, D)), _bip(rng, (12, D))
    refs1, dec1 = _bip(rng, (6, D)), _bip(rng, (3, D))
    reg.register("a", jnp.asarray(refs), decoys=jnp.asarray(dec))
    assert reg.compact("a") is False  # nothing to compact
    reg.append("a", refs1, dec1)
    q = jnp.asarray(_bip(rng, (8, D)))
    db, delta = reg.get_with_delta("a")
    before = merged_search_encoded(db, delta, encode_queries(db, q), q, K)
    assert reg.compact("a") is True
    db2, delta2 = reg.get_with_delta("a")
    assert delta2 is None and reg.compactions == 1
    assert db2.num_rows == 45 and db2.num_decoys == 15
    after = search_database(db2, q, K)
    assert (np.asarray(before[0]) == np.asarray(after[0])).all()
    assert (np.asarray(before[1]) == np.asarray(after[1])).all()
    assert reg.compact("a") is False and reg.compactions == 1  # idempotent


def test_compaction_atomic_under_build_failure(monkeypatch):
    """A failing merged build leaves the registry exactly as it was: old
    bank still served, delta still pending, counters untouched."""
    rng = np.random.default_rng(13)
    reg = BankRegistry(emulate_shards=2)
    refs, dec = _bip(rng, (16, D)), _bip(rng, (8, D))
    reg.register("a", jnp.asarray(refs), decoys=jnp.asarray(dec))
    reg.append("a", _bip(rng, (4, D)))
    old_db = reg.get("a")
    import repro.serve.db_search as db_search_mod

    def boom(*a, **kw):
        raise RuntimeError("injected build failure")

    monkeypatch.setattr(db_search_mod, "shard_database", boom)
    with pytest.raises(RuntimeError, match="injected"):
        reg.compact("a")
    monkeypatch.undo()
    assert reg.get("a") is old_db
    assert reg.delta("a") is not None and reg.delta("a").num_rows == 4
    assert reg.compactions == 0 and reg.tenants_with_delta() == ["a"]


# --------------------------------------------------------------------------
# server level: delta path through FDR, compaction between batches
# --------------------------------------------------------------------------

def _drain_results(server, queries, tenant, prec=None):
    rids = [server.submit(q, tenant=tenant,
                          precursor=None if prec is None else float(prec[i]))
            for i, q in enumerate(queries)]
    done = {r.rid: r for r in server.run_until_drained()}
    return [done[rid].result for rid in rids]


def _assert_results_equal(got, want):
    assert len(got) == len(want)
    for g, w in zip(got, want):
        assert (np.asarray(g.indices) == np.asarray(w.indices)).all()
        assert (np.asarray(g.scores) == np.asarray(w.scores)).all()
        assert g.is_target == w.is_target and g.accept == w.accept
        assert g.match == w.match and g.has_candidate == w.has_candidate


def test_server_delta_path_matches_rebuilt_through_fdr():
    rng = np.random.default_rng(17)
    refs0, dec0 = _bip(rng, (30, D)), _bip(rng, (15, D))
    refs1, dec1 = _bip(rng, (6, D)), _bip(rng, (3, D))
    refs1[1] = refs0[0]  # tie across the append boundary
    queries = list(_bip(rng, (10, D)))
    queries[2] = refs1[1].copy()

    live_reg = BankRegistry(emulate_shards=2)
    live_reg.register("a", jnp.asarray(refs0), decoys=jnp.asarray(dec0))
    live = DBSearchServer(live_reg, k=4, fdr=0.5, max_batch_size=4,
                          flush_timeout_s=0.0)
    live.append("a", refs1, dec1)

    oracle_reg = BankRegistry(emulate_shards=2)
    oracle_reg.register("a", jnp.asarray(np.concatenate([refs0, refs1])),
                        decoys=jnp.asarray(np.concatenate([dec0, dec1])))
    oracle = DBSearchServer(oracle_reg, k=4, fdr=0.5, max_batch_size=4,
                            flush_timeout_s=0.0)

    _assert_results_equal(_drain_results(live, queries, "a"),
                          _drain_results(oracle, queries, "a"))
    ing = live.summary()["ingest"]
    assert ing["appends"] == 1 and ing["tenants_with_delta"] == ["a"]


def test_server_oms_delta_path_matches_rebuilt_through_fdr():
    rng = np.random.default_rng(19)
    refs0, dec0 = _bip(rng, (30, D)), _bip(rng, (15, D))
    refs1, dec1 = _bip(rng, (6, D)), _bip(rng, (3, D))
    prec0 = rng.uniform(400, 1600, 30).astype(np.float32)
    prec1 = rng.uniform(400, 1600, 6).astype(np.float32)
    queries = list(_bip(rng, (10, D)))
    qprec = rng.uniform(420, 1650, 10).astype(np.float32)  # unsorted
    cfg = OMSConfig(tol=15.0, open_tol=150.0)

    live_reg = BankRegistry(emulate_shards=2)
    live_reg.register("a", jnp.asarray(refs0), decoys=jnp.asarray(dec0),
                      precursor=prec0, decoy_precursor=prec0[:15])
    live = DBSearchServer(live_reg, k=4, fdr=0.5, max_batch_size=4,
                          flush_timeout_s=0.0, oms=cfg)
    live.append("a", refs1, dec1, precursor=prec1,
                decoy_precursor=prec1[:3])

    oracle_reg = BankRegistry(emulate_shards=2)
    oracle_reg.register(
        "a", jnp.asarray(np.concatenate([refs0, refs1])),
        decoys=jnp.asarray(np.concatenate([dec0, dec1])),
        precursor=np.concatenate([prec0, prec1]),
        decoy_precursor=np.concatenate([prec0[:15], prec1[:3]]))
    oracle = DBSearchServer(oracle_reg, k=4, fdr=0.5, max_batch_size=4,
                            flush_timeout_s=0.0, oms=cfg)

    _assert_results_equal(_drain_results(live, queries, "a", qprec),
                          _drain_results(oracle, queries, "a", qprec))


def test_server_compacts_between_batches_without_dropping_requests():
    """Queries queued before a threshold-crossing append survive the
    compaction (it runs between batches) and return the rebuilt bank's
    exact results."""
    rng = np.random.default_rng(23)
    refs0, dec0 = _bip(rng, (20, D)), _bip(rng, (10, D))
    refs1, dec1 = _bip(rng, (8, D)), _bip(rng, (4, D))
    queries = list(_bip(rng, (8, D)))

    reg = BankRegistry(emulate_shards=2)
    reg.register("a", jnp.asarray(refs0), decoys=jnp.asarray(dec0))
    srv = DBSearchServer(reg, k=4, fdr=0.5, max_batch_size=4,
                         flush_timeout_s=0.0, compact_threshold=0.25)
    # small append below the threshold: delta stays pending across steps
    srv.append("a", refs1[:1])
    srv.submit(queries[0], tenant="a")
    srv.run_until_drained()
    assert reg.tenants_with_delta() == ["a"] and reg.compactions == 0
    # queue first, then cross the threshold; the drain must compact first
    rids = [srv.submit(q, tenant="a") for q in queries]
    srv.append("a", refs1[1:], dec1)
    done = {r.rid: r for r in srv.run_until_drained()}
    assert sorted(done) == sorted(rids)  # nothing dropped
    assert reg.compactions == 1 and reg.tenants_with_delta() == []

    oracle_reg = BankRegistry(emulate_shards=2)
    oracle_reg.register("a", jnp.asarray(np.concatenate([refs0, refs1])),
                        decoys=jnp.asarray(np.concatenate([dec0, dec1])))
    oracle = DBSearchServer(oracle_reg, k=4, fdr=0.5, max_batch_size=4,
                            flush_timeout_s=0.0)
    _assert_results_equal([done[r].result for r in rids],
                          _drain_results(oracle, queries, "a"))
    ing = srv.summary()["ingest"]
    assert ing["compactions"] == 1 and ing["compact_threshold"] == 0.25


def test_server_compact_threshold_validation():
    reg = BankRegistry()
    with pytest.raises(ValueError, match="compact_threshold"):
        DBSearchServer(reg, compact_threshold=0.0)
    with pytest.raises(ValueError, match="compact_threshold"):
        DBSearchServer(reg, compact_threshold=1.5)


# --------------------------------------------------------------------------
# real multi-device shard_map path (slow tier)
# --------------------------------------------------------------------------

def _run_py(code: str, devices: int = 8, timeout: int = 520):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("JAX_PLATFORMS", None)
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)


@pytest.mark.slow
def test_merged_search_bit_identical_on_8_device_mesh():
    """Base bank sharded over a real mesh, delta on one device: the merged
    search must still be bit-identical to a rebuilt mesh-sharded bank."""
    r = _run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.serve import (DeltaBank, OMSConfig, encode_queries,
                                 merged_oms_plan, merged_oms_search_encoded,
                                 merged_search_encoded, oms_search,
                                 search_database, shard_database)
        rng = np.random.default_rng(29)
        D, k = 64, 4
        refs0 = rng.choice([-1, 1], (57, D)).astype(np.int8)
        dec0 = rng.choice([-1, 1], (31, D)).astype(np.int8)
        refs1 = rng.choice([-1, 1], (6, D)).astype(np.int8)
        dec1 = rng.choice([-1, 1], (3, D)).astype(np.int8)
        refs1[0] = refs0[3]
        prec0 = rng.uniform(400, 1600, 57).astype(np.float32)
        prec1 = rng.uniform(400, 1600, 6).astype(np.float32)
        q = jnp.asarray(rng.choice([-1, 1], (12, D)).astype(np.int8))
        qprec = np.sort(rng.uniform(420, 1650, 12).astype(np.float32))
        cfg = OMSConfig(tol=15.0, open_tol=150.0)
        cat = lambda a, b: jnp.asarray(np.concatenate([a, b]))
        for model_n in (2, 4, 8):
            mesh = jax.make_mesh((8 // model_n, model_n), ("data", "model"))
            for pack in (True, False):
                base = shard_database(jnp.asarray(refs0),
                                      decoys=jnp.asarray(dec0),
                                      mesh=mesh, pack=pack)
                delta = DeltaBank(D, oms=False)
                delta.append(refs1, dec1)
                mi, mv = merged_search_encoded(
                    base, delta, encode_queries(base, q), q, k)
                oi, ov = search_database(
                    shard_database(cat(refs0, refs1),
                                   decoys=cat(dec0, dec1),
                                   mesh=mesh, pack=pack), q, k)
                assert (np.asarray(mi) == np.asarray(oi)).all(), (model_n, pack)
                assert (np.asarray(mv) == np.asarray(ov)).all(), (model_n, pack)
                obase = shard_database(jnp.asarray(refs0),
                                       decoys=jnp.asarray(dec0),
                                       mesh=mesh, pack=pack, precursor=prec0,
                                       decoy_precursor=prec0[:31])
                odelta = DeltaBank(D, oms=True)
                odelta.append(refs1, dec1, precursor=prec1,
                              decoy_precursor=prec1[:3])
                mplan = merged_oms_plan(obase, odelta, qprec, cfg)
                mi, mv = merged_oms_search_encoded(
                    obase, odelta, encode_queries(obase, q), q, mplan, k)
                oi, ov, _ = oms_search(
                    shard_database(cat(refs0, refs1), decoys=cat(dec0, dec1),
                                   mesh=mesh, pack=pack,
                                   precursor=np.concatenate([prec0, prec1]),
                                   decoy_precursor=np.concatenate(
                                       [prec0[:31], prec1[:3]])),
                    q, qprec, k, cfg)
                assert (np.asarray(mi) == np.asarray(oi)).all(), (model_n, pack)
                assert (np.asarray(mv) == np.asarray(ov)).all(), (model_n, pack)
        print("MERGED_8DEV_OK")
    """)
    assert "MERGED_8DEV_OK" in r.stdout, r.stdout + r.stderr
