"""Clustering-endpoint tests: streaming assign-or-spawn vs batch complete
linkage (partition agreement, batch-boundary invariance), periodic
consolidation (merge folding, id remap chains, stale-snapshot fallback),
kind-homogeneous queue lanes, mixed search+cluster serving through both
queue modes, and the serve_cluster launcher smoke."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.hd.clustering import complete_linkage, pairwise_distances
from repro.serve import (
    BankRegistry,
    ClusteringConfig,
    DBSearchServer,
    MicroBatchQueue,
    StreamingClusterer,
    search_database,
    shard_database,
)

D = 64


def _proto_stream(rng, n_proto, per_proto, flip_bits):
    """Well-separated synthetic stream: each point is its prototype with
    ``flip_bits`` random sign flips (intra-distance <= 2*flip_bits,
    inter-distance ~ D/2)."""
    protos = rng.choice([-1, 1], size=(n_proto, D)).astype(np.int8)
    hvs, truth = [], []
    for p in range(n_proto):
        for _ in range(per_proto):
            hv = protos[p].copy()
            flips = rng.choice(D, size=flip_bits, replace=False)
            hv[flips] = -hv[flips]
            hvs.append(hv)
            truth.append(p)
    order = rng.permutation(len(hvs))
    return (np.asarray(hvs, np.int8)[order],
            np.asarray(truth, np.int64)[order])


def _stream_through(cl, hvs, batch_size):
    """Feed a stream through the dispatch/finalize pair the executor uses:
    snapshot distances per batch, sequential assign at finalize."""
    out = []
    for i in range(0, hvs.shape[0], batch_size):
        batch = hvs[i:i + batch_size]
        c0 = cl.num_clusters
        sv = cl.struct_version
        d = cl.snapshot_distances(batch)
        d = None if d is None else np.asarray(d)
        out.extend(cl.assign_batch(batch, d, c0, sv))
    return out


def _partition_sets(labels):
    groups = {}
    for i, lab in enumerate(labels):
        groups.setdefault(int(lab), set()).add(i)
    return sorted(map(frozenset, groups.values()), key=min)


def test_streaming_matches_batch_complete_linkage():
    """On well-separated data the streaming partition equals the batch
    complete-linkage partition over all points (up to label renaming)."""
    rng = np.random.default_rng(0)
    hvs, _ = _proto_stream(rng, n_proto=5, per_proto=8, flip_bits=3)
    # intra <= 12 bits apart pairwise, inter ~ 32; threshold between
    cfg = ClusteringConfig(dim=D, threshold=14.0)
    cl = StreamingClusterer(cfg)
    assigns = _stream_through(cl, hvs, batch_size=7)
    stream_labels = cl.labels_for(assigns)
    batch = complete_linkage(
        pairwise_distances(jnp.asarray(hvs), dim=D), 14.0)
    assert _partition_sets(stream_labels) == \
        _partition_sets(np.asarray(batch.labels))
    assert cl.num_clusters == 5 and cl.spawned == 5


def test_streaming_partition_invariant_to_batch_boundaries():
    rng = np.random.default_rng(1)
    hvs, _ = _proto_stream(rng, n_proto=4, per_proto=6, flip_bits=2)
    parts = []
    for bs in (1, 5, hvs.shape[0]):
        cl = StreamingClusterer(ClusteringConfig(dim=D, threshold=10.0))
        labels = cl.labels_for(_stream_through(cl, hvs, bs))
        parts.append(_partition_sets(labels))
    assert parts[0] == parts[1] == parts[2]


def test_packed_and_int8_distance_paths_agree():
    rng = np.random.default_rng(2)
    hvs, _ = _proto_stream(rng, n_proto=4, per_proto=5, flip_bits=2)
    out = {}
    for pack in (True, False):
        cl = StreamingClusterer(
            ClusteringConfig(dim=D, threshold=10.0, pack=pack))
        assigns = _stream_through(cl, hvs, batch_size=4)
        out[pack] = ([(a.cluster_id, a.spawned, a.distance)
                      for a in assigns])
    assert out[True] == out[False]


def test_in_batch_spawn_is_assignable_to_its_own_batch():
    """A spectrum that spawns mid-batch must catch the rest of the batch
    (host-scored rows past the snapshot), not spawn duplicates."""
    rng = np.random.default_rng(3)
    proto = rng.choice([-1, 1], size=D).astype(np.int8)
    near = proto.copy()
    near[:2] = -near[:2]  # distance 2
    cl = StreamingClusterer(ClusteringConfig(dim=D, threshold=5.0))
    assigns = _stream_through(cl, np.stack([proto, near]), batch_size=2)
    assert assigns[0].spawned and not assigns[1].spawned
    assert assigns[1].cluster_id == assigns[0].cluster_id
    assert assigns[1].distance == 2.0
    assert cl.num_clusters == 1


def test_consolidation_merges_and_remaps():
    """Streaming keeps two founders apart (> threshold) that complete
    linkage folds together (<= link_threshold); consolidation must merge
    them, keep the oldest id canonical, and remap the dropped id."""
    rng = np.random.default_rng(4)
    a = rng.choice([-1, 1], size=D).astype(np.int8)
    b = a.copy()
    b[:10] = -b[:10]  # distance 10: beyond threshold, within link range
    cl = StreamingClusterer(ClusteringConfig(
        dim=D, threshold=4.0, link_threshold=12.0, consolidate_every=2))
    assigns = _stream_through(cl, np.stack([a, b]), batch_size=2)
    assert [x.spawned for x in assigns] == [True, True]
    assert cl.num_clusters == 1 and cl.merges == 1
    assert cl.consolidations == 1 and cl.struct_version == 1
    assert cl.resolve(1) == 0 and cl.resolve(0) == 0
    assert cl.labels_for(assigns).tolist() == [0, 0]
    # the merged accumulator is the sum of both members
    np.testing.assert_array_equal(
        cl.centroid(1), np.where(a.astype(np.int32) + b >= 0, 1, -1))
    s = cl.summary()
    assert s["clusters"] == 1 and s["merges"] == 1


def test_stale_snapshot_falls_back_to_host_scoring():
    """Distances snapshotted before a consolidation restructured the rows
    must not be trusted at finalize — the batch is re-scored host-side
    and still lands in the merged cluster."""
    rng = np.random.default_rng(5)
    a = rng.choice([-1, 1], size=D).astype(np.int8)
    b = a.copy()
    b[:10] = -b[:10]
    cl = StreamingClusterer(ClusteringConfig(
        dim=D, threshold=4.0, link_threshold=12.0, consolidate_every=2))
    merged_cent = np.where(a.astype(np.int32) + b >= 0, 1, -1).astype(np.int8)
    probe = merged_cent.copy()
    probe[:1] = -probe[:1]  # distance 1 from the merged centroid
    # snapshot against the pre-consolidation 2-row bank...
    _stream_through(cl, np.stack([a, b]), batch_size=2)
    stale_dists = np.asarray([[50.0, 0.0]])  # would pick the dropped row
    assert cl.struct_version == 1
    out = cl.assign_batch(probe[None, :], stale_dists, 2, struct_version=0)
    assert not out[0].spawned and cl.resolve(out[0].cluster_id) == 0
    assert out[0].distance == 1.0


def test_clustering_config_properties():
    assert ClusteringConfig(dim=64, threshold=4.0).packed
    assert not ClusteringConfig(dim=48, threshold=4.0).packed
    assert ClusteringConfig(dim=48, threshold=4.0, pack=True).packed
    c = ClusteringConfig(dim=64, threshold=4.0)
    assert c.merge_threshold == 4.0
    assert ClusteringConfig(dim=64, threshold=4.0,
                            link_threshold=9.0).merge_threshold == 9.0


# --------------------------------------------------------------------------
# queue lanes + server endpoint
# --------------------------------------------------------------------------

def test_queue_lanes_are_kind_homogeneous():
    t = [0.0]
    q = MicroBatchQueue(max_batch_size=4, flush_timeout_s=0.0,
                        clock=lambda: t[0])
    r0 = q.submit(np.zeros(4, np.int8), tenant="a")
    r1 = q.submit(np.zeros(4, np.int8), tenant="a", kind="cluster")
    r2 = q.submit(np.zeros(4, np.int8), tenant="a")
    r3 = q.submit(np.zeros(4, np.int8), tenant="a", kind="cluster")
    b1 = q.take_batch()
    assert [r.rid for r in b1] == [r0, r2]  # oldest lane first, search only
    assert all(r.kind == "search" for r in b1)
    b2 = q.take_batch()
    assert [r.rid for r in b2] == [r1, r3]
    assert all(r.kind == "cluster" for r in b2)


@pytest.mark.parametrize("continuous", [False, True])
def test_server_mixed_search_and_cluster_kinds(continuous):
    """Search and clustering share the queue/scheduler but never share a
    batch; both endpoints return correct results for interleaved
    submissions."""
    rng = np.random.default_rng(6)
    refs = rng.choice([-1, 1], size=(20, D)).astype(np.int8)
    dec = rng.choice([-1, 1], size=(10, D)).astype(np.int8)
    reg = BankRegistry(emulate_shards=2)
    reg.register("a", jnp.asarray(refs), decoys=jnp.asarray(dec))
    ccfg = ClusteringConfig(dim=D, threshold=10.0)
    srv = DBSearchServer(reg, k=3, fdr=0.5, max_batch_size=4,
                         flush_timeout_s=0.0, clustering=ccfg,
                         continuous=continuous)
    hvs, _ = _proto_stream(rng, n_proto=3, per_proto=4, flip_bits=2)
    queries = rng.choice([-1, 1], size=(8, D)).astype(np.int8)
    search_rids, cluster_rids = [], []
    for i in range(max(len(hvs), len(queries))):
        if i < len(queries):
            search_rids.append(srv.submit(queries[i], tenant="a"))
        if i < len(hvs):
            cluster_rids.append(srv.submit_cluster(hvs[i], tenant="a"))
    done = {r.rid: r for r in srv.run_until_drained()}
    assert sorted(done) == sorted(search_rids + cluster_rids)

    oi, _ = search_database(reg.get("a"), jnp.asarray(queries), 3)
    for i, rid in enumerate(search_rids):
        np.testing.assert_array_equal(done[rid].result.indices,
                                      np.asarray(oi)[i])
    cl = srv.clusterers["a"]
    labels = cl.labels_for([done[r].result for r in cluster_rids])
    # same partition as a fresh replay in submission order
    ref = StreamingClusterer(ccfg)
    ref_labels = ref.labels_for(
        _stream_through(ref, hvs, batch_size=len(hvs)))
    assert _partition_sets(labels) == _partition_sets(ref_labels)
    s = srv.summary()
    assert s["clustering"]["requests"] == len(cluster_rids)
    assert s["clustering"]["tenants"]["a"]["assigned"] == len(hvs)


def test_cluster_tenants_are_independent():
    rng = np.random.default_rng(7)
    srv = DBSearchServer(BankRegistry(), max_batch_size=4,
                         flush_timeout_s=0.0,
                         clustering=ClusteringConfig(dim=D, threshold=10.0))
    hv = rng.choice([-1, 1], size=D).astype(np.int8)
    srv.submit_cluster(hv, tenant="t0")
    srv.submit_cluster(hv, tenant="t1")
    srv.run_until_drained()
    assert srv.clusterers["t0"].num_clusters == 1
    assert srv.clusterers["t1"].num_clusters == 1


def test_submit_cluster_validation():
    srv = DBSearchServer(BankRegistry())
    with pytest.raises(ValueError, match="without clustering"):
        srv.submit_cluster(np.zeros(D, np.int8))
    srv2 = DBSearchServer(BankRegistry(),
                          clustering=ClusteringConfig(dim=D, threshold=4.0))
    with pytest.raises(ValueError, match="query shape"):
        srv2.submit_cluster(np.zeros(D + 1, np.int8))


def test_serve_cluster_cli_smoke():
    from repro.launch import serve_cluster
    s = serve_cluster.main(["--reduced", "--hd-dim", "64",
                            "--identities", "6",
                            "--spectra-per-identity", "4",
                            "--max-batch", "4", "--tenants", "2",
                            "--consolidate-every", "16"])
    assert s["count"] == 48 and s["qps"] > 0
    for tenant in ("tenant0", "tenant1"):
        q = s["cluster_quality"][tenant]
        assert q["clusters"] >= 1 and q["assigned"] == 24
        assert 0.0 <= q["incorrect_ratio"] <= 1.0
