"""Unit + property tests for HD encoding, packing, and similarity."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.hd.encoding import (
    HDEncoderConfig,
    encode_batch,
    encode_batch_reference,
    make_codebooks,
    quantize_levels,
)
from repro.core.hd.packing import pack_dimensions, packed_levels, unpack_dimensions
from repro.core.hd.similarity import (
    bitpack_bipolar,
    dot_similarity,
    hamming_similarity,
    hamming_similarity_packed,
    top1_search,
    topk_search,
)


def _dataset(b=8, f=64, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.uniform(0, 1, (b, f)).astype(np.float32)
    x[rng.uniform(size=(b, f)) < 0.7] = 0.0  # sparse like spectra
    return jnp.asarray(x)


class TestCodebooks:
    def test_shapes_and_values(self):
        cfg = HDEncoderConfig(dim=256, num_features=32, num_levels=8)
        id_hvs, lv_hvs = make_codebooks(cfg)
        assert id_hvs.shape == (32, 256) and lv_hvs.shape == (8, 256)
        assert set(np.unique(id_hvs)) <= {-1, 1}
        assert set(np.unique(lv_hvs)) <= {-1, 1}

    def test_level_similarity_decays_monotonically(self):
        cfg = HDEncoderConfig(dim=2048, num_levels=16)
        _, lv = make_codebooks(cfg)
        sims = [int(jnp.dot(lv[0].astype(jnp.int32), lv[k].astype(jnp.int32)))
                for k in range(16)]
        # sim(LV_0, LV_k) decreases in k; endpoints near-orthogonal
        assert all(sims[i] >= sims[i + 1] - 1 for i in range(15))
        assert abs(sims[-1]) < 0.15 * 2048

    def test_id_orthogonality(self):
        cfg = HDEncoderConfig(dim=4096, num_features=16)
        id_hvs, _ = make_codebooks(cfg)
        g = np.asarray(dot_similarity(id_hvs, id_hvs)).astype(float)
        off = g - np.diag(np.diag(g))
        assert np.abs(off).max() < 0.1 * 4096


class TestEncoding:
    def test_blocked_matches_reference(self):
        cfg = HDEncoderConfig(dim=128, num_features=100, num_levels=8)
        id_hvs, lv_hvs = make_codebooks(cfg)
        x = _dataset(6, 100)
        a = encode_batch(x, id_hvs, lv_hvs, block_features=32)
        b = encode_batch_reference(x, id_hvs, lv_hvs)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_zero_spectrum_is_all_minus_one(self):
        cfg = HDEncoderConfig(dim=64, num_features=16, num_levels=4)
        id_hvs, lv_hvs = make_codebooks(cfg)
        out = encode_batch_reference(jnp.zeros((1, 16)), id_hvs, lv_hvs)
        assert np.all(np.asarray(out) == -1)  # paper's sign(0) = -1

    def test_level_zero_reserved_for_absent(self):
        lv = quantize_levels(jnp.asarray([0.0, 1e-9, 0.01, 0.5, 1.0]), 8)
        assert lv[0] == 0 and lv[1] == 0
        assert int(lv[2]) >= 1 and int(lv[4]) == 7

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_similar_inputs_similar_hvs(self, seed):
        """Property: a small perturbation must not flip most HV bits."""
        cfg = HDEncoderConfig(dim=512, num_features=64, num_levels=16,
                              seed=seed % 97)
        id_hvs, lv_hvs = make_codebooks(cfg)
        x = _dataset(1, 64, seed=seed % 31)
        noisy = jnp.clip(x + 0.02 * (x > 0), 0, 1)  # jitter present peaks
        a = encode_batch_reference(x, id_hvs, lv_hvs)
        b = encode_batch_reference(noisy, id_hvs, lv_hvs)
        agreement = float((a == b).mean())
        assert agreement > 0.8


class TestPacking:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(1, 4), st.integers(0, 1000))
    def test_pack_preserves_blockwise_sums(self, n, seed):
        rng = np.random.default_rng(seed)
        d = 24 * n
        hv = jnp.asarray(rng.choice([-1, 1], (3, d)).astype(np.int8))
        packed = pack_dimensions(hv, n)
        assert packed.shape == (3, d // n)
        expect = np.asarray(hv).reshape(3, d // n, n).sum(-1)
        np.testing.assert_array_equal(np.asarray(packed), expect)
        assert np.abs(np.asarray(packed)).max() <= n

    def test_packed_dot_estimates_unpacked_dot(self):
        rng = np.random.default_rng(0)
        d, n = 3072, 3
        a = jnp.asarray(rng.choice([-1, 1], (16, d)).astype(np.int8))
        b = jnp.asarray(rng.choice([-1, 1], (16, d)).astype(np.int8))
        exact = np.asarray(dot_similarity(a, b))
        packed = np.asarray(dot_similarity(pack_dimensions(a, n),
                                           pack_dimensions(b, n)))
        # unbiased estimator: error std ~ sqrt((n-1)*D); 4 sigma bound
        err = np.abs(packed - exact)
        assert err.mean() < 4 * np.sqrt((n - 1) * d)

    def test_unpack_roundtrip_blockwise(self):
        rng = np.random.default_rng(1)
        hv = jnp.asarray(rng.choice([-1, 1], (2, 30)).astype(np.int8))
        p = pack_dimensions(hv, 3)
        u = unpack_dimensions(p, 3, 30)
        # blockwise sums must match (the information packing preserves)
        np.testing.assert_array_equal(
            np.asarray(u).reshape(2, 10, 3).sum(-1),
            np.asarray(p),
        )

    def test_levels_count(self):
        assert packed_levels(1) == 3
        assert packed_levels(3) == 7

    def test_invalid_args(self):
        hv = jnp.ones((2, 10), jnp.int8)
        with pytest.raises(ValueError):
            pack_dimensions(hv, 3)  # 10 % 3 != 0
        with pytest.raises(ValueError):
            pack_dimensions(hv, 0)


class TestSimilarity:
    def test_hamming_dot_identity(self):
        rng = np.random.default_rng(2)
        a = jnp.asarray(rng.choice([-1, 1], (4, 128)).astype(np.int8))
        b = jnp.asarray(rng.choice([-1, 1], (5, 128)).astype(np.int8))
        dots = np.asarray(dot_similarity(a, b))
        ham = np.asarray(hamming_similarity(a, b))
        np.testing.assert_array_equal(ham, (128 + dots) // 2)

    def test_bitpacked_matches_dense(self):
        rng = np.random.default_rng(3)
        a = jnp.asarray(rng.choice([-1, 1], (6, 96)).astype(np.int8))
        b = jnp.asarray(rng.choice([-1, 1], (7, 96)).astype(np.int8))
        dense = np.asarray(hamming_similarity(a, b))
        packed = np.asarray(hamming_similarity_packed(
            bitpack_bipolar(a), bitpack_bipolar(b), 96))
        np.testing.assert_array_equal(dense, packed)

    def test_top1_finds_self(self):
        rng = np.random.default_rng(4)
        refs = jnp.asarray(rng.choice([-1, 1], (20, 256)).astype(np.int8))
        idx, score = top1_search(refs[3:4], refs)
        assert int(idx[0]) == 3 and int(score[0]) == 256

    def test_topk_ordering(self):
        rng = np.random.default_rng(5)
        refs = jnp.asarray(rng.choice([-1, 1], (30, 128)).astype(np.int8))
        q = refs[:2]
        idx, vals = topk_search(q, refs, k=5)
        v = np.asarray(vals)
        assert (np.diff(v, axis=1) <= 0).all()
        assert int(idx[0, 0]) == 0 and int(idx[1, 0]) == 1
