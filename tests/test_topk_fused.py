"""Fused streaming top-k kernel vs the ``topk_search`` oracle.

Property tests (hypothesis; the conftest shim when the package is absent)
over ragged Q/R/W shapes, duplicate-score tie-breaking, k >= R edges, and
the shard-masking contract — all in interpret mode (tier-1, CPU). The
real-mesh fused path runs in the slow tier of tests/test_serve.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.hd.similarity import (
    bitpack_bipolar,
    topk_search,
    topk_search_packed,
)
from repro.kernels.topk_hamming import topk_hamming_pallas
from repro.kernels.topk_hamming.ref import topk_hamming_ref
from repro.serve import search_with_fdr, shard_database, sharded_topk_search

_SENTINEL = np.iinfo(np.int32).min


def _bipolar(rng, shape):
    return jnp.asarray(rng.choice([-1, 1], size=shape).astype(np.int8))


def _assert_same(got, want, *ctx):
    gi, gv = got
    wi, wv = want
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi), err_msg=str(ctx))
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv), err_msg=str(ctx))


# --------------------------------------------------------------------------
# property tests vs the materialize-then-top_k oracle
# --------------------------------------------------------------------------

class TestFusedVsOracleProperties:
    @settings(max_examples=12)
    @given(st.integers(1, 33), st.integers(1, 200), st.integers(1, 7),
           st.integers(1, 9))
    def test_packed_random_shapes(self, q, r, w, k):
        k = min(k, r)
        rng = np.random.default_rng(q * 7919 + r * 131 + w * 17 + k)
        qp = jnp.asarray(rng.integers(0, 2**32, (q, w), dtype=np.uint32))
        rp = jnp.asarray(rng.integers(0, 2**32, (r, w), dtype=np.uint32))
        got = topk_hamming_pallas(qp, rp, dim=w * 32, k=k, block_r=128)
        want = topk_hamming_ref(qp, rp, w * 32, k)
        _assert_same(got, want, q, r, w, k)

    @settings(max_examples=10)
    @given(st.integers(1, 17), st.integers(1, 90), st.integers(1, 100),
           st.integers(1, 8))
    def test_int8_dot_random_shapes(self, q, r, d, k):
        """The unpacked int8-dot variant (the D % 32 != 0 fallback) against
        the plain topk_search oracle."""
        k = min(k, r)
        rng = np.random.default_rng(q * 733 + r * 37 + d * 5 + k)
        qs = _bipolar(rng, (q, d))
        rs = _bipolar(rng, (r, d))
        got = topk_hamming_pallas(qs, rs, dim=d, k=k)
        want = topk_search(qs, rs, k)
        _assert_same(got, want, q, r, d, k)

    @settings(max_examples=10)
    @given(st.integers(2, 40), st.integers(1, 6))
    def test_duplicate_scores_tiebreak(self, r, k):
        """Duplicated reference rows force exact score ties everywhere; the
        streaming merge must order them by ascending index like lax.top_k."""
        k = min(k, 3 * r)
        rng = np.random.default_rng(r * 101 + k)
        base = _bipolar(rng, (r, 32))
        refs = jnp.concatenate([base, base, base], axis=0)
        queries = base[: min(r, 8)]
        got = topk_hamming_pallas(bitpack_bipolar(queries),
                                  bitpack_bipolar(refs), dim=32, k=k,
                                  block_r=128)
        want = topk_search(queries, refs, k)
        _assert_same(got, want, r, k)


# --------------------------------------------------------------------------
# edges: k >= R, masking, block invariance
# --------------------------------------------------------------------------

class TestFusedEdges:
    def test_k_equals_r(self):
        rng = np.random.default_rng(0)
        refs = _bipolar(rng, (9, 64))
        queries = _bipolar(rng, (4, 64))
        got = topk_hamming_pallas(bitpack_bipolar(queries),
                                  bitpack_bipolar(refs), dim=64, k=9)
        want = topk_search(queries, refs, 9)
        _assert_same(got, want)

    def test_k_exceeding_r_raises(self):
        rng = np.random.default_rng(1)
        qp = jnp.asarray(rng.integers(0, 2**32, (2, 2), dtype=np.uint32))
        rp = jnp.asarray(rng.integers(0, 2**32, (5, 2), dtype=np.uint32))
        with pytest.raises(ValueError, match="k="):
            topk_hamming_pallas(qp, rp, dim=64, k=6)

    @pytest.mark.parametrize("num_valid", [0, 1, 3, 7, 10])
    def test_num_valid_masks_like_local_topk(self, num_valid):
        """Rows >= num_valid must behave exactly like the sentinel-masked
        padding columns of db_search._local_topk: sentinel scores, and the
        overflow slots fill with ascending masked indices."""
        rng = np.random.default_rng(2)
        refs = _bipolar(rng, (10, 32))
        queries = _bipolar(rng, (5, 32))
        k = 6
        got = topk_hamming_pallas(bitpack_bipolar(queries),
                                  bitpack_bipolar(refs), dim=32, k=k,
                                  num_valid=num_valid)
        want = topk_hamming_ref(bitpack_bipolar(queries),
                                bitpack_bipolar(refs), 32, k,
                                num_valid=num_valid)
        _assert_same(got, want, num_valid)
        if num_valid < k:
            # overflow slots carry the sentinel at the lowest masked rows
            gi, gv = got
            assert (np.asarray(gv)[:, num_valid:] == _SENTINEL).all()
            np.testing.assert_array_equal(
                np.asarray(gi)[:, num_valid:],
                np.broadcast_to(np.arange(num_valid, k),
                                (5, k - num_valid)))

    def test_block_shape_invariance(self):
        rng = np.random.default_rng(3)
        qp = jnp.asarray(rng.integers(0, 2**32, (10, 4), dtype=np.uint32))
        rp = jnp.asarray(rng.integers(0, 2**32, (300, 4), dtype=np.uint32))
        a = topk_hamming_pallas(qp, rp, dim=128, k=5, block_q=8, block_r=64)
        b = topk_hamming_pallas(qp, rp, dim=128, k=5, block_q=128,
                                block_r=128)
        _assert_same(a, b)

    def test_word_padding_is_harmless(self):
        """W not a multiple of word_chunk pads with zero words on both
        operands (XOR -> 0 -> popcount 0)."""
        rng = np.random.default_rng(4)
        qp = jnp.asarray(rng.integers(0, 2**32, (6, 5), dtype=np.uint32))
        rp = jnp.asarray(rng.integers(0, 2**32, (40, 5), dtype=np.uint32))
        got = topk_hamming_pallas(qp, rp, dim=160, k=4, word_chunk=4)
        want = topk_hamming_ref(qp, rp, 160, 4)
        _assert_same(got, want)


# --------------------------------------------------------------------------
# serving integration: fused == unfused == oracle through the shard merge
# --------------------------------------------------------------------------

class TestFusedServingPath:
    @pytest.mark.parametrize("num_shards", [2, 4, 8])
    @pytest.mark.parametrize("num_refs,dim", [
        (61, 32),   # ragged last shard at every shard count, tie-heavy low D
        (64, 64),   # exact split
        (37, 48),   # D % 32 != 0 -> int8-dot kernel variant
    ])
    def test_fused_sharded_topk_matches_oracle(self, num_shards, num_refs,
                                               dim):
        rng = np.random.default_rng(num_refs * 100 + dim)
        refs = _bipolar(rng, (num_refs, dim))
        queries = _bipolar(rng, (16, dim))
        k = 5
        want = topk_search(queries, refs, k)
        for pack in ("auto", False):
            got = sharded_topk_search(queries, refs, k,
                                      num_shards=num_shards, pack=pack,
                                      fused=True)
            _assert_same(got, want, num_shards, pack)

    def test_fused_topk_search_packed(self):
        rng = np.random.default_rng(3)
        refs = _bipolar(rng, (50, 96))
        queries = _bipolar(rng, (9, 96))
        want = topk_search(queries, refs, 6)
        got = topk_search_packed(bitpack_bipolar(queries),
                                 bitpack_bipolar(refs), 96, 6, fused=True)
        _assert_same(got, want)

    def test_fused_fdr_routing_identical(self):
        """The whole serving search (decoy bank, shard merge, FDR) is
        unchanged by the fused flag."""
        rng = np.random.default_rng(5)
        refs = _bipolar(rng, (24, 64))
        decoys = _bipolar(rng, (24, 64))
        queries = _bipolar(rng, (7, 64))
        res = {}
        for fused in (False, True):
            db = shard_database(refs, decoys=decoys, emulate_shards=4,
                                fused=fused)
            res[fused] = search_with_fdr(db, queries, k=3, fdr=0.5)
        np.testing.assert_array_equal(res[True].indices, res[False].indices)
        np.testing.assert_array_equal(res[True].scores, res[False].scores)
        np.testing.assert_array_equal(res[True].accept, res[False].accept)
        np.testing.assert_array_equal(res[True].match, res[False].match)
