"""Serving-cache layer tests: QueryHVCache LRU/byte-budget semantics,
BankRegistry lazy build + pinning + LRU eviction, shape-bucketed
dispatch, tenant-aware queue fairness, and cached-vs-cold bit-identity
of the multi-tenant server against the unsharded oracle (tier-1 via
emulated shards; the real 8-device path lives in the slow tier)."""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hd.similarity import topk_search
from repro.serve import (
    BankRegistry,
    DBSearchServer,
    MicroBatchQueue,
    QueryHVCache,
    bucket_for,
    make_buckets,
    search_database,
    shard_database,
)

REPO = Path(__file__).resolve().parent.parent


def _bipolar(rng, shape):
    return jnp.asarray(rng.choice([-1, 1], size=shape).astype(np.int8))


# --------------------------------------------------------------------------
# QueryHVCache
# --------------------------------------------------------------------------

def _row(i, n=16):
    return np.full(n, i, dtype=np.int8)


def test_query_cache_lru_eviction_order():
    # each int8 row is 16 bytes; budget fits exactly two entries
    c = QueryHVCache(capacity_bytes=32)
    ka = c.content_key(_row(1));  c.insert(ka, _row(1))
    kb = c.content_key(_row(2));  c.insert(kb, _row(2))
    assert ka in c and kb in c and c.current_bytes == 32
    # touch A so B becomes the LRU entry, then insert C: B must go
    assert c.lookup(ka) is not None
    kc = c.content_key(_row(3));  c.insert(kc, _row(3))
    assert ka in c and kc in c and kb not in c
    assert c.evictions == 1 and len(c) == 2


def test_query_cache_byte_budget_enforced():
    c = QueryHVCache(capacity_bytes=100)
    for i in range(20):
        c.insert(c.content_key(_row(i)), _row(i))  # 16 bytes each
        assert c.current_bytes <= 100
    assert len(c) == 6 and c.current_bytes == 96  # floor(100 / 16)
    assert c.evictions == 14


def test_query_cache_oversized_value_rejected():
    c = QueryHVCache(capacity_bytes=8)
    key = c.content_key(_row(1))
    assert not c.insert(key, _row(1))   # 16 bytes > 8-byte budget
    assert key not in c and len(c) == 0 and c.current_bytes == 0


def test_query_cache_counters_and_get_or_encode():
    c = QueryHVCache(capacity_bytes=1 << 10)
    raw = _row(7)
    calls = []

    def encode(x):
        calls.append(1)
        return x.astype(np.int32) * 2

    v1, hit1 = c.get_or_encode(raw, encode)
    v2, hit2 = c.get_or_encode(raw, encode)
    assert not hit1 and hit2 and len(calls) == 1
    np.testing.assert_array_equal(v1, v2)
    assert c.hits == 1 and c.misses == 1 and c.hit_rate == 0.5
    # the same bytes under a different encoding variant is a distinct entry
    _, hit3 = c.get_or_encode(raw, encode, variant="other")
    assert not hit3 and len(calls) == 2


def test_query_cache_content_key_distinguishes_dtype_and_shape():
    a = np.zeros(8, np.int8)
    assert QueryHVCache.content_key(a) != QueryHVCache.content_key(
        a.astype(np.int16)[:4])
    assert QueryHVCache.content_key(a) != QueryHVCache.content_key(
        a.reshape(2, 4))


# --------------------------------------------------------------------------
# BankRegistry
# --------------------------------------------------------------------------

def test_bank_registry_lazy_build_and_rebuild():
    rng = np.random.default_rng(41)
    reg = BankRegistry(max_banks=2)
    for t in range(3):
        reg.register(f"t{t}", _bipolar(rng, (10 + t, 32)))
    assert reg.builds == 0 and not any(reg.is_built(f"t{t}") for t in range(3))
    assert reg.dim("t0") == 32  # available without building

    db0 = reg.get("t0")
    assert reg.builds == 1 and reg.is_built("t0")
    assert db0.num_rows == 10
    assert reg.get("t0") is db0 and reg.hits == 1  # cached handle

    reg.get("t1")
    reg.get("t2")                       # 3 built > max_banks=2: t0 evicted
    assert not reg.is_built("t0") and reg.evictions == 1
    db0b = reg.get("t0")                # transparently rebuilt from the spec
    assert db0b.num_rows == 10 and reg.builds == 4


def test_bank_registry_pinning_exempts_from_eviction():
    rng = np.random.default_rng(43)
    reg = BankRegistry(max_banks=1)
    reg.register("hot", _bipolar(rng, (8, 32)), pin=True)
    reg.register("cold", _bipolar(rng, (8, 32)))
    reg.get("hot")
    reg.get("cold")
    # 'hot' is older but pinned: 'cold' must be the eviction victim
    assert reg.is_built("hot") and not reg.is_built("cold")
    reg.unpin("hot")
    reg.get("cold")
    assert not reg.is_built("hot") and reg.is_built("cold")


def test_bank_registry_decoys_and_shard_options():
    rng = np.random.default_rng(47)
    reg = BankRegistry(emulate_shards=4)
    reg.register("t", _bipolar(rng, (9, 32)), decoys=_bipolar(rng, (5, 32)))
    db = reg.get("t")
    assert db.num_rows == 14 and db.num_decoys == 5
    assert db.num_shards == 4 and db.shard_rows == 4


def test_bank_registry_unknown_tenant_raises():
    reg = BankRegistry()
    with pytest.raises(KeyError):
        reg.get("nope")
    with pytest.raises(KeyError):
        reg.dim("nope")


# --------------------------------------------------------------------------
# shape buckets
# --------------------------------------------------------------------------

def test_make_buckets_geometric_ladder():
    assert make_buckets(32, 4) == (4, 8, 16, 32)
    assert make_buckets(32, 1) == (32,)
    assert make_buckets(3, 8) == (1, 3)  # ladder stops at 1
    assert make_buckets(1, 4) == (1,)


def test_bucket_for_smallest_cover():
    buckets = (4, 8, 16)
    assert bucket_for(1, buckets) == 4
    assert bucket_for(4, buckets) == 4
    assert bucket_for(5, buckets) == 8
    assert bucket_for(16, buckets) == 16
    with pytest.raises(ValueError, match="exceeds"):
        bucket_for(17, buckets)


# --------------------------------------------------------------------------
# tenant-aware queue
# --------------------------------------------------------------------------

def test_queue_batches_are_tenant_homogeneous():
    q = MicroBatchQueue(max_batch_size=8, flush_timeout_s=0.0)
    q.submit("a0", tenant="a")
    q.submit("b0", tenant="b")
    q.submit("a1", tenant="a")
    first = q.take_batch()
    assert [r.query for r in first] == ["a0", "a1"]  # oldest tenant, FIFO
    assert [r.query for r in q.take_batch()] == ["b0"]


def test_queue_full_lane_preempts_older_partial_lane():
    now = [0.0]
    q = MicroBatchQueue(max_batch_size=2, flush_timeout_s=10.0,
                        clock=lambda: now[0])
    q.submit("a0", tenant="a")           # oldest request, lane not full
    q.submit("b0", tenant="b")
    q.submit("b1", tenant="b")           # b's lane is full
    assert q.ready() and q.next_tenant() == "b"
    assert [r.query for r in q.take_batch()] == ["b0", "b1"]
    assert not q.ready()                 # a alone, not timed out
    now[0] = 11.0
    assert q.ready()                     # a's request aged out
    assert [r.query for r in q.take_batch()] == ["a0"]


def test_queue_fairness_cap_rotates_and_only_binds_with_others_waiting():
    q = MicroBatchQueue(max_batch_size=8, flush_timeout_s=0.0,
                        fairness_cap=2)
    for i in range(6):
        q.submit(f"a{i}", tenant="a")
    q.submit("b0", tenant="b")
    assert [r.query for r in q.take_batch()] == ["a0", "a1"]  # capped at 2
    # a was just served and b is waiting: rotation skips a
    assert [r.query for r in q.take_batch()] == ["b0"]
    # a is now alone: neither the cap nor the rotation binds
    assert [r.query for r in q.take_batch()] == ["a2", "a3", "a4", "a5"]
    for i in range(5):
        q.submit(f"b{i + 1}", tenant="b")
    assert len(q.take_batch()) == 5


# --------------------------------------------------------------------------
# server: cached vs cold bit-identity (emulated shards, across tenants)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("num_shards", [2, 4, 8])
def test_server_cached_vs_cold_bit_identity_emulated_shards(num_shards):
    """Every query is submitted twice — the first pass encodes cold, the
    second is served from the query-HV cache — and both passes must be
    bit-identical to the unsharded topk_search oracle on 2/4/8 emulated
    shards (packed and unpacked encodings)."""
    rng = np.random.default_rng(100 + num_shards)
    for dim, pack in ((64, "auto"), (48, False)):
        refs = _bipolar(rng, (29, dim))
        decoys = _bipolar(rng, (28, dim))
        bank = jnp.concatenate([decoys, refs], axis=0)
        queries = np.asarray(_bipolar(rng, (10, dim)))
        reg = BankRegistry(pack=pack, emulate_shards=num_shards)
        reg.register("default", refs, decoys=decoys)
        srv = DBSearchServer(reg, k=4, fdr=1.0, max_batch_size=5,
                             flush_timeout_s=0.0, cache_bytes=1 << 20)
        oracle_idx, oracle_vals = topk_search(jnp.asarray(queries), bank, 4)
        for pass_no in range(2):
            for q in queries:
                srv.submit(q)
            done = sorted(srv.run_until_drained(), key=lambda r: r.rid)
            for i, r in enumerate(done):
                np.testing.assert_array_equal(
                    r.result.indices, np.asarray(oracle_idx)[i],
                    err_msg=f"pass={pass_no} shards={num_shards} dim={dim}")
                np.testing.assert_array_equal(
                    r.result.scores, np.asarray(oracle_vals)[i])
        qc = srv.query_cache.summary()
        assert qc["misses"] == 10 and qc["hits"] == 10  # pass 2 fully cached


def test_server_cached_vs_cold_bit_identity_across_tenants():
    """Three tenants with different bank geometries, interleaved and with
    repeated queries: each tenant's results must equal its own oracle, and
    per-tenant accounting must see the repeats as cache hits."""
    rng = np.random.default_rng(7)
    reg = BankRegistry(emulate_shards=2)
    banks, queries = {}, {}
    for t, (n_refs, n_dec) in enumerate([(20, 10), (33, 0), (13, 13)]):
        name = f"t{t}"
        refs = _bipolar(rng, (n_refs, 64))
        decoys = _bipolar(rng, (n_dec, 64)) if n_dec else None
        reg.register(name, refs, decoys=decoys)
        banks[name] = (jnp.concatenate([decoys, refs], axis=0)
                       if n_dec else refs)
        queries[name] = np.asarray(_bipolar(rng, (6, 64)))
    srv = DBSearchServer(reg, k=3, fdr=1.0, max_batch_size=4,
                         flush_timeout_s=0.0, cache_bytes=1 << 20)
    meta = {}
    for pass_no in range(2):  # second pass repeats every query -> cache hits
        for i in range(6):
            for name in banks:
                meta[srv.submit(queries[name][i], tenant=name)] = (name, i)
    done = srv.run_until_drained()
    assert len(done) == 36
    for r in done:
        name, i = meta[r.rid]
        oi, ov = topk_search(jnp.asarray(queries[name][i : i + 1]),
                             banks[name], 3)
        np.testing.assert_array_equal(r.result.indices, np.asarray(oi)[0])
        np.testing.assert_array_equal(r.result.scores, np.asarray(ov)[0])
    s = srv.summary()
    assert set(s["tenants"]) == set(banks)
    for name in banks:
        ts = s["tenants"][name]
        assert ts["count"] == 12
        assert ts["cache_hits"] == 6 and ts["cache_misses"] == 6
        assert ts["p95_ms"] >= ts["p50_ms"] >= 0.0
    assert s["banks"]["builds"] == 3 and s["banks"]["registered"] == 3


def test_server_cache_disabled_matches_cached_results():
    rng = np.random.default_rng(11)
    refs = _bipolar(rng, (24, 64))
    decoys = _bipolar(rng, (24, 64))
    queries = np.asarray(_bipolar(rng, (7, 64)))

    def run(cache_bytes):
        reg = BankRegistry(emulate_shards=4)
        reg.register("default", refs, decoys=decoys)
        srv = DBSearchServer(reg, k=4, fdr=0.5, max_batch_size=4,
                             flush_timeout_s=0.0, cache_bytes=cache_bytes)
        for q in queries:
            srv.submit(q)
        return sorted(srv.run_until_drained(), key=lambda r: r.rid)

    cold = run(None)
    cached = run(1 << 20)
    for a, b in zip(cold, cached):
        np.testing.assert_array_equal(a.result.indices, b.result.indices)
        np.testing.assert_array_equal(a.result.scores, b.result.scores)
        assert a.result.accept == b.result.accept
        assert a.result.match == b.result.match


def test_server_bucketed_dispatch_pads_to_nearest_bucket():
    rng = np.random.default_rng(13)
    refs = _bipolar(rng, (20, 64))
    db = shard_database(refs)
    srv = DBSearchServer(db, k=2, fdr=1.0, max_batch_size=8,
                         flush_timeout_s=0.0, buckets=(2, 4, 8))
    queries = np.asarray(_bipolar(rng, (7, 64)))
    oi, ov = topk_search(jnp.asarray(queries), refs, 2)
    # submit in uneven waves to force ragged flushes of 1, 3 and 3, which
    # pad to buckets 2, 4 and 4
    srv.submit(queries[0])
    done = srv.run_until_drained()
    for q in queries[1:4]:
        srv.submit(q)
    done += srv.run_until_drained()
    for q in queries[4:7]:
        srv.submit(q)
    done += srv.run_until_drained()
    assert srv.summary()["buckets"] == {2: 1, 4: 2}
    done.sort(key=lambda r: r.rid)
    for i, r in enumerate(done):
        np.testing.assert_array_equal(r.result.indices, np.asarray(oi)[i])
        np.testing.assert_array_equal(r.result.scores, np.asarray(ov)[i])


def test_server_fairness_cap_interleaves_tenants():
    rng = np.random.default_rng(17)
    reg = BankRegistry()
    reg.register("a", _bipolar(rng, (12, 64)))
    reg.register("b", _bipolar(rng, (12, 64)))
    srv = DBSearchServer(reg, k=1, fdr=1.0, max_batch_size=8,
                         flush_timeout_s=0.0, fairness_cap=2)
    qa = np.asarray(_bipolar(rng, (6, 64)))
    qb = np.asarray(_bipolar(rng, (2, 64)))
    for q in qa:
        srv.submit(q, tenant="a")
    for q in qb:
        srv.submit(q, tenant="b")
    flushes = []
    while len(srv.queue):
        batch = srv.step(force=True)
        flushes.append((batch[0].tenant, len(batch)))
    # a is capped at 2 while b waits, then rotation serves b; once a is
    # alone again the cap stops binding and it flushes the remaining 4
    assert flushes == [("a", 2), ("b", 2), ("a", 4)]
    s = srv.summary()
    assert s["tenants"]["a"]["count"] == 6
    assert s["tenants"]["b"]["count"] == 2


def test_server_submit_validates_tenant_and_shape():
    rng = np.random.default_rng(19)
    reg = BankRegistry()
    reg.register("a", _bipolar(rng, (8, 64)))
    srv = DBSearchServer(reg, k=1, max_batch_size=4)
    with pytest.raises(KeyError):
        srv.submit(np.zeros(64, np.int8), tenant="unknown")
    with pytest.raises(ValueError, match="query shape"):
        srv.submit(np.zeros(32, np.int8), tenant="a")


def test_search_database_emulated_shards_matches_oracle():
    rng = np.random.default_rng(23)
    refs = _bipolar(rng, (45, 64))
    queries = _bipolar(rng, (9, 64))
    oi, ov = topk_search(queries, refs, 5)
    for ns in (2, 4, 8):
        db = shard_database(refs, emulate_shards=ns)
        assert db.num_shards == ns
        si, sv = search_database(db, queries, 5)
        np.testing.assert_array_equal(np.asarray(si), np.asarray(oi))
        np.testing.assert_array_equal(np.asarray(sv), np.asarray(ov))


def test_shard_database_rejects_mesh_plus_emulation():
    import jax

    rng = np.random.default_rng(29)
    refs = _bipolar(rng, (8, 32))
    if len(jax.devices()) > 1:  # pragma: no cover - single-device tier-1
        pytest.skip("tier-1 is single-device")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # size-1 mesh axis degrades to local: emulation is then allowed
    db = shard_database(refs, mesh=mesh, emulate_shards=2)
    assert db.mesh is None and db.num_shards == 2


# --------------------------------------------------------------------------
# real multi-device multi-tenant path (slow tier)
# --------------------------------------------------------------------------

def _run_py(code: str, devices: int = 8, timeout: int = 520):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("JAX_PLATFORMS", None)
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)


@pytest.mark.slow
def test_multi_tenant_cached_serving_on_8_device_mesh():
    """Real shard_map path: two tenants sharded over an 8-device 'model'
    axis, every query submitted twice (cold + cached), all results
    bit-identical to each tenant's unsharded oracle."""
    r = _run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.hd.similarity import topk_search
        from repro.serve import BankRegistry, DBSearchServer
        mesh = jax.make_mesh((1, 8), ("data", "model"))
        rng = np.random.default_rng(3)
        reg = BankRegistry(mesh=mesh, max_banks=2)
        banks, queries = {}, {}
        for name, (R, D) in [("t0", (61, 64)), ("t1", (40, 96))]:
            refs = jnp.asarray(rng.choice([-1, 1], (R, D)).astype(np.int8))
            dec = jnp.asarray(rng.choice([-1, 1], (R // 2, D)).astype(np.int8))
            reg.register(name, refs, decoys=dec, pin=name == "t0")
            banks[name] = jnp.concatenate([dec, refs], axis=0)
            queries[name] = np.asarray(
                rng.choice([-1, 1], (8, D)).astype(np.int8))
        srv = DBSearchServer(reg, k=4, fdr=1.0, max_batch_size=4,
                             flush_timeout_s=0.0, cache_bytes=1 << 20,
                             buckets=2, fairness_cap=2)
        meta = {}
        for _ in range(2):
            for i in range(8):
                for name in banks:
                    meta[srv.submit(queries[name][i], tenant=name)] = (name, i)
        done = srv.run_until_drained()
        assert len(done) == 32, len(done)
        for r in done:
            name, i = meta[r.rid]
            oi, ov = topk_search(jnp.asarray(queries[name][i:i+1]),
                                 banks[name], 4)
            assert (r.result.indices == np.asarray(oi)[0]).all(), (name, i)
            assert (r.result.scores == np.asarray(ov)[0]).all(), (name, i)
        s = srv.summary()
        assert s["query_cache"]["hits"] == 16, s["query_cache"]
        assert s["banks"]["builds"] == 2, s["banks"]
        assert set(s["tenants"]) == {"t0", "t1"}
        print("MULTITENANT_CACHED_OK")
    """)
    assert "MULTITENANT_CACHED_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_serve_db_cli_multi_tenant_on_8_device_mesh():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve_db", "--reduced",
         "--tenants", "2", "--buckets", "2", "--cache-mb", "8",
         "--fairness-cap", "8"],
        capture_output=True, text=True, timeout=520, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "queries/sec" in r.stdout and "cache" in r.stdout, r.stdout
