"""Property tests for repro.dist.compression — the invariants that make
compressed cross-pod gradient sync safe to run for millions of steps:

* EF-SGD conservation: ``sent + new_err == grads + old_err`` holds
  *bit-for-bit* in fp32 (masks are complementary selections of one
  accumulator), for any grads/residual and any top-k fraction.
* int8 stochastic rounding is unbiased within statistical tolerance when
  averaged over many rounding keys (and bounded by one quantization step
  elementwise for every key).
* top-k keeps exactly ``max(round(frac * n), 1)`` coordinates — ties
  included (exact cardinality is what the (index, value) wire-format
  accounting in ``tree_wire_bytes`` assumes).
* ``method='none'`` is the identity, and the per-step key threading
  actually changes the rounding noise between steps.

Strategies stick to the integers/floats/sampled_from subset that both
real hypothesis (CI) and the deterministic conftest micro-shim provide.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.dist.compression import (
    compress_tree,
    dcn_allreduce_tree,
    dcn_send,
    init_error_state,
    leaf_wire_bytes,
    per_step_key,
    topk_count,
    topk_ef_compress,
    tree_wire_bytes,
)


def _grad_tree(seed: int, n: int):
    """A small two-level grads pytree with an n-element and an n//3+1
    element leaf (multi-leaf trees exercise the per-leaf key fold)."""
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(n,)).astype(np.float32)),
        "inner": {"b": jnp.asarray(
            rng.normal(size=(n // 3 + 1,)).astype(np.float32))},
    }


# ---------------------------------------------------------------------------
# EF-SGD conservation
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 257),
       st.sampled_from([0.01, 0.1, 0.25, 0.5, 1.0]))
def test_ef_invariant_exact(seed, n, frac):
    """sent + new_err == grads + old_err, bit-for-bit in fp32, with a
    *nonzero* incoming residual (the steady-state case, not just step 0)."""
    grads = _grad_tree(seed, n)
    err = _grad_tree(seed + 1, n)  # arbitrary prior residual
    sent, new_err = topk_ef_compress(grads, err, topk_frac=frac)
    for g, e, s, ne in zip(jax.tree.leaves(grads), jax.tree.leaves(err),
                           jax.tree.leaves(sent), jax.tree.leaves(new_err)):
        lhs = np.asarray(s) + np.asarray(ne)     # fp32 adds, like the rhs
        rhs = np.asarray(g) + np.asarray(e)
        np.testing.assert_array_equal(lhs, rhs)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 200))
def test_ef_sent_and_residual_disjoint(seed, n):
    """A coordinate is either sent or kept — never both, never scaled."""
    grads = _grad_tree(seed, n)
    err = init_error_state(grads)
    sent, new_err = topk_ef_compress(grads, err, topk_frac=0.25)
    for s, ne in zip(jax.tree.leaves(sent), jax.tree.leaves(new_err)):
        assert not np.any((np.asarray(s) != 0) & (np.asarray(ne) != 0))


# ---------------------------------------------------------------------------
# int8 stochastic rounding
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(st.integers(0, 1_000))
def test_int8_unbiased_over_keys(seed):
    """E[decompress(compress(x))] == x: the mean rounding error over many
    keys shrinks as 1/sqrt(K), far inside a 5%-of-scale budget."""
    rng = np.random.default_rng(seed)
    x = {"w": jnp.asarray(rng.normal(size=(256,)).astype(np.float32))}
    scale = float(jnp.abs(x["w"]).max()) / 127.0
    fn = jax.jit(lambda key: compress_tree(x, method="int8", key=key)["w"])
    keys = 64
    acc = np.zeros(256, np.float64)
    for k in range(keys):
        out = np.asarray(fn(jax.random.PRNGKey(seed * keys + k)))
        err = out - np.asarray(x["w"])
        assert np.abs(err).max() <= scale + 1e-6  # bounded for every key
        acc += err
    # mean over 64 keys x 256 elements: sigma ~ scale/sqrt(12*16384)
    assert abs(acc.mean() / keys) < 0.05 * scale


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(64, 300))
def test_int8_key_threading(seed, n):
    """Same key -> identical codes; per-step keys -> fresh noise. The
    pre-fix behavior (no key argument) stays the fixed legacy key."""
    grads = _grad_tree(seed, n)
    k5 = per_step_key(0, 5)
    a = compress_tree(grads, method="int8", key=k5)
    b = compress_tree(grads, method="int8", key=k5)
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    legacy1 = compress_tree(grads, method="int8")
    legacy2 = compress_tree(grads, method="int8",
                            key=jax.random.PRNGKey(0))
    for la, lb in zip(jax.tree.leaves(legacy1), jax.tree.leaves(legacy2)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    c = compress_tree(grads, method="int8", key=per_step_key(0, 6))
    same = all(np.array_equal(np.asarray(la), np.asarray(lc))
               for la, lc in zip(jax.tree.leaves(a), jax.tree.leaves(c)))
    assert not same  # a different step must draw different noise


# ---------------------------------------------------------------------------
# top-k cardinality
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 400),
       st.sampled_from([0.001, 0.01, 0.1, 0.5, 1.0]))
def test_topk_exact_count(seed, n, frac):
    """Exactly max(round(frac*n), 1) coordinates survive — even with
    heavy magnitude ties (integer-valued inputs)."""
    rng = np.random.default_rng(seed)
    # values in {-3..-1, 1..3}: no zeros, many |.| ties
    vals = rng.integers(1, 4, size=n) * rng.choice([-1.0, 1.0], size=n)
    g = {"w": jnp.asarray(vals.astype(np.float32))}
    out = compress_tree(g, method="topk", topk_frac=frac)
    assert int(np.count_nonzero(np.asarray(out["w"]))) == topk_count(n, frac)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 300),
       st.sampled_from([0.01, 0.1, 0.25]))
def test_topk_ef_exact_count(seed, n, frac):
    """The EF send keeps the same exact cardinality on its accumulator."""
    rng = np.random.default_rng(seed)
    vals = rng.integers(1, 4, size=n) * rng.choice([-1.0, 1.0], size=n)
    g = {"w": jnp.asarray(vals.astype(np.float32))}
    sent, _ = topk_ef_compress(g, init_error_state(g), topk_frac=frac)
    assert int(np.count_nonzero(np.asarray(sent["w"]))) == topk_count(n, frac)


def test_topk_keeps_largest_magnitudes():
    g = {"w": jnp.asarray(np.asarray(
        [0.1, -5.0, 0.2, 4.0, -0.3, 3.0, 0.05, -2.0], np.float32))}
    out = np.asarray(compress_tree(g, method="topk", topk_frac=0.5)["w"])
    np.testing.assert_array_equal(
        out, np.asarray([0, -5.0, 0, 4.0, 0, 3.0, 0, -2.0], np.float32))


# ---------------------------------------------------------------------------
# identity + dcn_send plumbing
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 300))
def test_none_is_identity(seed, n):
    grads = _grad_tree(seed, n)
    out = compress_tree(grads, method="none")
    assert out is grads  # short-circuit, not a copy
    sent, err = dcn_send(grads, {}, method="none")
    assert sent is grads and err == {}


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 200),
       st.sampled_from(["int8", "topk"]))
def test_dcn_send_stateless_methods_keep_error(seed, n, method):
    """Stateless methods pass the (empty) error tree through untouched."""
    grads = _grad_tree(seed, n)
    sent, err = dcn_send(grads, {}, method=method, key=per_step_key(0, 1))
    assert err == {}
    assert jax.tree.structure(sent) == jax.tree.structure(grads)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 200),
       st.sampled_from([0.01, 0.25]))
def test_dcn_send_topk_ef_matches_topk_ef_compress(seed, n, frac):
    grads = _grad_tree(seed, n)
    err = _grad_tree(seed + 7, n)
    a = dcn_send(grads, err, method="topk_ef", topk_frac=frac)
    b = topk_ef_compress(grads, err, topk_frac=frac)
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# wire-format accounting
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(1, 100_000),
       st.sampled_from([0.001, 0.01, 0.1, 1.0]))
def test_leaf_wire_bytes_formulas(n, frac):
    assert leaf_wire_bytes(n, "none") == 4 * n
    assert leaf_wire_bytes(n, "int8") == n + 4
    assert (leaf_wire_bytes(n, "topk", frac)
            == leaf_wire_bytes(n, "topk_ef", frac)
            == 8 * topk_count(n, frac))


def test_tree_wire_bytes_sums_leaves():
    tree = {"a": jnp.zeros((8, 4)), "b": {"c": jnp.zeros((3,))}}
    assert tree_wire_bytes(tree, "none") == 4 * 35
    assert tree_wire_bytes(tree, "int8") == (32 + 4) + (3 + 4)
    # 1% of 32 rounds to 0 -> floor of one coordinate per leaf
    assert tree_wire_bytes(tree, "topk", 0.01) == 8 * (1 + 1)


def test_topk_wire_bytes_beat_raw_by_4x():
    """The acceptance-bar ratio: top-k at the default 1% fraction moves
    >=4x fewer bytes than raw fp32 on realistically-sized leaves."""
    tree = {"w": jnp.zeros((4096, 128))}
    raw = tree_wire_bytes(tree, "none")
    for method in ("topk", "topk_ef"):
        assert raw / tree_wire_bytes(tree, method, 0.01) >= 4.0


# ---------------------------------------------------------------------------
# dcn_allreduce_tree degradation (single-device 'pod' axis of size 1 —
# the real multi-pod collective runs in tests/test_multidevice.py)
# ---------------------------------------------------------------------------

def test_dcn_allreduce_tree_single_pod_none_is_identity():
    mesh = jax.make_mesh((1,), ("pod",))
    grads = _grad_tree(0, 64)
    stacked = jax.tree.map(lambda x: x[None], grads)
    red, new_ef = dcn_allreduce_tree(stacked, {}, mesh, method="none")
    assert new_ef == {}
    for a, b in zip(jax.tree.leaves(red), jax.tree.leaves(grads)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dcn_allreduce_tree_single_pod_topk_ef_invariant():
    """Through the shard_map wrapper, the EF invariant still holds:
    reduced + residual == grads + old residual (one pod, so the psum is
    the send itself)."""
    mesh = jax.make_mesh((1,), ("pod",))
    grads = _grad_tree(1, 64)
    err = _grad_tree(2, 64)
    stacked = jax.tree.map(lambda x: x[None], grads)
    err_s = jax.tree.map(lambda x: x[None], err)
    red, new_ef = dcn_allreduce_tree(stacked, err_s, mesh,
                                     method="topk_ef", topk_frac=0.25)
    for r, ne, g, e in zip(jax.tree.leaves(red), jax.tree.leaves(new_ef),
                           jax.tree.leaves(grads), jax.tree.leaves(err)):
        lhs = np.asarray(r) + np.asarray(ne)[0]
        np.testing.assert_array_equal(lhs, np.asarray(g) + np.asarray(e))


def test_dcn_allreduce_tree_rejects_unknown_method():
    mesh = jax.make_mesh((1,), ("pod",))
    with pytest.raises(ValueError):
        dcn_allreduce_tree({"w": jnp.zeros((1, 4))}, {}, mesh,
                           method="zstd")
