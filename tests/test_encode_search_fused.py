"""Fused encode->pack->search kernel vs the staged oracle.

The fused kernel (``repro.kernels.encode_search``) must be bit-identical
— indices, scores, tie order, overflow slots — to running the stages
through HBM: Eq. 1 encode, bank-form encode (bit-pack / int8 cast), then
top-k. Property tests (hypothesis; the conftest shim when the package is
absent) cover ragged Q/R shapes, the D % 32 != 0 int8 fallback,
duplicate-score ties, banded/OMS windows, and the emulated-shard routed
configurations (1/2/4/8 shards) up through the serving FDR route — all
in interpret mode (tier-1, CPU).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.hd.encoding import encode_levels_batch
from repro.kernels.encode_search import (
    encode_search_banded_pallas,
    encode_search_banded_ref,
    encode_search_pallas,
    encode_search_ref,
)
from repro.serve import (
    OMSConfig,
    QueryEncoder,
    encode_queries,
    oms_plan,
    oms_search_encoded,
    oms_search_levels,
    search_database_encoded,
    search_database_levels,
    shard_database,
)


def _codebooks(rng, f, d, m):
    id_hvs = jnp.asarray(rng.choice([-1, 1], size=(f, d)).astype(np.int8))
    level_hvs = jnp.asarray(rng.choice([-1, 1], size=(m, d)).astype(np.int8))
    return id_hvs, level_hvs


def _levels(rng, q, f, m):
    return jnp.asarray(rng.integers(0, m, size=(q, f)), jnp.int32)


def _assert_same(got, want, *ctx):
    gi, gv = got
    wi, wv = want
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi),
                                  err_msg=str(ctx))
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv),
                                  err_msg=str(ctx))


# --------------------------------------------------------------------------
# kernel vs staged oracle
# --------------------------------------------------------------------------

class TestFusedVsStagedOracle:
    @settings(max_examples=8)
    @given(st.integers(1, 19), st.integers(1, 140), st.sampled_from([32, 64]),
           st.integers(1, 7))
    def test_packed_random_shapes(self, q, r, d, k):
        """Ragged Q/R over a packed bank: one dispatch == three stages."""
        k = min(k, r)
        rng = np.random.default_rng(q * 7919 + r * 131 + d + k)
        f, m = 11, 5
        id_hvs, level_hvs = _codebooks(rng, f, d, m)
        levels = _levels(rng, q, f, m)
        bank = jnp.asarray(
            rng.integers(0, 2**32, (r, d // 32), dtype=np.uint32))
        got = encode_search_pallas(levels, id_hvs, level_hvs, bank, dim=d,
                                   k=k)
        want = encode_search_ref(levels, id_hvs, level_hvs, bank, k=k)
        _assert_same(got, want, q, r, d, k)

    @settings(max_examples=8)
    @given(st.integers(1, 13), st.integers(1, 90),
           st.sampled_from([17, 40, 100]), st.integers(1, 6))
    def test_int8_fallback_random_shapes(self, q, r, d, k):
        """D % 32 != 0 routes the int8-dot tile path; same contract."""
        k = min(k, r)
        rng = np.random.default_rng(q * 733 + r * 37 + d * 5 + k)
        f, m = 9, 4
        id_hvs, level_hvs = _codebooks(rng, f, d, m)
        levels = _levels(rng, q, f, m)
        bank = jnp.asarray(rng.choice([-1, 1], size=(r, d)).astype(np.int8))
        got = encode_search_pallas(levels, id_hvs, level_hvs, bank, dim=d,
                                   k=k)
        want = encode_search_ref(levels, id_hvs, level_hvs, bank, k=k)
        _assert_same(got, want, q, r, d, k)

    @settings(max_examples=6)
    @given(st.integers(2, 20), st.integers(1, 5))
    def test_duplicate_scores_tiebreak(self, r, k):
        """A bank of each query's own encoded HV repeated 3x ties every
        repeat exactly; the fused path must keep lax.top_k's ascending-
        index tie order through the in-kernel encode."""
        k = min(k, 3 * r)
        rng = np.random.default_rng(r * 101 + k)
        f, d, m = 8, 32, 4
        id_hvs, level_hvs = _codebooks(rng, f, d, m)
        levels = _levels(rng, min(r, 6), f, m)
        hv = encode_levels_batch(levels, id_hvs, level_hvs)
        base = jnp.concatenate([hv] * max(1, -(-r // hv.shape[0])))[:r]
        bank_hv = jnp.concatenate([base, base, base], axis=0)
        from repro.core.hd.similarity import bitpack_bipolar
        bank = bitpack_bipolar(bank_hv)
        got = encode_search_pallas(levels, id_hvs, level_hvs, bank, dim=d,
                                   k=k)
        want = encode_search_ref(levels, id_hvs, level_hvs, bank, k=k)
        _assert_same(got, want, r, k)

    @pytest.mark.parametrize("num_valid", [0, 1, 5, 9, 12])
    def test_num_valid_masks_like_shard_padding(self, num_valid):
        """Rows >= num_valid are sentinel-masked with ascending overflow
        fillers — the shard-padding contract of db_search."""
        rng = np.random.default_rng(3)
        f, d, m, r, k = 10, 32, 4, 12, 6
        id_hvs, level_hvs = _codebooks(rng, f, d, m)
        levels = _levels(rng, 5, f, m)
        bank = jnp.asarray(
            rng.integers(0, 2**32, (r, 1), dtype=np.uint32))
        got = encode_search_pallas(levels, id_hvs, level_hvs, bank, dim=d,
                                   k=k, num_valid=num_valid)
        want = encode_search_ref(levels, id_hvs, level_hvs, bank, k=k,
                                 num_valid=num_valid)
        _assert_same(got, want, num_valid)

    @settings(max_examples=8)
    @given(st.integers(1, 12), st.integers(8, 90), st.sampled_from([32, 55]),
           st.integers(1, 5))
    def test_banded_windows(self, q, r, d, k):
        """Banded (OMS-window) variant vs the masked-full-matrix oracle,
        including empty and overflowing (len < k) windows."""
        k = min(k, r)
        rng = np.random.default_rng(q * 311 + r * 13 + d + k)
        f, m = 8, 4
        id_hvs, level_hvs = _codebooks(rng, f, d, m)
        levels = _levels(rng, q, f, m)
        if d % 32 == 0:
            bank = jnp.asarray(
                rng.integers(0, 2**32, (r, d // 32), dtype=np.uint32))
        else:
            bank = jnp.asarray(
                rng.choice([-1, 1], size=(r, d)).astype(np.int8))
        starts = rng.integers(0, r, size=q).astype(np.int32)
        lens = rng.integers(0, r, size=q).astype(np.int32)
        got = encode_search_banded_pallas(
            levels, id_hvs, level_hvs, bank, jnp.asarray(starts),
            jnp.asarray(lens), dim=d, k=k)
        want = encode_search_banded_ref(levels, id_hvs, level_hvs, bank,
                                        starts, lens, k=k)
        _assert_same(got, want, q, r, d, k)


# --------------------------------------------------------------------------
# routed configurations: fused e2e == staged e2e through the serve layer
# --------------------------------------------------------------------------

def _bank_inputs(seed, *, d, n_refs=30, n_decoys=30):
    rng = np.random.default_rng(seed)
    refs = jnp.asarray(rng.choice([-1, 1], size=(n_refs, d)).astype(np.int8))
    decoys = jnp.asarray(
        rng.choice([-1, 1], size=(n_decoys, d)).astype(np.int8))
    return rng, refs, decoys


class TestRoutedConfigurations:
    @pytest.mark.parametrize("shards", [1, 2, 4, 8])
    @pytest.mark.parametrize("d", [64, 48])  # packed / int8 banks
    def test_emulated_shards_exact(self, shards, d):
        rng, refs, decoys = _bank_inputs(shards * 100 + d, d=d)
        enc = QueryEncoder.from_config(dim=d, num_features=16, num_levels=6,
                                       seed=7)
        levels = _levels(rng, 9, 16, 6)
        db = shard_database(refs, decoys=decoys,
                            emulate_shards=shards if shards > 1 else None)
        fused = search_database_levels(db, enc, levels, 3, fused_e2e=True)
        staged = search_database_levels(db, enc, levels, 3)
        _assert_same(fused, staged, shards, d)
        # and the staged-levels route equals the pre-encoded-HV route
        hv = encode_levels_batch(levels, enc.id_hvs, enc.level_hvs)
        oracle = search_database_encoded(db, encode_queries(db, hv), 3)
        _assert_same(staged, oracle, shards, d)

    @pytest.mark.parametrize("shards", [1, 2, 4])
    def test_emulated_shards_oms(self, shards):
        d = 64
        rng, refs, decoys = _bank_inputs(shards * 77, d=d)
        enc = QueryEncoder.from_config(dim=d, num_features=16, num_levels=6,
                                       seed=7)
        levels = _levels(rng, 8, 16, 6)
        prec = np.sort(rng.uniform(100, 900, refs.shape[0])).astype(
            np.float32)
        qprec = np.sort(rng.uniform(100, 900, 8)).astype(np.float32)
        db = shard_database(refs, decoys=decoys, precursor=prec,
                            emulate_shards=shards if shards > 1 else None)
        plan = oms_plan(db, qprec, OMSConfig(tol=40, open_tol=250))
        fused = oms_search_levels(db, enc, levels, plan, 3, fused_e2e=True)
        staged = oms_search_levels(db, enc, levels, plan, 3)
        _assert_same(fused, staged, shards)
        hv = encode_levels_batch(levels, enc.id_hvs, enc.level_hvs)
        oracle = oms_search_encoded(db, encode_queries(db, hv), plan, 3)
        _assert_same(staged, oracle, shards)

    def test_encoder_bank_dim_mismatch_raises(self):
        rng, refs, _ = _bank_inputs(5, d=64)
        enc = QueryEncoder.from_config(dim=32, num_features=8, num_levels=4)
        db = shard_database(refs)
        with pytest.raises(ValueError, match="encoder dim"):
            search_database_levels(db, enc, _levels(rng, 2, 8, 4), 2)
