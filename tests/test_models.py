"""Layer-level model tests: attention paths agree, MoE vs dense-expert
oracle, recurrent chunked-vs-step consistency, quantized KV cache, rope."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import layers as L
from repro.models import recurrent as R


@pytest.fixture(scope="module")
def cfg():
    return get_config("qwen2_7b").reduced()


def test_chunked_attention_matches_full(cfg):
    key = jax.random.PRNGKey(0)
    p, _ = L.init_attention(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model),
                          jnp.float32) * 0.1
    full = L.attention_train(p, x, cfg, chunk_threshold=8192)
    chunked = L.attention_train(p, x, cfg, chunk_threshold=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               rtol=2e-3, atol=2e-4)


def test_sliding_window_masks_past(cfg):
    swcfg = dataclasses.replace(cfg, sliding_window=8)
    key = jax.random.PRNGKey(0)
    p, _ = L.init_attention(key, swcfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model),
                          jnp.float32) * 0.1
    # attention at position 31 must not see positions <= 23: perturbing
    # position 0 must not change output at position 31
    y1 = L.attention_train(p, x, swcfg)
    x2 = x.at[:, 0].add(10.0)
    y2 = L.attention_train(p, x2, swcfg)
    np.testing.assert_allclose(np.asarray(y1[:, 31]), np.asarray(y2[:, 31]),
                               atol=1e-5)
    assert not np.allclose(np.asarray(y1[:, 1]), np.asarray(y2[:, 1]),
                           atol=1e-5)


def test_decode_matches_train_stepwise(cfg):
    """Greedy decode over a short sequence must reproduce training-mode
    attention outputs position by position."""
    key = jax.random.PRNGKey(0)
    p, _ = L.init_attention(key, cfg)
    S = 8
    x = jax.random.normal(jax.random.PRNGKey(1), (1, S, cfg.d_model),
                          jnp.float32) * 0.1
    train_out = L.attention_train(p, x, cfg)
    cache = L.init_kv_cache(cfg, 1, S, dtype=jnp.float32)
    outs = []
    for t in range(S):
        y, cache = L.attention_decode(p, x[:, t:t + 1], cfg, cache,
                                      jnp.asarray(t, jnp.int32))
        outs.append(y)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(train_out), np.asarray(dec),
                               rtol=2e-3, atol=2e-4)


def test_quantized_kv_decode_close_to_exact(cfg):
    qcfg = dataclasses.replace(cfg, kv_quant_int8=True)
    key = jax.random.PRNGKey(0)
    p, _ = L.init_attention(key, cfg)
    S = 8
    x = jax.random.normal(jax.random.PRNGKey(1), (1, S, cfg.d_model),
                          jnp.float32) * 0.1
    exact_cache = L.init_kv_cache(cfg, 1, S, dtype=jnp.float32)
    quant_cache = L.init_kv_cache(qcfg, 1, S)
    assert isinstance(quant_cache, L.QuantKVCache)
    for t in range(S):
        ye, exact_cache = L.attention_decode(p, x[:, t:t + 1], cfg,
                                             exact_cache,
                                             jnp.asarray(t, jnp.int32))
        yq, quant_cache = L.attention_decode(p, x[:, t:t + 1], qcfg,
                                             quant_cache,
                                             jnp.asarray(t, jnp.int32))
    # int8 with per-position scales: ~1% relative error budget
    np.testing.assert_allclose(np.asarray(ye), np.asarray(yq), rtol=0.05,
                               atol=5e-3)


def test_quantized_prefill_then_decode(cfg):
    qcfg = dataclasses.replace(cfg, kv_quant_int8=True)
    p, _ = L.init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model),
                          jnp.float32) * 0.1
    qc = L.init_kv_cache(qcfg, 1, 8)
    _, qc = L.attention_prefill(p, x[:, :7], qcfg, qc)
    yq, _ = L.attention_decode(p, x[:, 7:8], qcfg, qc,
                               jnp.asarray(7, jnp.int32))
    ec = L.init_kv_cache(cfg, 1, 8, dtype=jnp.float32)
    _, ec = L.attention_prefill(p, x[:, :7], cfg, ec)
    ye, _ = L.attention_decode(p, x[:, 7:8], cfg, ec,
                               jnp.asarray(7, jnp.int32))
    np.testing.assert_allclose(np.asarray(ye), np.asarray(yq), rtol=0.05,
                               atol=5e-3)


def test_moe_matches_dense_expert_oracle():
    """With top_k == num_experts and generous capacity every token reaches
    every expert, so MoE output == gate-weighted sum of expert FFNs."""
    cfg = dataclasses.replace(
        get_config("deepseek_moe_16b").reduced(),
        num_experts=4, top_k=4, num_shared_experts=0, capacity_factor=4.0,
        moe_group_size=16, dtype="float32")
    p, _ = L.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float32) * 0.1
    out = L.apply_moe(p, x, cfg)

    xt = x.reshape(-1, cfg.d_model)
    gates = jax.nn.softmax(xt @ p["router"], -1)
    dense = jnp.zeros_like(xt)
    for e in range(4):
        h = jax.nn.silu(xt @ p["w_gate"][e]) * (xt @ p["w_up"][e])
        dense += gates[:, e:e + 1] * (h @ p["w_down"][e])
    np.testing.assert_allclose(np.asarray(out.reshape(-1, cfg.d_model)),
                               np.asarray(dense), rtol=2e-3, atol=2e-4)


def test_moe_capacity_drops_tokens():
    """With capacity 1 and many tokens routed to one expert, overflow
    tokens must be dropped (output zero for their expert contribution)."""
    cfg = dataclasses.replace(
        get_config("deepseek_moe_16b").reduced(),
        num_experts=2, top_k=1, num_shared_experts=0, capacity_factor=0.2,
        moe_group_size=16, dtype="float32")
    p, _ = L.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.broadcast_to(
        jax.random.normal(jax.random.PRNGKey(1), (1, 1, cfg.d_model)),
        (1, 16, cfg.d_model)).astype(jnp.float32)
    out = L.apply_moe(p, x, cfg)
    # identical tokens all route to one expert; capacity = 16*1*0.2/2 = 1
    # -> only ~1 token served, rest zeros
    nonzero_rows = (np.abs(np.asarray(out[0])).max(-1) > 1e-6).sum()
    assert nonzero_rows <= 2


class TestRecurrent:
    def test_mamba_chunked_matches_stepwise(self):
        cfg = get_config("hymba_1_5b").reduced()
        p, _ = R.init_mamba(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                              jnp.float32) * 0.5
        full = R.mamba_train(p, x, cfg, chunk=8)
        st = R.init_mamba_state(cfg, 2)
        outs = []
        for t in range(16):
            y, st = R.mamba_decode(p, x[:, t:t + 1], cfg, st)
            outs.append(y)
        step = jnp.concatenate(outs, 1)
        np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                                   rtol=2e-3, atol=2e-4)

    def test_mlstm_chunked_matches_stepwise(self):
        cfg = get_config("xlstm_125m").reduced()
        p, _ = R.init_mlstm(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                              jnp.float32) * 0.5
        full = R.mlstm_train(p, x, cfg, chunk=4)
        st = R.init_mlstm_state(cfg, 2)
        outs = []
        for t in range(16):
            y, st = R.mlstm_decode(p, x[:, t:t + 1], cfg, st)
            outs.append(y)
        step = jnp.concatenate(outs, 1)
        np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                                   rtol=5e-3, atol=5e-4)

    def test_slstm_scan_matches_stepwise(self):
        cfg = get_config("xlstm_125m").reduced()
        p, _ = R.init_slstm(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model),
                              jnp.float32) * 0.5
        full = R.slstm_train(p, x, cfg)
        st = R.init_slstm_state(cfg, 2)
        outs = []
        for t in range(12):
            y, st = R.slstm_decode(p, x[:, t:t + 1], cfg, st)
            outs.append(y)
        step = jnp.concatenate(outs, 1)
        np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                                   rtol=2e-3, atol=2e-4)

    def test_mlstm_long_sequence_stable(self):
        cfg = get_config("xlstm_125m").reduced()
        p, _ = R.init_mlstm(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 512, cfg.d_model),
                              jnp.float32)
        out = R.mlstm_train(p, x, cfg)
        assert np.isfinite(np.asarray(out)).all()


def test_rope_rotation_properties():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 2, 8), jnp.float32)
    pos = jnp.arange(4)
    y = L.apply_rope(x, pos, 10000.0)
    # norms preserved (rotation)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i - j
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 8))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 8))
    def dot_at(i, j):
        qi = L.apply_rope(q, jnp.asarray([i]), 10000.0)
        kj = L.apply_rope(k, jnp.asarray([j]), 10000.0)
        return float(jnp.sum(qi * kj))
    assert dot_at(3, 1) == pytest.approx(dot_at(7, 5), rel=1e-4)
    assert dot_at(3, 1) != pytest.approx(dot_at(3, 2), rel=1e-3)
