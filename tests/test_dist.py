"""Distribution substrate tests: sharding rules, checkpoint/restore (incl.
elastic reshard + corruption tolerance), gradient compression, collective
matmul, straggler monitor."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.checkpoint import CheckpointManager
from repro.dist.compression import compress_tree, init_error_state, topk_ef_compress
from repro.dist.sharding import DEFAULT_RULES, logical_to_spec, set_mesh
from repro.dist.straggler import Action, HeartbeatRegistry, StragglerMonitor


class TestShardingRules:
    def setup_method(self):
        set_mesh(None)

    def test_divisibility_fallback(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        # model axis size 1 -> everything divisible, spec uses names
        spec = logical_to_spec(("vocab", "fsdp"), (256, 128), mesh)
        assert spec == jax.sharding.PartitionSpec("model", "data")

    def test_missing_axis_degrades(self):
        mesh = jax.make_mesh((1,), ("data",))
        spec = logical_to_spec(("batch", None), (8, 4), mesh)
        # ('pod','data') degrades to ('data',) since pod doesn't exist
        assert spec == jax.sharding.PartitionSpec("data", None)

    def test_indivisible_replicates(self):
        devs = jax.devices()
        if len(devs) < 1:
            pytest.skip("no devices")
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        rules = DEFAULT_RULES
        # 7 not divisible by ... 1 always divides; simulate via dim check
        spec = logical_to_spec(("heads",), (7,), mesh, rules)
        assert spec == jax.sharding.PartitionSpec("model")  # 7 % 1 == 0

    def test_axis_used_once(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        spec = logical_to_spec(("vocab", "heads"), (256, 256), mesh)
        # both want 'model'; second falls back to replication
        assert spec == jax.sharding.PartitionSpec("model", None)

    def test_rules_replace(self):
        r = DEFAULT_RULES.replace(seq="model")
        assert r.lookup("seq") == "model"
        assert r.lookup("vocab") == "model"

    def test_without_axis(self):
        from repro.dist.sharding import without_axis
        assert without_axis(("pod", "data"), "pod") == ("data",)
        assert without_axis(("pod",), "pod") is None
        assert without_axis("pod", "pod") is None
        assert without_axis("data", "pod") == "data"
        assert without_axis(None, "pod") is None

    def test_rules_override_scoped(self):
        from repro.dist.sharding import get_rules, rules_override
        base = get_rules()
        with rules_override(batch=("data",)) as r:
            assert r.lookup("batch") == ("data",)
            assert get_rules().lookup("batch") == ("data",)
            assert get_rules().lookup("fsdp") == base.lookup("fsdp")
        assert get_rules() is base


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32)),
        "b": {"c": jnp.asarray(rng.normal(size=(3,)).astype(np.float32)),
              "d": jnp.asarray(np.int32(7))},
    }


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2)
        tree = _tree()
        mgr.save(10, tree)
        out = mgr.restore(10, tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_keep_n_gc(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, _tree(s))
        assert mgr.list_steps() == [3, 4]

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=3)
        mgr.save_async(5, _tree())
        mgr.wait()
        assert mgr.list_steps() == [5]
        assert mgr.validate(5)

    def test_restore_latest_skips_corrupt(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=5)
        mgr.save(1, _tree(1))
        mgr.save(2, _tree(2))
        # corrupt the newest checkpoint's arrays
        (tmp_path / "step_00000002" / "arrays.npz").write_bytes(b"garbage")
        got = mgr.restore_latest(_tree())
        assert got is not None
        step, tree = got
        assert step == 1
        np.testing.assert_array_equal(
            np.asarray(tree["a"]), np.asarray(_tree(1)["a"]))

    def test_torn_write_invisible(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=5)
        # a .tmp directory (torn write) must not be listed
        (tmp_path / "step_00000009.tmp").mkdir()
        assert mgr.list_steps() == []

    def test_structure_mismatch_raises(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, _tree())
        with pytest.raises(ValueError):
            mgr.restore(1, {"different": jnp.zeros(3)})

    def test_elastic_reshard_on_load(self, tmp_path):
        """Restore with explicit shardings (the elastic path): values must
        survive a device_put through a different layout."""
        mgr = CheckpointManager(tmp_path)
        tree = _tree()
        mgr.save(1, tree)
        mesh = jax.make_mesh((1,), ("data",))
        sh = jax.tree.map(
            lambda x: jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec(*([None] * np.ndim(x)))),
            tree)
        out = mgr.restore(1, tree, shardings=sh)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestCompression:
    def test_int8_unbiased_and_bounded(self):
        g = {"w": jnp.asarray(np.random.default_rng(0).normal(
            size=(64, 64)).astype(np.float32))}
        out = compress_tree(g, method="int8")
        err = np.asarray(out["w"] - g["w"])
        scale = float(jnp.abs(g["w"]).max()) / 127
        assert np.abs(err).max() <= scale + 1e-6
        assert abs(err.mean()) < scale  # stochastic rounding ~unbiased

    def test_topk_keeps_largest(self):
        g = {"w": jnp.asarray(np.arange(100, dtype=np.float32) - 50)}
        out = compress_tree(g, method="topk", topk_frac=0.1)
        nz = np.nonzero(np.asarray(out["w"]))[0]
        assert len(nz) <= 12
        assert 0 in nz and 99 in nz  # extremes survive

    def test_error_feedback_conserves_signal(self):
        """EF invariant: sent + new_error == grads + old_error exactly."""
        g = {"w": jnp.asarray(np.random.default_rng(1).normal(
            size=(32,)).astype(np.float32))}
        err = init_error_state(g)
        sent, new_err = topk_ef_compress(g, err, topk_frac=0.25)
        lhs = np.asarray(sent["w"], dtype=np.float64) + np.asarray(new_err["w"], dtype=np.float64)
        rhs = np.asarray(g["w"], dtype=np.float64) + np.asarray(err["w"], dtype=np.float64)
        np.testing.assert_allclose(lhs, rhs, rtol=1e-6)

    def test_ef_residual_transmitted_eventually(self):
        g = {"w": jnp.asarray(np.ones(16, np.float32))}
        err = init_error_state(g)
        total = np.zeros(16)
        for _ in range(8):
            sent, err = topk_ef_compress(g, err, topk_frac=0.25)
            total += np.asarray(sent["w"])
        # after 8 steps of identical grads, every coordinate was sent
        assert (total > 0).all()


class TestCollectiveMatmul:
    def test_ring_matmul_reduce_matches_dense(self):
        from repro.dist.collective_matmul import ring_matmul_reduce
        mesh = jax.make_mesh((1,), ("model",))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(16, 4)).astype(np.float32))
        out = ring_matmul_reduce(x, w, mesh)
        np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w),
                                   rtol=1e-5)

    def test_ag_matmul_pipelined_matches_dense(self):
        from repro.dist.collective_matmul import ag_matmul_pipelined
        mesh = jax.make_mesh((1,), ("model",))
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(8, 6)).astype(np.float32))
        out = ag_matmul_pipelined(x, w, mesh)
        np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w),
                                   rtol=1e-5)


class TestStraggler:
    def test_healthy_steps_ok(self):
        m = StragglerMonitor(warmup_steps=3)
        acts = [m.observe(1.0 + 0.01 * i) for i in range(20)]
        assert all(a == Action.OK for a in acts)

    def test_single_spike_warns_then_recovers(self):
        m = StragglerMonitor(warmup_steps=3, consecutive_limit=2)
        for _ in range(10):
            m.observe(1.0)
        assert m.observe(5.0) == Action.WARN
        assert m.observe(1.0) == Action.OK
        assert m.consecutive == 0

    def test_consecutive_slow_evicts(self):
        events = []
        m = StragglerMonitor(warmup_steps=3, consecutive_limit=2,
                             on_evict=lambda s, dt: events.append((s, dt)))
        for _ in range(10):
            m.observe(1.0)
        assert m.observe(5.0) == Action.WARN
        assert m.observe(5.0) == Action.EVICT
        assert len(events) == 1

    def test_straggler_does_not_poison_stats(self):
        m = StragglerMonitor(warmup_steps=3)
        for _ in range(10):
            m.observe(1.0)
        mean_before = m.mean
        m.observe(50.0)
        assert m.mean == mean_before  # slow step excluded from EWMA

    def test_heartbeat_detects_dead_host(self):
        reg = HeartbeatRegistry(num_hosts=3, timeout_steps=2)
        for _ in range(2):
            for h in (0, 1):
                reg.beat(h)
            dead = reg.tick()
        assert dead == [2]
