"""End-to-end SpecPCM pipeline behaviour tests (clustering + DB search)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SpecPCMConfig, run_clustering, run_db_search
from repro.spectra import SyntheticMSConfig, generate_dataset
from repro.spectra.fdr import fdr_filter, make_decoys
from repro.spectra.preprocess import (
    bin_spectra,
    bucket_by_precursor,
    candidate_window_mask,
    sqrt_normalize,
)
from repro.spectra.synthetic import generate_query_set


@pytest.fixture(scope="module")
def ds():
    return generate_dataset(SyntheticMSConfig(
        num_identities=24, spectra_per_identity=8, num_bins=1024))


@pytest.fixture(scope="module")
def refs(ds):
    t = ds.templates
    return t / jnp.maximum(t.max(1, keepdims=True), 1e-6)


@pytest.fixture(scope="module")
def ref_prec(ds):
    return jnp.asarray(np.asarray(ds.precursor)[::8])


class TestClusteringPipeline:
    def test_clusters_replicates(self, ds):
        cfg = SpecPCMConfig(hd_dim=1026, mlc_bits=3, num_levels=16)
        rep = run_clustering(ds.spectra, ds.precursor, ds.identity, cfg)
        assert rep.clustered_ratio > 0.8
        assert rep.incorrect_ratio < 0.05
        assert rep.cost.latency_s > 0 and rep.cost.energy_j > 0

    def test_slc_quality_geq_mlc3(self, ds):
        """Fig. 9 trend: SLC >= MLC3 clustered-spectra ratio (at the same
        low incorrect ratio)."""
        slc = run_clustering(ds.spectra, ds.precursor, ds.identity,
                             SpecPCMConfig(hd_dim=1024, mlc_bits=1,
                                           num_levels=16))
        mlc = run_clustering(ds.spectra, ds.precursor, ds.identity,
                             SpecPCMConfig(hd_dim=1026, mlc_bits=3,
                                           num_levels=16))
        assert slc.clustered_ratio >= mlc.clustered_ratio - 0.05
        assert slc.incorrect_ratio < 0.05 and mlc.incorrect_ratio < 0.05

    def test_ideal_vs_noisy(self, ds):
        ideal = run_clustering(ds.spectra, ds.precursor, ds.identity,
                               SpecPCMConfig(hd_dim=1026, mlc_bits=3,
                                             num_levels=16, ideal=True))
        assert ideal.clustered_ratio > 0.8


class TestDBSearchPipeline:
    def test_identifies_peptides_at_fdr(self, ds, refs, ref_prec):
        cfg = SpecPCMConfig(hd_dim=1026, mlc_bits=3, num_levels=16)
        q = generate_query_set(ds, SyntheticMSConfig(
            num_identities=24, spectra_per_identity=8, num_bins=1024), 48)
        rep = run_db_search(q.spectra, q.precursor, refs, ref_prec, cfg,
                            query_identity=q.identity,
                            ref_identity=jnp.arange(24))
        assert rep.num_identified > 0.5 * q.spectra.shape[0]
        assert rep.recall > 0.5
        assert rep.cost.latency_s > 0

    def test_dimension_hurts_when_tiny(self, ds, refs, ref_prec):
        """Fig. S4 trend: very small HD dim degrades identification."""
        q = generate_query_set(ds, SyntheticMSConfig(
            num_identities=24, spectra_per_identity=8, num_bins=1024), 48)

        def mk(d):
            return run_db_search(
                q.spectra, q.precursor, refs, ref_prec,
                SpecPCMConfig(hd_dim=d, mlc_bits=3, num_levels=16),
                query_identity=q.identity, ref_identity=jnp.arange(24))

        small, large = mk(96), mk(2049)
        assert large.recall >= small.recall

    def test_no_candidate_queries_do_not_poison_fdr(self, ds, refs, ref_prec):
        """Queries whose precursor window is empty are excluded from the
        FDR estimate (not counted as decoy wins), rejected with match=-1,
        and reported via num_no_candidate — while staying in the recall
        denominator."""
        cfg = SpecPCMConfig(hd_dim=1026, mlc_bits=3, num_levels=16)
        q = generate_query_set(ds, SyntheticMSConfig(
            num_identities=24, spectra_per_identity=8, num_bins=1024), 48)
        prec = np.asarray(q.precursor).copy()
        prec[:5] = 1e6  # far outside every reference window
        rep = run_db_search(q.spectra, jnp.asarray(prec), refs, ref_prec, cfg,
                            query_identity=q.identity,
                            ref_identity=jnp.arange(24))
        base = run_db_search(q.spectra, q.precursor, refs, ref_prec, cfg,
                             query_identity=q.identity,
                             ref_identity=jnp.arange(24))
        assert rep.num_no_candidate == 5
        assert (rep.matches[:5] == -1).all() and not rep.accepted[:5].any()
        # the other queries still identify: the 5 phantom "decoy wins" no
        # longer drag the whole batch's acceptance down
        assert rep.num_identified >= base.num_identified - 5
        assert rep.num_identified > 0.5 * (48 - 5)


class TestFDR:
    def test_fdr_filter_controls_rate(self):
        rng = np.random.default_rng(0)
        n = 2000
        # targets score high, decoys low, with overlap
        is_target = rng.uniform(size=n) < 0.7
        scores = np.where(is_target, rng.normal(5, 2, n), rng.normal(0, 2, n))
        accept = np.asarray(fdr_filter(jnp.asarray(scores),
                                       jnp.asarray(is_target), fdr=0.01))
        assert accept.sum() > 0
        assert not (accept & ~is_target).any()  # only targets accepted
        # the achieved decoy rate above the implied threshold is near 1%
        thr = scores[accept].min()
        n_dec_above = ((~is_target) & (scores >= thr)).sum()
        n_tgt_above = (is_target & (scores >= thr)).sum()
        assert n_dec_above / max(n_tgt_above, 1) <= 0.02

    def test_decoys_are_reversed(self):
        s = jnp.asarray(np.random.default_rng(1).uniform(0, 1, (3, 8)))
        d = make_decoys(s)
        np.testing.assert_array_equal(np.asarray(d), np.asarray(s)[:, ::-1])

    def test_fdr_filter_excludes_invalid_queries(self):
        """Queries with an empty candidate window (valid=False) must not
        count as decoy wins: a handful of them used to depress acceptance
        for the whole batch."""
        scores = jnp.asarray([9.0, 8.0, 7.0, 6.0, 5.0])
        is_target = jnp.asarray([True, True, True, True, True])
        # three no-candidate queries whose best_t == best_d "tie" shows up
        # as is_target=False at a high score
        bad = jnp.asarray([10.0, 9.5, 9.2])
        all_scores = jnp.concatenate([bad, scores])
        all_tgt = jnp.concatenate([jnp.zeros(3, bool), is_target])
        valid = jnp.concatenate([jnp.zeros(3, bool), jnp.ones(5, bool)])
        without = np.asarray(fdr_filter(all_scores, all_tgt, fdr=0.05))
        with_valid = np.asarray(fdr_filter(all_scores, all_tgt, fdr=0.05,
                                           valid=valid))
        assert not without.any()        # phantom decoys poison the estimate
        assert with_valid[3:].all()     # excluded, every real target passes
        assert not with_valid[:3].any()  # invalid queries are never accepted

    def test_fdr_filter_valid_all_true_is_noop(self):
        rng = np.random.default_rng(5)
        scores = jnp.asarray(rng.normal(0, 3, 64))
        tgt = jnp.asarray(rng.uniform(size=64) < 0.6)
        a = fdr_filter(scores, tgt, fdr=0.1)
        b = fdr_filter(scores, tgt, fdr=0.1, valid=jnp.ones(64, bool))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestPreprocess:
    def test_bin_spectra(self):
        mz = jnp.asarray([[300.0, 500.0, 1999.0], [200.0, 200.1, 1000.0]])
        inten = jnp.asarray([[1.0, 0.5, 0.2], [0.3, 0.9, 0.6]])
        out = bin_spectra(mz, inten, num_bins=64)
        assert out.shape == (2, 64)
        assert float(out.max()) == 1.0
        assert (np.asarray(out) >= 0).all()

    def test_sqrt_normalize(self):
        x = jnp.asarray([[0.0, 0.25, 1.0]])
        out = np.asarray(sqrt_normalize(x))
        assert out[0, 2] == pytest.approx(1.0)
        assert out[0, 1] == pytest.approx(0.5)

    def test_bucketing_partitions(self):
        prec = np.asarray([400., 401., 500., 502., 900.])
        buckets = bucket_by_precursor(prec, bucket_width=50.0)
        all_idx = np.sort(np.concatenate(buckets))
        np.testing.assert_array_equal(all_idx, np.arange(5))
        # nearby masses share a bucket
        b_of = {i: bi for bi, b in enumerate(buckets) for i in b}
        assert b_of[0] == b_of[1] and b_of[2] == b_of[3]
        assert b_of[0] != b_of[4]

    def test_bucketing_empty_input(self):
        assert bucket_by_precursor(np.asarray([], np.float32), 50.0) == []

    def test_candidate_window_open_search(self):
        """An open-search window admits references *lighter* than the query
        (query - ref in (-tol, open_tol)): a modification adds mass to the
        query, so its unmodified reference sits open_tol below it — never
        open_tol above."""
        qp = jnp.asarray([500.0])
        rp = jnp.asarray([480.0, 495.0, 510.0, 690.0, 710.0])
        open_m = np.asarray(candidate_window_mask(qp, rp, tol=20.,
                                                  open_search=True,
                                                  open_tol=200.))
        closed_m = np.asarray(candidate_window_mask(qp, rp, tol=20.,
                                                    open_search=False))
        np.testing.assert_array_equal(open_m[0], [True, True, True, False, False])
        np.testing.assert_array_equal(closed_m[0], [False, True, True, False, False])

    def test_candidate_window_phospho_offset(self):
        """Directed regression for the mirrored-window bug: a query carrying
        a phosphorylation (+79.97 Da) must still see its unmodified
        reference; a reference 79.97 Da *heavier* than the query must not
        enter the window (no modification removes that much mass here)."""
        ref = 500.0
        phospho = 79.97
        qp = jnp.asarray([ref + phospho,   # modified query, unmodified ref
                          ref - phospho])  # query lighter than ref
        rp = jnp.asarray([ref])
        m = np.asarray(candidate_window_mask(qp, rp, tol=20.,
                                             open_search=True, open_tol=200.))
        assert m[0, 0]          # the whole point of open search
        assert not m[1, 0]      # the mirrored direction stays closed
        # a shift beyond the modification-mass budget is out of the window
        far = np.asarray(candidate_window_mask(
            jnp.asarray([ref + 250.0]), rp, tol=20., open_search=True,
            open_tol=200.))
        assert not far[0, 0]
