"""End-to-end SpecPCM pipeline behaviour tests (clustering + DB search)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SpecPCMConfig, run_clustering, run_db_search
from repro.spectra import SyntheticMSConfig, generate_dataset
from repro.spectra.fdr import fdr_filter, make_decoys
from repro.spectra.preprocess import (
    bin_spectra,
    bucket_by_precursor,
    candidate_window_mask,
    sqrt_normalize,
)
from repro.spectra.synthetic import generate_query_set


@pytest.fixture(scope="module")
def ds():
    return generate_dataset(SyntheticMSConfig(
        num_identities=24, spectra_per_identity=8, num_bins=1024))


@pytest.fixture(scope="module")
def refs(ds):
    t = ds.templates
    return t / jnp.maximum(t.max(1, keepdims=True), 1e-6)


@pytest.fixture(scope="module")
def ref_prec(ds):
    return jnp.asarray(np.asarray(ds.precursor)[::8])


class TestClusteringPipeline:
    def test_clusters_replicates(self, ds):
        cfg = SpecPCMConfig(hd_dim=1026, mlc_bits=3, num_levels=16)
        rep = run_clustering(ds.spectra, ds.precursor, ds.identity, cfg)
        assert rep.clustered_ratio > 0.8
        assert rep.incorrect_ratio < 0.05
        assert rep.cost.latency_s > 0 and rep.cost.energy_j > 0

    def test_slc_quality_geq_mlc3(self, ds):
        """Fig. 9 trend: SLC >= MLC3 clustered-spectra ratio (at the same
        low incorrect ratio)."""
        slc = run_clustering(ds.spectra, ds.precursor, ds.identity,
                             SpecPCMConfig(hd_dim=1024, mlc_bits=1,
                                           num_levels=16))
        mlc = run_clustering(ds.spectra, ds.precursor, ds.identity,
                             SpecPCMConfig(hd_dim=1026, mlc_bits=3,
                                           num_levels=16))
        assert slc.clustered_ratio >= mlc.clustered_ratio - 0.05
        assert slc.incorrect_ratio < 0.05 and mlc.incorrect_ratio < 0.05

    def test_ideal_vs_noisy(self, ds):
        ideal = run_clustering(ds.spectra, ds.precursor, ds.identity,
                               SpecPCMConfig(hd_dim=1026, mlc_bits=3,
                                             num_levels=16, ideal=True))
        assert ideal.clustered_ratio > 0.8


class TestDBSearchPipeline:
    def test_identifies_peptides_at_fdr(self, ds, refs, ref_prec):
        cfg = SpecPCMConfig(hd_dim=1026, mlc_bits=3, num_levels=16)
        q = generate_query_set(ds, SyntheticMSConfig(
            num_identities=24, spectra_per_identity=8, num_bins=1024), 48)
        rep = run_db_search(q.spectra, q.precursor, refs, ref_prec, cfg,
                            query_identity=q.identity,
                            ref_identity=jnp.arange(24))
        assert rep.num_identified > 0.5 * q.spectra.shape[0]
        assert rep.recall > 0.5
        assert rep.cost.latency_s > 0

    def test_dimension_hurts_when_tiny(self, ds, refs, ref_prec):
        """Fig. S4 trend: very small HD dim degrades identification."""
        q = generate_query_set(ds, SyntheticMSConfig(
            num_identities=24, spectra_per_identity=8, num_bins=1024), 48)

        def mk(d):
            return run_db_search(
                q.spectra, q.precursor, refs, ref_prec,
                SpecPCMConfig(hd_dim=d, mlc_bits=3, num_levels=16),
                query_identity=q.identity, ref_identity=jnp.arange(24))

        small, large = mk(96), mk(2049)
        assert large.recall >= small.recall


class TestFDR:
    def test_fdr_filter_controls_rate(self):
        rng = np.random.default_rng(0)
        n = 2000
        # targets score high, decoys low, with overlap
        is_target = rng.uniform(size=n) < 0.7
        scores = np.where(is_target, rng.normal(5, 2, n), rng.normal(0, 2, n))
        accept = np.asarray(fdr_filter(jnp.asarray(scores),
                                       jnp.asarray(is_target), fdr=0.01))
        assert accept.sum() > 0
        assert not (accept & ~is_target).any()  # only targets accepted
        # the achieved decoy rate above the implied threshold is near 1%
        thr = scores[accept].min()
        n_dec_above = ((~is_target) & (scores >= thr)).sum()
        n_tgt_above = (is_target & (scores >= thr)).sum()
        assert n_dec_above / max(n_tgt_above, 1) <= 0.02

    def test_decoys_are_reversed(self):
        s = jnp.asarray(np.random.default_rng(1).uniform(0, 1, (3, 8)))
        d = make_decoys(s)
        np.testing.assert_array_equal(np.asarray(d), np.asarray(s)[:, ::-1])


class TestPreprocess:
    def test_bin_spectra(self):
        mz = jnp.asarray([[300.0, 500.0, 1999.0], [200.0, 200.1, 1000.0]])
        inten = jnp.asarray([[1.0, 0.5, 0.2], [0.3, 0.9, 0.6]])
        out = bin_spectra(mz, inten, num_bins=64)
        assert out.shape == (2, 64)
        assert float(out.max()) == 1.0
        assert (np.asarray(out) >= 0).all()

    def test_sqrt_normalize(self):
        x = jnp.asarray([[0.0, 0.25, 1.0]])
        out = np.asarray(sqrt_normalize(x))
        assert out[0, 2] == pytest.approx(1.0)
        assert out[0, 1] == pytest.approx(0.5)

    def test_bucketing_partitions(self):
        prec = np.asarray([400., 401., 500., 502., 900.])
        buckets = bucket_by_precursor(prec, bucket_width=50.0)
        all_idx = np.sort(np.concatenate(buckets))
        np.testing.assert_array_equal(all_idx, np.arange(5))
        # nearby masses share a bucket
        b_of = {i: bi for bi, b in enumerate(buckets) for i in b}
        assert b_of[0] == b_of[1] and b_of[2] == b_of[3]
        assert b_of[0] != b_of[4]

    def test_candidate_window_open_search(self):
        qp = jnp.asarray([500.0])
        rp = jnp.asarray([480.0, 495.0, 510.0, 690.0, 710.0])
        open_m = np.asarray(candidate_window_mask(qp, rp, tol=20.,
                                                  open_search=True,
                                                  open_tol=200.))
        closed_m = np.asarray(candidate_window_mask(qp, rp, tol=20.,
                                                    open_search=False))
        np.testing.assert_array_equal(open_m[0], [False, True, True, True, False])
        np.testing.assert_array_equal(closed_m[0], [False, True, True, False, False])
