"""Continuous-batching scheduler: deterministic tests over the seams.

Everything here runs against the two injectable seams the scheduler was
built around — a settable fake clock and fake executors (recording /
simulated-service-time) — so admission order, tenant fairness, slot
accounting, cancellation, and the tail-latency behavior of both queue
modes are asserted exactly, with no real time and no device.
"""

import numpy as np
import pytest

from repro.serve import (
    ContinuousScheduler,
    DBSearchServer,
    MicroBatchQueue,
    shard_database,
)


class Clock:
    """Settable fake clock (the queue/scheduler/server time seam)."""

    def __init__(self, now: float = 0.0):
        self.now = now

    def __call__(self) -> float:
        return self.now


class RecordingExecutor:
    """Executor seam fake: records every dispatched batch; completion is
    test-controlled via ``ready`` handles."""

    def __init__(self, clock):
        self.clock = clock
        self.dispatched = []          # list[list[Request]] in dispatch order
        self.ready = set()            # handles poll() reports complete
        self._handles = {}
        self._next = 0

    def dispatch(self, reqs):
        t = self.clock()
        for r in reqs:
            r.t_dispatch = t
        h = self._next
        self._next += 1
        self.dispatched.append(list(reqs))
        self._handles[h] = reqs
        return h

    def poll(self, h):
        return h in self.ready

    def finalize(self, h):
        reqs = self._handles.pop(h)
        t = self.clock()
        live = [r for r in reqs if not r.cancelled]
        for r in live:
            r.t_done = t
            r.result = "done"
        return live


class SimulatedExecutor:
    """Executor seam fake with a serial device model: each dispatch takes
    ``c0 + c1 * batch`` seconds of device time, batches execute one after
    another (a single accelerator), and ``finalize`` advances the fake
    clock to the completion time when asked to block early."""

    def __init__(self, clock, c0=0.01, c1=0.0025):
        self.clock = clock
        self.c0, self.c1 = c0, c1
        self._free_at = 0.0
        self._handles = {}
        self._next = 0

    def dispatch(self, reqs):
        t = self.clock()
        for r in reqs:
            r.t_dispatch = t
        start = max(t, self._free_at)
        t_ready = start + self.c0 + self.c1 * len(reqs)
        self._free_at = t_ready
        h = self._next
        self._next += 1
        self._handles[h] = (reqs, t_ready)
        return h

    def poll(self, h):
        return self.clock() >= self._handles[h][1]

    def finalize(self, h):
        reqs, t_ready = self._handles.pop(h)
        self.clock.now = max(self.clock.now, t_ready)  # block on the device
        live = [r for r in reqs if not r.cancelled]
        for r in live:
            r.t_done = self.clock()
            r.result = "done"
        return live


def _make(clock, *, max_batch=2, num_slots=2, fairness_cap=None,
          flush_timeout_s=0.5):
    queue = MicroBatchQueue(max_batch_size=max_batch,
                            flush_timeout_s=flush_timeout_s, clock=clock,
                            fairness_cap=fairness_cap)
    ex = RecordingExecutor(clock)
    sched = ContinuousScheduler(queue, ex, num_slots=num_slots, clock=clock)
    return queue, ex, sched


# --------------------------------------------------------------------------
# admission, slot accounting, refill
# --------------------------------------------------------------------------

class TestAdmission:
    def test_fifo_admission_fills_slots_in_order(self):
        clock = Clock()
        queue, ex, sched = _make(clock)
        rids = [queue.submit(i) for i in range(6)]
        assert sched.admit() == 2           # both slots filled, no waiting
        assert sched.in_flight == 2 and sched.free_slots == 0
        assert [[r.rid for r in b] for b in ex.dispatched] == [
            rids[0:2], rids[2:4]]
        assert len(queue) == 2              # backlog held until a slot frees
        assert sched.admit() == 0           # no free slot -> no admission

    def test_retire_then_admit_refills_freed_slot_same_step(self):
        clock = Clock()
        queue, ex, sched = _make(clock)
        rids = [queue.submit(i) for i in range(6)]
        sched.admit()
        ex.ready.add(0)
        clock.now = 1.0
        done = sched.step()
        assert [r.rid for r in done] == rids[0:2]
        assert sched.in_flight == 2         # freed slot refilled this step
        assert [r.rid for r in ex.dispatched[2]] == rids[4:6]
        assert sched.retired_batches == 1 and sched.dispatched_batches == 3

    def test_admission_needs_no_flush_trigger(self):
        """The continuous mode's defining property: a lone request is
        admitted immediately — no full lane, no flush timeout."""
        clock = Clock()
        queue, ex, sched = _make(clock, max_batch=8, flush_timeout_s=10.0)
        rid = queue.submit(0)
        assert not queue.ready()            # flush-sync would sit on this
        assert sched.step() == []           # nothing finished yet...
        assert sched.in_flight == 1         # ...but the request is in flight
        assert ex.dispatched[0][0].rid == rid
        assert ex.dispatched[0][0].queue_wait_s == 0.0

    def test_step_block_waits_out_in_flight_slots(self):
        clock = Clock()
        queue, ex, sched = _make(clock)
        queue.submit(0)
        sched.step()
        done = sched.step(block=True)       # finalize without poll-ready
        assert len(done) == 1 and sched.in_flight == 0

    def test_drain_empties_queue_and_slots(self):
        clock = Clock()
        queue, ex, sched = _make(clock, max_batch=3, num_slots=2)
        rids = [queue.submit(i) for i in range(10)]
        done = sched.drain()
        assert sorted(r.rid for r in done) == rids
        assert sched.in_flight == 0 and len(queue) == 0
        assert sched.dispatched_batches == sched.retired_batches == 4

    def test_num_slots_validation(self):
        clock = Clock()
        queue, ex, _ = _make(clock)
        with pytest.raises(ValueError, match="num_slots"):
            ContinuousScheduler(queue, ex, num_slots=0, clock=clock)


# --------------------------------------------------------------------------
# tenant fairness and starvation
# --------------------------------------------------------------------------

class TestFairness:
    def test_fairness_cap_under_skewed_load(self):
        """One hot tenant floods; the cap bounds its per-batch take while
        the cold tenant waits, and the rotation serves the cold tenant on
        the very next admission."""
        clock = Clock()
        queue, ex, sched = _make(clock, max_batch=4, num_slots=8,
                                 fairness_cap=2)
        for i in range(8):
            queue.submit(i, tenant="hot")
        queue.submit(99, tenant="cold")
        sched.admit()
        batches = [(b[0].tenant, len(b)) for b in ex.dispatched]
        # capped at 2 while cold waits, cold next, then hot uncapped
        assert batches == [("hot", 2), ("cold", 1), ("hot", 4), ("hot", 2)]

    def test_cold_tenant_not_starved_with_one_slot(self):
        """Even with a single slot and a hot tenant that keeps its lane
        full, the skip-last-served rotation admits the cold tenant on the
        second admission — its wait is one batch, not unbounded."""
        clock = Clock()
        queue, ex, sched = _make(clock, max_batch=4, num_slots=1,
                                 fairness_cap=4)
        for i in range(4):
            queue.submit(i, tenant="hot")
        cold_rid = queue.submit(99, tenant="cold")
        sched.step()
        for i in range(4):                   # hot keeps flooding
            queue.submit(10 + i, tenant="hot")
        ex.ready.add(0)
        sched.step()
        assert ex.dispatched[1][0].rid == cold_rid
        assert [b[0].tenant for b in ex.dispatched] == ["hot", "cold"]


# --------------------------------------------------------------------------
# cancellation and slot accounting
# --------------------------------------------------------------------------

class TestCancellation:
    def test_pending_cancel_removes_from_queue(self):
        clock = Clock()
        queue, ex, sched = _make(clock, max_batch=2, num_slots=1)
        rids = [queue.submit(i) for i in range(4)]
        sched.admit()                        # rids[0:2] in flight
        assert sched.cancel(rids[2]) is True
        assert len(queue) == 1               # removed before dispatch
        ex.ready.add(0)
        done = sched.drain()
        assert sorted(r.rid for r in done) == [rids[0], rids[1], rids[3]]
        assert sched.cancellations == 1

    def test_in_flight_cancel_keeps_slot_accounting(self):
        """Cancelling an in-flight request marks it (device work is not
        restartable) without perturbing slots: the batch retires as one
        unit and only the cancelled result is dropped."""
        clock = Clock()
        queue, ex, sched = _make(clock, max_batch=2, num_slots=2)
        rids = [queue.submit(i) for i in range(4)]
        sched.admit()
        assert sched.cancel(rids[1]) is True
        assert sched.in_flight == 2          # slot untouched
        assert sched.in_flight_requests() == 4
        ex.ready.update({0, 1})
        done = sched.step()
        assert [r.rid for r in done] == [rids[0], rids[2], rids[3]]
        assert sched.retired_batches == 2    # both slots retired whole
        assert sched.cancel(rids[0]) is False  # already finished

    def test_unknown_rid_cancel_returns_false(self):
        clock = Clock()
        _, _, sched = _make(clock)
        assert sched.cancel(123) is False
        assert sched.cancellations == 0


# --------------------------------------------------------------------------
# latency accounting: t_submit at enqueue, t_dispatch at queue exit
# --------------------------------------------------------------------------

class TestLatencyAccounting:
    def test_queue_wait_visible_in_continuous_mode(self):
        clock = Clock()
        queue = MicroBatchQueue(max_batch_size=4, clock=clock)
        ex = SimulatedExecutor(clock, c0=0.1, c1=0.0)
        sched = ContinuousScheduler(queue, ex, num_slots=1, clock=clock)
        queue.submit(0)
        clock.now = 0.3                      # sat in the queue 0.3s
        done = sched.drain()
        (r,) = done
        assert r.queue_wait_s == pytest.approx(0.3)
        assert r.service_s == pytest.approx(0.1)
        assert r.latency_s == pytest.approx(0.4)  # includes the queue wait

    def test_queue_wait_visible_in_flush_sync_mode(self):
        """Regression pin for the starts-at-flush latency bug class:
        ``t_submit`` is stamped at enqueue, so a request that waits out
        the flush timeout shows that wait in ``latency_s`` — and the
        ``t_dispatch`` split exposes it as queue wait, not service."""
        clock = Clock()
        db = _tiny_db(7)
        server = DBSearchServer(db, k=2, fdr=0.5, max_batch_size=4,
                                flush_timeout_s=1.0, clock=clock)
        server.submit(_tiny_query(7))
        assert server.step() == []           # not flushable yet
        clock.now = 1.5
        (r,) = server.step()
        assert r.t_submit == 0.0             # stamped at enqueue, not flush
        assert r.queue_wait_s == pytest.approx(1.5)
        assert r.latency_s == pytest.approx(1.5)
        s = server.summary()
        assert s["queue_wait_p50_ms"] == pytest.approx(1500.0)

    def test_stats_summary_reports_queue_wait_percentiles(self):
        clock = Clock()
        queue = MicroBatchQueue(max_batch_size=2, clock=clock)
        ex = SimulatedExecutor(clock, c0=0.05, c1=0.0)
        sched = ContinuousScheduler(queue, ex, num_slots=1, clock=clock)
        from repro.serve import LatencyStats
        stats = LatencyStats()
        for _ in range(4):
            queue.submit(0)
        clock.now = 0.2
        stats.record_batch(sched.drain())
        s = stats.summary()
        assert s["queue_wait_p50_ms"] > 0.0
        assert s["queue_wait_p95_ms"] >= s["queue_wait_p50_ms"]
        assert s["p50_ms"] > s["queue_wait_p50_ms"]  # service on top


# --------------------------------------------------------------------------
# tail latency: continuous vs flush-sync on an open-loop trace
# --------------------------------------------------------------------------

def _drive(trace, clock, queue, step_fn, drain_fn, tick=0.005):
    """Open-loop driver: arrivals happen at their trace times regardless
    of server progress; between arrivals the serving loop ticks."""
    done = []
    for t_arrival, n in trace:
        while clock.now < t_arrival:
            clock.now = min(t_arrival, clock.now + tick)
            done.extend(step_fn())
        for _ in range(n):
            queue.submit(0)
        done.extend(step_fn())
    done.extend(drain_fn())
    return done


def _open_loop_trace():
    """Steady full bursts (the happy path) plus ~9% lone stragglers, each
    followed by a gap longer than the flush timeout — the traffic shape
    that makes flush-and-wait's p95 collapse."""
    trace = []
    t = 0.0
    for _ in range(10):
        trace.append((t, 8))
        t += 0.08
    for _ in range(8):
        trace.append((t, 1))
        t += 0.7
    return trace


class TestTailLatency:
    FLUSH_TIMEOUT = 0.5

    def _run_flush_sync(self, trace):
        clock = Clock()
        queue = MicroBatchQueue(max_batch_size=8,
                                flush_timeout_s=self.FLUSH_TIMEOUT,
                                clock=clock)
        ex = SimulatedExecutor(clock)

        def step():
            if not queue.ready():
                return []
            return ex.finalize(ex.dispatch(queue.take_batch()))

        def drain():
            done = []
            while len(queue):
                done.extend(ex.finalize(ex.dispatch(queue.take_batch())))
            return done

        return _drive(trace, clock, queue, step, drain)

    def _run_continuous(self, trace):
        clock = Clock()
        queue = MicroBatchQueue(max_batch_size=8,
                                flush_timeout_s=self.FLUSH_TIMEOUT,
                                clock=clock)
        sched = ContinuousScheduler(queue, SimulatedExecutor(clock),
                                    num_slots=2, clock=clock)
        return _drive(trace, clock, queue, sched.step, sched.drain)

    def test_continuous_holds_p95_within_4x_p50(self):
        trace = _open_loop_trace()
        total = sum(n for _, n in trace)

        sync_done = self._run_flush_sync(trace)
        cont_done = self._run_continuous(trace)
        assert len(sync_done) == len(cont_done) == total

        def ratio(done):
            lat = np.asarray([r.latency_s for r in done])
            return float(np.percentile(lat, 95) / np.percentile(lat, 50))

        sync_ratio, cont_ratio = ratio(sync_done), ratio(cont_done)
        # flush-and-wait strands every straggler on the flush timeout;
        # continuous admits it on the next tick
        assert sync_ratio > 4.0, sync_ratio
        assert cont_ratio <= 4.0, cont_ratio
        # and the improvement is structural, not marginal
        assert cont_ratio < sync_ratio / 2


# --------------------------------------------------------------------------
# both modes through the real executor: bit-identical results, bucket reuse
# --------------------------------------------------------------------------

def _tiny_db(seed, n=24, d=64):
    rng = np.random.default_rng(seed)
    import jax.numpy as jnp
    refs = jnp.asarray(rng.choice([-1, 1], size=(n, d)).astype(np.int8))
    decoys = jnp.asarray(rng.choice([-1, 1], size=(n, d)).astype(np.int8))
    return shard_database(refs, decoys=decoys)


def _tiny_query(seed, d=64):
    rng = np.random.default_rng(seed)
    return rng.choice([-1, 1], size=d).astype(np.int8)


class TestServerModes:
    def test_continuous_and_flush_sync_bit_identical(self):
        """Both queue modes run the identical SearchExecutor device path,
        so per-request results must match exactly."""
        queries = [_tiny_query(100 + i) for i in range(7)]
        results = {}
        for continuous in (False, True):
            clock = Clock()
            server = DBSearchServer(_tiny_db(3), k=3, fdr=0.5,
                                    max_batch_size=4, flush_timeout_s=0.01,
                                    clock=clock, continuous=continuous,
                                    num_slots=2)
            rids = [server.submit(q) for q in queries]
            done = server.run_until_drained()
            assert sorted(r.rid for r in done) == rids
            results[continuous] = {
                r.rid: (tuple(r.result.indices), tuple(r.result.scores),
                        r.result.match) for r in done}
            assert server.summary()["mode"] == (
                "continuous" if continuous else "flush-sync")
        assert results[False] == results[True]

    def test_bucket_reuse_across_admissions(self):
        """Equal-size admissions pad to the same shape bucket, so the jit
        signature is reused instead of recompiling per ragged batch."""
        clock = Clock()
        server = DBSearchServer(_tiny_db(4), k=2, fdr=0.5, max_batch_size=8,
                                clock=clock, buckets=2, continuous=True,
                                num_slots=1)
        for i in range(3):
            server.submit(_tiny_query(i))
        server.run_until_drained()
        for i in range(3):
            server.submit(_tiny_query(10 + i))
        server.run_until_drained()
        buckets = server.summary()["buckets"]
        assert buckets == {4: 2}             # same bucket both rounds

    def test_server_cancel_roundtrip(self):
        clock = Clock()
        server = DBSearchServer(_tiny_db(5), k=2, fdr=0.5, max_batch_size=8,
                                clock=clock, continuous=True, num_slots=1)
        rids = [server.submit(_tiny_query(i)) for i in range(3)]
        assert server.cancel(rids[1]) is True
        done = server.run_until_drained()
        assert sorted(r.rid for r in done) == [rids[0], rids[2]]
