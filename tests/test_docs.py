"""Tier-1 slice of the docs CI gate (scripts/check_docs.py): internal
links in README/docs must resolve and the doctest-bearing modules must
pass. CI's docs job additionally doctest-sweeps every repro module."""

import importlib.util
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def _load_check_docs():
    spec = importlib.util.spec_from_file_location(
        "check_docs", REPO / "scripts" / "check_docs.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_internal_markdown_links_resolve():
    cd = _load_check_docs()
    files = cd.markdown_files()
    assert any(f.name == "README.md" for f in files)
    assert any(f.name == "ARCHITECTURE.md" for f in files)
    assert cd.check_links(files) == []


def test_doctest_modules_pass():
    cd = _load_check_docs()
    failed, with_examples = cd.run_doctests(
        ["repro.core.hd.similarity", "repro.serve.queue"])
    assert failed == 0
    assert with_examples == 2


def test_check_docs_cli_links_only():
    r = subprocess.run(
        [sys.executable, str(REPO / "scripts" / "check_docs.py"),
         "--links-only"],
        capture_output=True, text=True, timeout=120)
    assert r.returncode == 0, r.stdout + r.stderr
