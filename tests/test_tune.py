"""Autotuner tests: microbench ceilings, tuning-table lifecycle, trace-time
block resolution, per-kernel validation, and the tuned == default
bit-identity property across serving configurations."""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hd.similarity import bitpack_bipolar
from repro.kernels.block_utils import ALIGN, DEFAULTS, resolve_blocks
from repro.tune import table as tune_table
from repro.tune.table import (
    TuningTable,
    device_kind,
    load_table,
    lookup_blocks,
    set_active_table,
    shape_bucket,
)

RNG = np.random.default_rng(7)


@pytest.fixture(autouse=True)
def _clean_table_state(monkeypatch):
    """Every test starts and ends with no active table and a cleared
    one-time-log memory."""
    monkeypatch.delenv(tune_table.ENV_VAR, raising=False)
    tune_table.reset()
    yield
    tune_table.reset()


def bip(shape):
    return RNG.choice([-1, 1], size=shape).astype(np.int8)


# --------------------------------------------------------------------------
# microbench ceilings
# --------------------------------------------------------------------------

def test_measured_ceilings_positive_on_cpu():
    from repro.tune.microbench import measure_mem_bandwidth, measure_peak_flops
    flops = measure_peak_flops(sizes=(128, 256), iters=2)
    bw = measure_mem_bandwidth(sizes_mb=(1, 4), iters=2)
    assert flops["peak_flops"] > 0
    assert all(v > 0 for v in flops["by_size"].values())
    assert bw["hbm_bw"] > 0
    # the ceiling is the max of the sweep, by construction
    assert flops["peak_flops"] == max(flops["by_size"].values())
    assert bw["hbm_bw"] == max(bw["by_size_mb"].values())


# --------------------------------------------------------------------------
# table lifecycle
# --------------------------------------------------------------------------

def _mk_table(kind=None, **ceilings):
    return TuningTable(device_kind=kind or device_kind(),
                       ceilings=ceilings, meta={"quick": True})


def test_shape_bucket_pow2():
    assert shape_bucket((100, 8000, 32)) == "128x8192x32"
    assert shape_bucket((1,)) == "1"
    assert shape_bucket((129,)) == "256"


def test_table_roundtrip(tmp_path):
    t = _mk_table(peak_flops=1e11, hbm_bw=2e10)
    t.set_entry("topk_hamming", (100, 8000, 32),
                {"block_q": 32, "block_r": 256, "word_chunk": 32},
                us=10.0, default_us=20.0)
    path = t.save(tmp_path / "table.json")
    loaded = load_table(path)
    assert loaded is not None
    assert loaded.device_kind == t.device_kind
    assert loaded.ceilings["peak_flops"] == 1e11
    assert loaded.lookup("topk_hamming", (128, 8192, 32)) == {
        "block_q": 32, "block_r": 256, "word_chunk": 32}
    # a different bucket misses
    assert loaded.lookup("topk_hamming", (128, 1024, 32)) is None


def test_corrupt_table_falls_back(tmp_path, caplog):
    p = tmp_path / "bad.json"
    p.write_text("{not json")
    with caplog.at_level("WARNING", logger="repro.tune"):
        assert load_table(p) is None
        assert load_table(p) is None  # second load: no second log line
    assert sum("unreadable" in r.message for r in caplog.records) == 1


def test_partial_table_falls_back(tmp_path):
    p = tmp_path / "partial.json"
    p.write_text(json.dumps({"schema": 99, "device_kind": "cpu"}))
    assert load_table(p) is None


def test_misaligned_entry_dropped_at_load(tmp_path, caplog):
    t = _mk_table()
    t.set_entry("topk_hamming", (8, 128, 4),
                {"block_q": 7, "block_r": 128, "word_chunk": 32})
    t.set_entry("topk_hamming", (8, 256, 4),
                {"block_q": 8, "block_r": 128, "word_chunk": 32})
    path = t.save(tmp_path / "table.json")
    with caplog.at_level("WARNING", logger="repro.tune"):
        loaded = load_table(path)
    assert loaded.lookup("topk_hamming", (8, 128, 4)) is None  # dropped
    assert loaded.lookup("topk_hamming", (8, 256, 4)) is not None  # kept
    assert any("misaligned" in r.message for r in caplog.records)


def test_unknown_op_dropped_at_load(tmp_path):
    t = _mk_table()
    t.set_entry("not_a_kernel", (8,), {"block_q": 8})
    loaded = load_table(t.save(tmp_path / "table.json"))
    assert loaded.ops == {}


def test_device_kind_mismatch_ignored(tmp_path, caplog):
    t = _mk_table(kind="TPU v99")
    t.set_entry("topk_hamming", (8, 128, 4),
                {"block_q": 8, "block_r": 128, "word_chunk": 8})
    set_active_table(t.save(tmp_path / "table.json"))
    with caplog.at_level("WARNING", logger="repro.tune"):
        assert lookup_blocks("topk_hamming", (8, 128, 4)) is None
        assert lookup_blocks("topk_hamming", (8, 128, 4)) is None
    kind_logs = [r for r in caplog.records if "device kind" in r.message]
    assert len(kind_logs) == 1  # one-time log


def test_env_var_activation(tmp_path, monkeypatch):
    t = _mk_table()
    t.set_entry("topk_hamming", (8, 128, 4),
                {"block_q": 16, "block_r": 128, "word_chunk": 8})
    path = t.save(tmp_path / "table.json")
    assert lookup_blocks("topk_hamming", (8, 128, 4)) is None
    monkeypatch.setenv(tune_table.ENV_VAR, str(path))
    # env change is picked up without an explicit reset()
    assert lookup_blocks("topk_hamming", (8, 128, 4)) == {
        "block_q": 16, "block_r": 128, "word_chunk": 8}
    monkeypatch.delenv(tune_table.ENV_VAR)
    assert lookup_blocks("topk_hamming", (8, 128, 4)) is None


def test_resolve_blocks_precedence():
    t = _mk_table()
    t.set_entry("topk_hamming", (8, 128, 4),
                {"block_q": 16, "block_r": 256, "word_chunk": 16})
    set_active_table(t)
    # table beats defaults
    assert resolve_blocks("topk_hamming", (8, 128, 4),
                          {"block_q": None, "block_r": None,
                           "word_chunk": None}) == {
        "block_q": 16, "block_r": 256, "word_chunk": 16}
    # explicit beats table
    cfg = resolve_blocks("topk_hamming", (8, 128, 4),
                         {"block_q": 32, "block_r": None, "word_chunk": None})
    assert cfg["block_q"] == 32 and cfg["block_r"] == 256
    # no table entry for this bucket -> defaults
    assert resolve_blocks("topk_hamming", (64, 1024, 4),
                          {"block_q": None, "block_r": None,
                           "word_chunk": None}) == DEFAULTS["topk_hamming"]


def test_defaults_are_aligned():
    for op, cfg in DEFAULTS.items():
        for name, value in cfg.items():
            assert value % ALIGN[op][name] == 0, (op, name)


# --------------------------------------------------------------------------
# per-kernel explicit-block validation (the satellite-1 regression tests)
# --------------------------------------------------------------------------

def _topk_operands(q_n=8, r_n=128, dim=64):
    q = bitpack_bipolar(jnp.asarray(bip((q_n, dim))))
    r = bitpack_bipolar(jnp.asarray(bip((r_n, dim))))
    return q, r


def test_topk_hamming_rejects_misaligned_blocks():
    from repro.kernels.topk_hamming import topk_hamming_pallas
    q, r = _topk_operands()
    with pytest.raises(ValueError, match="block_q=7 must be a positive"):
        topk_hamming_pallas(q, r, dim=64, k=4, block_q=7)
    with pytest.raises(ValueError, match="block_r=100"):
        topk_hamming_pallas(q, r, dim=64, k=4, block_r=100)
    with pytest.raises(ValueError, match="word_chunk=-8"):
        topk_hamming_pallas(q, r, dim=64, k=4, word_chunk=-8)


def test_topk_hamming_banded_rejects_misaligned_blocks():
    from repro.kernels.topk_hamming import topk_hamming_banded_pallas
    q, r = _topk_operands()
    starts = jnp.zeros(8, jnp.int32)
    lens = jnp.full(8, 64, jnp.int32)
    with pytest.raises(ValueError, match="topk_hamming_banded: block_q=12"):
        topk_hamming_banded_pallas(q, r, starts, lens, dim=64, k=4,
                                   block_q=12)


def test_encode_search_rejects_misaligned_blocks():
    from repro.kernels.encode_search import (
        encode_search_banded_pallas,
        encode_search_pallas,
    )
    lv = jnp.asarray(RNG.integers(0, 4, size=(8, 16)).astype(np.int32))
    id_hvs = jnp.asarray(bip((16, 64)))
    level_hvs = jnp.asarray(bip((4, 64)))
    bank = bitpack_bipolar(jnp.asarray(bip((128, 64))))
    with pytest.raises(ValueError, match="block_f=5"):
        encode_search_pallas(lv, id_hvs, level_hvs, bank, dim=64, k=4,
                             block_f=5)
    starts = jnp.zeros(8, jnp.int32)
    lens = jnp.full(8, 64, jnp.int32)
    with pytest.raises(ValueError, match="word_chunk=3"):
        encode_search_banded_pallas(lv, id_hvs, level_hvs, bank, starts,
                                    lens, dim=64, k=4, word_chunk=3)


def test_hd_encode_rejects_misaligned_blocks():
    from repro.kernels.hd_encode import hd_encode_pallas
    lv = jnp.asarray(RNG.integers(0, 4, size=(8, 16)).astype(np.int32))
    id_hvs = jnp.asarray(bip((16, 128)))
    level_hvs = jnp.asarray(bip((4, 128)))
    with pytest.raises(ValueError, match="block_d=100"):
        hd_encode_pallas(lv, id_hvs, level_hvs, block_d=100)


def test_imc_mvm_rejects_misaligned_blocks():
    from repro.kernels.imc_mvm import imc_mvm_pallas
    q = jnp.asarray(RNG.standard_normal((8, 128)).astype(np.float32))
    w = jnp.asarray(RNG.standard_normal((16, 128)).astype(np.float32))
    with pytest.raises(ValueError, match="tile_cols=64"):
        imc_mvm_pallas(q, w, full_scale=128.0, tile_cols=64)


# --------------------------------------------------------------------------
# tuned == default bit-identity (the satellite-4 property suite)
# --------------------------------------------------------------------------

# a deliberately non-default (but aligned) tuned config per op
_TUNED = {
    "topk_hamming": {"block_q": 16, "block_r": 256, "word_chunk": 8},
    "topk_hamming_banded": {"block_q": 16, "block_r": 128, "word_chunk": 8},
    "encode_search": {"block_q": 16, "block_r": 256, "block_f": 32,
                      "word_chunk": 16},
}


def _install(op, shape):
    t = _mk_table()
    t.set_entry(op, shape, _TUNED[op])
    set_active_table(t)


@pytest.mark.parametrize("packed", [True, False])
@pytest.mark.parametrize("q_n,r_n", [(5, 100), (8, 300), (13, 257)])
def test_topk_tuned_bit_identical(packed, q_n, r_n):
    from repro.kernels.topk_hamming import topk_hamming_pallas
    dim = 96 if not packed else 64
    qb = jnp.asarray(bip((q_n, dim)))
    rb = jnp.asarray(bip((r_n, dim)))
    q = bitpack_bipolar(qb) if packed else qb
    r = bitpack_bipolar(rb) if packed else rb
    idx0, val0 = topk_hamming_pallas(q, r, dim=dim, k=4,
                                     **DEFAULTS["topk_hamming"])
    _install("topk_hamming", (q_n, r_n, q.shape[1]))
    assert resolve_blocks("topk_hamming", (q_n, r_n, q.shape[1]),
                          {"block_q": None, "block_r": None,
                           "word_chunk": None}) == _TUNED["topk_hamming"]
    idx1, val1 = topk_hamming_pallas(q, r, dim=dim, k=4)
    np.testing.assert_array_equal(np.asarray(idx0), np.asarray(idx1))
    np.testing.assert_array_equal(np.asarray(val0), np.asarray(val1))


@pytest.mark.parametrize("packed", [True, False])
def test_topk_banded_tuned_bit_identical(packed):
    from repro.kernels.topk_hamming import topk_hamming_banded_pallas
    q_n, r_n, dim = 9, 300, 96 if not packed else 64
    qb = jnp.asarray(bip((q_n, dim)))
    rb = jnp.asarray(bip((r_n, dim)))
    q = bitpack_bipolar(qb) if packed else qb
    r = bitpack_bipolar(rb) if packed else rb
    starts = jnp.asarray(RNG.integers(0, 200, size=q_n).astype(np.int32))
    lens = jnp.full(q_n, 80, jnp.int32)
    kw = dict(dim=dim, k=4, num_tiles=2)
    idx0, val0 = topk_hamming_banded_pallas(
        q, r, starts, lens, **kw, **DEFAULTS["topk_hamming_banded"])
    _install("topk_hamming_banded", (q_n, r_n, q.shape[1]))
    idx1, val1 = topk_hamming_banded_pallas(q, r, starts, lens, **kw)
    np.testing.assert_array_equal(np.asarray(idx0), np.asarray(idx1))
    np.testing.assert_array_equal(np.asarray(val0), np.asarray(val1))


@pytest.mark.parametrize("q_n,r_n", [(5, 100), (11, 260)])
def test_encode_search_tuned_bit_identical(q_n, r_n):
    from repro.kernels.encode_search import encode_search_pallas
    feats, dim, levels_n = 24, 64, 8
    lv = jnp.asarray(
        RNG.integers(0, levels_n, size=(q_n, feats)).astype(np.int32))
    id_hvs = jnp.asarray(bip((feats, dim)))
    level_hvs = jnp.asarray(bip((levels_n, dim)))
    bank = bitpack_bipolar(jnp.asarray(bip((r_n, dim))))
    idx0, val0 = encode_search_pallas(lv, id_hvs, level_hvs, bank, dim=dim,
                                      k=4, **DEFAULTS["encode_search"])
    _install("encode_search", (q_n, r_n, feats))
    idx1, val1 = encode_search_pallas(lv, id_hvs, level_hvs, bank, dim=dim,
                                      k=4)
    np.testing.assert_array_equal(np.asarray(idx0), np.asarray(idx1))
    np.testing.assert_array_equal(np.asarray(val0), np.asarray(val1))


@pytest.mark.parametrize("shards", [1, 2, 4, 8])
def test_sharded_search_tuned_bit_identical(shards):
    """The serving path (fused emulated shards) returns bit-identical
    results whether blocks come from the table or the defaults."""
    from repro.serve.db_search import search_database, shard_database
    q_n, r_n, dim = 6, 290, 64
    refs = jnp.asarray(bip((r_n, dim)))
    queries = jnp.asarray(bip((q_n, dim)))
    db = shard_database(refs, emulate_shards=shards, fused=True)
    idx0, val0 = search_database(db, queries, 5)
    t = _mk_table()
    t.set_entry("topk_hamming", (q_n, db.shard_rows, dim // 32),
                _TUNED["topk_hamming"])
    set_active_table(t)
    idx1, val1 = search_database(db, queries, 5)
    np.testing.assert_array_equal(np.asarray(idx0), np.asarray(idx1))
    np.testing.assert_array_equal(np.asarray(val0), np.asarray(val1))


def test_shard_database_block_plumbing():
    """Explicit per-bank blocks reach the kernel (and are validated)."""
    from repro.serve.db_search import search_database, shard_database
    refs = jnp.asarray(bip((200, 64)))
    queries = jnp.asarray(bip((4, 64)))
    db0 = shard_database(refs, fused=True)
    db1 = shard_database(refs, fused=True, block_q=16, block_r=256,
                         word_chunk=8)
    assert (db1.block_q, db1.block_r, db1.word_chunk) == (16, 256, 8)
    idx0, val0 = search_database(db0, queries, 3)
    idx1, val1 = search_database(db1, queries, 3)
    np.testing.assert_array_equal(np.asarray(idx0), np.asarray(idx1))
    np.testing.assert_array_equal(np.asarray(val0), np.asarray(val1))
    with pytest.raises(ValueError, match="block_r=100"):
        shard_database(refs, fused=True, block_r=100)


# --------------------------------------------------------------------------
# sweep + CLI
# --------------------------------------------------------------------------

def test_sweep_op_winner_never_slower():
    from repro.tune.sweep import sweep_op
    res = sweep_op("imc_mvm", quick=True, iters=2)
    assert res["us"] <= res["default_us"]
    assert res["blocks"].keys() == DEFAULTS["imc_mvm"].keys()


def test_tune_cli_produces_usable_table(tmp_path, capsys):
    from repro.launch.tune import main
    out = tmp_path / "table.json"
    table = main(["--out", str(out), "--quick", "--iters", "1",
                  "--ops", "imc_mvm", "--skip-ceilings"])
    assert out.exists()
    printed = capsys.readouterr().out
    assert "imc_mvm" in printed and "device_kind" in printed
    loaded = load_table(out)
    assert loaded is not None and loaded.device_kind == device_kind()
    assert "imc_mvm" in loaded.ops
    from repro.tune.sweep import tuned_vs_default_ratio
    assert tuned_vs_default_ratio(table) >= 0.95


def test_build_tuning_table_records_ceilings(tmp_path):
    from repro.tune.sweep import build_tuning_table
    table = build_tuning_table(tmp_path / "t.json", quick=True,
                               ops=("imc_mvm",), iters=1)
    assert table.ceilings["peak_flops"] > 0
    assert table.ceilings["hbm_bw"] > 0
    # the measured ceilings feed the roofline profile once active
    set_active_table(table)
    from repro.launch.roofline import active_profile
    prof = active_profile()
    assert prof.source == "measured"
    assert prof.peak_flops == table.ceilings["peak_flops"]
    assert prof.hbm_bw == table.ceilings["hbm_bw"]
