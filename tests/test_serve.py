"""Serving subsystem tests: shard-merge bit-identity vs the unsharded
oracle (tier-1, emulated shards; slow, real 8-device shard_map), the
micro-batching queue's flush policies, and FDR routing conventions."""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hd.similarity import bitpack_bipolar, topk_search, topk_search_packed
from repro.serve import (
    DBSearchServer,
    MicroBatchQueue,
    OMSConfig,
    oms_plan,
    oms_search,
    oms_search_with_fdr,
    search_database,
    search_with_fdr,
    shard_database,
    sharded_topk_search,
)
from repro.serve.queue import LatencyStats, Request

_SENTINEL = np.iinfo(np.int32).min

REPO = Path(__file__).resolve().parent.parent


def _bipolar(rng, shape):
    return jnp.asarray(rng.choice([-1, 1], size=shape).astype(np.int8))


# --------------------------------------------------------------------------
# shard-merge correctness (tier-1: emulated shards, same local/merge code)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("num_shards", [2, 4, 8])
@pytest.mark.parametrize("num_refs,dim", [
    (61, 32),   # ragged last shard at every shard count, tie-heavy low D
    (64, 64),   # exact split
    (37, 48),   # ragged + unpacked-only dim path when pack=False
])
def test_sharded_topk_matches_oracle(num_shards, num_refs, dim):
    rng = np.random.default_rng(num_refs * 100 + dim)
    refs = _bipolar(rng, (num_refs, dim))
    queries = _bipolar(rng, (16, dim))
    k = 5
    oracle_idx, oracle_vals = topk_search(queries, refs, k)
    for pack in ("auto", False):
        idx, vals = sharded_topk_search(queries, refs, k,
                                        num_shards=num_shards, pack=pack)
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(oracle_idx))
        np.testing.assert_array_equal(np.asarray(vals), np.asarray(oracle_vals))


def test_sharded_topk_duplicate_rows_tiebreak():
    """Duplicated reference rows across shard boundaries force exact score
    ties; the merge must still pick the same (lowest) indices the oracle
    does."""
    rng = np.random.default_rng(7)
    base = _bipolar(rng, (12, 32))
    refs = jnp.concatenate([base, base, base], axis=0)  # 36 rows, all tied
    queries = base[:6]
    oi, ov = topk_search(queries, refs, 4)
    for ns in (2, 4, 8):
        si, sv = sharded_topk_search(queries, refs, 4, num_shards=ns)
        np.testing.assert_array_equal(np.asarray(si), np.asarray(oi))
        np.testing.assert_array_equal(np.asarray(sv), np.asarray(ov))


def test_topk_search_packed_bit_identical():
    rng = np.random.default_rng(3)
    refs = _bipolar(rng, (50, 96))
    queries = _bipolar(rng, (9, 96))
    oi, ov = topk_search(queries, refs, 6)
    pi, pv = topk_search_packed(bitpack_bipolar(queries),
                                bitpack_bipolar(refs), 96, 6)
    np.testing.assert_array_equal(np.asarray(pi), np.asarray(oi))
    np.testing.assert_array_equal(np.asarray(pv), np.asarray(ov))


def test_sharded_topk_no_shards_fallback():
    rng = np.random.default_rng(5)
    refs = _bipolar(rng, (20, 32))
    queries = _bipolar(rng, (4, 32))
    oi, ov = topk_search(queries, refs, 3)
    for kw in ({}, {"num_shards": 1}):
        si, sv = sharded_topk_search(queries, refs, 3, **kw)
        np.testing.assert_array_equal(np.asarray(si), np.asarray(oi))
        np.testing.assert_array_equal(np.asarray(sv), np.asarray(ov))


def test_single_device_database_path():
    rng = np.random.default_rng(11)
    refs = _bipolar(rng, (30, 64))
    queries = _bipolar(rng, (5, 64))
    db = shard_database(refs)
    idx, vals = search_database(db, queries, 3)
    oi, ov = topk_search(queries, refs, 3)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(oi))
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(ov))


def test_k_exceeding_shard_rows_raises():
    rng = np.random.default_rng(13)
    refs = _bipolar(rng, (8, 32))
    queries = _bipolar(rng, (2, 32))
    with pytest.raises(ValueError, match="shard_rows"):
        sharded_topk_search(queries, refs, 5, num_shards=4)
    db = shard_database(refs)
    with pytest.raises(ValueError, match="bank rows"):
        search_database(db, queries, 9)


# --------------------------------------------------------------------------
# FDR routing
# --------------------------------------------------------------------------

def test_fdr_route_accepts_clear_target_hits():
    rng = np.random.default_rng(17)
    refs = _bipolar(rng, (40, 128))
    decoys = _bipolar(rng, (40, 128))
    db = shard_database(refs, decoys=decoys)
    res = search_with_fdr(db, refs[:10], k=4, fdr=0.05)
    # querying exact library rows: every hit is its own row, all accepted
    np.testing.assert_array_equal(res.match, np.arange(10))
    assert res.accept.all() and res.is_target.all()
    # indices are bank rows: targets live after the decoy block
    assert (res.indices[:, 0] == np.arange(10) + db.num_decoys).all()


def test_fdr_route_tie_resolves_to_decoy():
    """A target/decoy exact score tie must lose the competition (the
    conservative best_target > best_decoy convention): decoys precede
    targets in the bank, so the tied decoy wins rank 0."""
    rng = np.random.default_rng(19)
    row = _bipolar(rng, (1, 32))
    refs = jnp.concatenate([row, _bipolar(rng, (5, 32))], axis=0)
    decoys = jnp.concatenate([row, _bipolar(rng, (5, 32))], axis=0)
    db = shard_database(refs, decoys=decoys)
    res = search_with_fdr(db, row, k=3, fdr=1.0)
    assert not res.is_target[0]
    assert res.match[0] == -1


# --------------------------------------------------------------------------
# open-modification search: banded/sharded routes vs the masked oracle
# --------------------------------------------------------------------------

def _oms_oracle(db, q, sorted_bank, plan, k):
    """Sentinel-mask the full score matrix over the *sorted* bank outside
    the plan's bands, run lax.top_k, translate winners through the
    permutation — the definition oms_search_encoded must match bit-exactly,
    tie order and overflow slots included."""
    scores = q.astype(jnp.int32) @ sorted_bank.T.astype(jnp.int32)
    col = jnp.arange(sorted_bank.shape[0], dtype=jnp.int32)[None, :]
    band = jnp.zeros(scores.shape, bool)
    starts = jnp.asarray(plan.starts)
    ends = starts + jnp.asarray(plan.lens)
    for b in range(starts.shape[0]):
        band = band | ((col >= starts[b][:, None]) & (col < ends[b][:, None]))
    scores = jnp.where(band, scores, _SENTINEL)
    vals, idx = jax.lax.top_k(scores, k)
    return jnp.take(jnp.asarray(db.oms.perm), idx, axis=0), vals


def _oms_fixture(rng, *, num_refs=150, dim=64, num_queries=23):
    refs = _bipolar(rng, (num_refs, dim))
    decoys = _bipolar(rng, (num_refs, dim))
    prec = rng.uniform(400, 1600, num_refs).astype(np.float32)
    qprec = rng.uniform(420, 1650, num_queries).astype(np.float32)
    queries = _bipolar(rng, (num_queries, dim))
    return refs, decoys, prec, queries, qprec


@pytest.mark.parametrize("num_shards", [1, 2, 4, 8])
@pytest.mark.parametrize("fused", [False, True])
def test_oms_search_bit_identical_to_masked_oracle(num_shards, fused):
    """Every OMS route (banded kernel or masked unfused, any emulated shard
    count, packed or int8 banks) must equal sentinel-masking the full score
    matrix over the sorted bank and translating through the permutation."""
    rng = np.random.default_rng(num_shards * 10 + fused)
    refs, decoys, prec, queries, qprec = _oms_fixture(rng)
    cfg = OMSConfig(tol=15.0, open_tol=150.0)
    k = 7
    for pack in ("auto", False):
        db = shard_database(refs, decoys=decoys, pack=pack, fused=fused,
                            emulate_shards=(num_shards if num_shards > 1
                                            else None),
                            precursor=prec)
        plan = oms_plan(db, qprec, cfg)
        idx, vals, _ = oms_search(db, queries, qprec, k, cfg)
        sorted_bank = jnp.concatenate([decoys, refs])[jnp.asarray(db.oms.perm)]
        oi, ov = _oms_oracle(db, queries, sorted_bank, plan, k)
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(oi),
                                      err_msg=str((num_shards, fused, pack)))
        np.testing.assert_array_equal(np.asarray(vals), np.asarray(ov),
                                      err_msg=str((num_shards, fused, pack)))


def test_oms_fused_equals_unfused_through_fdr():
    rng = np.random.default_rng(41)
    refs, decoys, prec, queries, qprec = _oms_fixture(rng, num_refs=90)
    res = {}
    for fused in (False, True):
        db = shard_database(refs, decoys=decoys, emulate_shards=4,
                            fused=fused, precursor=prec)
        res[fused] = oms_search_with_fdr(db, queries, qprec, k=4, fdr=0.5)
    np.testing.assert_array_equal(res[True].indices, res[False].indices)
    np.testing.assert_array_equal(res[True].scores, res[False].scores)
    np.testing.assert_array_equal(res[True].accept, res[False].accept)
    np.testing.assert_array_equal(res[True].match, res[False].match)


def test_oms_empty_window_rejected_not_counted_as_decoy():
    """A query whose precursor window is empty must come back rejected
    (match -1, valid False) without depressing the FDR acceptance of the
    rest of the batch."""
    rng = np.random.default_rng(43)
    refs = _bipolar(rng, (40, 64))
    decoys = _bipolar(rng, (40, 64))
    prec = rng.uniform(400, 1600, 40).astype(np.float32)
    db = shard_database(refs, decoys=decoys, precursor=prec)
    queries = jnp.concatenate([refs[:6], _bipolar(rng, (3, 64))])
    qprec = np.concatenate([prec[:6], np.full(3, 1e6, np.float32)])
    res = oms_search_with_fdr(db, queries, qprec, k=3, fdr=0.05)
    assert res.valid is not None
    np.testing.assert_array_equal(np.asarray(res.valid),
                                  [True] * 6 + [False] * 3)
    assert (res.match[6:] == -1).all() and not res.accept[6:].any()
    assert not res.is_target[6:].any()
    # exact library rows with a clean window: all six accepted
    assert res.accept[:6].all()


def test_oms_requires_precursor_bank():
    rng = np.random.default_rng(47)
    refs = _bipolar(rng, (20, 32))
    db = shard_database(refs)  # no precursor=
    with pytest.raises(ValueError, match="precursor"):
        oms_plan(db, np.asarray([500.0], np.float32))


def test_oms_server_matches_direct_search():
    """One OMS server flush == the direct oms_search_with_fdr call on the
    same queries: the server's precursor sort/unsort and padding must be
    invisible in the results."""
    rng = np.random.default_rng(53)
    refs, decoys, prec, queries, qprec = _oms_fixture(
        rng, num_refs=60, num_queries=8)
    db = shard_database(refs, decoys=decoys, precursor=prec)
    cfg = OMSConfig(tol=15.0, open_tol=150.0)
    srv = DBSearchServer(db, k=3, fdr=0.5, max_batch_size=8,
                         flush_timeout_s=0.0, oms=cfg)
    for q, p in zip(np.asarray(queries), qprec):
        srv.submit(q, precursor=float(p))
    done = srv.run_until_drained()
    direct = oms_search_with_fdr(db, queries, qprec, k=3, fdr=0.5, cfg=cfg)
    assert len(done) == 8
    for i, r in enumerate(done):
        np.testing.assert_array_equal(r.result.indices, direct.indices[i])
        np.testing.assert_array_equal(r.result.scores, direct.scores[i])
        assert r.result.accept == bool(direct.accept[i])
        assert r.result.match == int(direct.match[i])
        assert r.result.has_candidate == bool(direct.valid[i])
    oms_stats = srv.summary()["oms"]
    assert oms_stats["batches"] == 1
    assert 0.0 < oms_stats["candidate_fraction"] < 1.0


def test_oms_server_ragged_flush_padding_is_invisible():
    """A ragged OMS flush (n < max_batch_size) pads queries *and*
    precursors; padded rows must not perturb the real results."""
    rng = np.random.default_rng(59)
    refs, decoys, prec, queries, qprec = _oms_fixture(
        rng, num_refs=60, num_queries=3)
    db = shard_database(refs, decoys=decoys, precursor=prec)
    srv = DBSearchServer(db, k=3, fdr=0.5, max_batch_size=8,
                         flush_timeout_s=0.0, oms=OMSConfig())
    for q, p in zip(np.asarray(queries), qprec):
        srv.submit(q, precursor=float(p))
    done = srv.run_until_drained()
    direct = oms_search_with_fdr(db, queries, qprec, k=3, fdr=0.5,
                                 cfg=OMSConfig())
    for i, r in enumerate(done):
        np.testing.assert_array_equal(r.result.indices, direct.indices[i])
        assert r.result.match == int(direct.match[i])


def test_oms_server_submit_without_precursor_raises():
    rng = np.random.default_rng(61)
    refs = _bipolar(rng, (20, 32))
    prec = rng.uniform(400, 1600, 20).astype(np.float32)
    db = shard_database(refs, precursor=prec)
    srv = DBSearchServer(db, k=2, max_batch_size=4, oms=OMSConfig())
    with pytest.raises(ValueError, match="precursor"):
        srv.submit(np.asarray(refs[0]))


# --------------------------------------------------------------------------
# micro-batching queue
# --------------------------------------------------------------------------

def test_queue_flushes_on_max_batch():
    now = [0.0]
    q = MicroBatchQueue(max_batch_size=3, flush_timeout_s=10.0,
                        clock=lambda: now[0])
    assert not q.ready()
    q.submit("a"), q.submit("b")
    assert not q.ready()                      # 2 < max, nothing timed out
    q.submit("c")
    assert q.ready()                          # full batch, no time passed
    batch = q.take_batch()
    assert [r.query for r in batch] == ["a", "b", "c"]  # FIFO
    assert len(q) == 0 and not q.ready()


def test_queue_flushes_on_timeout():
    now = [100.0]
    q = MicroBatchQueue(max_batch_size=64, flush_timeout_s=0.5,
                        clock=lambda: now[0])
    q.submit("only")
    assert not q.ready()
    assert q.time_until_flush() == pytest.approx(0.5)
    now[0] += 0.49
    assert not q.ready()
    now[0] += 0.02
    assert q.ready() and q.time_until_flush() == 0.0
    assert [r.query for r in q.take_batch()] == ["only"]


def test_queue_take_batch_caps_at_max_and_keeps_fifo():
    q = MicroBatchQueue(max_batch_size=4, flush_timeout_s=0.0)
    rids = [q.submit(i) for i in range(10)]
    first = q.take_batch()
    assert [r.rid for r in first] == rids[:4]
    assert len(q) == 6
    assert [r.rid for r in q.take_batch()] == rids[4:8]


def test_latency_stats_percentiles():
    now = [0.0]
    stats = LatencyStats()
    reqs = []
    for i in range(10):
        reqs.append(Request(rid=i, query=None, t_submit=float(i),
                            t_done=float(i) + (i + 1) * 0.01))
    stats.record_batch(reqs)
    s = stats.summary()
    assert s["count"] == 10 and s["batches"] == 1
    assert s["p50_ms"] == pytest.approx(55.0)
    assert s["p95_ms"] == pytest.approx(95.5)
    del now


def test_latency_stats_bounded_window():
    stats = LatencyStats(window=4)
    reqs = [Request(rid=i, query=None, t_submit=float(i),
                    t_done=float(i) + 0.1 * (i + 1)) for i in range(10)]
    for r in reqs:
        stats.record_batch([r])
    s = stats.summary()
    assert s["count"] == 10 and s["batches"] == 10  # exact running totals
    assert len(stats._latencies) == 4               # bounded memory
    # percentiles over the latest window only (latencies 0.7..1.0)
    assert s["p50_ms"] == pytest.approx(850.0)


# --------------------------------------------------------------------------
# server loop
# --------------------------------------------------------------------------

def _make_server(rng, clock, **kw):
    refs = _bipolar(rng, (24, 64))
    decoys = _bipolar(rng, (24, 64))
    db = shard_database(refs, decoys=decoys)
    return refs, DBSearchServer(db, clock=clock, **kw)


def test_server_flush_on_batch_and_timeout():
    now = [0.0]
    rng = np.random.default_rng(23)
    refs, srv = _make_server(rng, lambda: now[0], k=3, fdr=1.0,
                             max_batch_size=4, flush_timeout_s=1.0)
    for i in range(3):
        srv.submit(np.asarray(refs[i]))
    assert srv.step() == []                   # 3 < max batch, no timeout
    srv.submit(np.asarray(refs[3]))
    done = srv.step()                         # flush on max batch
    assert [r.rid for r in done] == [0, 1, 2, 3]
    srv.submit(np.asarray(refs[4]))
    assert srv.step() == []
    now[0] += 1.5
    done = srv.step()                         # flush on timeout
    assert [r.rid for r in done] == [4]
    assert done[0].latency_s == pytest.approx(1.5)


def test_server_padded_batch_matches_direct_search():
    """A ragged flush (n < max_batch_size) is padded for a single jit
    signature; results must equal searching exactly those queries."""
    now = [0.0]
    rng = np.random.default_rng(29)
    refs = _bipolar(rng, (32, 64))
    decoys = _bipolar(rng, (32, 64))
    db = shard_database(refs, decoys=decoys)
    srv = DBSearchServer(db, k=4, fdr=0.5, max_batch_size=8,
                         flush_timeout_s=0.0, clock=lambda: now[0])
    queries = _bipolar(rng, (3, 64))
    for q in np.asarray(queries):
        srv.submit(q)
    done = srv.run_until_drained()
    direct = search_with_fdr(db, queries, k=4, fdr=0.5)
    for i, r in enumerate(done):
        np.testing.assert_array_equal(r.result.indices, direct.indices[i])
        np.testing.assert_array_equal(r.result.scores, direct.scores[i])
        assert r.result.accept == bool(direct.accept[i])
        assert r.result.match == int(direct.match[i])


def test_serve_db_cli_single_device():
    from repro.launch import serve_db
    s = serve_db.main(["--reduced", "--hd-dim", "64", "--identities", "8",
                       "--queries", "16", "--max-batch", "4",
                       "--k", "2", "--fdr", "0.5"])
    assert s["count"] > 0 and s["qps"] > 0


# --------------------------------------------------------------------------
# real multi-device shard_map path (slow tier)
# --------------------------------------------------------------------------

def _run_py(code: str, devices: int = 8, timeout: int = 520):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("JAX_PLATFORMS", None)
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)


@pytest.mark.slow
def test_sharded_search_bit_identical_on_8_device_mesh():
    r = _run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.hd.similarity import topk_search
        from repro.serve import shard_database, search_database
        rng = np.random.default_rng(1)
        for model_n in (2, 4, 8):
            mesh = jax.make_mesh((8 // model_n, model_n), ("data", "model"))
            for R, D in [(61, 32), (64, 64), (37, 48)]:
                refs = jnp.asarray(rng.choice([-1, 1], (R, D)).astype(np.int8))
                q = jnp.asarray(rng.choice([-1, 1], (16, D)).astype(np.int8))
                oi, ov = topk_search(q, refs, 4)
                for pack in ([True, False] if D % 32 == 0 else [False]):
                    for fused in (False, True):
                        db = shard_database(refs, mesh=mesh, pack=pack,
                                            fused=fused)
                        si, sv = search_database(db, q, 4)
                        assert (np.asarray(si) == np.asarray(oi)).all(), (model_n, R, D, pack, fused)
                        assert (np.asarray(sv) == np.asarray(ov)).all(), (model_n, R, D, pack, fused)
        print("SHARDED_TOPK_OK")
    """)
    assert "SHARDED_TOPK_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_oms_search_bit_identical_on_8_device_mesh():
    """Real shard_map OMS routes (scalar bands broadcast via the in_specs,
    banded kernel per shard) vs the single-device masked path."""
    r = _run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.serve import OMSConfig, oms_search, shard_database
        rng = np.random.default_rng(2)
        R, D, Q, k = 150, 64, 16, 5
        refs = jnp.asarray(rng.choice([-1, 1], (R, D)).astype(np.int8))
        decoys = jnp.asarray(rng.choice([-1, 1], (R, D)).astype(np.int8))
        prec = rng.uniform(400, 1600, R).astype(np.float32)
        q = jnp.asarray(rng.choice([-1, 1], (Q, D)).astype(np.int8))
        qprec = np.sort(rng.uniform(420, 1650, Q).astype(np.float32))
        cfg = OMSConfig(tol=15.0, open_tol=150.0)
        ref_db = shard_database(refs, decoys=decoys, precursor=prec)
        oi, ov, _ = oms_search(ref_db, q, qprec, k, cfg)
        for model_n in (2, 4, 8):
            mesh = jax.make_mesh((8 // model_n, model_n), ("data", "model"))
            for pack in (True, False):
                for fused in (False, True):
                    db = shard_database(refs, decoys=decoys, mesh=mesh,
                                        pack=pack, fused=fused,
                                        precursor=prec)
                    si, sv, _ = oms_search(db, q, qprec, k, cfg)
                    assert (np.asarray(si) == np.asarray(oi)).all(), (model_n, pack, fused)
                    assert (np.asarray(sv) == np.asarray(ov)).all(), (model_n, pack, fused)
        print("OMS_SHARDED_OK")
    """)
    assert "OMS_SHARDED_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_serve_db_cli_on_8_device_mesh():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve_db", "--reduced"],
        capture_output=True, text=True, timeout=520, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "queries/sec" in r.stdout and "p50" in r.stdout, r.stdout
