"""PCM device model, array model, ISA, and energy-model tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.imc.array import (
    ArrayConfig,
    adc_quantize,
    dac_quantize,
    default_full_scale,
    imc_mvm,
    imc_mvm_reference,
    program_hvs,
)
from repro.core.imc.device import (
    SB2TE3_GST,
    TITE2_GST,
    DeviceConfig,
    apply_write_noise,
    bit_error_rate,
    noise_sigma,
)
from repro.core.imc.energy import (
    DATASETS,
    DEFAULT_HW,
    PAPER_ENERGY,
    PAPER_TABLE2,
    PAPER_TABLE3,
    clustering_cost,
    db_search_cost,
)
from repro.core.imc.isa import (
    Instruction,
    ISAExecutor,
    Opcode,
    decode_instruction,
    encode_instruction,
)


class TestDevice:
    def test_material_table_s1(self):
        assert SB2TE3_GST.programming_energy_pj == pytest.approx(1.12)
        assert TITE2_GST.programming_energy_pj == pytest.approx(2.88)
        assert TITE2_GST.retention_hours_105c > SB2TE3_GST.retention_hours_105c

    def test_ber_decreases_with_write_verify(self):
        """Fig. 7 trend: BER falls monotonically with write-verify cycles."""
        bers = [bit_error_rate(DeviceConfig("tite2", 3, c)) for c in range(6)]
        assert all(bers[i] > bers[i + 1] for i in range(5))
        # the paper's measured range: >10% at 0 cycles, a few % by 5
        assert bers[0] > 0.08
        assert bers[5] < 0.08

    def test_ber_increases_with_bits_per_cell(self):
        for c in (0, 3):
            b = [bit_error_rate(DeviceConfig("tite2", n, c)) for n in (1, 2, 3)]
            assert b[0] < b[1] and b[0] < b[2]
            # 2- and 3-bit are close under level-proportional noise (the
            # rarer +-3 levels offset their higher per-level error)
            assert b[1] <= b[2] * 1.15

    def test_materials_error_ordering(self):
        """TiTe2 has the lower error floor (paper §III.E)."""
        assert noise_sigma(DeviceConfig("tite2", 3, 5)) < \
            noise_sigma(DeviceConfig("sb2te3", 3, 5))

    def test_write_noise_is_multiplicative(self):
        w = jnp.asarray([[0.0, 1.0, -3.0]])
        out = apply_write_noise(jax.random.PRNGKey(0), w,
                                DeviceConfig("tite2", 3, 3))
        assert float(out[0, 0]) == 0.0  # zero weights stay zero
        assert out.shape == w.shape


class TestArray:
    def test_dac_clamps(self):
        cfg = ArrayConfig()
        out = dac_quantize(jnp.asarray([-10.0, -1.2, 0.4, 9.0]), cfg)
        np.testing.assert_array_equal(np.asarray(out), [-3, -1, 0, 3])

    def test_adc_saturates_and_quantizes(self):
        cfg = ArrayConfig(adc_bits=6)
        fs = 10.0
        lsb = fs / cfg.adc_levels
        x = jnp.asarray([0.0, lsb * 0.4, lsb * 0.6, 100.0, -100.0])
        out = np.asarray(adc_quantize(x, cfg, fs))
        assert out[0] == 0
        assert out[1] == 0 and out[2] == pytest.approx(lsb)
        assert out[3] == pytest.approx(fs) and out[4] == pytest.approx(-fs)

    def test_ideal_limit_matches_exact_dot(self):
        """With huge ADC precision + full scale, IMC == exact dot product."""
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.integers(-3, 4, (4, 256)).astype(np.float32))
        w = jnp.asarray(rng.integers(-3, 4, (8, 256)).astype(np.float32))
        cfg = ArrayConfig(adc_bits=24, full_scale=4096.0)
        out = imc_mvm_reference(q, w, cfg)
        exact = np.asarray(q) @ np.asarray(w).T
        np.testing.assert_allclose(np.asarray(out), exact, rtol=1e-4, atol=0.2)

    def test_quantization_error_bounded(self):
        rng = np.random.default_rng(1)
        q = jnp.asarray(rng.integers(-3, 4, (8, 384)).astype(np.float32))
        w = jnp.asarray(rng.integers(-3, 4, (16, 384)).astype(np.float32))
        cfg = ArrayConfig(adc_bits=6)
        out = np.asarray(imc_mvm_reference(q, w, cfg))
        exact = np.asarray(q) @ np.asarray(w).T
        ntiles = 384 // 128
        lsb = default_full_scale(cfg) / cfg.adc_levels
        # per-tile quantization error <= lsb/2 (unclipped partials)
        assert np.abs(out - exact).max() <= ntiles * lsb / 2 + 1e-3

    def test_program_then_mvm(self):
        rng = np.random.default_rng(2)
        hv = jnp.asarray(rng.integers(-3, 4, (16, 128)).astype(np.int8))
        state = program_hvs(jax.random.PRNGKey(0), hv, ArrayConfig(),
                            DeviceConfig("tite2", 3, 5))
        scores = imc_mvm(hv.astype(jnp.float32), state)
        # self-similarity should dominate despite noise
        assert (np.asarray(scores).argmax(1) == np.arange(16)).mean() > 0.9


class TestISA:
    def test_roundtrip(self):
        inst = Instruction(Opcode.MVM_COMPUTE, arr_idx=37, col_addr=5,
                           row_addr=1023, mlc_bits=3, aux=6)
        assert decode_instruction(encode_instruction(inst)) == inst

    def test_encoding_is_64bit(self):
        inst = Instruction(Opcode.STORE_HV, arr_idx=2**16 - 1, col_addr=255,
                           row_addr=2**16 - 1, mlc_bits=15, aux=63)
        assert encode_instruction(inst) < 2**64

    def test_field_validation(self):
        with pytest.raises(ValueError):
            Instruction(Opcode.READ_HV, arr_idx=2**16)
        with pytest.raises(ValueError):
            Instruction(Opcode.READ_HV, aux=64)

    def test_executor_store_mvm(self):
        rng = np.random.default_rng(3)
        refs = jnp.asarray(rng.integers(-3, 4, (32, 256)).astype(np.int8))
        ex = ISAExecutor(ArrayConfig(), DeviceConfig("tite2", 3, 3))
        ex.load_stage(refs)
        ex.execute_one(Instruction(Opcode.STORE_HV, mlc_bits=3, aux=3))
        ex.load_stage(refs[:4])
        ex.execute_one(Instruction(Opcode.MVM_COMPUTE, mlc_bits=3, aux=6))
        assert ex.result.shape == (4, 32)
        assert (np.asarray(ex.result).argmax(1) == np.arange(4)).all()
        assert ex.trace.cycles > 0 and ex.trace.energy_j > 0
        assert ex.trace.instructions == 2

    def test_executor_read(self):
        rng = np.random.default_rng(4)
        refs = jnp.asarray(rng.integers(-3, 4, (16, 128)).astype(np.int8))
        ex = ISAExecutor(ArrayConfig(), DeviceConfig("tite2", 3, 5), seed=7)
        ex.load_stage(refs)
        ex.execute_one(Instruction(Opcode.STORE_HV, mlc_bits=3, aux=5))
        ex.execute_one(Instruction(Opcode.READ_HV, row_addr=0, aux=8))
        assert ex.stage.shape == (8, 128)
        # with write-verify=5 noise is small: most levels read back exactly
        agree = (np.asarray(ex.stage) == np.asarray(refs[:8])).mean()
        assert agree > 0.6


class TestEnergyModel:
    """The analytic model must reproduce the paper's own Tables 2/3."""

    @pytest.mark.parametrize("ds,col", [("PXD001468", "SpecPCM(paper)"),
                                        ("PXD000561", "SpecPCM(paper)")])
    def test_clustering_latency_within_10pct(self, ds, col):
        r = clustering_cost(DATASETS[ds]["num_spectra"])
        assert r.latency_s == pytest.approx(PAPER_TABLE2[ds][col], rel=0.10)

    @pytest.mark.parametrize("ds", ["iPRG2012", "HEK293"])
    def test_db_search_latency_within_10pct(self, ds):
        d = DATASETS[ds]
        r = db_search_cost(d["num_queries"], d["num_refs"],
                           candidate_fraction=d["candidate_fraction"])
        assert r.latency_s == pytest.approx(
            PAPER_TABLE3[ds]["SpecPCM(paper)"], rel=0.10)

    def test_db_search_energy(self):
        d = DATASETS["HEK293"]
        r = db_search_cost(d["num_queries"], d["num_refs"],
                           candidate_fraction=d["candidate_fraction"])
        assert r.energy_j == pytest.approx(PAPER_ENERGY["HEK293_db_search_j"],
                                           rel=0.10)

    def test_clustering_energy(self):
        r = clustering_cost(DATASETS["PXD000561"]["num_spectra"])
        assert r.energy_j == pytest.approx(
            PAPER_ENERGY["PXD000561_clustering_j"], rel=0.15)

    def test_adc_bits_scale_energy(self):
        """§IV.B(4): 4-bit flash ADC ~ 4x cheaper than 6-bit (ADC part)."""
        e6 = DEFAULT_HW.macro_power_w(6) - DEFAULT_HW.macro_power_w(1)
        e4 = DEFAULT_HW.macro_power_w(4) - DEFAULT_HW.macro_power_w(1)
        assert e6 / e4 == pytest.approx(63 / 15, rel=0.3)

    def test_mlc_speedup_vs_slc(self):
        """3-bit MLC packs 3x density -> ~3x fewer array ops (Table 2/3)."""
        d = DATASETS["HEK293"]
        slc = db_search_cost(d["num_queries"], d["num_refs"], mlc_bits=1,
                             candidate_fraction=d["candidate_fraction"])
        mlc = db_search_cost(d["num_queries"], d["num_refs"], mlc_bits=3,
                             candidate_fraction=d["candidate_fraction"])
        assert slc.latency_s / mlc.latency_s == pytest.approx(3.0, rel=0.15)

    def test_write_verify_scales_clustering_latency(self):
        a = clustering_cost(100_000, write_verify=0)
        b = clustering_cost(100_000, write_verify=3)
        assert b.breakdown["program_s"] == pytest.approx(
            4 * a.breakdown["program_s"], rel=0.01)
