"""Property-style coverage for the dist substrate beyond the seed
contract: checkpoint behaviour under concurrent async saves, RULE_PRESETS
round-trips through tree_shardings, and compression determinism."""

import threading
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.checkpoint import CheckpointManager
from repro.dist.compression import (
    compress_tree,
    cross_pod_allreduce,
    init_error_state,
    topk_ef_compress,
)
from repro.dist.sharding import (
    DEFAULT_RULES,
    RULE_PRESETS,
    ShardingRules,
    logical_to_spec,
    set_mesh,
    tree_shardings,
)
from repro.dist.straggler import Action, HeartbeatRegistry, StragglerMonitor


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32)),
        "nested": {"b": jnp.asarray(rng.normal(size=(4,)).astype(np.float32)),
                   "step": jnp.asarray(np.int32(seed))},
    }


class TestCheckpointConcurrency:
    def test_concurrent_save_async_all_valid(self, tmp_path):
        """Interleaved save_async calls from multiple threads must leave
        only complete, valid step directories (atomic rename + keep GC)."""
        mgr = CheckpointManager(tmp_path, keep=4)
        threads = [threading.Thread(target=mgr.save_async, args=(s, _tree(s)))
                   for s in range(1, 9)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        mgr.wait()
        steps = mgr.list_steps()
        assert len(steps) == 4
        for s in steps:
            assert mgr.validate(s), s
            got = mgr.restore(s, _tree())
            np.testing.assert_array_equal(np.asarray(got["w"]),
                                          np.asarray(_tree(s)["w"]))
        # no torn .tmp directories left behind
        assert not list(tmp_path.glob("*.tmp*"))

    def test_async_then_sync_same_step_overwrites(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save_async(7, _tree(1))
        mgr.wait()
        mgr.save(7, _tree(2))
        got = mgr.restore(7, _tree())
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.asarray(_tree(2)["w"]))

    def test_restore_latest_empty_dir_is_none(self, tmp_path):
        assert CheckpointManager(tmp_path).restore_latest(_tree()) is None

    def test_bf16_leaves_roundtrip(self, tmp_path):
        """Non-numpy-native dtypes survive the byte-view encoding."""
        mgr = CheckpointManager(tmp_path)
        tree = {"p": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4)}
        mgr.save(1, tree)
        got = mgr.restore(1, tree)
        assert got["p"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(got["p"], np.float32),
                                      np.asarray(tree["p"], np.float32))


class TestRulePresets:
    def setup_method(self):
        set_mesh(None)

    @pytest.mark.parametrize("preset", sorted(RULE_PRESETS))
    def test_tree_shardings_roundtrip_1device(self, preset):
        """Every preset must produce valid shardings on a 1-device mesh
        (the degradation guarantee), and device_put through them must
        preserve values exactly."""
        rules = RULE_PRESETS[preset]
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        axes = {"emb": ("vocab", "fsdp"),
                "attn": {"wq": ("fsdp", "heads", None)},
                "scale": (None,),
                "step": ()}
        tree = {"emb": jnp.ones((32, 16)),
                "attn": {"wq": jnp.ones((16, 4, 8))},
                "scale": jnp.ones((16,)),
                "step": jnp.zeros(())}
        sh = tree_shardings(axes, tree, mesh, rules)
        placed = jax.tree.map(jax.device_put, tree, sh)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(placed)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_fsdp_only_preset_never_uses_model_axis(self):
        # spec resolution only reads mesh.shape, so a stub stands in for
        # the 8-device mesh this CPU process cannot build
        mesh = types.SimpleNamespace(shape={"data": 2, "model": 4})
        rules = RULE_PRESETS["fsdp_only"]
        for name in ("heads", "ff", "experts", "vocab", "seq_shard"):
            spec = logical_to_spec((name,), (8,), mesh, rules)
            assert "model" not in jax.tree.leaves(tuple(spec)), (name, spec)

    def test_partial_multi_axis_divisibility(self):
        """batch -> ('pod','data'): a dim divisible by pod but not by
        pod*data shards over pod only."""
        set_mesh(None)
        mesh = types.SimpleNamespace(shape={"pod": 2, "data": 3, "model": 1})
        spec = logical_to_spec(("batch",), (4,), mesh, DEFAULT_RULES)
        assert spec == jax.sharding.PartitionSpec("pod")

    def test_unknown_logical_axis_raises(self):
        with pytest.raises(AttributeError):
            DEFAULT_RULES.lookup("not_an_axis")

    def test_replace_is_pure(self):
        r = DEFAULT_RULES.replace(kv_seq="model")
        assert DEFAULT_RULES.kv_seq is None
        assert r.kv_seq == "model"
        assert isinstance(r, ShardingRules)


class TestCompressionDeterminism:
    def test_int8_deterministic_under_fixed_key(self):
        g = {"w": jnp.asarray(np.random.default_rng(3).normal(
            size=(64, 32)).astype(np.float32))}
        key = jax.random.PRNGKey(7)
        a = compress_tree(g, method="int8", key=key)
        b = compress_tree(g, method="int8", key=key)
        np.testing.assert_array_equal(np.asarray(a["w"]), np.asarray(b["w"]))
        c = compress_tree(g, method="int8", key=jax.random.PRNGKey(8))
        assert not np.array_equal(np.asarray(a["w"]), np.asarray(c["w"]))

    def test_int8_under_jit_matches_eager(self):
        g = {"w": jnp.linspace(-1.0, 1.0, 128).reshape(8, 16)}
        eager = compress_tree(g, method="int8")
        jitted = jax.jit(lambda t: compress_tree(t, method="int8"))(g)
        np.testing.assert_allclose(np.asarray(eager["w"]),
                                   np.asarray(jitted["w"]), rtol=1e-6)

    def test_topk_zero_frac_keeps_at_least_one(self):
        g = {"w": jnp.asarray([0.0, 3.0, -1.0, 0.5])}
        out = compress_tree(g, method="topk", topk_frac=0.0)
        nz = np.nonzero(np.asarray(out["w"]))[0]
        assert list(nz) == [1]  # the single largest coordinate

    def test_ef_state_stays_finite_over_many_steps(self):
        rng = np.random.default_rng(0)
        g = {"w": jnp.asarray(rng.normal(size=(64,)).astype(np.float32))}
        err = init_error_state(g)
        for _ in range(50):
            _, err = topk_ef_compress(g, err, topk_frac=0.1)
        assert np.isfinite(np.asarray(err["w"])).all()

    def test_cross_pod_allreduce_1device(self):
        mesh = jax.make_mesh((1,), ("pod",))
        x = jnp.arange(8, dtype=jnp.float32).reshape(1, 8)
        out = cross_pod_allreduce(x, mesh, axis="pod", method="none")
        np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


class TestStragglerEdges:
    def test_evict_resets_streak(self):
        m = StragglerMonitor(warmup_steps=2, consecutive_limit=2)
        for _ in range(5):
            m.observe(1.0)
        assert m.observe(9.0) == Action.WARN
        assert m.observe(9.0) == Action.EVICT
        # streak reset: the next slow step starts a new WARN cycle
        assert m.observe(9.0) == Action.WARN

    def test_heartbeat_recovers_after_beat(self):
        reg = HeartbeatRegistry(num_hosts=2, timeout_steps=2)
        reg.beat(0)
        assert reg.tick() == []          # nobody has missed 2 ticks yet
        reg.beat(0)
        assert reg.tick() == [1]         # 1 has been silent for 2 ticks
        reg.beat(1)
        assert reg.tick() == [0]         # 0 went quiet, 1 recovered
