"""Open-modification search (OMS): banded kernel vs the masked-matrix
oracle, precursor index/candidate-range semantics, and the host-side plan.

Property tests (hypothesis; the conftest shim when the package is absent)
over ragged Q/R, per-query empty windows, windows spanning tile/shard
boundaries, duplicate-score ties, and k >= window length — all in
interpret mode (tier-1, CPU). The emulated-shard OMS serving routes are
covered in tests/test_serve.py; the real 8-device mesh in its slow tier.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.hd.similarity import bitpack_bipolar
from repro.kernels.topk_hamming import (
    canonicalize_overflow_slots,
    topk_hamming_banded_pallas,
)
from repro.kernels.topk_hamming.ref import topk_hamming_banded_ref
from repro.serve.oms import (
    OMSConfig,
    OMSPlan,
    PrecursorIndex,
    build_precursor_index,
    plan_candidates,
    translate_indices,
)
from repro.spectra.preprocess import candidate_window_mask

_SENTINEL = np.iinfo(np.int32).min


def _bipolar(rng, shape):
    return jnp.asarray(rng.choice([-1, 1], size=shape).astype(np.int8))


def _assert_same(got, want, *ctx):
    gi, gv = got
    wi, wv = want
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi), err_msg=str(ctx))
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv), err_msg=str(ctx))


def _random_bands(rng, q, r, *, allow_empty=True):
    """Per-query [start, start+len) bands, a mix of empty / narrow / wide."""
    starts = rng.integers(0, r + 1, q).astype(np.int32)
    lens = rng.integers(0, r + 1, q).astype(np.int32)
    lens = np.minimum(lens, r - starts)
    if not allow_empty:
        lens = np.maximum(lens, 1)
        starts = np.minimum(starts, r - 1)
    return jnp.asarray(starts), jnp.asarray(lens)


# --------------------------------------------------------------------------
# banded kernel vs the sentinel-masked full-matrix oracle
# --------------------------------------------------------------------------

class TestBandedVsOracleProperties:
    @settings(max_examples=10)
    @given(st.integers(1, 33), st.integers(1, 300), st.integers(1, 5),
           st.integers(1, 9))
    def test_packed_random_bands(self, q, r, w, k):
        """Random per-query bands (empty ones included, and k wider than
        many bands) over the packed XOR+popcount path."""
        k = min(k, r)
        rng = np.random.default_rng(q * 7919 + r * 131 + w * 17 + k)
        qp = jnp.asarray(rng.integers(0, 2**32, (q, w), dtype=np.uint32))
        rp = jnp.asarray(rng.integers(0, 2**32, (r, w), dtype=np.uint32))
        starts, lens = _random_bands(rng, q, r)
        got = topk_hamming_banded_pallas(qp, rp, starts, lens, dim=w * 32, k=k)
        want = topk_hamming_banded_ref(qp, rp, starts, lens, w * 32, k)
        _assert_same(got, want, q, r, w, k)

    @settings(max_examples=8)
    @given(st.integers(1, 17), st.integers(1, 150), st.integers(1, 80),
           st.integers(1, 8))
    def test_int8_dot_random_bands(self, q, r, d, k):
        """The unpacked int8-dot variant (the D % 32 != 0 fallback)."""
        k = min(k, r)
        rng = np.random.default_rng(q * 733 + r * 37 + d * 5 + k)
        qs = _bipolar(rng, (q, d))
        rs = _bipolar(rng, (r, d))
        starts, lens = _random_bands(rng, q, r)
        got = topk_hamming_banded_pallas(qs, rs, starts, lens, dim=d, k=k)
        want = topk_hamming_banded_ref(qs, rs, starts, lens, d, k)
        _assert_same(got, want, q, r, d, k)

    @settings(max_examples=8)
    @given(st.integers(2, 30), st.integers(1, 6))
    def test_duplicate_scores_tiebreak_inside_band(self, r, k):
        """Duplicated reference rows force exact score ties; the banded
        running merge must order them by ascending index like lax.top_k
        over the masked matrix."""
        rng = np.random.default_rng(r * 101 + k)
        base = _bipolar(rng, (r, 32))
        refs = jnp.concatenate([base, base, base], axis=0)  # 3r rows, tied
        queries = base[: min(r, 8)]
        q = queries.shape[0]
        k = min(k, 3 * r)
        starts, lens = _random_bands(rng, q, 3 * r, allow_empty=False)
        got = topk_hamming_banded_pallas(
            bitpack_bipolar(queries), bitpack_bipolar(refs), starts, lens,
            dim=32, k=k)
        want = topk_hamming_banded_ref(
            bitpack_bipolar(queries), bitpack_bipolar(refs), starts, lens,
            32, k)
        _assert_same(got, want, r, k)

    @settings(max_examples=8)
    @given(st.integers(1, 16), st.integers(1, 9))
    def test_band_narrower_than_k_canonical_overflow(self, q, k):
        """k >= window length: overflow slots must carry the sentinel at
        the oracle's ascending *masked* rows (bit-identity includes the
        slots past the band)."""
        r = 40
        rng = np.random.default_rng(q * 31 + k)
        qp = jnp.asarray(rng.integers(0, 2**32, (q, 2), dtype=np.uint32))
        rp = jnp.asarray(rng.integers(0, 2**32, (r, 2), dtype=np.uint32))
        starts = jnp.asarray(rng.integers(0, r, q).astype(np.int32))
        lens = jnp.asarray(rng.integers(0, k, q).astype(np.int32))
        lens = jnp.minimum(lens, r - starts)
        got = topk_hamming_banded_pallas(qp, rp, starts, lens, dim=64, k=k)
        want = topk_hamming_banded_ref(qp, rp, starts, lens, 64, k)
        _assert_same(got, want, q, k)
        gi, gv = got
        n_real = np.asarray(lens)
        for i in range(q):
            assert (np.asarray(gv)[i, n_real[i]:] == _SENTINEL).all()

    def test_band_spanning_tile_boundaries(self):
        """Bands that straddle 128-row tile (== aligned shard) boundaries,
        under the tightest tile budget that still covers them."""
        rng = np.random.default_rng(0)
        r, q, w, k = 520, 12, 3, 5
        qp = jnp.asarray(rng.integers(0, 2**32, (q, w), dtype=np.uint32))
        rp = jnp.asarray(rng.integers(0, 2**32, (r, w), dtype=np.uint32))
        # every band crosses at least one multiple of 128
        starts = jnp.asarray((rng.integers(0, 3, q) * 128 + 100).astype(np.int32))
        lens = jnp.asarray(rng.integers(60, 200, q).astype(np.int32))
        lens = jnp.minimum(lens, r - starts)
        # tightest budget honouring the caller contract: cover from the
        # block's lowest start tile to its highest end tile
        tb = int(np.asarray(starts).min()) // 128
        tight = -(-int(np.asarray(starts + lens).max()) // 128) - tb
        for nt in (tight, tight + 1, None):
            got = topk_hamming_banded_pallas(qp, rp, starts, lens, dim=w * 32,
                                             k=k, num_tiles=nt)
            want = topk_hamming_banded_ref(qp, rp, starts, lens, w * 32, k)
            _assert_same(got, want, nt)

    def test_num_valid_composes_with_bands(self):
        """num_valid (shard padding) truncates every band exactly like the
        unfused per-shard mask."""
        rng = np.random.default_rng(1)
        qp = jnp.asarray(rng.integers(0, 2**32, (6, 2), dtype=np.uint32))
        rp = jnp.asarray(rng.integers(0, 2**32, (64, 2), dtype=np.uint32))
        starts = jnp.asarray(np.arange(6, dtype=np.int32) * 9)
        lens = jnp.full((6,), 30, jnp.int32)
        for nv in (0, 10, 40, 64):
            got = topk_hamming_banded_pallas(qp, rp, starts, lens, dim=64,
                                             k=4, num_valid=nv)
            want = topk_hamming_banded_ref(qp, rp, starts, lens, 64, 4,
                                           num_valid=nv)
            _assert_same(got, want, nv)

    def test_full_band_matches_unbanded_semantics(self):
        """A [0, R) band on every query degrades to the plain fused search."""
        from repro.kernels.topk_hamming import topk_hamming_pallas
        rng = np.random.default_rng(2)
        qp = jnp.asarray(rng.integers(0, 2**32, (9, 3), dtype=np.uint32))
        rp = jnp.asarray(rng.integers(0, 2**32, (77, 3), dtype=np.uint32))
        got = topk_hamming_banded_pallas(
            qp, rp, jnp.zeros(9, jnp.int32), jnp.full(9, 77, jnp.int32),
            dim=96, k=6)
        want = topk_hamming_pallas(qp, rp, dim=96, k=6)
        _assert_same(got, want)

    def test_all_empty_bands(self):
        """Every window empty: all slots are sentinel overflow, indices the
        oracle's ascending masked rows (0..k-1)."""
        rng = np.random.default_rng(3)
        qp = jnp.asarray(rng.integers(0, 2**32, (4, 1), dtype=np.uint32))
        rp = jnp.asarray(rng.integers(0, 2**32, (20, 1), dtype=np.uint32))
        z = jnp.zeros(4, jnp.int32)
        idx, vals = topk_hamming_banded_pallas(qp, rp, z, z, dim=32, k=3)
        assert (np.asarray(vals) == _SENTINEL).all()
        np.testing.assert_array_equal(np.asarray(idx),
                                      np.broadcast_to(np.arange(3), (4, 3)))

    def test_canonicalize_overflow_multi_band(self):
        """Two disjoint bands per query: overflow slots walk the three
        masked runs ([0,s0), [e0,s1), [e1,R)) in ascending order."""
        starts = jnp.asarray([[4], [10]], jnp.int32)   # (B=2, Q=1)
        ends = jnp.asarray([[5], [11]], jnp.int32)
        idx = jnp.asarray([[4, 10, -7, -7, -7]], jnp.int32)
        vals = jnp.asarray([[3, 1, _SENTINEL, _SENTINEL, _SENTINEL]],
                           jnp.int32)
        out = canonicalize_overflow_slots(idx, vals, starts, ends, 12)
        # masked rows ascending: 0,1,2,3, 5..9, 11
        np.testing.assert_array_equal(np.asarray(out), [[4, 10, 0, 1, 2]])


# --------------------------------------------------------------------------
# precursor index + candidate ranges == candidate_window_mask
# --------------------------------------------------------------------------

class TestPrecursorIndex:
    @settings(max_examples=10)
    @given(st.integers(1, 60), st.integers(1, 40), st.integers(0, 1))
    def test_ranges_select_exactly_the_window_mask(self, r, q, open_s):
        """For every query, the sorted rows inside the [start, len) ranges
        are exactly the rows candidate_window_mask keeps — strict bounds,
        both conventions, through the permutation."""
        rng = np.random.default_rng(r * 71 + q * 3 + open_s)
        ref_prec = rng.uniform(400, 1600, r).astype(np.float32)
        query_prec = rng.uniform(350, 1800, q).astype(np.float32)
        cfg = OMSConfig(tol=25.0, open_tol=180.0, open_search=bool(open_s))
        index = build_precursor_index(ref_prec)
        starts, lens = index.candidate_ranges(query_prec, cfg)
        mask = np.asarray(candidate_window_mask(
            jnp.asarray(query_prec), jnp.asarray(ref_prec), tol=cfg.tol,
            open_search=cfg.open_search, open_tol=cfg.open_tol))
        for i in range(q):
            rows = index.perm[starts[0, i]:starts[0, i] + lens[0, i]]
            assert set(rows.tolist()) == set(np.flatnonzero(mask[i]).tolist())

    def test_two_block_layout_keeps_decoys_first(self):
        rng = np.random.default_rng(9)
        tgt = rng.uniform(400, 1600, 15).astype(np.float32)
        dec = rng.uniform(400, 1600, 15).astype(np.float32)
        index = build_precursor_index(tgt, dec)
        assert index.block_bounds == (0, 15, 30)
        # decoy rows keep original indices < 15, targets >= 15: the global
        # order the decoy-wins-ties merge convention relies on
        assert (index.perm[:15] < 15).all() and (index.perm[15:] >= 15).all()
        # ascending within each block
        assert (np.diff(index.prec_sorted[:15]) >= 0).all()
        assert (np.diff(index.prec_sorted[15:]) >= 0).all()

    def test_empty_bank(self):
        index = build_precursor_index(np.asarray([], np.float32))
        assert index.num_rows == 0
        starts, lens = index.candidate_ranges(
            np.asarray([500.0], np.float32), OMSConfig())
        assert lens.sum() == 0

    def test_translate_indices_roundtrip(self):
        rng = np.random.default_rng(11)
        prec = rng.uniform(400, 1600, 20).astype(np.float32)
        index = build_precursor_index(prec)
        rows = np.arange(20)
        np.testing.assert_array_equal(
            np.sort(translate_indices(index, rows)), rows)


class TestOMSPlan:
    def test_plan_covers_every_band(self):
        """The invariant the kernel relies on: every query's band fits in
        num_tiles tiles starting at its Q block's lowest start tile."""
        rng = np.random.default_rng(13)
        prec = np.sort(rng.uniform(400, 1600, 700)).astype(np.float32)
        index = build_precursor_index(prec)
        qp = rng.uniform(450, 1550, 37).astype(np.float32)
        plan = plan_candidates(index, qp, OMSConfig(tol=10.0, open_tol=120.0),
                               num_rows_padded=768)
        bq, br = min(128, 40), 128
        for b in range(plan.starts.shape[0]):
            s, e = plan.starts[b], plan.starts[b] + plan.lens[b]
            for i in range(0, 37, bq):
                tb = int(s[i:i + bq].min()) // br
                assert int(e[i:i + bq].max()) <= (tb + plan.num_tiles) * br
        assert 0.0 < plan.candidate_fraction < 1.0
        assert 0.0 < plan.scanned_fraction <= 1.0

    def test_sorted_queries_shrink_the_scan(self):
        """Precursor-sorting the batch (what the server does) plus the
        serving path's narrow Q blocks keeps the scanned span near the
        window width — a genuine sub-linear scan, not a full pass."""
        rng = np.random.default_rng(17)
        prec = np.sort(rng.uniform(400, 1600, 2000)).astype(np.float32)
        index = build_precursor_index(prec)
        qp = rng.uniform(450, 1550, 64).astype(np.float32)
        cfg = OMSConfig(tol=5.0, open_tol=100.0)
        unsorted = plan_candidates(index, qp, cfg, num_rows_padded=2048,
                                   block_q=8)
        srt = plan_candidates(index, np.sort(qp), cfg, num_rows_padded=2048,
                              block_q=8)
        assert srt.num_tiles <= unsorted.num_tiles
        assert srt.scanned_fraction < 1.0

    def test_has_candidate_flags_empty_windows(self):
        prec = np.asarray([500.0, 510.0], np.float32)
        index = build_precursor_index(prec)
        plan = plan_candidates(index, np.asarray([505.0, 5000.0], np.float32),
                               OMSConfig(), num_rows_padded=128)
        np.testing.assert_array_equal(plan.has_candidate, [True, False])
        assert isinstance(plan, OMSPlan)
        assert isinstance(index, PrecursorIndex)
