import os

# Tests run single-device (the dry-run sets its own 512-device flag in its
# own process). Cap compilation parallelism noise on the 1-core container.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# hypothesis gate: the property tests in test_hd_encoding.py use a small
# slice of the hypothesis API (@given / @settings / st.integers). When the
# real package is absent (the pinned accelerator image does not ship it and
# installs are frozen), install a deterministic micro-shim into sys.modules
# so the suite still collects and the properties still run over a fixed
# pseudo-random sample of the strategy space. With hypothesis installed
# (e.g. in CI), the real engine is used and this block is a no-op.
# ---------------------------------------------------------------------------

def _install_hypothesis_shim():
    import functools
    import inspect
    import random
    import sys
    import types

    class _Integers:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def draw(self, rng, i):
            if i == 0:
                return self.lo
            if i == 1:
                return self.hi
            return rng.randint(self.lo, self.hi)

    class _SampledFrom:
        def __init__(self, options):
            self.options = list(options)

        def draw(self, rng, i):
            if i < len(self.options):
                return self.options[i]  # cover every option first
            return rng.choice(self.options)

    def given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 10)
                rng = random.Random(fn.__qualname__)
                for i in range(n):
                    drawn = [s.draw(rng, i) for s in strategies]
                    fn(*args, *drawn, **kwargs)
            # hide the strategy-filled params from pytest's fixture
            # resolution (the real hypothesis does the same)
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())[:-len(strategies)]
            wrapper.__signature__ = sig.replace(parameters=params)
            return wrapper
        return deco

    def settings(max_examples=10, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = lambda lo, hi: _Integers(lo, hi)
    st_mod.sampled_from = lambda options: _SampledFrom(options)
    mod.strategies = st_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod


try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - depends on environment
    _install_hypothesis_shim()
