import os

# Tests run single-device (the dry-run sets its own 512-device flag in its
# own process). Cap compilation parallelism noise on the 1-core container.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)
