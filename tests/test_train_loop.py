"""End-to-end trainer behaviour: loss goes down, checkpoint resume is exact,
microbatching is consistent, IMC-linear trains, and the hierarchical
ICI/DCN gradient reduction is equivalent to the global path (bit-identical
with ``dcn_compression='none'``, tolerance-tracking with int8/EF)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.tokens import TokenPipeline, synthetic_batch
from repro.models import build_model
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, schedule
from repro.train.train_step import TrainConfig, init_train_state, make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2_7b").reduced()
    model = build_model(cfg)
    pipe = TokenPipeline(batch=8, seq=64, vocab=cfg.vocab_size)
    return cfg, model, pipe


def _run(model, pipe, cfg, tcfg, steps, state=None, start=0,
         collect_metrics=False):
    if state is None:
        state, _ = init_train_state(model, jax.random.PRNGKey(0), tcfg)
    step_fn = jax.jit(make_train_step(model, tcfg))
    losses, metrics = [], []
    for s in range(start, steps):
        state, m = step_fn(state, pipe.get_for(cfg, s))
        losses.append(float(m["loss"]))
        metrics.append({k: float(v) for k, v in m.items()})
    if collect_metrics:
        return state, losses, metrics
    return state, losses


def _assert_states_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    for la, lb in zip(jax.tree.leaves(a.opt), jax.tree.leaves(b.opt)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_loss_decreases(setup):
    cfg, model, pipe = setup
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup_steps=2,
                                             total_steps=30))
    _, losses = _run(model, pipe, cfg, tcfg, 30)
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])


def test_microbatch_equivalence(setup):
    """4 microbatches must give (nearly) the same step as one big batch."""
    cfg, model, pipe = setup
    t1 = TrainConfig(optimizer=AdamWConfig(lr=1e-3))
    t4 = TrainConfig(optimizer=AdamWConfig(lr=1e-3), microbatches=4)
    s1, _ = _run(model, pipe, cfg, t1, 2)
    s4, _ = _run(model, pipe, cfg, t4, 2)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s4.params)):
        np.testing.assert_allclose(np.asarray(a, dtype=np.float32),
                                   np.asarray(b, dtype=np.float32),
                                   rtol=2e-3, atol=2e-5)


def test_cast_params_bf16_close_to_fp32(setup):
    cfg, model, pipe = setup
    t_fp = TrainConfig(optimizer=AdamWConfig(lr=1e-3))
    t_bf = TrainConfig(optimizer=AdamWConfig(lr=1e-3), cast_params_bf16=True)
    _, l_fp = _run(model, pipe, cfg, t_fp, 5)
    _, l_bf = _run(model, pipe, cfg, t_bf, 5)
    assert abs(l_fp[-1] - l_bf[-1]) < 0.1


def test_checkpoint_resume_exact(tmp_path, setup):
    """Train 6 steps straight vs 3 + save + restore + 3: identical params."""
    from repro.dist.checkpoint import CheckpointManager
    cfg, model, pipe = setup
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3))

    state_a, _ = _run(model, pipe, cfg, tcfg, 6)

    state_b, _ = _run(model, pipe, cfg, tcfg, 3)
    mgr = CheckpointManager(tmp_path)
    mgr.save(3, state_b)
    restored_step, state_c = mgr.restore_latest(state_b)
    assert restored_step == 3
    state_c, _ = _run(model, pipe, cfg, tcfg, 6, state=state_c, start=3)

    for a, b in zip(jax.tree.leaves(state_a.params),
                    jax.tree.leaves(state_c.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_imc_linear_trains(setup):
    """The paper's IMC-routed FFN down-projection must train stably."""
    import dataclasses
    cfg, model, pipe = setup
    cfg_imc = dataclasses.replace(cfg, imc_linear=True)
    model_imc = build_model(cfg_imc)
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup_steps=2,
                                             total_steps=20))
    _, losses = _run(model_imc, pipe, cfg_imc, tcfg, 20)
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] - 0.2


def test_grad_compression_trains(setup):
    cfg, model, pipe = setup
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup_steps=2,
                                             total_steps=15),
                       grad_compression="int8")
    _, losses = _run(model, pipe, cfg, tcfg, 15)
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] - 0.1


class TestHierarchicalDCN:
    """The hierarchical ICI/DCN reduction on emulated pod shards (tier-1,
    single device — the shard_map route runs in tests/test_multidevice.py).
    The reduction contract: grads arrive pre-psum per pod-slice, each
    pod's payload is compressed, the fold crosses pods in ascending pod
    order — with ``dcn_compression='none'`` that is the accumulate-then-
    psum global path, and must match it bit-for-bit."""

    @pytest.mark.parametrize("pods", [2, 4, 8])
    def test_none_bit_identical_to_global_psum(self, setup, pods):
        """On `pods` emulated shards, the hierarchical path with
        method='none' reproduces the global-psum step bit-for-bit
        (params, optimizer state, loss, grad_norm) over several steps."""
        cfg, model, pipe = setup
        opt = AdamWConfig(lr=1e-3)
        s_global, _, m_global = _run(
            model, pipe, cfg,
            TrainConfig(optimizer=opt, microbatches=pods), 3,
            collect_metrics=True)
        s_hier, _, m_hier = _run(
            model, pipe, cfg,
            TrainConfig(optimizer=opt, dcn_pods=pods), 3,
            collect_metrics=True)
        _assert_states_equal(s_global, s_hier)
        for mg, mh in zip(m_global, m_hier):
            assert mg["loss"] == mh["loss"]
            assert mg["grad_norm"] == mh["grad_norm"]

    def test_pods1_none_bit_identical_to_pre_hierarchy_step(self, setup):
        """Degradation: a size-1 pod axis collapses to the pre-hierarchy
        global step exactly (same single AD pass, no fold, no scaling)."""
        cfg, model, pipe = setup
        opt = AdamWConfig(lr=1e-3)
        s_old, _ = _run(model, pipe, cfg, TrainConfig(optimizer=opt), 3)
        s_new, _ = _run(model, pipe, cfg,
                        TrainConfig(optimizer=opt, dcn_pods=1,
                                    dcn_compression="none"), 3)
        _assert_states_equal(s_old, s_new)

    def test_hierarchy_composes_with_microbatches(self, setup):
        """pods=2 x microbatches=2 sees the same slices in the same order
        as the flat 4-way accumulation; only the 1/P scaling point
        differs, so states match to float tolerance."""
        cfg, model, pipe = setup
        opt = AdamWConfig(lr=1e-3)
        s_flat, _ = _run(model, pipe, cfg,
                         TrainConfig(optimizer=opt, microbatches=4), 2)
        s_hier, _ = _run(model, pipe, cfg,
                         TrainConfig(optimizer=opt, dcn_pods=2,
                                     microbatches=2), 2)
        for a, b in zip(jax.tree.leaves(s_flat.params),
                        jax.tree.leaves(s_hier.params)):
            np.testing.assert_allclose(np.asarray(a, dtype=np.float32),
                                       np.asarray(b, dtype=np.float32),
                                       rtol=2e-3, atol=2e-5)

    @pytest.mark.parametrize("method", ["int8", "topk_ef"])
    def test_compressed_tracks_uncompressed(self, setup, method):
        """int8/EF-top-k on 8 emulated pods track the uncompressed loss
        trajectory within tolerance over 20+ steps (EF keeps top-k
        unbiased across steps; int8 rounding is zero-mean)."""
        cfg, model, pipe = setup
        opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=25)
        frac = 0.25  # aggressive enough to hurt if EF were broken
        _, l_ref = _run(model, pipe, cfg,
                        TrainConfig(optimizer=opt, dcn_pods=8), 22)
        _, l_c = _run(model, pipe, cfg,
                      TrainConfig(optimizer=opt, dcn_pods=8,
                                  dcn_compression=method,
                                  dcn_topk_frac=frac), 22)
        assert np.isfinite(l_c).all()
        # same warm-start point, loss still goes down...
        assert l_c[-1] < l_c[0] - 0.3, (l_c[0], l_c[-1])
        # ...and the trajectory stays close to the uncompressed one
        dev = np.abs(np.asarray(l_c) - np.asarray(l_ref)).max()
        assert dev < 0.25, (dev, method)

    def test_ef_state_carried_and_conserved(self, setup):
        """TrainState.ef is per-pod, nonzero after a step, and one more
        step keeps the EF invariant: what was not sent is exactly what
        the residual holds (checked through the jitted step)."""
        cfg, model, pipe = setup
        tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3), dcn_pods=2,
                           dcn_compression="topk_ef", dcn_topk_frac=0.1)
        state, _ = init_train_state(model, jax.random.PRNGKey(0), tcfg)
        assert all(l.shape[0] == 2 for l in jax.tree.leaves(state.ef))
        assert all(float(jnp.abs(l).max()) == 0.0
                   for l in jax.tree.leaves(state.ef))
        step_fn = jax.jit(make_train_step(model, tcfg))
        state, _ = step_fn(state, pipe.get_for(cfg, 0))
        assert sum(float(jnp.abs(l).sum())
                   for l in jax.tree.leaves(state.ef)) > 0.0

    def test_dcn_bytes_metric(self, setup):
        """The step reports its wire footprint: none == raw fp32 bytes,
        int8 ~4x smaller, EF-top-k >=4x smaller (the acceptance bar)."""
        cfg, model, pipe = setup
        opt = AdamWConfig(lr=1e-3)
        byt = {}
        for method in ("none", "int8", "topk_ef"):
            _, _, ms = _run(model, pipe, cfg,
                            TrainConfig(optimizer=opt, dcn_pods=2,
                                        dcn_compression=method), 1,
                            collect_metrics=True)
            byt[method] = ms[0]["dcn_bytes"]
            assert ms[0]["dcn_raw_bytes"] == byt["none"] or method == "none"
        assert byt["none"] > 0
        assert byt["none"] / byt["int8"] > 3.9
        assert byt["none"] / byt["topk_ef"] >= 4.0

    def test_checkpoint_roundtrip_with_ef(self, tmp_path, setup):
        """EF residuals are part of TrainState: save/restore mid-run and
        the continued trajectory is identical to an uninterrupted one."""
        from repro.dist.checkpoint import CheckpointManager
        cfg, model, pipe = setup
        tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3), dcn_pods=2,
                           dcn_compression="topk_ef")
        s_a, _ = _run(model, pipe, cfg, tcfg, 4)
        s_b, _ = _run(model, pipe, cfg, tcfg, 2)
        mgr = CheckpointManager(tmp_path)
        mgr.save(2, s_b)
        _, s_c = mgr.restore_latest(s_b)
        s_c, _ = _run(model, pipe, cfg, tcfg, 4, state=s_c, start=2)
        _assert_states_equal(s_a, s_c)
        for la, lb in zip(jax.tree.leaves(s_a.ef), jax.tree.leaves(s_c.ef)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


class TestSeedDeterminism:
    """Same seed => bit-identical metrics across two runs (regression
    gate for the per-step rounding-key threading)."""

    @pytest.mark.parametrize("kw", [
        dict(),
        dict(microbatches=4, remat="none"),
        dict(dcn_pods=4, dcn_compression="int8"),
        dict(dcn_pods=2, dcn_compression="topk_ef", microbatches=2,
             remat="dots"),
    ], ids=["plain", "microbatch-noremat", "hier-int8", "hier-ef-mb-dots"])
    def test_same_seed_same_metrics(self, setup, kw):
        cfg, model, pipe = setup
        tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3), **kw)
        _, _, m1 = _run(model, pipe, cfg, tcfg, 3, collect_metrics=True)
        _, _, m2 = _run(model, pipe, cfg, tcfg, 3, collect_metrics=True)
        assert m1 == m2

    def test_different_seed_different_rounding(self, setup):
        cfg, model, pipe = setup
        base = dict(optimizer=AdamWConfig(lr=1e-3), dcn_pods=2,
                    dcn_compression="int8")
        _, l0 = _run(model, pipe, cfg, TrainConfig(**base, seed=0), 2)
        _, l1 = _run(model, pipe, cfg, TrainConfig(**base, seed=1), 2)
        assert l0[1] != l1[1]  # step-1 loss sees step-0's rounding noise


def test_serve_step_factories_match_model(setup):
    """The serving-step factories are thin shims over the model API:
    prefill returns only the last position, decode matches the model."""
    from repro.train.serve_step import make_decode_step, make_prefill
    cfg, model, _ = setup
    state, _ = init_train_state(model, jax.random.PRNGKey(0))
    pipe = TokenPipeline(batch=2, seq=16, vocab=cfg.vocab_size)
    batch = pipe.get_for(cfg, 0)
    cache = model.init_cache(2, 16)
    logits, cache = make_prefill(model)(state.params, batch, cache)
    assert logits.shape == (2, 1, cfg.padded_vocab)
    ref, _ = model.prefill(state.params, batch, model.init_cache(2, 16))
    np.testing.assert_array_equal(np.asarray(logits),
                                  np.asarray(ref[:, -1:]))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, _ = make_decode_step(model)(
        state.params, tok, cache, jnp.asarray(15, jnp.int32))
    assert logits2.shape == (2, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits2)).all()


class TestOptimizer:
    def test_schedule_warmup_and_decay(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
        assert float(schedule(cfg, jnp.asarray(0))) == 0.0
        assert float(schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
        assert float(schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1)

    def test_clip_norm(self):
        cfg = AdamWConfig(lr=0.0, clip_norm=1.0, weight_decay=0.0)
        params = {"w": jnp.ones((4,))}
        st = adamw_init(params)
        huge = {"w": jnp.full((4,), 1e6)}
        _, _, metrics = adamw_update(cfg, params, huge, st)
        assert float(metrics["grad_norm"]) == pytest.approx(2e6)

    def test_weight_decay_shrinks(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=1.0, warmup_steps=0,
                          total_steps=10)
        params = {"w": jnp.ones((4,))}
        st = adamw_init(params)
        zero = {"w": jnp.zeros((4,))}
        new, _, _ = adamw_update(cfg, params, zero, st)
        assert float(new["w"][0]) < 1.0


def test_synthetic_batch_deterministic():
    a = synthetic_batch(jnp.asarray(3), 4, 16, 1000)["tokens"]
    b = synthetic_batch(jnp.asarray(3), 4, 16, 1000)["tokens"]
    c = synthetic_batch(jnp.asarray(4), 4, 16, 1000)["tokens"]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))
    assert int(a.max()) < 1000 and int(a.min()) >= 0


def test_synthetic_batch_zipf_skew():
    t = np.asarray(synthetic_batch(jnp.asarray(0), 64, 256, 10_000)["tokens"])
    # cubed-uniform transform concentrates mass at small ids
    assert (t < 1250).mean() > 0.45
