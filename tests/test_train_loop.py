"""End-to-end trainer behaviour: loss goes down, checkpoint resume is exact,
microbatching is consistent, IMC-linear trains."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.tokens import TokenPipeline, synthetic_batch
from repro.models import build_model
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, schedule
from repro.train.train_step import TrainConfig, init_train_state, make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2_7b").reduced()
    model = build_model(cfg)
    pipe = TokenPipeline(batch=8, seq=64, vocab=cfg.vocab_size)
    return cfg, model, pipe


def _run(model, pipe, cfg, tcfg, steps, state=None, start=0):
    if state is None:
        state, _ = init_train_state(model, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(model, tcfg))
    losses = []
    for s in range(start, steps):
        state, m = step_fn(state, pipe.get_for(cfg, s))
        losses.append(float(m["loss"]))
    return state, losses


def test_loss_decreases(setup):
    cfg, model, pipe = setup
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup_steps=2,
                                             total_steps=30))
    _, losses = _run(model, pipe, cfg, tcfg, 30)
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])


def test_microbatch_equivalence(setup):
    """4 microbatches must give (nearly) the same step as one big batch."""
    cfg, model, pipe = setup
    t1 = TrainConfig(optimizer=AdamWConfig(lr=1e-3))
    t4 = TrainConfig(optimizer=AdamWConfig(lr=1e-3), microbatches=4)
    s1, _ = _run(model, pipe, cfg, t1, 2)
    s4, _ = _run(model, pipe, cfg, t4, 2)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s4.params)):
        np.testing.assert_allclose(np.asarray(a, dtype=np.float32),
                                   np.asarray(b, dtype=np.float32),
                                   rtol=2e-3, atol=2e-5)


def test_cast_params_bf16_close_to_fp32(setup):
    cfg, model, pipe = setup
    t_fp = TrainConfig(optimizer=AdamWConfig(lr=1e-3))
    t_bf = TrainConfig(optimizer=AdamWConfig(lr=1e-3), cast_params_bf16=True)
    _, l_fp = _run(model, pipe, cfg, t_fp, 5)
    _, l_bf = _run(model, pipe, cfg, t_bf, 5)
    assert abs(l_fp[-1] - l_bf[-1]) < 0.1


def test_checkpoint_resume_exact(tmp_path, setup):
    """Train 6 steps straight vs 3 + save + restore + 3: identical params."""
    from repro.dist.checkpoint import CheckpointManager
    cfg, model, pipe = setup
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3))

    state_a, _ = _run(model, pipe, cfg, tcfg, 6)

    state_b, _ = _run(model, pipe, cfg, tcfg, 3)
    mgr = CheckpointManager(tmp_path)
    mgr.save(3, state_b)
    restored_step, state_c = mgr.restore_latest(state_b)
    assert restored_step == 3
    state_c, _ = _run(model, pipe, cfg, tcfg, 6, state=state_c, start=3)

    for a, b in zip(jax.tree.leaves(state_a.params),
                    jax.tree.leaves(state_c.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_imc_linear_trains(setup):
    """The paper's IMC-routed FFN down-projection must train stably."""
    import dataclasses
    cfg, model, pipe = setup
    cfg_imc = dataclasses.replace(cfg, imc_linear=True)
    model_imc = build_model(cfg_imc)
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup_steps=2,
                                             total_steps=20))
    _, losses = _run(model_imc, pipe, cfg_imc, tcfg, 20)
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] - 0.2


def test_grad_compression_trains(setup):
    cfg, model, pipe = setup
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup_steps=2,
                                             total_steps=15),
                       grad_compression="int8")
    _, losses = _run(model, pipe, cfg, tcfg, 15)
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] - 0.1


class TestOptimizer:
    def test_schedule_warmup_and_decay(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
        assert float(schedule(cfg, jnp.asarray(0))) == 0.0
        assert float(schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
        assert float(schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1)

    def test_clip_norm(self):
        cfg = AdamWConfig(lr=0.0, clip_norm=1.0, weight_decay=0.0)
        params = {"w": jnp.ones((4,))}
        st = adamw_init(params)
        huge = {"w": jnp.full((4,), 1e6)}
        _, _, metrics = adamw_update(cfg, params, huge, st)
        assert float(metrics["grad_norm"]) == pytest.approx(2e6)

    def test_weight_decay_shrinks(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=1.0, warmup_steps=0,
                          total_steps=10)
        params = {"w": jnp.ones((4,))}
        st = adamw_init(params)
        zero = {"w": jnp.zeros((4,))}
        new, _, _ = adamw_update(cfg, params, zero, st)
        assert float(new["w"][0]) < 1.0


def test_synthetic_batch_deterministic():
    a = synthetic_batch(jnp.asarray(3), 4, 16, 1000)["tokens"]
    b = synthetic_batch(jnp.asarray(3), 4, 16, 1000)["tokens"]
    c = synthetic_batch(jnp.asarray(4), 4, 16, 1000)["tokens"]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))
    assert int(a.max()) < 1000 and int(a.min()) >= 0


def test_synthetic_batch_zipf_skew():
    t = np.asarray(synthetic_batch(jnp.asarray(0), 64, 256, 10_000)["tokens"])
    # cubed-uniform transform concentrates mass at small ids
    assert (t < 1250).mean() > 0.45
