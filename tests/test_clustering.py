"""Complete-linkage clustering vs the scipy oracle + metric tests."""

import jax.numpy as jnp
import numpy as np
import pytest
from scipy.cluster.hierarchy import fcluster, linkage
from scipy.spatial.distance import squareform

from repro.core.hd.clustering import (
    clustered_spectra_ratio,
    complete_linkage,
    incorrect_clustering_ratio,
    pairwise_distances,
)


def _labels_agree(a: np.ndarray, b: np.ndarray) -> bool:
    """Same partition up to label permutation."""
    pairs_a = a[:, None] == a[None, :]
    pairs_b = b[:, None] == b[None, :]
    return bool((pairs_a == pairs_b).all())


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("n", [8, 20, 40])
def test_matches_scipy_complete_linkage(seed, n):
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(n, 4))
    d = np.sqrt(((pts[:, None] - pts[None, :]) ** 2).sum(-1))
    thr = np.median(d) * 0.7

    res = complete_linkage(jnp.asarray(d, jnp.float32), thr)
    ours = np.asarray(res.labels)

    z = linkage(squareform(d, checks=False), method="complete")
    ref = fcluster(z, t=thr, criterion="distance")
    assert _labels_agree(ours, ref)


def test_threshold_extremes():
    rng = np.random.default_rng(0)
    d = rng.uniform(1, 2, (10, 10))
    d = (d + d.T) / 2
    np.fill_diagonal(d, 0)
    all_merge = complete_linkage(jnp.asarray(d, jnp.float32), 100.0)
    assert int(all_merge.num_clusters) == 1
    none_merge = complete_linkage(jnp.asarray(d, jnp.float32), 0.5)
    assert int(none_merge.num_clusters) == 10


def test_pairwise_distance_properties():
    rng = np.random.default_rng(1)
    hv = jnp.asarray(rng.choice([-1, 1], (12, 256)).astype(np.int8))
    d = np.asarray(pairwise_distances(hv))
    assert np.allclose(d, d.T)
    assert np.allclose(np.diag(d), 0)
    assert (d >= 0).all()
    # identical vectors at distance 0
    hv2 = jnp.concatenate([hv[:1], hv[:1]], 0)
    d2 = np.asarray(pairwise_distances(hv2))
    assert d2[0, 1] == 0


def test_pairwise_distances_packed_kernel_bit_identical():
    """The uint32 fast path (hamming_pop Pallas kernel) must equal the
    einsum path on the unpacked bipolar vectors exactly — both count
    disagreeing positions, one via popcount, one via (D - <a,b>) / 2."""
    from repro.core.hd.similarity import bitpack_bipolar

    rng = np.random.default_rng(7)
    hv = jnp.asarray(rng.choice([-1, 1], (20, 256)).astype(np.int8))
    dense = np.asarray(pairwise_distances(hv))
    packed = np.asarray(pairwise_distances(bitpack_bipolar(hv), dim=256))
    np.testing.assert_array_equal(dense, packed)
    # and clustering over either matrix is the same partition
    ra = complete_linkage(jnp.asarray(dense), 100.0)
    rb = complete_linkage(jnp.asarray(packed), 100.0)
    np.testing.assert_array_equal(np.asarray(ra.labels), np.asarray(rb.labels))


def _complete_linkage_numpy(d: np.ndarray, thr: float):
    """Straightforward host-side reference of the merge loop (argmin over
    the masked matrix, elementwise-max row merge, lowest-index labels)."""
    n = d.shape[0]
    big = np.finfo(np.float32).max
    dm = d.astype(np.float32).copy()
    np.fill_diagonal(dm, big)
    labels = np.arange(n, dtype=np.int32)
    active = np.ones(n, bool)
    merges = 0
    while True:
        md = np.where(active[:, None] & active[None, :], dm, big)
        np.fill_diagonal(md, big)
        flat = int(md.argmin())
        if md.flat[flat] > thr:
            break
        i, j = flat // n, flat % n
        lo, hi = min(i, j), max(i, j)
        newrow = np.maximum(dm[lo], dm[hi])
        dm[lo, :] = newrow
        dm[:, lo] = newrow
        dm[lo, lo] = big
        active[hi] = False
        labels[labels == hi] = lo
        merges += 1
    return labels, merges, int(active.sum())


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_complete_linkage_carry_restructure_no_behavior_change(seed):
    """The masked-matrix-in-carry while loop (one masked() per merge) must
    reproduce the straightforward reference exactly on fixed seeds."""
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(24, 3))
    d = np.sqrt(((pts[:, None] - pts[None, :]) ** 2).sum(-1)).astype(np.float32)
    thr = float(np.median(d)) * 0.8
    res = complete_linkage(jnp.asarray(d), thr)
    ref_labels, ref_merges, ref_clusters = _complete_linkage_numpy(d, thr)
    np.testing.assert_array_equal(np.asarray(res.labels), ref_labels)
    assert int(res.num_merges) == ref_merges
    assert int(res.num_clusters) == ref_clusters


def test_quality_metrics():
    labels = jnp.asarray([0, 0, 2, 2, 4, 5], jnp.int32)
    assert float(clustered_spectra_ratio(labels)) == pytest.approx(4 / 6)
    truth_good = jnp.asarray([1, 1, 2, 2, 3, 4], jnp.int32)
    assert float(incorrect_clustering_ratio(labels, truth_good)) == 0.0
    truth_bad = jnp.asarray([1, 2, 2, 2, 3, 4], jnp.int32)
    # cluster {0,1} has mixed truth; exactly one of its members disagrees
    # with the majority -> 1 wrong out of 4 clustered
    assert float(incorrect_clustering_ratio(labels, truth_bad)) == pytest.approx(1 / 4)
