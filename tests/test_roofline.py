"""Roofline parser unit tests (HLO collective-bytes extraction)."""

import pytest

from repro.launch.roofline import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    HardwareProfile,
    RooflineReport,
    active_profile,
    collective_bytes,
)

HLO_FLAT = """
HloModule jit_f, entry_computation_layout={(f32[16,64]{1,0})->f32[16,64]{1,0}}

%add.clone (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %add.1 = f32[] add(%x, %y)
}

ENTRY %main (p0: f32[16,64]) -> f32[16,64] {
  %p0 = f32[16,64]{1,0} parameter(0)
  %dot = f32[16,64]{1,0} dot(%p0, %p0)
  ROOT %all-reduce = f32[16,64]{1,0} all-reduce(%dot), replica_groups=[2,4]<=[8], to_apply=%add.clone
}
"""

HLO_WHILE = """
HloModule jit_g

%add (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %a = f32[] add(%x, %y)
}

%cond (s: (s32[], f32[8,8])) -> pred[] {
  %s = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%s), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (s: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %s = (s32[], f32[8,8]{1,0}) parameter(0)
  %x = f32[8,8]{1,0} get-tuple-element(%s), index=1
  %ar = f32[8,8]{1,0} all-reduce(%x), to_apply=%add
  %i = s32[] get-tuple-element(%s), index=0
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]{1,0}) tuple(%ip, %ar)
}

ENTRY %main (p0: f32[8,8]) -> f32[8,8] {
  %p0 = f32[8,8]{1,0} parameter(0)
  %init = (s32[], f32[8,8]{1,0}) tuple(s32[] constant(0), %p0)
  %w = (s32[], f32[8,8]{1,0}) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_flat_all_reduce_counted_once():
    out = collective_bytes(HLO_FLAT)
    assert out["all-reduce"] == 16 * 64 * 4
    assert out["all-gather"] == 0


def test_while_body_multiplied_by_trip_count():
    out = collective_bytes(HLO_WHILE)
    assert out["all-reduce"] == 5 * 8 * 8 * 4


def test_inline_operand_types_preferred():
    hlo = """
ENTRY %main () -> f32[4] {
  %x = f32[4]{0} parameter(0)
  ROOT %ag = f32[16]{0} all-gather(f32[4]{0} %x), dimensions={0}
}
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 16  # operand bytes (4 f32), not result (16 f32)


def test_async_pairs_counted_once():
    hlo = """
ENTRY %main () -> f32[4] {
  %x = f32[4]{0} parameter(0)
  %s = f32[4]{0} all-reduce-start(%x), to_apply=%add
  ROOT %d = f32[4]{0} all-reduce-done(%s)
}
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 16


def test_sub_byte_types_priced_at_half_byte():
    """s4/u4 operands must cost 0.5 bytes per element, not 1 (satellite-2
    regression: packed-int4 traffic was double-counted)."""
    hlo = """
ENTRY %main () -> s4[16,64] {
  %x = s4[16,64]{1,0} parameter(0)
  ROOT %ar = s4[16,64]{1,0} all-reduce(s4[16,64]{1,0} %x), to_apply=%add
}
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 16 * 64 * 0.5


def test_sub_byte_exec_cost_memory_term():
    from repro.launch.roofline import exec_cost
    hlo = """
ENTRY %main () -> s4[128] {
  %x = s4[128]{0} parameter(0)
  ROOT %n = s4[128]{0} negate(s4[128]{0} %x)
}
"""
    _, b = exec_cost(hlo)
    assert b == 128 * 0.5 * 2  # operand + result, 4 bits each


def test_roofline_report_terms():
    r = RooflineReport(
        flops=PEAK_FLOPS, hbm_bytes=HBM_BW / 2, coll_bytes=ICI_BW / 4,
        coll_breakdown={}, chips=4, t_compute=1.0, t_memory=0.5,
        t_collective=0.25, bottleneck="compute", model_flops=PEAK_FLOPS * 2)
    assert r.step_time_lower_bound == 1.0
    assert r.mfu_bound == pytest.approx(0.5)


def test_mfu_bound_uses_report_ceiling():
    r = RooflineReport(
        flops=1e9, hbm_bytes=1.0, coll_bytes=0.0, coll_breakdown={},
        chips=1, t_compute=1.0, t_memory=0.1, t_collective=0.0,
        bottleneck="compute", model_flops=1e10, peak_flops=1e10,
        profile_source="measured")
    assert r.mfu_bound == pytest.approx(1.0)
    assert r.to_dict()["profile_source"] == "measured"


class TestHardwareProfile:
    def test_defaults_match_v5e_constants(self):
        p = HardwareProfile()
        assert (p.peak_flops, p.hbm_bw, p.ici_bw) == (
            PEAK_FLOPS, HBM_BW, ICI_BW)
        assert p.source == "default:v5e"

    def test_active_profile_defaults_without_table(self):
        from repro.tune import table as tune_table
        tune_table.reset()
        try:
            assert active_profile() == HardwareProfile()
        finally:
            tune_table.reset()

    def test_active_profile_uses_measured_ceilings(self):
        from repro.tune import table as tune_table
        from repro.tune.table import TuningTable, device_kind, \
            set_active_table
        tune_table.reset()
        try:
            set_active_table(TuningTable(
                device_kind=device_kind(),
                ceilings={"peak_flops": 3.0e12, "hbm_bw": 4.0e11}))
            p = active_profile()
            assert p.source == "measured"
            assert p.peak_flops == 3.0e12
            assert p.hbm_bw == 4.0e11
            assert p.ici_bw == ICI_BW  # never measured single-host
        finally:
            tune_table.reset()

    def test_mismatched_kind_table_keeps_defaults(self):
        from repro.tune import table as tune_table
        from repro.tune.table import TuningTable, set_active_table
        tune_table.reset()
        try:
            set_active_table(TuningTable(
                device_kind="TPU v99",
                ceilings={"peak_flops": 1.0, "hbm_bw": 1.0}))
            assert active_profile() == HardwareProfile()
        finally:
            tune_table.reset()


def test_model_flops_estimate_orders():
    from repro.configs import SHAPES, get_config
    from repro.launch.roofline import model_flops_estimate
    cfg = get_config("qwen2_7b")
    train = model_flops_estimate(cfg, SHAPES["train_4k"])
    decode = model_flops_estimate(cfg, SHAPES["decode_32k"])
    # ~7.1B active params x 6 x 1.05M tokens -> ~4.5e16 model flops
    assert 1e16 < train < 1e17
    assert decode < train / 1e3


class TestExecCost:
    def test_scan_multiplies_flops(self):
        import jax
        import jax.numpy as jnp
        from repro.launch.roofline import exec_cost

        def one(x, w):
            return x @ w

        def scanned(x, w):
            def body(c, _):
                return c @ w, None
            out, _ = jax.lax.scan(body, x, None, length=10)
            return out

        xs = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        ws = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        f1, _ = exec_cost(jax.jit(one).lower(xs, ws).compile().as_text())
        f10, _ = exec_cost(jax.jit(scanned).lower(xs, ws).compile().as_text())
        assert f1 == pytest.approx(2 * 256**3, rel=0.01)
        assert f10 == pytest.approx(10 * f1, rel=0.01)

    def test_dus_counts_update_not_buffer(self):
        import jax
        import jax.numpy as jnp
        from repro.launch.roofline import exec_cost

        def f(buf, upd):
            def body(b, i):
                return jax.lax.dynamic_update_index_in_dim(b, upd, i, 0), None
            out, _ = jax.lax.scan(body, buf, jnp.arange(64))
            return out

        buf = jax.ShapeDtypeStruct((64, 1024, 1024), jnp.float32)
        upd = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
        _, b = exec_cost(jax.jit(f).lower(buf, upd).compile().as_text())
        buffer_bytes = 64 * 1024 * 1024 * 4
        # traffic must scale with 64 updates x slice, NOT 64 x full buffer
        assert b < 10 * buffer_bytes
