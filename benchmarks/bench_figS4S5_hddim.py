"""Paper Fig. S4/S5: quality vs HD dimension for DB search and clustering
(+ the linear latency/energy scaling the paper notes)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import SpecPCMConfig, run_clustering, run_db_search
from repro.core.imc.energy import DATASETS, db_search_cost
from repro.spectra import SyntheticMSConfig, generate_dataset
from repro.spectra.synthetic import generate_query_set


def run(quick: bool = False) -> None:
    ms = SyntheticMSConfig(num_identities=32, spectra_per_identity=6,
                           num_bins=1024, dropout=0.3, intensity_jitter=0.4,
                           noise_peaks=24, peaks_per_peptide=32)
    ds = generate_dataset(ms)
    refs = ds.templates / jnp.maximum(ds.templates.max(1, keepdims=True), 1e-6)
    ref_prec = jnp.asarray(np.asarray(ds.precursor)[::ms.spectra_per_identity])
    q = generate_query_set(ds, ms, num_queries=64)
    d = DATASETS["HEK293"]

    for dim in (513, 1026, 2049, 4098, 8193):
        cfg = SpecPCMConfig(hd_dim=dim, mlc_bits=3, num_levels=16,
                            material="tite2", write_verify=3)
        rep = run_db_search(q.spectra, q.precursor, refs, ref_prec, cfg,
                            query_identity=q.identity,
                            ref_identity=jnp.arange(ms.num_identities))
        cost = db_search_cost(d["num_queries"], d["num_refs"], hd_dim=dim,
                              candidate_fraction=d["candidate_fraction"])
        emit(f"figS4/dim{dim}/recall", f"{rep.recall:.3f}",
             f"hek293_latency_s={cost.latency_s:.4f}")

    for dim in (513, 1026, 2049):
        cfg = SpecPCMConfig(hd_dim=dim, mlc_bits=3, num_levels=16,
                            material="sb2te3")
        rep = run_clustering(ds.spectra, ds.precursor, ds.identity, cfg)
        emit(f"figS5/dim{dim}/clustered_ratio", f"{rep.clustered_ratio:.4f}",
             f"incorrect={rep.incorrect_ratio:.4f}")


if __name__ == "__main__":
    run()
