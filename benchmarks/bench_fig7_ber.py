"""Paper Fig. 7: bit error rate vs write-verify cycles (3-bit MLC)."""

from __future__ import annotations

from benchmarks.common import emit
from repro.core.imc.device import DeviceConfig, bit_error_rate


def run() -> None:
    for material in ("tite2", "sb2te3"):
        for c in range(7):
            ber = bit_error_rate(DeviceConfig(material, 3, c))
            emit(f"fig7/{material}/wv{c}/ber", f"{ber:.4f}",
                 "decreases_with_write_verify")


if __name__ == "__main__":
    run()
