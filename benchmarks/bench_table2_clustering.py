"""Paper Table 2: clustering latency/speedup vs published baselines.

Our analytic hardware model (calibrated once, EXPERIMENTS.md §Tables) is
evaluated on the paper's two dataset scales and compared against the
paper's published baseline and SpecPCM numbers.
"""

from __future__ import annotations

from benchmarks.common import emit
from repro.core.imc.energy import DATASETS, PAPER_TABLE2, clustering_cost


def run() -> None:
    for ds in ("PXD001468", "PXD000561"):
        n = DATASETS[ds]["num_spectra"]
        ours = clustering_cost(n)
        falcon = PAPER_TABLE2[ds]["Falcon(CPU)"]
        paper = PAPER_TABLE2[ds]["SpecPCM(paper)"]
        emit(f"table2/{ds}/model_latency_s", f"{ours.latency_s:.3f}",
             f"paper={paper:.2f}s err={abs(ours.latency_s - paper) / paper:.1%}")
        emit(f"table2/{ds}/speedup_vs_falcon", f"{falcon / ours.latency_s:.1f}",
             f"paper_claims={falcon / paper:.1f}x")
        emit(f"table2/{ds}/energy_j", f"{ours.energy_j:.3f}",
             "paper=3.27J" if ds == "PXD000561" else "")
        for tool, lat in PAPER_TABLE2[ds].items():
            emit(f"table2/{ds}/baseline/{tool}", f"{lat:.3f}", "published")


if __name__ == "__main__":
    run()
