"""Benchmark orchestrator: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Set REPRO_BENCH_QUICK=1 for
the fast path (used by CI/tests)."""

from __future__ import annotations

import os
import sys
import time
import traceback


def main() -> None:
    quick = os.environ.get("REPRO_BENCH_QUICK", "0") == "1"
    from benchmarks import (
        bench_dryrun_roofline,
        bench_fig10_dbsearch_quality,
        bench_fig7_ber,
        bench_fig9_clustering_quality,
        bench_figS3_tradeoffs,
        bench_figS4S5_hddim,
        bench_kernels,
        bench_table2_clustering,
        bench_table3_dbsearch,
    )

    suites = [
        ("table2_clustering", bench_table2_clustering.run, {}),
        ("table3_dbsearch", bench_table3_dbsearch.run, {}),
        ("fig7_ber", bench_fig7_ber.run, {}),
        ("fig9_clustering_quality", bench_fig9_clustering_quality.run,
         {"quick": quick}),
        ("fig10_dbsearch_quality", bench_fig10_dbsearch_quality.run,
         {"quick": quick}),
        ("figS3_tradeoffs", bench_figS3_tradeoffs.run, {"quick": quick}),
        ("figS4S5_hddim", bench_figS4S5_hddim.run, {"quick": quick}),
        ("kernels", bench_kernels.run, {"quick": quick}),
        ("dryrun_roofline", bench_dryrun_roofline.run, {}),
    ]

    print("name,us_per_call,derived")
    failures = 0
    for name, fn, kw in suites:
        t0 = time.time()
        try:
            fn(**kw)
            print(f"suite/{name},{(time.time() - t0) * 1e6:.0f},ok",
                  flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"suite/{name},0,FAILED", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
