"""Shared benchmark utilities: timing + CSV row emission."""

from __future__ import annotations

import time

import jax


def time_call(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-time of fn(*args) in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float | str, derived: str = "") -> None:
    print(f"{name},{us_per_call},{derived}", flush=True)
