"""Roofline summary from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads artifacts/dryrun/*.json (produced by repro.launch.dryrun) and emits
one row per (arch, shape, mesh) with the three roofline terms; does not
compile anything itself."""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit

ART = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


def run(quick: bool = False) -> None:
    if not ART.exists():
        emit("roofline/no_artifacts", "0",
             "run: python -m repro.launch.dryrun --all --mesh both")
        return
    for f in sorted(ART.glob("*.json")):
        d = json.loads(f.read_text())
        tag = f"{d['arch']}/{d['shape']}/{d['mesh']}"
        if d["status"] == "skipped":
            emit(f"roofline/{tag}", "skip", d.get("reason", ""))
            continue
        if d["status"] != "ok":
            emit(f"roofline/{tag}", "FAIL", d.get("error", "")[:80])
            continue
        r = d["roofline"]
        lb = r["step_time_lower_bound"]
        emit(f"roofline/{tag}", f"{lb * 1e6:.1f}",
             f"bottleneck={r['bottleneck']};compute_s={r['t_compute']:.4g};"
             f"memory_s={r['t_memory']:.4g};collective_s={r['t_collective']:.4g};"
             f"mfu_bound={r['mfu_bound']:.3f}")


if __name__ == "__main__":
    run()
