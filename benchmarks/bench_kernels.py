"""Kernel micro-benchmarks: host wall-time of the jnp reference paths (the
measurable quantity on CPU) + the bit-packed beyond-paper path, with the
derived column carrying the analytic IMC-chip numbers for the same op."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core.hd.similarity import (
    bitpack_bipolar,
    dot_similarity,
    hamming_similarity_packed,
    topk_search_packed,
)
from repro.core.imc.array import ArrayConfig, default_full_scale
from repro.core.imc.energy import DEFAULT_HW, stripes
from repro.kernels.imc_mvm.ref import imc_mvm_ref
from repro.kernels.topk_hamming import topk_hamming_pallas


def run(quick: bool = False) -> None:
    rng = np.random.default_rng(0)
    qn, rn, d = (64, 2048, 2049) if quick else (128, 8192, 8193)
    dp = d // 3

    q = jnp.asarray(rng.integers(-3, 4, (qn, dp)).astype(np.float32))
    w = jnp.asarray(rng.integers(-3, 4, (rn, dp)).astype(np.float32))
    fs = default_full_scale(ArrayConfig())

    f_imc = jax.jit(lambda a, b: imc_mvm_ref(a, b, full_scale=fs))
    us = time_call(f_imc, q, w)
    ops = qn * (-(-rn // 128)) * stripes(dp)
    chip_us = ops * DEFAULT_HW.cycles_per_mvm / DEFAULT_HW.parallel_arrays \
        / DEFAULT_HW.clock_hz * 1e6
    emit("kernels/imc_mvm_ref_cpu", f"{us:.1f}",
         f"Q={qn};R={rn};Dp={dp};modeled_chip_us={chip_us:.1f}")

    # dense int path (what a GPU/TPU baseline does)
    a8 = jnp.asarray(rng.choice([-1, 1], (qn, d)).astype(np.int8))
    b8 = jnp.asarray(rng.choice([-1, 1], (rn, d)).astype(np.int8))
    f_dense = jax.jit(dot_similarity)
    us_dense = time_call(f_dense, a8, b8)
    emit("kernels/dense_dot_int8_cpu", f"{us_dense:.1f}", f"Q={qn};R={rn};D={d}")

    # bit-packed popcount path (beyond-paper, 32x less traffic)
    d32 = (d // 32) * 32
    ap = bitpack_bipolar(a8[:, :d32])
    bp = bitpack_bipolar(b8[:, :d32])
    f_pop = jax.jit(lambda x, y: hamming_similarity_packed(x, y, d32))
    us_pop = time_call(f_pop, ap, bp)
    emit("kernels/hamming_popcount_cpu", f"{us_pop:.1f}",
         f"Q={qn};R={rn};D={d32};speedup_vs_dense={us_dense / us_pop:.2f}x")

    # top-k DB-search hot path, fused vs unfused: the unfused path
    # materializes the (Q, R) int32 score matrix in HBM before lax.top_k;
    # the fused kernel streams tiles through a VMEM running top-k and
    # only ever writes (Q, k). On CPU the fused kernel runs in interpret
    # mode (a correctness artifact, not perf), so the timed row is the
    # unfused search it replaces and the derived column carries the
    # analytic per-call HBM-traffic reduction.
    kk = 8
    f_topk = jax.jit(lambda x, y: topk_search_packed(x, y, d32, kk))
    us_topk = time_call(f_topk, ap, bp)
    score_bytes = qn * rn * 4
    fused_bytes = qn * kk * 8  # (Q, k) values + (Q, k) indices
    emit("kernels/topk_unfused_packed_cpu", f"{us_topk:.1f}",
         f"Q={qn};R={rn};D={d32};k={kk};score_matrix_bytes={score_bytes}")
    # agreement check on a slice spanning multiple Q and R blocks (forced
    # small blocks), so the VMEM scratch reset and cross-tile merge both
    # run; derived fields describe this checked shape, the traffic ratio
    # is shape-independent (R*4 bytes/query vs k*8)
    qf, rf = ap[:16], bp[:384]
    ik, vk = topk_hamming_pallas(qf, rf, dim=d32, k=kk, block_q=8,
                                 block_r=128)
    io, vo = topk_search_packed(qf, rf, d32, kk)
    mism = int((np.asarray(ik) != np.asarray(io)).sum()
               + (np.asarray(vk) != np.asarray(vo)).sum())
    emit("kernels/topk_fused_interpret_mismatches", f"{mism:d}",
         f"Q={qf.shape[0]};R={rf.shape[0]};k={kk};"
         f"bytes_per_query_unfused={rf.shape[0] * 4};"
         f"bytes_per_query_fused={kk * 8};"
         f"traffic_reduction={rf.shape[0] * 4 / (kk * 8):.0f}x")

    # banded (OMS) variant: per-query windows over a 5-tile bank, checked
    # against sentinel-masking the full score matrix (the serving OMS
    # oracle); the derived column carries the scan reduction the per-block
    # tile budget buys over a full-bank pass. Bands mimic the server's
    # precursor-sorted batches: two 8-query blocks, each clustered in its
    # own mass region, with window ends crossing a 128-row tile boundary.
    from repro.kernels.topk_hamming import topk_hamming_banded_pallas
    from repro.kernels.topk_hamming.ref import topk_hamming_banded_ref
    rb = bp[:640]
    b_starts = (np.repeat([0, 384], 8)
                + np.arange(16) % 8 * 16).astype(np.int32)
    b_lens = rng.integers(32, 129, 16).astype(np.int32)
    b_tiles = max(
        -(-int((b_starts + b_lens)[i:i + 8].max()) // 128)
        - int(b_starts[i:i + 8].min()) // 128
        for i in range(0, 16, 8))
    ib, vb = topk_hamming_banded_pallas(qf, rb, jnp.asarray(b_starts),
                                        jnp.asarray(b_lens), dim=d32,
                                        k=kk, num_tiles=b_tiles, block_q=8)
    ibo, vbo = topk_hamming_banded_ref(qf, rb, b_starts, b_lens, d32, kk)
    mismb = int((np.asarray(ib) != np.asarray(ibo)).sum()
                + (np.asarray(vb) != np.asarray(vbo)).sum())
    emit("kernels/topk_banded_interpret_mismatches", f"{mismb:d}",
         f"Q={qf.shape[0]};R={rb.shape[0]};k={kk};num_tiles={b_tiles};"
         f"scan_reduction={rb.shape[0] / 128 / b_tiles:.1f}x")

    # Pallas kernels in interpret mode are correctness artifacts, not perf;
    # emit their numerical agreement instead of timing
    from repro.kernels.imc_mvm.ops import imc_mvm_pallas
    small_q, small_w = q[:16, :256], w[:32, :256]
    out_k = imc_mvm_pallas(small_q, small_w, full_scale=fs)
    out_r = imc_mvm_ref(small_q, small_w, full_scale=fs)
    err = float(jnp.max(jnp.abs(out_k - out_r)))
    emit("kernels/imc_mvm_pallas_interpret_maxerr", f"{err:.2e}",
         "vs_ref_oracle")


if __name__ == "__main__":
    run()
