"""Paper Fig. S3: (a) quality vs write-verify cycles, (b) quality vs ADC
bits — the two ISA-controlled accuracy/efficiency knobs."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import SpecPCMConfig, run_clustering, run_db_search
from repro.core.imc.energy import DATASETS, db_search_cost
from repro.spectra import SyntheticMSConfig, generate_dataset
from repro.spectra.synthetic import generate_query_set


def run(quick: bool = False) -> None:
    ms = SyntheticMSConfig(num_identities=32, spectra_per_identity=6,
                           num_bins=1024, dropout=0.3, intensity_jitter=0.4,
                           noise_peaks=24, peaks_per_peptide=32)
    ds = generate_dataset(ms)
    refs = ds.templates / jnp.maximum(ds.templates.max(1, keepdims=True), 1e-6)
    ref_prec = jnp.asarray(np.asarray(ds.precursor)[::ms.spectra_per_identity])
    q = generate_query_set(ds, ms, num_queries=64)

    # (a) write-verify sweep — DB search quality + energy/latency cost
    for wv in (0, 1, 3, 5):
        cfg = SpecPCMConfig(hd_dim=2049, mlc_bits=3, num_levels=16,
                            write_verify=wv, material="tite2")
        rep = run_db_search(q.spectra, q.precursor, refs, ref_prec, cfg,
                            query_identity=q.identity,
                            ref_identity=jnp.arange(ms.num_identities))
        emit(f"figS3a/wv{wv}/recall", f"{rep.recall:.3f}",
             f"identified={rep.num_identified}")

    # (a') clustering is insensitive to write-verify (paper uses 0)
    for wv in (0, 3):
        cfg = SpecPCMConfig(hd_dim=2049, mlc_bits=3, num_levels=16,
                            write_verify=wv, material="sb2te3")
        rep = run_clustering(ds.spectra, ds.precursor, ds.identity, cfg)
        emit(f"figS3a/clustering_wv{wv}/clustered_ratio",
             f"{rep.clustered_ratio:.4f}",
             f"incorrect={rep.incorrect_ratio:.4f}")

    # (b) ADC precision sweep — quality degrades gracefully, energy drops
    d = DATASETS["HEK293"]
    for adc in (6, 5, 4, 3, 2):
        cfg = SpecPCMConfig(hd_dim=2049, mlc_bits=3, num_levels=16,
                            adc_bits=adc, material="tite2", write_verify=3)
        rep = run_db_search(q.spectra, q.precursor, refs, ref_prec, cfg,
                            query_identity=q.identity,
                            ref_identity=jnp.arange(ms.num_identities))
        cost = db_search_cost(d["num_queries"], d["num_refs"], adc_bits=adc,
                              candidate_fraction=d["candidate_fraction"])
        emit(f"figS3b/adc{adc}/recall", f"{rep.recall:.3f}",
             f"hek293_energy_j={cost.energy_j:.4f}")


if __name__ == "__main__":
    run()
