"""Paper Table 3: DB search latency/speedup vs published baselines."""

from __future__ import annotations

from benchmarks.common import emit
from repro.core.imc.energy import DATASETS, PAPER_TABLE3, db_search_cost


def run() -> None:
    for ds in ("iPRG2012", "HEK293"):
        d = DATASETS[ds]
        ours = db_search_cost(d["num_queries"], d["num_refs"],
                              candidate_fraction=d["candidate_fraction"])
        paper = PAPER_TABLE3[ds]["SpecPCM(paper)"]
        base = PAPER_TABLE3[ds].get("ANN-SoLo(CPU-GPU)")
        emit(f"table3/{ds}/model_latency_s", f"{ours.latency_s:.4f}",
             f"paper={paper:.3f}s err={abs(ours.latency_s - paper) / paper:.1%}")
        emit(f"table3/{ds}/speedup_vs_annsolo", f"{base / ours.latency_s:.1f}",
             f"paper_claims={base / paper:.1f}x")
        emit(f"table3/{ds}/energy_j", f"{ours.energy_j:.4f}",
             "paper=0.149J" if ds == "HEK293" else "")
        for tool, lat in PAPER_TABLE3[ds].items():
            emit(f"table3/{ds}/baseline/{tool}", f"{lat:.4f}", "published")

    # MLC3 vs SLC throughput claim (3x from dimension packing)
    d = DATASETS["HEK293"]
    slc = db_search_cost(d["num_queries"], d["num_refs"], mlc_bits=1,
                         candidate_fraction=d["candidate_fraction"])
    mlc = db_search_cost(d["num_queries"], d["num_refs"], mlc_bits=3,
                         candidate_fraction=d["candidate_fraction"])
    emit("table3/HEK293/mlc3_vs_slc_speedup",
         f"{slc.latency_s / mlc.latency_s:.2f}", "paper_claims=3x")


if __name__ == "__main__":
    run()
