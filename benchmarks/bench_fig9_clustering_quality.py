"""Paper Fig. 9: clustering quality (clustered-spectra ratio at a bounded
incorrect-clustering ratio) for SLC / MLC2 / MLC3 on synthetic spectra."""

from __future__ import annotations

from benchmarks.common import emit
from repro.core import SpecPCMConfig, run_clustering
from repro.spectra import SyntheticMSConfig, generate_dataset


def run(quick: bool = False) -> None:
    # operating point with realistic difficulty (dropout/jitter/noise set
    # so accuracy sits below saturation and the MLC knobs are visible)
    ms = SyntheticMSConfig(num_identities=32 if quick else 48,
                           spectra_per_identity=8, num_bins=1024,
                           dropout=0.3, intensity_jitter=0.4,
                           noise_peaks=24, peaks_per_peptide=32)
    ds = generate_dataset(ms)
    for bits, dim in ((1, 2048), (2, 2048), (3, 2049)):
        cfg = SpecPCMConfig(hd_dim=dim, mlc_bits=bits, num_levels=16,
                            material="sb2te3", write_verify=0)
        rep = run_clustering(ds.spectra, ds.precursor, ds.identity, cfg)
        emit(f"fig9/mlc{bits}/clustered_ratio", f"{rep.clustered_ratio:.4f}",
             f"incorrect={rep.incorrect_ratio:.4f} paper_trend=SLC>=MLC2>=MLC3")


if __name__ == "__main__":
    run()
