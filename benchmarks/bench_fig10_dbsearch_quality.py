"""Paper Fig. 10 / S1: DB-search identification quality at fixed 1% FDR
for SLC / MLC2 / MLC3 (synthetic query/reference sets)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import SpecPCMConfig, run_db_search
from repro.spectra import SyntheticMSConfig, generate_dataset
from repro.spectra.synthetic import generate_query_set


def run(quick: bool = False) -> None:
    ms = SyntheticMSConfig(num_identities=32 if quick else 64,
                           spectra_per_identity=4, num_bins=1024,
                           dropout=0.3, intensity_jitter=0.4,
                           noise_peaks=24, peaks_per_peptide=32)
    ds = generate_dataset(ms)
    refs = ds.templates / jnp.maximum(ds.templates.max(1, keepdims=True), 1e-6)
    ref_prec = jnp.asarray(np.asarray(ds.precursor)[::ms.spectra_per_identity])
    q = generate_query_set(ds, ms, num_queries=2 * ms.num_identities)
    for bits, dim in ((1, 2048), (2, 2048), (3, 2049)):
        cfg = SpecPCMConfig(hd_dim=dim, mlc_bits=bits, num_levels=16,
                            material="tite2", write_verify=3)
        rep = run_db_search(q.spectra, q.precursor, refs, ref_prec, cfg,
                            query_identity=q.identity,
                            ref_identity=jnp.arange(ms.num_identities))
        emit(f"fig10/mlc{bits}/identified", str(rep.num_identified),
             f"of={q.spectra.shape[0]} recall={rep.recall:.3f} fdr=1%")


if __name__ == "__main__":
    run()
