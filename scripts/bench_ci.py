"""Benchmark-regression gate for CI: machine-readable perf trajectory.

Runs the benchmark orchestrator (``benchmarks/run.py``) under
``REPRO_BENCH_QUICK=1``, parses its ``name,us_per_call,derived`` CSV rows,
adds serving metrics (queries/sec, query-HV cache hit rate, p50/p95) from
a reduced multi-tenant ``repro.launch.serve_db`` run, and writes the
result as a repo-root ``BENCH_PR3.json`` — the artifact CI uploads so
every PR leaves a perf data point behind.

If a prior ``BENCH_*.json`` exists at the repo root, timing rows are
compared against the newest one: a suite that got more than ``--warn-pct``
slower prints a warning, more than ``--fail-pct`` slower fails the job
(new/removed suites are reported, never fatal).

Usage:
  PYTHONPATH=src python scripts/bench_ci.py                # full gate
  PYTHONPATH=src python scripts/bench_ci.py --skip-serving # suites only
  PYTHONPATH=src python scripts/bench_ci.py --output /tmp/bench.json
"""

from __future__ import annotations

import argparse
import json
import os
import re
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

_BENCH_NAME_RE = re.compile(r"BENCH_PR(\d+)\.json$")


def run_suites() -> list[dict]:
    """Run benchmarks/run.py quick and parse its CSV rows."""
    env = dict(os.environ)
    env["REPRO_BENCH_QUICK"] = "1"
    # src for the repro package, the repo root for the benchmarks package
    path = str(REPO / "src") + os.pathsep + str(REPO)
    if env.get("PYTHONPATH"):
        path += os.pathsep + env["PYTHONPATH"]
    env["PYTHONPATH"] = path
    proc = subprocess.run([sys.executable, str(REPO / "benchmarks" / "run.py")],
                          capture_output=True, text=True, cwd=REPO, env=env)
    rows = []
    for line in proc.stdout.splitlines():
        if not line.startswith("suite/"):
            continue
        name, us, derived = line.split(",", 2)
        rows.append({"name": name, "us_per_call": float(us),
                     "derived": derived})
    failed = [r["name"] for r in rows if r["derived"] == "FAILED"]
    if proc.returncode != 0 or failed or not rows:
        sys.stderr.write(proc.stdout[-4000:] + proc.stderr[-4000:])
        raise SystemExit(
            f"benchmark suites failed (rc={proc.returncode}, "
            f"failed={failed or 'no rows parsed'})")
    return rows


def serving_metrics() -> dict:
    """Reduced multi-tenant serve_db run -> queries/sec + cache hit rate."""
    from repro.launch import serve_db
    s = serve_db.main([
        "--reduced", "--hd-dim", "64", "--identities", "8", "--queries", "32",
        "--max-batch", "8", "--k", "2", "--fdr", "0.5", "--flush-ms", "2",
        "--tenants", "2", "--cache-mb", "8", "--buckets", "2",
    ])
    qc = s["query_cache"] or {}
    return {
        "queries_per_sec": s["qps"],
        "p50_ms": s["p50_ms"],
        "p95_ms": s["p95_ms"],
        "cache_hit_rate": qc.get("hit_rate", 0.0),
        "cache_hits": qc.get("hits", 0),
        "cache_misses": qc.get("misses", 0),
        "bank_builds": s["banks"]["builds"],
        "tenants": len(s["tenants"]),
    }


def find_baseline(output: Path) -> Path | None:
    """The newest prior BENCH_*.json at the repo root (numeric PR order,
    then mtime for non-conforming names), excluding the output file."""
    cands = [p for p in REPO.glob("BENCH_*.json") if p.resolve() != output.resolve()]
    if not cands:
        return None

    def order(p: Path):
        m = _BENCH_NAME_RE.search(p.name)
        # PR-numbered files outrank non-conforming names at any mtime
        return (1, int(m.group(1))) if m else (0, p.stat().st_mtime)

    return max(cands, key=order)


def compare(baseline: dict, current: list[dict], *, warn_pct: float,
            fail_pct: float) -> tuple[list[str], list[str]]:
    """(warnings, failures) from timing-row regressions vs the baseline."""
    old = {r["name"]: r["us_per_call"] for r in baseline.get("rows", [])}
    warnings, failures = [], []
    for row in current:
        prev = old.get(row["name"])
        if prev is None:
            warnings.append(f"{row['name']}: new suite (no baseline)")
            continue
        if prev <= 0:
            continue
        delta = row["us_per_call"] / prev - 1.0
        msg = (f"{row['name']}: {prev:.0f} -> {row['us_per_call']:.0f} us "
               f"({delta:+.1%})")
        if delta > fail_pct:
            failures.append(msg)
        elif delta > warn_pct:
            warnings.append(msg)
    for name in sorted(set(old) - {r["name"] for r in current}):
        warnings.append(f"{name}: suite removed since baseline")
    return warnings, failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--output", type=Path, default=REPO / "BENCH_PR3.json")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="explicit baseline JSON (default: newest prior "
                         "BENCH_*.json at the repo root)")
    ap.add_argument("--warn-pct", type=float, default=0.10,
                    help="warn when a timing row regresses more than this")
    ap.add_argument("--fail-pct", type=float, default=0.50,
                    help="fail when a timing row regresses more than this")
    ap.add_argument("--skip-serving", action="store_true",
                    help="skip the reduced serve_db run (suites only)")
    args = ap.parse_args(argv)

    rows = run_suites()
    result = {
        "schema": 1,
        "source": "scripts/bench_ci.py",
        "quick": True,
        "rows": rows,
        "serving": None if args.skip_serving else serving_metrics(),
    }
    args.output.write_text(json.dumps(result, indent=2) + "\n")
    print(f"wrote {args.output} ({len(rows)} timing rows"
          + ("" if args.skip_serving else
         f", serving {result['serving']['queries_per_sec']:.1f} q/s, "
         f"cache hit rate {result['serving']['cache_hit_rate']:.1%}") + ")")

    base_path = args.baseline or find_baseline(args.output)
    if base_path is None:
        print("no prior BENCH_*.json baseline found; comparison skipped")
        return 0
    baseline = json.loads(base_path.read_text())
    warnings, failures = compare(baseline, rows, warn_pct=args.warn_pct,
                                 fail_pct=args.fail_pct)
    print(f"compared against {base_path.name}: "
          f"{len(failures)} failure(s), {len(warnings)} warning(s)")
    for w in warnings:
        print(f"  WARN  {w}")
    for f in failures:
        print(f"  FAIL  {f}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
