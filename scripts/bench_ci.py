"""Benchmark-regression gate for CI: machine-readable perf trajectory.

Runs the benchmark orchestrator (``benchmarks/run.py``) under
``REPRO_BENCH_QUICK=1``, parses its ``name,us_per_call,derived`` CSV rows
(whole-suite timings plus the per-kernel ``kernels/`` rows, including the
fused-vs-unfused top-k search pair), adds serving metrics (queries/sec,
query-HV cache hit rate, p50/p95) from a reduced multi-tenant
``repro.launch.serve_db`` run, and writes the result as a repo-root
``BENCH_PR<N>.json`` (``--pr``, default: newest existing + 1) — the
artifact CI uploads so every PR leaves a perf data point behind.

If a prior ``BENCH_*.json`` exists at the repo root, rows are compared
against the newest one: a timing row that got more than ``--warn-pct``
slower prints a warning, more than ``--fail-pct`` slower fails the job
(new/removed suites are reported, never fatal). Serving metrics gate
direction-aware at the same thresholds — queries/sec regresses downward,
p50/p95 latency upward. Kernel correctness artifacts (``*_maxerr``,
``*_mismatches``) are recorded but never timing-compared; a nonzero
``*_mismatches`` row fails the job outright (kernel bit-identity broken).

Usage:
  PYTHONPATH=src python scripts/bench_ci.py                # full gate
  PYTHONPATH=src python scripts/bench_ci.py --pr 4         # pin the name
  PYTHONPATH=src python scripts/bench_ci.py --skip-serving # suites only
  PYTHONPATH=src python scripts/bench_ci.py --output /tmp/bench.json
"""

from __future__ import annotations

import argparse
import json
import os
import re
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

_BENCH_NAME_RE = re.compile(r"BENCH_PR(\d+)\.json$")
# rows captured into the JSON (and the regression gate): whole-suite
# timings plus the per-kernel rows (the fused-vs-unfused search pair)
_ROW_RE = re.compile(r"^(suite|kernels)/")
# correctness artifacts, not timings: excluded from the slower-than
# comparison. A *_mismatches row instead hard-fails whenever nonzero
# (bit-identity broken), baseline or not; *_maxerr rows are float noise
# and only recorded.
_ARTIFACT_RE = re.compile(r"(_maxerr|_mismatches)$")


def run_suites() -> list[dict]:
    """Run benchmarks/run.py quick and parse its CSV rows."""
    env = dict(os.environ)
    env["REPRO_BENCH_QUICK"] = "1"
    # src for the repro package, the repo root for the benchmarks package
    path = str(REPO / "src") + os.pathsep + str(REPO)
    if env.get("PYTHONPATH"):
        path += os.pathsep + env["PYTHONPATH"]
    env["PYTHONPATH"] = path
    proc = subprocess.run([sys.executable, str(REPO / "benchmarks" / "run.py")],
                          capture_output=True, text=True, cwd=REPO, env=env)
    rows = []
    for line in proc.stdout.splitlines():
        if not _ROW_RE.match(line):
            continue
        name, us, derived = line.split(",", 2)
        try:
            us_f = float(us)
        except ValueError:
            continue  # non-numeric kernel artifacts stay out of the gate
        rows.append({"name": name, "us_per_call": us_f, "derived": derived})
    failed = [r["name"] for r in rows
              if r["name"].startswith("suite/") and r["derived"] == "FAILED"]
    if proc.returncode != 0 or failed or not any(
            r["name"].startswith("suite/") for r in rows):
        sys.stderr.write(proc.stdout[-4000:] + proc.stderr[-4000:])
        raise SystemExit(
            f"benchmark suites failed (rc={proc.returncode}, "
            f"failed={failed or 'no rows parsed'})")
    return rows


def serving_metrics() -> dict:
    """Reduced multi-tenant serve_db run -> queries/sec + cache hit rate."""
    from repro.launch import serve_db
    s = serve_db.main([
        "--reduced", "--hd-dim", "64", "--identities", "8", "--queries", "32",
        "--max-batch", "8", "--k", "2", "--fdr", "0.5", "--flush-ms", "2",
        "--tenants", "2", "--cache-mb", "8", "--buckets", "2",
    ])
    qc = s["query_cache"] or {}
    return {
        "queries_per_sec": s["qps"],
        "p50_ms": s["p50_ms"],
        "p95_ms": s["p95_ms"],
        "cache_hit_rate": qc.get("hit_rate", 0.0),
        "cache_hits": qc.get("hits", 0),
        "cache_misses": qc.get("misses", 0),
        "bank_builds": s["banks"]["builds"],
        "tenants": len(s["tenants"]),
    }


def find_baseline(output: Path) -> Path | None:
    """The newest prior BENCH_*.json at the repo root (numeric PR order,
    then mtime for non-conforming names), excluding the output file."""
    cands = [p for p in REPO.glob("BENCH_*.json") if p.resolve() != output.resolve()]
    if not cands:
        return None

    def order(p: Path):
        m = _BENCH_NAME_RE.search(p.name)
        # PR-numbered files outrank non-conforming names at any mtime
        return (1, int(m.group(1))) if m else (0, p.stat().st_mtime)

    return max(cands, key=order)


def compare(baseline: dict, current: list[dict], *, warn_pct: float,
            fail_pct: float) -> tuple[list[str], list[str]]:
    """(warnings, failures) from timing-row regressions vs the baseline."""
    old = {r["name"]: r["us_per_call"] for r in baseline.get("rows", [])}
    warnings, failures = [], []
    for row in current:
        if _ARTIFACT_RE.search(row["name"]):
            continue  # gated by artifact_failures(), baseline or not
        prev = old.get(row["name"])
        if prev is None:
            warnings.append(f"{row['name']}: new suite (no baseline)")
            continue
        if prev <= 0:
            continue
        delta = row["us_per_call"] / prev - 1.0
        msg = (f"{row['name']}: {prev:.0f} -> {row['us_per_call']:.0f} us "
               f"({delta:+.1%})")
        if delta > fail_pct:
            failures.append(msg)
        elif delta > warn_pct:
            warnings.append(msg)
    for name in sorted(set(old) - {r["name"] for r in current}):
        warnings.append(f"{name}: suite removed since baseline")
    return warnings, failures


# serving metrics are direction-aware: throughput regresses downward,
# latency regresses upward; both gate at the same warn/fail thresholds
_SERVING_DIRECTIONS = {
    "queries_per_sec": "higher",
    "p50_ms": "lower",
    "p95_ms": "lower",
}


def compare_serving(baseline: dict, serving: dict | None, *, warn_pct: float,
                    fail_pct: float) -> tuple[list[str], list[str]]:
    """(warnings, failures) from serving-metric regressions vs baseline."""
    old = baseline.get("serving") or {}
    cur = serving or {}
    warnings, failures = [], []
    for name, direction in _SERVING_DIRECTIONS.items():
        prev, now = old.get(name), cur.get(name)
        if prev is None or now is None or prev <= 0:
            continue
        # positive delta == worse, whichever way the metric points
        if direction == "higher":
            delta = (prev - now) / prev
        else:
            delta = (now - prev) / prev
        msg = (f"serving.{name}: {prev:.2f} -> {now:.2f} "
               f"({delta:+.1%} worse, {direction} is better)")
        if delta > fail_pct:
            failures.append(msg)
        elif delta > warn_pct:
            warnings.append(msg)
    return warnings, failures


def artifact_failures(rows: list[dict]) -> list[str]:
    """Hard failures from correctness-artifact rows — a nonzero
    ``*_mismatches`` count means a kernel stopped matching its oracle.
    Checked unconditionally, baseline or not."""
    return [f"{r['name']}: {r['us_per_call']:.0f} mismatches "
            f"(bit-identity broken)" for r in rows
            if r["name"].endswith("_mismatches") and r["us_per_call"] > 0]


def next_pr_number() -> int:
    """One past the highest BENCH_PR<N>.json at the repo root (else 0)."""
    nums = [int(m.group(1)) for p in REPO.glob("BENCH_*.json")
            if (m := _BENCH_NAME_RE.search(p.name))]
    return max(nums, default=-1) + 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--output", type=Path, default=None,
                    help="explicit output path (default: BENCH_PR<N>.json "
                         "at the repo root, N from --pr)")
    ap.add_argument("--pr", type=int, default=None,
                    help="PR number for the default output name "
                         "(default: newest existing BENCH_PR number + 1)")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="explicit baseline JSON (default: newest prior "
                         "BENCH_*.json at the repo root)")
    ap.add_argument("--warn-pct", type=float, default=0.10,
                    help="warn when a timing row regresses more than this")
    ap.add_argument("--fail-pct", type=float, default=0.50,
                    help="fail when a timing row regresses more than this")
    ap.add_argument("--skip-serving", action="store_true",
                    help="skip the reduced serve_db run (suites only)")
    args = ap.parse_args(argv)
    if args.output is None:
        pr = args.pr if args.pr is not None else next_pr_number()
        args.output = REPO / f"BENCH_PR{pr}.json"

    rows = run_suites()
    result = {
        "schema": 1,
        "source": "scripts/bench_ci.py",
        "quick": True,
        "rows": rows,
        "serving": None if args.skip_serving else serving_metrics(),
    }
    args.output.write_text(json.dumps(result, indent=2) + "\n")
    print(f"wrote {args.output} ({len(rows)} timing rows"
          + ("" if args.skip_serving else
         f", serving {result['serving']['queries_per_sec']:.1f} q/s, "
         f"cache hit rate {result['serving']['cache_hit_rate']:.1%}") + ")")

    hard_failures = artifact_failures(rows)

    base_path = args.baseline or find_baseline(args.output)
    if base_path is None:
        print("no prior BENCH_*.json baseline found; comparison skipped")
        for f in hard_failures:
            print(f"  FAIL  {f}")
        return 1 if hard_failures else 0
    baseline = json.loads(base_path.read_text())
    warnings, failures = compare(baseline, rows, warn_pct=args.warn_pct,
                                 fail_pct=args.fail_pct)
    failures = hard_failures + failures
    sw, sf = compare_serving(baseline, result["serving"],
                             warn_pct=args.warn_pct, fail_pct=args.fail_pct)
    warnings += sw
    failures += sf
    print(f"compared against {base_path.name}: "
          f"{len(failures)} failure(s), {len(warnings)} warning(s)")
    for w in warnings:
        print(f"  WARN  {w}")
    for f in failures:
        print(f"  FAIL  {f}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
