"""Benchmark-regression gate for CI: machine-readable perf trajectory.

Runs the benchmark orchestrator (``benchmarks/run.py``) under
``REPRO_BENCH_QUICK=1``, parses its ``name,us_per_call,derived`` CSV rows
(whole-suite timings plus the per-kernel ``kernels/`` rows, including the
fused-vs-unfused top-k search pair), adds serving metrics (queries/sec,
query-HV cache hit rate, p50/p95) from a reduced multi-tenant
``repro.launch.serve_db`` run, open-modification serving metrics
(``oms_*``: qps/p50/p95 plus the candidate and scanned fractions of the
banded precursor-window scan) from a second ``serve_db --oms --fused``
run, continuous-batching serving metrics (``continuous_*``: qps, p50,
p95, and the p95/p50 tail ratio — hard-floored at <= 4, the PR-7
acceptance bound that flush-and-wait serving cannot meet under straggler
traffic) from a third ``serve_db --continuous`` run, plus training
metrics (per-step time and DCN bytes for the
hierarchical compressed gradient sync, as ``train/`` rows), streaming-
ingestion metrics (``ingest_*``: append latency, search qps on the pure
base bank / the merged base+delta path / the post-compaction bank, and
the delta fraction — hard-floored at delta-path qps within 1.5x of
pure-base, the PR-8 acceptance bound), and clustering-endpoint metrics
(``cluster_*``: spectra/sec plus the paper's incorrect-clustering
ratio from a reduced ``repro.launch.serve_cluster`` run), and
autotuner metrics (``tune_*``: measured compute / memory-bandwidth
ceilings and the worst tuned-vs-default timing ratio from a reduced
``repro.tune`` sweep — hard-floored at >= 0.95, and the resulting
``artifacts/tuning_table.json`` is uploaded as a CI artifact), and
writes the result as a repo-root ``BENCH_PR<N>.json`` — the artifact
CI uploads so every PR leaves a perf data point behind. The output name
needs no hand-editing per PR: ``--pr`` wins if given, else the
``REPRO_BENCH_PR`` env var, else under ``GITHUB_ACTIONS`` the newest
committed ``BENCH_PR<N>`` is *re-run* (so the previous PR's file stays
the comparison baseline), else newest + 1.

If a prior ``BENCH_*.json`` exists at the repo root, rows are compared
against the newest one: a timing row that got more than ``--warn-pct``
slower prints a warning, more than ``--fail-pct`` slower fails the job
(new/removed suites are reported, never fatal). Baseline timings are
first rescaled by a machine-speed factor — the ratio of the frozen
matmul canary (``canary_us``, measured every run and stored in the
JSON) between the two runs — so a CI-runner or container re-placement
between PRs doesn't fail the gate on code that didn't change; the
factor is clamped to [1, 3] and only ever forgives machine-wide drift. Serving metrics gate
direction-aware at the same thresholds — queries/sec regresses downward,
p50/p95 latency upward; ``train/`` step-time rows gate like any timing
row. Kernel correctness artifacts (``*_maxerr``, ``*_mismatches``) are
recorded but never timing-compared; a nonzero ``*_mismatches`` row fails
the job outright (kernel bit-identity broken), and so does a compressed
DCN payload less than 4x smaller than raw fp32 (the PR-5 acceptance
floor on wire traffic) or an OMS scanned/candidate fraction >= 1 (the
PR-6 floor: the banded kernel must beat a full-bank scan).

Usage:
  PYTHONPATH=src python scripts/bench_ci.py                # full gate
  PYTHONPATH=src python scripts/bench_ci.py --pr 5         # pin the name
  PYTHONPATH=src python scripts/bench_ci.py --skip-serving --skip-train
  PYTHONPATH=src python scripts/bench_ci.py --output /tmp/bench.json
"""

from __future__ import annotations

import argparse
import json
import os
import re
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

_BENCH_NAME_RE = re.compile(r"BENCH_PR(\d+)\.json$")
# rows captured into the JSON (and the regression gate): whole-suite
# timings plus the per-kernel rows (the fused-vs-unfused search pair)
_ROW_RE = re.compile(r"^(suite|kernels)/")
# correctness artifacts, not timings: excluded from the slower-than
# comparison. A *_mismatches row instead hard-fails whenever nonzero
# (bit-identity broken), baseline or not; *_maxerr rows are float noise
# and only recorded.
_ARTIFACT_RE = re.compile(r"(_maxerr|_mismatches)$")
# jitter-floor demotion ceiling: a micro-row regression beyond this
# relative slowdown fails even when its absolute delta is tiny
_DEMOTE_MAX_DELTA = 2.0  # +200% == 3x
# machine-speed normalization: timing rows compare against a
# speed-adjusted baseline (prev * speed) so host drift — container
# re-placement, a different CPU generation, BLAS/vector-ISA differences —
# doesn't fail the gate on code that didn't change. The speed factor
# comes from the frozen-matmul canary stored in each JSON; baselines that
# predate ``canary_us`` fall back to the dense int8 dot row as a
# retroactive probe (fixed shape since PR 4, pure matmul, no repo-code
# dependence beyond ``dot_similarity``). Clamped to [1, _SPEED_CLAMP]:
# a faster machine never relaxes the gate, and a broken probe can't
# hide a blowup past 3x.
_CANARY_PROXY_ROW = "kernels/dense_dot_int8_cpu"
_SPEED_CLAMP = 3.0


def machine_canary(warmup: int = 1, iters: int = 5) -> float:
    """Median wall-time (us) of a frozen jitted float32 matmul.

    The workload never changes with repo code, so the only thing that can
    move it between two bench runs is the host itself — which makes the
    pair (baseline canary, current canary) a measurement of machine
    drift that ``compare`` can divide out of every timing row."""
    import time as _time

    import jax
    import jax.numpy as jnp
    import numpy as np

    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.standard_normal((64, 2048)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((2048, 2048)).astype(np.float32))
    f = jax.jit(lambda x, y: x @ y)
    for _ in range(warmup):
        jax.block_until_ready(f(a, b))
    times = []
    for _ in range(iters):
        t0 = _time.perf_counter()
        jax.block_until_ready(f(a, b))
        times.append(_time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def machine_speed(baseline: dict, canary_us: float,
                  rows: list[dict]) -> tuple[float, str]:
    """(speed, source): this machine's slowdown factor vs the baseline's
    machine (1.0 == same speed, 1.5 == CPU-bound rows should read ~1.5x
    slower here), and where the estimate came from."""
    old_canary = baseline.get("canary_us")
    if old_canary:
        s, src = canary_us / old_canary, "canary"
    else:
        old = {r["name"]: r["us_per_call"] for r in baseline.get("rows", [])}
        prev = old.get(_CANARY_PROXY_ROW)
        now = next((r["us_per_call"] for r in rows
                    if r["name"] == _CANARY_PROXY_ROW), None)
        if not prev or not now:
            return 1.0, "none"
        s, src = now / prev, "proxy " + _CANARY_PROXY_ROW
    return min(max(s, 1.0), _SPEED_CLAMP), src


def run_suites() -> list[dict]:
    """Run benchmarks/run.py quick and parse its CSV rows."""
    env = dict(os.environ)
    env["REPRO_BENCH_QUICK"] = "1"
    # src for the repro package, the repo root for the benchmarks package
    path = str(REPO / "src") + os.pathsep + str(REPO)
    if env.get("PYTHONPATH"):
        path += os.pathsep + env["PYTHONPATH"]
    env["PYTHONPATH"] = path
    proc = subprocess.run([sys.executable, str(REPO / "benchmarks" / "run.py")],
                          capture_output=True, text=True, cwd=REPO, env=env)
    rows = []
    for line in proc.stdout.splitlines():
        if not _ROW_RE.match(line):
            continue
        name, us, derived = line.split(",", 2)
        try:
            us_f = float(us)
        except ValueError:
            continue  # non-numeric kernel artifacts stay out of the gate
        rows.append({"name": name, "us_per_call": us_f, "derived": derived})
    failed = [r["name"] for r in rows
              if r["name"].startswith("suite/") and r["derived"] == "FAILED"]
    if proc.returncode != 0 or failed or not any(
            r["name"].startswith("suite/") for r in rows):
        sys.stderr.write(proc.stdout[-4000:] + proc.stderr[-4000:])
        raise SystemExit(
            f"benchmark suites failed (rc={proc.returncode}, "
            f"failed={failed or 'no rows parsed'})")
    return rows


def serving_metrics() -> dict:
    """Reduced multi-tenant serve_db run -> queries/sec + cache hit rate,
    plus an OMS pass (precursor-sorted bank, banded kernel) at a realistic
    tolerance pair over a bank large enough that the banded scan is
    genuinely sub-linear (``oms_scanned_fraction`` < 1 is a hard gate)."""
    from repro.launch import serve_db
    s = serve_db.main([
        "--reduced", "--hd-dim", "64", "--identities", "8", "--queries", "32",
        "--max-batch", "8", "--k", "2", "--fdr", "0.5", "--flush-ms", "2",
        "--tenants", "2", "--cache-mb", "8", "--buckets", "2",
    ])
    qc = s["query_cache"] or {}
    # OMS: big sorted bank (8192 rows = 64 kernel tiles), batch of 32
    # precursor-sorted queries in 8-query blocks, window (-2.5, +150) Da
    o = serve_db.main([
        "--reduced", "--hd-dim", "256", "--identities", "1024",
        "--refs-per-identity", "4", "--queries", "64", "--max-batch", "32",
        "--k", "4", "--fdr", "0.5", "--flush-ms", "2", "--cache-mb", "8",
        "--buckets", "1", "--fused", "--oms", "--tolerance", "2.5",
        "--open-tol", "150",
    ])
    oms = o["oms"]
    # continuous batching: one tenant, one shape bucket, run twice — the
    # first run eats every residual one-time compile (a single cold batch
    # inflates p95 by ~50x against a ~7 ms p50) and is discarded, the
    # second measures steady-state scheduling. Its p95/p50 ratio is the
    # tail-latency acceptance floor.
    continuous_args = [
        "--reduced", "--hd-dim", "64", "--identities", "8", "--queries",
        "48", "--max-batch", "8", "--k", "2", "--fdr", "0.5", "--flush-ms",
        "2", "--tenants", "1", "--cache-mb", "8", "--buckets", "1",
        "--continuous", "--num-slots", "2",
    ]
    serve_db.main(continuous_args)  # warm-up, discarded
    c = serve_db.main(continuous_args)
    return {
        "queries_per_sec": s["qps"],
        "p50_ms": s["p50_ms"],
        "p95_ms": s["p95_ms"],
        "cache_hit_rate": qc.get("hit_rate", 0.0),
        "cache_hits": qc.get("hits", 0),
        "cache_misses": qc.get("misses", 0),
        "bank_builds": s["banks"]["builds"],
        "tenants": len(s["tenants"]),
        "oms_queries_per_sec": o["qps"],
        "oms_p50_ms": o["p50_ms"],
        "oms_p95_ms": o["p95_ms"],
        "oms_candidate_fraction": oms["candidate_fraction"],
        "oms_scanned_fraction": oms["scanned_fraction"],
        "oms_no_candidate": oms["no_candidate"],
        "continuous_queries_per_sec": c["qps"],
        "continuous_p50_ms": c["p50_ms"],
        "continuous_p95_ms": c["p95_ms"],
        "continuous_p95_p50_ratio": (c["p95_ms"] / c["p50_ms"]
                                     if c["p50_ms"] > 0 else 1.0),
        "continuous_queue_wait_p95_ms": c["queue_wait_p95_ms"],
        "continuous_batches": c["scheduler"]["dispatched_batches"],
    }


def ingest_metrics() -> dict:
    """Streaming-ingestion serving run -> append latency + search qps on
    the pure base bank, the merged base+delta path, and the compacted
    bank (same queries, same server; each geometry gets one discarded
    warm-up pass so the gate times steady-state serving, not jit)."""
    import time as _time

    import jax.numpy as jnp
    import numpy as np

    from repro.serve import BankRegistry, DBSearchServer

    rng = np.random.default_rng(41)
    dim, n_q = 64, 256

    def bip(shape):
        return rng.choice([-1, 1], size=shape).astype(np.int8)

    refs, dec = bip((3072, dim)), bip((1536, dim))
    d_refs, d_dec = bip((512, dim)), bip((256, dim))
    queries = bip((n_q, dim))
    reg = BankRegistry(emulate_shards=2)
    reg.register("t", jnp.asarray(refs), decoys=jnp.asarray(dec))
    srv = DBSearchServer(reg, k=4, fdr=0.5, max_batch_size=32,
                         flush_timeout_s=0.0, buckets=1)

    def qps() -> float:
        t0 = _time.perf_counter()
        for q in queries:
            srv.submit(q, tenant="t")
        srv.run_until_drained()
        return n_q / (_time.perf_counter() - t0)

    qps()  # base-geometry warm-up, discarded
    base_qps = qps()
    append_ms = []
    for i in range(8):  # 8 appends of 64+32 rows -> 768 delta rows
        t0 = _time.perf_counter()
        srv.append("t", d_refs[i * 64:(i + 1) * 64],
                   d_dec[i * 32:(i + 1) * 32])
        append_ms.append((_time.perf_counter() - t0) * 1e3)
    delta_fraction = reg.delta_fraction("t")
    qps()  # merged-path warm-up, discarded
    delta_qps = qps()
    assert reg.compact("t")
    qps()  # compacted-geometry warm-up, discarded
    compacted_qps = qps()
    append_ms.sort()
    return {
        "ingest_append_ms": append_ms[len(append_ms) // 2],
        "ingest_base_qps": base_qps,
        "ingest_delta_qps": delta_qps,
        "ingest_compacted_qps": compacted_qps,
        "ingest_delta_fraction": delta_fraction,
    }


def cluster_metrics() -> dict:
    """Reduced clustering-endpoint run -> spectra/sec + the paper's
    quality ratios (synthetic ground truth)."""
    from repro.launch import serve_cluster
    s = serve_cluster.main(["--reduced", "--consolidate-every", "64"])
    q = s["cluster_quality"]["tenant0"]
    return {
        "cluster_spectra_per_sec": s["qps"],
        "cluster_p95_ms": s["p95_ms"],
        "cluster_count": q["clusters"],
        "cluster_clustered_ratio": q["clustered_ratio"],
        "cluster_incorrect_ratio": q["incorrect_ratio"],
    }


def train_metrics() -> tuple[list[dict], dict]:
    """Reduced hierarchical train runs -> per-step time + DCN bytes.

    Three short runs on 2 emulated pods (dcn_compression none / int8 /
    topk_ef): per-method ``train/step_<method>`` timing rows for the
    regression gate, plus a summary dict recording bytes-on-DCN per pod
    per step and the compression ratios the acceptance gate checks."""
    import time as _time

    import jax

    from repro.configs import get_config
    from repro.data.tokens import TokenPipeline
    from repro.models import build_model
    from repro.train.optimizer import AdamWConfig
    from repro.train.train_step import (
        TrainConfig,
        init_train_state,
        make_train_step,
    )

    cfg = get_config("qwen2_7b").reduced()
    model = build_model(cfg)
    pipe = TokenPipeline(batch=8, seq=64, vocab=cfg.vocab_size)
    rows, summary = [], {}
    for method in ("none", "int8", "topk_ef"):
        tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3, total_steps=10),
                           dcn_pods=2, dcn_compression=method)
        state, _ = init_train_state(model, jax.random.PRNGKey(0), tcfg)
        fn = jax.jit(make_train_step(model, tcfg))
        timed = 3
        # batches pre-generated so host-side data-gen jitter stays out of
        # the gated per-step timing
        batches = [pipe.get_for(cfg, s) for s in range(timed + 1)]
        state, m = fn(state, batches[0])  # compile + warm
        jax.block_until_ready(state.params)
        t0 = _time.perf_counter()
        for s in range(1, timed + 1):
            state, m = fn(state, batches[s])
        jax.block_until_ready(state.params)
        us = (_time.perf_counter() - t0) / timed * 1e6
        dcn = float(m["dcn_bytes"])
        raw = float(m["dcn_raw_bytes"])
        rows.append({"name": f"train/step_{method}", "us_per_call": us,
                     "derived": f"dcn_bytes={int(dcn)}"})
        summary[method] = {"step_us": us, "dcn_bytes_per_pod": dcn,
                           "dcn_raw_bytes": raw,
                           "reduction_x": raw / dcn if dcn else 1.0}

    # measured (not closed-form) wire payload: run one real dcn_send on
    # actual gradients and count the coordinates that would cross the
    # DCN — a broken top-k mask that sent everything fails this even
    # though the analytic accounting above would not move
    import jax.numpy as jnp

    from repro.dist.compression import (
        dcn_send,
        init_error_state,
        per_step_key,
        tree_wire_bytes,
    )
    batch = pipe.get_for(cfg, 0)
    _, g = jax.value_and_grad(
        lambda p: model.loss(p, batch, remat="none"))(state.params)
    sent, _ = dcn_send(g, init_error_state(g), "topk_ef", 0.01,
                       per_step_key(0, 0))
    measured = sum(8 * int(jnp.count_nonzero(l))
                   for l in jax.tree.leaves(sent))
    raw = tree_wire_bytes(g, "none")
    summary["measured"] = {"method": "topk_ef", "sent_bytes": measured,
                           "raw_bytes": raw,
                           "reduction_x": raw / max(measured, 1)}
    return rows, summary


def tune_metrics() -> dict:
    """Reduced autotuner run -> measured ceilings + tuned-vs-default ratio.

    Builds a quick tuning table (``repro.tune``: growing-matmul /
    growing-copy ceiling microbenchmarks plus a reduced block-size sweep
    over every kernel op) at ``artifacts/tuning_table.json`` — the
    artifact CI uploads next to the bench JSON — and reports the worst
    tuned-vs-default timing ratio across all table entries. The sweep
    only accepts a candidate that is bit-identical to the default config
    and >=3% faster, so the ratio has a structural floor near 1; the
    hard gate in ``tune_failures`` holds it at >= 0.95 (tuned configs
    must never make a kernel materially slower than the hand-tuned
    defaults)."""
    from repro.tune.sweep import build_tuning_table, tuned_vs_default_ratio

    out = REPO / "artifacts" / "tuning_table.json"
    table = build_tuning_table(out, quick=True, iters=3)
    ceil = table.ceilings or {}
    entries = sum(len(b) for b in table.ops.values())
    non_default = sum(
        1 for buckets in table.ops.values() for e in buckets.values()
        if e.get("us") and e.get("default_us")
        and e["us"] < e["default_us"])
    return {
        "tune_peak_gflops": ceil.get("peak_flops", 0.0) / 1e9,
        "tune_mem_gbs": ceil.get("hbm_bw", 0.0) / 1e9,
        "tune_device_kind": table.device_kind,
        "tune_entries": entries,
        "tune_non_default_entries": non_default,
        "tune_tuned_vs_default": tuned_vs_default_ratio(table),
        "tune_table_path": str(out.relative_to(REPO)),
    }


def tune_failures(tune: dict | None) -> list[str]:
    """Hard failures from the autotuner floor: every table entry must
    run at >= 0.95x default-config throughput on its sweep workload
    (``tuned_vs_default_ratio`` is the worst ``default_us / tuned_us``
    across entries; >= 1 when every winner is at least as fast as the
    default it displaced). A ratio below 0.95 means the sweep picked a
    config that made a kernel materially slower than the hand-tuned
    defaults — the table would be a de-optimization.
    The measured ceilings must also be positive (a zero ceiling would
    silently poison every dryrun roofline). Checked whenever the tune
    run ran, baseline or not."""
    if not tune:
        return []
    fails = []
    ratio = tune["tune_tuned_vs_default"]
    if ratio < 0.95:
        fails.append(f"tune: tuned-vs-default ratio {ratio:.3f} < 0.95 "
                     "(a swept block config is materially slower than the "
                     "hand-tuned defaults)")
    if tune["tune_peak_gflops"] <= 0 or tune["tune_mem_gbs"] <= 0:
        fails.append(f"tune: non-positive measured ceiling "
                     f"(peak {tune['tune_peak_gflops']:.2f} GFLOP/s, "
                     f"hbm {tune['tune_mem_gbs']:.2f} GB/s)")
    return fails


def train_failures(train: dict | None) -> list[str]:
    """Hard failures from the training wire-traffic floor: the compressed
    payload *measured* from a real dcn_send (nonzero coordinates actually
    leaving the pod, always recorded by train_metrics) must be >=4x
    smaller than raw fp32 grads. Checked whenever the train runs ran,
    baseline or not."""
    if not train:
        return []
    meas = train["measured"]
    if meas["reduction_x"] < 4.0:
        return [f"train: measured {meas['method']} DCN compression ratio "
                f"{meas['reduction_x']:.2f}x < 4x "
                "(per-step cross-pod bytes barely compressed)"]
    return []


def find_baseline(output: Path) -> Path | None:
    """The newest prior BENCH_*.json at the repo root (numeric PR order,
    then mtime for non-conforming names), excluding the output file."""
    cands = [p for p in REPO.glob("BENCH_*.json") if p.resolve() != output.resolve()]
    if not cands:
        return None

    def order(p: Path):
        m = _BENCH_NAME_RE.search(p.name)
        # PR-numbered files outrank non-conforming names at any mtime
        return (1, int(m.group(1))) if m else (0, p.stat().st_mtime)

    return max(cands, key=order)


def compare(baseline: dict, current: list[dict], *, warn_pct: float,
            fail_pct: float, min_delta_us: float = 1000.0,
            speed: float = 1.0) -> tuple[list[str], list[str]]:
    """(warnings, failures) from timing-row regressions vs the baseline.

    Percentage thresholds alone misfire on micro-rows (a 200 us
    bookkeeping row jitters by +75% from filesystem-cache state alone),
    so a regression whose *absolute* slowdown is under ``min_delta_us``
    is demoted from failure to warning — still reported, never fatal.
    The demotion is capped: past ``_DEMOTE_MAX_DELTA`` (3x) even a
    micro-row fails, so the floor cannot hide a genuine blowup.

    ``speed`` (from ``machine_speed``) rescales every baseline timing
    before comparison: only the machine-wide drift it measures is
    forgiven, so a code regression in one row still stands out against
    the speed-adjusted baseline."""
    old = {r["name"]: r["us_per_call"] for r in baseline.get("rows", [])}
    warnings, failures = [], []
    for row in current:
        if _ARTIFACT_RE.search(row["name"]):
            continue  # gated by artifact_failures(), baseline or not
        prev = old.get(row["name"])
        if prev is None:
            warnings.append(f"{row['name']}: new suite (no baseline)")
            continue
        if prev <= 0:
            continue
        adj = prev * speed
        delta = row["us_per_call"] / adj - 1.0
        msg = (f"{row['name']}: {prev:.0f} -> {row['us_per_call']:.0f} us "
               f"({delta:+.1%}" + ("" if speed == 1.0 else
                                   " vs speed-adjusted baseline") + ")")
        if delta > fail_pct:
            if (row["us_per_call"] - adj < min_delta_us
                    and delta <= _DEMOTE_MAX_DELTA):
                warnings.append(msg + " [below jitter floor, demoted]")
            else:
                failures.append(msg)
        elif delta > warn_pct:
            warnings.append(msg)
    for name in sorted(set(old) - {r["name"] for r in current}):
        warnings.append(f"{name}: suite removed since baseline")
    return warnings, failures


# serving metrics are direction-aware: throughput regresses downward,
# latency regresses upward; both gate at the same warn/fail thresholds.
# The oms_* rows gate the open-modification serving path independently of
# the exact-search path (missing in pre-PR-6 baselines: skipped, not fatal).
_SERVING_DIRECTIONS = {
    "queries_per_sec": "higher",
    "p50_ms": "lower",
    "p95_ms": "lower",
    "oms_queries_per_sec": "higher",
    "oms_p50_ms": "lower",
    "oms_p95_ms": "lower",
    "continuous_queries_per_sec": "higher",
    "continuous_p50_ms": "lower",
    "continuous_p95_ms": "lower",
    "continuous_p95_p50_ratio": "lower",
    "ingest_append_ms": "lower",
    "ingest_base_qps": "higher",
    "ingest_delta_qps": "higher",
    "ingest_compacted_qps": "higher",
    "cluster_spectra_per_sec": "higher",
    "cluster_p95_ms": "lower",
    "cluster_incorrect_ratio": "lower",
}


def compare_serving(baseline: dict, serving: dict | None, *, warn_pct: float,
                    fail_pct: float) -> tuple[list[str], list[str]]:
    """(warnings, failures) from serving-metric regressions vs baseline."""
    old = baseline.get("serving") or {}
    cur = serving or {}
    warnings, failures = [], []
    for name, direction in _SERVING_DIRECTIONS.items():
        prev, now = old.get(name), cur.get(name)
        if prev is None or now is None or prev <= 0:
            continue
        # positive delta == worse, whichever way the metric points
        if direction == "higher":
            delta = (prev - now) / prev
        else:
            delta = (now - prev) / prev
        msg = (f"serving.{name}: {prev:.2f} -> {now:.2f} "
               f"({delta:+.1%} worse, {direction} is better)")
        if delta > fail_pct:
            failures.append(msg)
        elif delta > warn_pct:
            warnings.append(msg)
    return warnings, failures


def oms_failures(serving: dict | None) -> list[str]:
    """Hard failures from the OMS serving floor: the banded kernel must do
    strictly less work than a full-bank scan (scanned fraction < 1) on a
    window that is itself selective (candidate fraction < 1). Checked
    whenever the OMS run ran, baseline or not."""
    if not serving or "oms_scanned_fraction" not in serving:
        return []
    fails = []
    if serving["oms_scanned_fraction"] >= 1.0:
        fails.append(f"oms: scanned fraction "
                     f"{serving['oms_scanned_fraction']:.3f} >= 1 "
                     "(banded kernel degenerated to a full-bank scan)")
    if serving["oms_candidate_fraction"] >= 1.0:
        fails.append(f"oms: candidate fraction "
                     f"{serving['oms_candidate_fraction']:.3f} >= 1 "
                     "(precursor window admits the whole bank)")
    return fails


def continuous_failures(serving: dict | None) -> list[str]:
    """Hard failures from the continuous-batching tail floor: p95 must
    stay within 4x p50 — the whole point of per-step slot admission is
    that no request waits out a flush timeout or an unrelated batch.
    Checked whenever the continuous run ran, baseline or not."""
    if not serving or "continuous_p95_p50_ratio" not in serving:
        return []
    ratio = serving["continuous_p95_p50_ratio"]
    if ratio > 4.0:
        return [f"continuous: p95/p50 ratio {ratio:.2f} > 4 "
                f"(p50 {serving['continuous_p50_ms']:.2f} ms, p95 "
                f"{serving['continuous_p95_ms']:.2f} ms — tail latency "
                "regressed to flush-and-wait territory)"]
    return []


def ingest_failures(serving: dict | None) -> list[str]:
    """Hard failures from the streaming-ingestion floor: the merged
    base+delta search path must hold qps within 1.5x of the pure-base
    path (the delta is one small extra unpacked shard, not a rebuild-
    sized detour). Checked whenever the ingest run ran, baseline or
    not."""
    if not serving or "ingest_delta_qps" not in serving:
        return []
    base, delta = serving["ingest_base_qps"], serving["ingest_delta_qps"]
    if delta <= 0 or base / delta > 1.5:
        return [f"ingest: delta-path search {delta:.1f} q/s is more than "
                f"1.5x slower than pure-base {base:.1f} q/s "
                "(merged base+delta search regressed)"]
    return []


def artifact_failures(rows: list[dict]) -> list[str]:
    """Hard failures from correctness-artifact rows — a nonzero
    ``*_mismatches`` count means a kernel stopped matching its oracle.
    Checked unconditionally, baseline or not."""
    return [f"{r['name']}: {r['us_per_call']:.0f} mismatches "
            f"(bit-identity broken)" for r in rows
            if r["name"].endswith("_mismatches") and r["us_per_call"] > 0]


def next_pr_number() -> int:
    """One past the highest BENCH_PR<N>.json at the repo root (else 0)."""
    nums = [int(m.group(1)) for p in REPO.glob("BENCH_*.json")
            if (m := _BENCH_NAME_RE.search(p.name))]
    return max(nums, default=-1) + 1


def derive_pr_number(cli_pr: int | None) -> int:
    """Output PR number without hand-edited workflow pins.

    Precedence: ``--pr`` > ``REPRO_BENCH_PR`` > (under GitHub Actions)
    the newest committed BENCH_PR number — CI *re-runs* that file, so the
    previous PR's JSON stays the comparison baseline — > newest + 1 for
    local runs, which are minting a new data point."""
    if cli_pr is not None:
        return cli_pr
    env = os.environ.get("REPRO_BENCH_PR")
    if env:
        return int(env)
    if os.environ.get("GITHUB_ACTIONS"):
        return max(next_pr_number() - 1, 0)
    return next_pr_number()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--output", type=Path, default=None,
                    help="explicit output path (default: BENCH_PR<N>.json "
                         "at the repo root, N from --pr)")
    ap.add_argument("--pr", type=int, default=None,
                    help="PR number for the default output name (default: "
                         "REPRO_BENCH_PR env, else in CI the newest "
                         "existing BENCH_PR number, else newest + 1)")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="explicit baseline JSON (default: newest prior "
                         "BENCH_*.json at the repo root)")
    ap.add_argument("--warn-pct", type=float, default=0.10,
                    help="warn when a timing row regresses more than this")
    ap.add_argument("--fail-pct", type=float, default=0.50,
                    help="fail when a timing row regresses more than this")
    ap.add_argument("--min-delta-us", type=float, default=1000.0,
                    help="demote over-threshold regressions to warnings "
                         "when the absolute slowdown is smaller than this "
                         "many microseconds (micro-row jitter floor)")
    ap.add_argument("--skip-serving", action="store_true",
                    help="skip the reduced serve_db run (suites only)")
    ap.add_argument("--skip-train", action="store_true",
                    help="skip the reduced hierarchical train runs")
    ap.add_argument("--skip-tune", action="store_true",
                    help="skip the reduced autotuner sweep")
    args = ap.parse_args(argv)
    if args.output is None:
        args.output = REPO / f"BENCH_PR{derive_pr_number(args.pr)}.json"

    rows = run_suites()
    train = None
    if not args.skip_train:
        train_rows, train = train_metrics()
        rows += train_rows
    serving = None
    if not args.skip_serving:
        serving = serving_metrics()
        serving.update(ingest_metrics())
        serving.update(cluster_metrics())
    tune = None
    if not args.skip_tune:
        tune = tune_metrics()
    result = {
        "schema": 1,
        "source": "scripts/bench_ci.py",
        "quick": True,
        "canary_us": machine_canary(),
        "rows": rows,
        "serving": serving,
        "train": train,
        "tune": tune,
    }
    args.output.write_text(json.dumps(result, indent=2) + "\n")
    print(f"wrote {args.output} ({len(rows)} timing rows"
          + ("" if args.skip_serving else
         f", serving {result['serving']['queries_per_sec']:.1f} q/s, "
         f"cache hit rate {result['serving']['cache_hit_rate']:.1%}, "
         f"oms {result['serving']['oms_queries_per_sec']:.1f} q/s scanning "
         f"{result['serving']['oms_scanned_fraction']:.0%} of the bank, "
         f"continuous {result['serving']['continuous_queries_per_sec']:.1f} "
         "q/s p95/p50 "
         f"{result['serving']['continuous_p95_p50_ratio']:.2f}, "
         f"ingest delta-path {result['serving']['ingest_delta_qps']:.1f} "
         f"vs base {result['serving']['ingest_base_qps']:.1f} q/s, "
         f"cluster {result['serving']['cluster_spectra_per_sec']:.1f} "
         "spectra/s")
          + ("" if args.skip_train else
         f", train DCN {max(v['reduction_x'] for k, v in train.items() if k != 'none'):.1f}x compressed")
          + ("" if args.skip_tune else
         f", tune ceilings {tune['tune_peak_gflops']:.0f} GFLOP/s / "
         f"{tune['tune_mem_gbs']:.0f} GB/s, tuned-vs-default "
         f"{tune['tune_tuned_vs_default']:.2f}")
          + ")")

    hard_failures = (artifact_failures(rows) + train_failures(train)
                     + tune_failures(tune)
                     + oms_failures(result["serving"])
                     + continuous_failures(result["serving"])
                     + ingest_failures(result["serving"]))

    base_path = args.baseline or find_baseline(args.output)
    if base_path is None:
        print("no prior BENCH_*.json baseline found; comparison skipped")
        for f in hard_failures:
            print(f"  FAIL  {f}")
        return 1 if hard_failures else 0
    baseline = json.loads(base_path.read_text())
    speed, speed_src = machine_speed(baseline, result["canary_us"], rows)
    warnings, failures = compare(baseline, rows, warn_pct=args.warn_pct,
                                 fail_pct=args.fail_pct,
                                 min_delta_us=args.min_delta_us, speed=speed)
    failures = hard_failures + failures
    sw, sf = compare_serving(baseline, result["serving"],
                             warn_pct=args.warn_pct, fail_pct=args.fail_pct)
    warnings += sw
    failures += sf
    print(f"compared against {base_path.name} "
          f"(machine speed {speed:.2f}x baseline, via {speed_src}): "
          f"{len(failures)} failure(s), {len(warnings)} warning(s)")
    for w in warnings:
        print(f"  WARN  {w}")
    for f in failures:
        print(f"  FAIL  {f}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
