"""Emit the EXPERIMENTS.md roofline table from dry-run artifacts."""
import glob
import json

def fmt(v):
    if v == 0: return "0"
    if v < 1e-3: return f"{v*1e6:.1f}us"
    if v < 1: return f"{v*1e3:.1f}ms"
    return f"{v:.2f}s"

rows = []
for f in sorted(glob.glob('artifacts/dryrun/*.json')):
    d = json.load(open(f))
    tag = (d['arch'], d['shape'], d['mesh'])
    if d['status'] == 'skipped':
        rows.append((tag, None))
        continue
    r = d['roofline']
    mem = d.get('memory', {})
    hbm = (mem.get('argument_size_in_bytes', 0) + mem.get('temp_size_in_bytes', 0)
           + mem.get('output_size_in_bytes', 0) - mem.get('alias_size_in_bytes', 0))
    rows.append((tag, (r, hbm, d.get('compile_s'))))

print('| arch | shape | mesh | compute | memory | collective | bottleneck | MODEL_FLOPs/HLO | MFU bound | bytes/dev |')
print('|---|---|---|---|---|---|---|---|---|---|')
for (a, s, m), v in rows:
    if v is None:
        print(f'| {a} | {s} | {m} | — | — | — | skip (full-attn, long_500k) | — | — | — |')
        continue
    r, hbm, cs = v
    ratio = r['model_flops'] / (r['flops'] * r['chips']) if r['flops'] else 0
    print(f"| {a} | {s} | {m} | {fmt(r['t_compute'])} | {fmt(r['t_memory'])} | "
          f"{fmt(r['t_collective'])} | {r['bottleneck']} | {ratio:.2f} | "
          f"{r['mfu_bound']:.3f} | {hbm/1e9:.1f}GB |")
