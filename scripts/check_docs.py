"""Docs gate for CI: internal markdown links must resolve, doctests must pass.

Two checks, both runnable standalone:

  * link check — every relative link target in the repo's markdown files
    (root ``*.md`` + ``docs/``) must exist on disk. External schemes
    (http/https/mailto) and pure in-page anchors are skipped; a
    ``path#fragment`` link is checked for the path only.
  * doctests — every module under ``src/repro`` is imported and run
    through ``doctest.testmod``; modules without examples are free.

Usage:
  PYTHONPATH=src python scripts/check_docs.py              # both checks
  PYTHONPATH=src python scripts/check_docs.py --links-only
  PYTHONPATH=src python scripts/check_docs.py --modules repro.serve.queue
"""

from __future__ import annotations

import argparse
import doctest
import importlib
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# [text](target) — target up to the first unescaped ')' or whitespace
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_EXTERNAL = ("http://", "https://", "mailto:")


def markdown_files() -> list[Path]:
    files = sorted(REPO.glob("*.md"))
    docs = REPO / "docs"
    if docs.is_dir():
        files += sorted(docs.rglob("*.md"))
    return files


def check_links(files: list[Path] | None = None) -> list[str]:
    """Return one error string per broken relative link."""
    errors = []
    for md in files or markdown_files():
        text = md.read_text()
        for lineno, line in enumerate(text.splitlines(), 1):
            for target in _LINK_RE.findall(line):
                if target.startswith(_EXTERNAL) or target.startswith("#"):
                    continue
                path = target.split("#", 1)[0]
                if not path:
                    continue
                resolved = (md.parent / path).resolve()
                if not resolved.exists():
                    rel = md.relative_to(REPO)
                    errors.append(f"{rel}:{lineno}: broken link -> {target}")
    return errors


def repro_modules() -> list[str]:
    """All importable module names under src/repro."""
    src = REPO / "src"
    names = []
    for py in sorted((src / "repro").rglob("*.py")):
        rel = py.relative_to(src).with_suffix("")
        parts = list(rel.parts)
        if parts[-1] == "__init__":
            parts = parts[:-1]
        names.append(".".join(parts))
    return sorted(set(names))


def run_doctests(modules: list[str] | None = None) -> tuple[int, int]:
    """Import each module and run its doctests.

    Returns (failed_examples, modules_with_examples).
    """
    failed = 0
    with_examples = 0
    for name in modules or repro_modules():
        mod = importlib.import_module(name)
        result = doctest.testmod(mod, verbose=False)
        if result.attempted:
            with_examples += 1
            print(f"doctest {name}: {result.attempted} example(s), "
                  f"{result.failed} failure(s)")
        failed += result.failed
    return failed, with_examples


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--links-only", action="store_true")
    ap.add_argument("--modules", nargs="*", default=None,
                    help="restrict doctests to these modules")
    args = ap.parse_args(argv)

    files = markdown_files()
    errors = check_links(files)
    print(f"link check: {len(files)} markdown file(s), "
          f"{len(errors)} broken link(s)")
    for e in errors:
        print(f"  {e}")
    rc = 1 if errors else 0

    if not args.links_only:
        failed, with_examples = run_doctests(args.modules)
        print(f"doctests: {with_examples} module(s) with examples, "
              f"{failed} failure(s)")
        if failed:
            rc = 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
