"""Splice the generated roofline table and perf log into EXPERIMENTS.md."""
import subprocess
import sys
from pathlib import Path

doc = Path('EXPERIMENTS.md').read_text()
table = subprocess.run([sys.executable, 'scripts/roofline_table.py'],
                       capture_output=True, text=True).stdout
perf = subprocess.run([sys.executable, 'scripts/perf_log.py'],
                      capture_output=True, text=True).stdout
doc = doc.replace('<!-- ROOFLINE_TABLE -->', table.rstrip())
doc = doc.replace('<!-- PERF_LOG -->', perf.strip())
Path('EXPERIMENTS.md').write_text(doc)
print('EXPERIMENTS.md updated:', len(table.splitlines()), 'roofline rows')
