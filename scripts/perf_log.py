"""Emit the EXPERIMENTS.md §Perf iteration tables from artifacts."""
import json
from pathlib import Path

BASE = Path('artifacts/dryrun')
OPT = Path('artifacts/dryrun_opt')

def load(p):
    d = json.loads(p.read_text())
    r = d['roofline']
    return r

def row(label, r):
    return (f"| {label} | {r['t_compute']:.3f} | {r['t_memory']:.3f} | "
            f"{r['t_collective']:.3f} | {r['bottleneck']} | "
            f"{r['step_time_lower_bound']:.3f} | {r['mfu_bound']:.3f} |")

CELLS = {
    'internvl2_76b train_4k single': [
        ('baseline (naive: fp32 gathers, after-add AR)', BASE / 'internvl2_76b__train_4k__single.json'),
        ('it1: bf16 pre-gather cast + RS-before-add', OPT / 'internvl2_76b__train_4k__single__opt_bf16cast.json'),
        ('it2: + fsdp_only (ZeRO-3, no TP)', OPT / 'internvl2_76b__train_4k__single__opt_fsdponly.json'),
        ('it3: + remat=dots (fewer gather passes)', OPT / 'internvl2_76b__train_4k__single__opt_fsdp_dots.json'),
    ],
    'llama4_scout_17b_a16e prefill_32k multi': [
        ('baseline (dispatch replicated over experts)', BASE / 'llama4_scout_17b_a16e__prefill_32k__multi.json'),
        ('it1: 2D batch x expert dispatch sharding', OPT / 'llama4_scout_17b_a16e__prefill_32k__multi__opt_dispatch2d.json'),
    ],
    'qwen2_7b decode_32k single': [
        ('baseline (cache batch-sharded only)', BASE / 'qwen2_7b__decode_32k__single.json'),
        ('it1: + int8 MLC-style KV (quant only)', OPT / 'qwen2_7b__decode_32k__single__opt_kvquant_only.json'),
        ('it2: KV seq-striping over model axis', OPT / 'qwen2_7b__decode_32k__single__opt_kvstripe.json'),
        ('it3: striping + int8 KV', OPT / 'qwen2_7b__decode_32k__single__opt_kvquant.json'),
    ],
    'deepseek_moe_16b prefill_32k multi (same MoE fix)': [
        ('baseline', BASE / 'deepseek_moe_16b__prefill_32k__multi.json'),
        ('it1: 2D dispatch sharding', OPT / 'deepseek_moe_16b__prefill_32k__multi__opt_dispatch2d.json'),
    ],
}

for cell, rows in CELLS.items():
    print(f"\n### {cell}\n")
    print("| variant | compute s | memory s | collective s | bottleneck | bound s | MFU bound |")
    print("|---|---|---|---|---|---|---|")
    base_bound = None
    for label, p in rows:
        if not p.exists():
            print(f"| {label} | (pending) | | | | | |")
            continue
        r = load(p)
        if base_bound is None:
            base_bound = r['step_time_lower_bound']
        print(row(label, r))
    if base_bound:
        existing = [p for _, p in rows if p.exists()]
        if len(existing) > 1:
            best = min((load(p) for p in existing),
                       key=lambda r: r['step_time_lower_bound'])
            print(f"\n**{base_bound / best['step_time_lower_bound']:.2f}x step-time-bound improvement "
                  f"(best accepted variant)**, MFU bound "
                  f"{load(existing[0])['mfu_bound']:.3f} -> {best['mfu_bound']:.3f}")
